import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import histogram as hg
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable


def make_index(values, page_card=8, resolution=32, density=0.25, **kw):
    table = PagedTable.from_values(values, page_card=page_card, spare_pages=256)
    return HippoIndex.create(table, resolution=resolution, density=density, **kw)


def brute_force(table, lo, hi):
    live = table.valid[: table.num_pages]
    keys = table.keys[: table.num_pages]
    return int((live & (keys >= lo) & (keys <= hi)).sum())


@pytest.mark.parametrize("relocate", [False, True])
def test_eager_insert_existing_and_new_pages(relocate):
    rng = np.random.default_rng(0)
    values = rng.uniform(0, 100, 333)  # last page partially filled
    idx = make_index(values, relocate_on_update=relocate)
    new_vals = rng.uniform(0, 100, 60)
    for v in new_vals:
        idx.insert(float(v))
    # Every subsequent query must see the inserted tuples (§5.1 correctness).
    for lo, hi in [(0, 100), (10, 20), (50, 50.5)]:
        res = idx.search(Predicate.between(lo, hi))
        assert int(res.count) == brute_force(idx.table, lo, hi)


def test_insert_extends_or_creates_last_entry():
    # Histogram over spread-out data, so bucketization is meaningful.
    values = np.linspace(0, 99, 64)
    idx = make_index(values, page_card=8, resolution=32, density=0.5)
    # Insert identical values: after at most one new entry is opened, its
    # density stays at 1/32 < D, so further new pages extend it (Alg. 3).
    idx.insert(5.0)
    e1 = idx.num_entries
    for _ in range(32):
        idx.insert(5.0)
    assert idx.num_entries <= e1 + 1  # one creation at most (the first new page)
    starts, ends, _ = idx.entries_host()
    assert ends[-1] == idx.table.num_pages - 1
    # Diverse inserts push density over D => new entries get created.
    e2 = idx.num_entries
    for v in list(np.linspace(0, 99, 64)) * 2:
        idx.insert(float(v))
    assert idx.num_entries > e2


def test_sorted_list_stays_sorted_under_relocation():
    rng = np.random.default_rng(1)
    values = rng.uniform(0, 100, 256)
    idx = make_index(values, relocate_on_update=True)
    for v in rng.uniform(0, 100, 64):
        idx.insert(float(v))
    starts, ends, _ = idx.entries_host()
    assert (np.diff(starts) > 0).all()          # logical order ascending
    np.testing.assert_array_equal(starts[1:], ends[:-1] + 1)
    # Relocation happened (num_slots grew past num_entries) yet search is exact.
    assert int(idx.state.num_slots) >= idx.num_entries
    res = idx.search(Predicate.between(0, 100))
    assert int(res.count) == brute_force(idx.table, 0, 100)


def test_batch_insert_matches_sequential():
    rng = np.random.default_rng(2)
    base = rng.uniform(0, 100, 200)
    extra = rng.uniform(0, 100, 150)

    idx_a = make_index(base.copy(), relocate_on_update=False)
    for v in extra:
        idx_a.insert(float(v))

    idx_b = make_index(base.copy(), relocate_on_update=False)
    idx_b.insert_batch(extra)

    for lo, hi in [(0, 100), (25, 30), (77, 77.5)]:
        ra = idx_a.search(Predicate.between(lo, hi))
        rb = idx_b.search(Predicate.between(lo, hi))
        assert int(ra.count) == int(rb.count) == brute_force(idx_b.table, lo, hi)


def test_lazy_delete_correct_before_and_after_vacuum():
    rng = np.random.default_rng(3)
    values = rng.uniform(0, 100, 1000)
    idx = make_index(values)
    # Delete a band; index NOT updated yet — queries must still be exact (§5.2).
    idx.table.delete_where(40, 60)
    for lo, hi in [(0, 100), (45, 55), (39, 41)]:
        res = idx.search(Predicate.between(lo, hi))
        assert int(res.count) == brute_force(idx.table, lo, hi)
    before_pages = int(idx.search(Predicate.between(45, 55)).pages_inspected)
    n = idx.vacuum()
    assert n > 0
    assert not idx.table.dirty[: idx.table.num_pages].any()
    # After vacuum, bitmaps shrink => fewer possible-qualified pages.
    after = idx.search(Predicate.between(45, 55))
    assert int(after.count) == brute_force(idx.table, 45, 55) == 0
    assert int(after.pages_inspected) <= before_pages


def test_vacuum_only_resummarizes_dirty_entries():
    rng = np.random.default_rng(4)
    values = rng.uniform(0, 100, 800)
    idx = make_index(values)
    bitmaps_before = np.asarray(idx.state.bitmaps).copy()
    idx.table.delete_where(0.0, 1.0)   # touches few pages
    idx.vacuum()
    bitmaps_after = np.asarray(idx.state.bitmaps)
    changed = (bitmaps_before != bitmaps_after).any(axis=1).sum()
    assert 0 < changed < idx.num_entries  # localized maintenance


def test_insert_into_empty_index():
    """A zero-page build must yield a working zero-entry index that grows
    through Algorithm 3 on first insert (the histogram comes from the DBMS,
    not the empty table)."""
    table = PagedTable.from_values(np.zeros(0), page_card=8, spare_pages=64)
    hist = hg.build_uniform(0.0, 100.0, 32)
    idx = HippoIndex.create(table, resolution=32, density=0.25, hist=hist)
    assert idx.num_entries == 0
    assert int(idx.state.summarized_until) == -1
    assert int(idx.search(Predicate.between(0, 100)).count) == 0
    vals = [5.0, 50.0, 95.0, 12.0, 13.0]
    for v in vals:
        idx.insert(v)
    assert idx.num_entries >= 1
    assert int(idx.search(Predicate.between(0, 100)).count) == len(vals)
    assert int(idx.search(Predicate.between(40, 60)).count) == 1
    # batch insert into a fresh empty index agrees too
    t2 = PagedTable.from_values(np.zeros(0), page_card=8, spare_pages=64)
    idx2 = HippoIndex.create(t2, resolution=32, density=0.25, hist=hist)
    idx2.insert_batch(np.asarray(vals))
    assert int(idx2.search(Predicate.between(0, 100)).count) == len(vals)


def test_insert_at_max_slots_refuses_cleanly():
    """Relocation/creation at physical capacity must raise before mutating
    anything — not scatter out of bounds and corrupt the sorted list."""
    values = np.linspace(0, 99, 64)
    idx = make_index(values, page_card=8, resolution=32, density=0.25,
                     max_slots=12, relocate_on_update=True)
    with pytest.raises(RuntimeError, match="slot capacity"):
        for v in np.linspace(0, 99, 500):
            idx.insert(float(v))
    # refusal left table and index consistent: every query is still exact
    assert int(idx.state.num_slots) <= idx.cfg.max_slots
    for lo, hi in [(0, 99), (10, 20), (50, 50.5)]:
        assert int(idx.search(Predicate.between(lo, hi)).count) == \
            brute_force(idx.table, lo, hi)
    # single insert refuses BEFORE touching the table; batch insert rolls the
    # table back to its pre-batch snapshot (atomic refuse)
    cardinality = idx.table.cardinality
    with pytest.raises(RuntimeError, match="slot capacity"):
        idx.insert(1.0)
    with pytest.raises(RuntimeError, match="slot capacity"):
        idx.insert_batch(np.linspace(0, 99, 300))
    assert idx.table.cardinality == cardinality
    assert int(idx.search(Predicate.between(0, 99)).count) == \
        brute_force(idx.table, 0, 99)


def test_large_batch_insert_not_refused_at_low_occupancy():
    """The capacity guard charges slots at actual need, not a worst-case
    up-front bound: a duplicate-heavy batch far larger than the remaining
    slot headroom consumes ~no slots and must succeed."""
    rng = np.random.default_rng(8)
    idx = make_index(rng.uniform(0, 100, 333), relocate_on_update=True)
    assert int(idx.state.num_slots) + 1500 > idx.cfg.max_slots  # worst case "full"
    idx.insert_batch(np.full(1500, 50.0, np.float32))
    assert int(idx.search(Predicate.between(0, 100)).count) == \
        brute_force(idx.table, 0, 100) == 333 + 1500


def test_counters_track_maintenance():
    rng = np.random.default_rng(5)
    idx = make_index(rng.uniform(0, 100, 200))
    for v in rng.uniform(0, 100, 10):
        idx.insert(float(v))
    assert idx.counters.inserts == 10
    idx.table.delete_where(0, 50)
    idx.vacuum()
    assert idx.counters.vacuums == 1
    assert idx.counters.entries_resummarized > 0
