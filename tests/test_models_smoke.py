"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import transformer

pytestmark = pytest.mark.slow

ARCHS = [
    "llama4-maverick-400b-a17b", "qwen2-moe-a2.7b", "qwen2-vl-7b",
    "musicgen-large", "recurrentgemma-9b", "yi-6b", "stablelm-3b",
    "qwen2.5-3b", "smollm-360m", "rwkv6-3b",
]

B, S = 2, 32


def make_batch(cfg, key):
    kb, kl = jax.random.split(key)
    if cfg.frontend == "tokens":
        inputs = jax.random.randint(kb, (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(kb, (B, S, cfg.d_model), jnp.float32)
    labels = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    return {"inputs": inputs, "labels": labels, "positions": positions}


def test_registry_complete():
    assert set(ARCHS) <= set(list_archs())
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    logits = jax.jit(lambda p, b: transformer.forward(
        cfg, p, b["inputs"], b["positions"]))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: transformer.loss_fn(cfg, p, batch)))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # loss is near log(vocab) at init (sanity of the head/loss wiring)
    assert float(loss) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ["llama4-maverick-400b-a17b", "recurrentgemma-9b",
                                  "rwkv6-3b", "qwen2-vl-7b"])
def test_full_config_shapes_consistent(arch):
    """Full (unreduced) configs are structurally valid: pattern divides depth
    bookkeeping, head dims resolve, MoE divisibility recorded."""
    cfg = get_config(arch)
    assert cfg.num_units * cfg.unit_len + len(cfg.leftover_pattern) == cfg.num_layers
    if cfg.num_heads:
        assert cfg.resolved_head_dim * cfg.num_heads in (
            cfg.d_model, cfg.num_heads * cfg.resolved_head_dim)
        assert cfg.num_heads % max(cfg.num_kv_heads, 1) == 0


def test_two_steps_reduce_loss_smollm():
    """A couple of SGD steps on repeated data reduce the loss (end-to-end
    trainability of the assembly)."""
    cfg = get_config("smollm-360m").reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: transformer.loss_fn(cfg, q, batch))(p)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)
        return p, loss

    losses = []
    for _ in range(5):
        params, loss = step(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
