"""Roofline package: the per-kernel traffic models, the hardware table, and
the achieved-bandwidth statement every kernel bench row derives from."""
import math

import pytest

from repro import roofline as rl
from repro.roofline import analysis, report


def test_kernel_registry_covers_all_five_kernels():
    assert set(rl.KERNELS) == {"bitmap_and", "batch_filter", "bucketize",
                               "page_inspect", "compact_inspect"}


def test_bitmap_and_cost_counts_mandatory_traffic():
    c = rl.KERNELS["bitmap_and"](e=65_536, w=13)
    # entries + query read, one flag per entry written
    assert c.bytes_moved == (65_536 * 13 + 13 + 65_536) * 4
    assert c.ops == 2 * 65_536 * 13
    assert 0 < c.arithmetic_intensity < 1      # memory-bound territory


def test_costs_scale_linearly_in_the_streamed_axis():
    for kernel, small, big, axis in (
            ("bitmap_and", dict(e=1024, w=13), dict(e=2048, w=13), "e"),
            ("batch_filter", dict(q=8, e=1024, w=13),
             dict(q=8, e=2048, w=13), "e"),
            ("bucketize", dict(n=1024, h=400), dict(n=2048, h=400), "n"),
            ("page_inspect", dict(p=512, c=128), dict(p=1024, c=128), "p"),
            ("compact_inspect", dict(q=8, m=512, c=128),
             dict(q=8, m=1024, c=128), "m")):
        lo, hi = rl.KERNELS[kernel](**small), rl.KERNELS[kernel](**big)
        ratio = hi.bytes_moved / lo.bytes_moved
        assert 1.8 < ratio <= 2.05, (kernel, axis, ratio)
        assert hi.ops == 2 * lo.ops, (kernel, axis)


def test_all_kernels_are_memory_bound_on_both_hardware_rows():
    """Hippo's phases sit far under every ridge — the roofline statement is
    a bandwidth statement on v5e and on this host alike."""
    shapes = {
        "bitmap_and": dict(e=65_536, w=13),
        "batch_filter": dict(q=64, e=16_384, w=13),
        "bucketize": dict(n=1_048_576, h=400),
        "page_inspect": dict(p=16_384, c=128),
        "compact_inspect": dict(q=64, m=2_048, c=128),
    }
    for name, shape in shapes.items():
        cost = rl.KERNELS[name](**shape)
        for hw in (rl.TPU_V5E, rl.hardware("cpu_stream")):
            verdict = rl.roofline(cost, 1e-3, hw)
            assert verdict["bound"] == "memory", (name, hw.name)


def test_roofline_math():
    hw = rl.Hardware("toy", mem_bw=100e9, vector_ops=1e12)
    cost = analysis.KernelCost("toy_kernel", bytes_moved=1e9, ops=1e9)
    out = rl.roofline(cost, seconds=0.02, hw=hw)
    assert out["achieved_gbps"] == pytest.approx(50.0)   # 1 GB / 20 ms
    assert out["roofline_us"] == pytest.approx(10_000.0)  # 1 GB / 100 GB/s
    assert out["roofline_frac"] == pytest.approx(0.5)
    assert out["bound"] == "memory" and out["kernel"] == "toy_kernel"
    # compute-bound when the ops term dominates
    heavy = analysis.KernelCost("heavy", bytes_moved=1.0, ops=1e12)
    assert rl.roofline(heavy, 1.0, hw)["bound"] == "compute"
    with pytest.raises(ValueError):
        rl.roofline(cost, 0.0, hw)


def test_hardware_table_and_detection():
    assert rl.hardware("tpu_v5e").mem_bw == 819e9
    cpu = rl.hardware("cpu_stream")
    assert cpu.name == "cpu_stream"
    # measured STREAM bandwidth is cached and plausible for any host
    assert 1e9 < cpu.mem_bw < 1e12
    assert rl.hardware("cpu_stream") is cpu             # lru-cached
    assert rl.hardware().name in ("tpu_v5e", "cpu_stream")  # backend detect
    assert rl.TPU_V5E.ridge_ai > 1.0
    with pytest.raises(KeyError):
        rl.hardware("abacus")


def test_measure_cpu_stream_is_positive_and_cached():
    a = rl.measure_cpu_stream(mbytes=8, reps=2)
    b = rl.measure_cpu_stream(mbytes=8, reps=2)
    assert a == b and math.isfinite(a) and a > 0


def test_report_builds_table_from_trajectory_doc():
    doc = {"suites": {"kernels": [
        {"name": "kernel_bitmap_and_64k", "us_per_call": 1500.0,
         "derived": {"bytes": 3_670_068, "ops": 1_703_936}},
        {"name": "no_traffic_row", "us_per_call": 3.0, "derived": {}},
    ]}}
    table = report.build_table(doc, "tpu_v5e")
    assert "kernel_bitmap_and_64k" in table
    assert "no_traffic_row" not in table       # rows without bytes/ops skip
    assert "819 GB/s" in table and "memory" in table
    empty = report.build_table({"suites": {}}, "tpu_v5e")
    assert "no kernels-suite rows" in empty


def test_report_cli_round_trip(tmp_path, capsys):
    import json
    doc = {"suites": {"kernels": [
        {"name": "kernel_bucketize_1m", "us_per_call": 28_000.0,
         "derived": {"bytes": 8_390_212, "ops": 9_437_184}}]}}
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(doc))
    assert report.main([str(p), "--hardware", "tpu_v5e"]) == 0
    assert "kernel_bucketize_1m" in capsys.readouterr().out
