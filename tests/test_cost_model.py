"""Validate the §6 cost model against the real index (uniform data, as the
paper assumes for the model's derivation)."""
import numpy as np
import pytest

from repro.core import cost
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable


def test_prob_inspect_piecewise():
    # Fig. 5 worked example: SF=20%, H=10, D=0.2 -> Prob = 40%.
    assert cost.prob_inspect(0.2, 10, 0.2) == pytest.approx(0.4)
    # Saturation branch: SF*H > 1/D -> 1.
    assert cost.prob_inspect(0.9, 10, 0.5) == 1.0
    # SF*H floors at one bucket.
    assert cost.prob_inspect(1e-6, 10, 0.2) == pytest.approx(0.2)


def test_coupon_collector_examples_from_paper():
    # §6.2: H=1000, D=0.1 -> T ~ 105.3 ; H=10000, D=0.2 -> T ~ 2230.
    assert cost.tuples_per_entry(1000, 0.1) == pytest.approx(105.3, rel=0.01)
    assert cost.tuples_per_entry(10000, 0.2) == pytest.approx(2230, rel=0.01)


def test_observations_6_2():
    # Obs 1: higher D => fewer entries.  Obs 2: higher H => fewer entries.
    card = 1_000_000
    assert cost.num_entries(card, 400, 0.4) < cost.num_entries(card, 400, 0.2)
    assert cost.num_entries(card, 800, 0.2) < cost.num_entries(card, 400, 0.2)


def test_entry_count_estimate_matches_measured():
    rng = np.random.default_rng(0)
    card, page_card, h, d = 40_000, 50, 400, 0.2
    values = rng.uniform(0, 1e6, card)
    table = PagedTable.from_values(values, page_card=page_card)
    idx = HippoIndex.create(table, resolution=h, density=d)
    est = cost.num_entries(card, h, d)
    # Coupon-collector model assumes tuple-granularity cuts; page granularity
    # quantizes upward. Accept 35% relative error (the paper's own estimates
    # in §7.2.1 are similarly approximate).
    assert abs(idx.num_entries - est) / est < 0.35


def test_query_time_estimate_matches_measured_inspection():
    rng = np.random.default_rng(1)
    card, page_card, h, d = 40_000, 50, 400, 0.2
    values = rng.uniform(0, 1e6, card)
    table = PagedTable.from_values(values, page_card=page_card)
    idx = HippoIndex.create(table, resolution=h, density=d)
    for sf in (0.001, 0.01, 0.05):
        width = 1e6 * sf
        lo = 5e5 - width / 2
        res = idx.search(Predicate.between(lo, lo + width))
        measured_tuples = int(res.pages_inspected) * page_card
        est = cost.query_time_tuples(sf, h, d, card)
        # Within 2x of the model (Prob is an expectation over uniform data).
        assert measured_tuples <= 2.2 * max(est, page_card)
        # At SF=0.001 the model gives Prob = 1 bucket * D = 0.2 => strong
        # pruning vs a full scan; verify the real index achieves it.
        if sf <= 0.001:
            assert measured_tuples < 0.3 * card


def test_insert_cost_logarithmic():
    assert cost.insert_time_ios(10**6, 400, 0.2) < cost.btree_insert_time_ios(10**6)
    # Hippo insert cost grows with log(entries), far slower than log(Card).
    small = cost.insert_time_ios(10**5, 400, 0.2)
    big = cost.insert_time_ios(10**8, 400, 0.2)
    assert big - small < 12
