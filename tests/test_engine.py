"""Batched query engine: ``search_many`` / ``QueryEngine`` must agree
bit-for-bit with a Python loop of single-predicate ``index.search`` calls,
including empty-result and full-table predicates."""
import numpy as np
import pytest

from repro.core import index as hix
from repro.core.hippo import HippoIndex
from repro.core.predicate import (Predicate, intervals, to_bucket_bitmap,
                                  to_bucket_bitmaps)
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable


def make_index(values, page_card=8, resolution=32, density=0.25, **kw):
    table = PagedTable.from_values(values, page_card=page_card, spare_pages=64)
    return HippoIndex.create(table, resolution=resolution, density=density, **kw)


def workload(rng, n):
    """Random ranges plus the edge predicates: empty interval, out-of-domain
    range (matches nothing), full table, point, and open-ended."""
    preds = []
    for _ in range(n):
        lo = float(rng.uniform(0, 1000))
        preds.append(Predicate.between(lo, lo + float(rng.uniform(0, 300))))
    preds += [
        Predicate(lo=5.0, hi=1.0),            # empty interval (lo > hi)
        Predicate.between(2000, 3000),        # no key in range
        Predicate.between(-1e30, 1e30),       # full table
        Predicate(),                          # unconstrained (±inf)
        Predicate.equality(float(rng.uniform(0, 1000))),
        Predicate.greater(500.0),
        Predicate.less(100.0),
    ]
    return preds


def test_to_bucket_bitmaps_matches_single():
    rng = np.random.default_rng(0)
    idx = make_index(rng.uniform(0, 1000, 600))
    preds = workload(rng, 25)
    qbms = np.asarray(to_bucket_bitmaps(preds, idx.state.histogram))
    for q, p in enumerate(preds):
        single = np.asarray(to_bucket_bitmap(p, idx.state.histogram))
        np.testing.assert_array_equal(qbms[q], single, err_msg=f"pred {q}")


def test_to_bucket_bitmaps_empty_batch():
    rng = np.random.default_rng(1)
    idx = make_index(rng.uniform(0, 1000, 100))
    assert to_bucket_bitmaps([], idx.state.histogram).shape[0] == 0


@pytest.mark.parametrize("dist", ["uniform", "skewed", "lowcard"])
def test_search_many_matches_search_loop(dist):
    rng = np.random.default_rng({"uniform": 10, "skewed": 11, "lowcard": 12}[dist])
    n = 3000
    if dist == "uniform":
        values = rng.uniform(0, 1000, n)
    elif dist == "skewed":
        values = rng.exponential(50, n)
    else:
        values = rng.integers(0, 12, n).astype(float)
    idx = make_index(values)
    preds = workload(rng, 32)
    assert len(preds) >= 32
    qbms = to_bucket_bitmaps(preds, idx.state.histogram)
    los, his = intervals(preds)
    res = idx.search_batch(preds)
    many = hix.search_many(idx.state, qbms, idx.table.device_keys(),
                           idx.table.device_valid(), los, his)
    for q, p in enumerate(preds):
        single = idx.search(p)
        for batched in (res, many):
            assert int(batched.counts[q]) == int(single.count), (dist, q)
            assert int(batched.pages_inspected[q]) == int(single.pages_inspected)
            assert int(batched.entries_matched[q]) == int(single.entries_matched)
            np.testing.assert_array_equal(np.asarray(batched.page_mask[q]),
                                          np.asarray(single.page_mask))


def test_search_many_sees_maintenance():
    """The batched path reads the same state as the scalar path across
    insert and delete+vacuum maintenance."""
    rng = np.random.default_rng(7)
    idx = make_index(rng.uniform(0, 100, 400))
    for v in rng.uniform(0, 100, 10):
        idx.insert(float(v))
    idx.table.delete_where(40, 60)
    idx.vacuum()
    preds = [Predicate.between(0, 100), Predicate.between(45, 55),
             Predicate.between(39, 41)]
    res = idx.search_batch(preds)
    for q, p in enumerate(preds):
        assert int(res.counts[q]) == int(idx.search(p).count)


def test_query_engine_recycles_slots_and_matches_loop():
    rng = np.random.default_rng(3)
    idx = make_index(rng.uniform(0, 1000, 2000))
    preds = workload(rng, 32)
    engine = QueryEngine(idx, batch=8)      # < len(preds): forces recycling
    counts = engine.run_all(preds)
    want = np.asarray([int(idx.search(p).count) for p in preds])
    np.testing.assert_array_equal(counts, want)
    assert engine.stats.served == len(preds)
    assert engine.stats.batches == -(-len(preds) // 8)
    assert all(t is None for t in engine.slots)


def test_query_engine_partial_batch_and_tickets():
    rng = np.random.default_rng(4)
    idx = make_index(rng.uniform(0, 1000, 500))
    engine = QueryEngine(idx, batch=16)
    t1 = engine.submit(Predicate.between(0, 1000))
    t2 = engine.submit(Predicate(lo=5.0, hi=1.0))
    assert not t1.done and t1.count is None
    finished = engine.run_batch()
    assert {t.qid for t in finished} == {t1.qid, t2.qid}
    assert t1.done and t1.count == int(idx.search(Predicate.between(0, 1000)).count)
    assert t2.done and t2.count == 0 and t2.entries_matched == 0
    assert engine.run_batch() == []         # nothing pending -> no-op


def test_query_engine_results_in_submission_order():
    rng = np.random.default_rng(5)
    idx = make_index(rng.uniform(0, 1000, 800))
    engine = QueryEngine(idx, batch=4)
    preds = workload(rng, 10)
    tickets = [engine.submit(p) for p in preds]
    engine.drain()
    for t, p in zip(tickets, preds):
        assert t.count == int(idx.search(p).count)


def test_admission_is_constant_time_per_query():
    """The queue is a deque and slots come off a free list: admitting from a
    deep backlog must not re-scan the queue (the old list.pop(0) was O(n)
    per admit, O(n^2) per backlog). Guarded structurally — the queue type
    popleft's in O(1) — and behaviorally: FIFO order survives slot recycling
    and an external slot reset (the documented way to discard pending work)."""
    from collections import deque
    rng = np.random.default_rng(8)
    idx = make_index(rng.uniform(0, 1000, 200))
    engine = QueryEngine(idx, batch=4)
    assert isinstance(engine.queue, deque)
    tickets = [engine.submit(Predicate.between(i, i + 1.0)) for i in range(16)]
    engine.run_batch()
    assert [t.done for t in tickets[:4]] == [True] * 4      # FIFO head first
    assert not any(t.done for t in tickets[4:])
    # external slot reset (the writer suite's idiom for dropping admitted
    # work): the free list must resync instead of stranding the slots
    engine._admit()                        # tickets[4:8] occupy the slots
    engine.slots = [None] * engine.batch   # ... and are dropped on the floor
    engine.drain()
    assert not any(t.done for t in tickets[4:8])   # dropped, never served
    assert all(t.done for t in tickets[8:])        # the rest drain FIFO
    assert engine.stats.served == 12


def test_compact_fallback_accounted_in_occupancy_and_gather_stats():
    """Bugfix regression: the truncation fallback is a real extra dispatch,
    but it used to update neither slots_filled/pad_slots nor the gather
    telemetry — so occupancy and gather_occupancy overreported exactly when
    the engine was doing extra work. A compact_bucket of 1 forces every
    page-selecting query through the fallback."""
    rng = np.random.default_rng(21)
    idx = make_index(np.sort(rng.uniform(0, 1000, 800)))
    engine = QueryEngine(idx, batch=4, compact_bucket=1)
    preds = [Predicate.between(0, 1000), Predicate.between(100, 900)]
    counts = engine.run_all(preds)
    want = [int(idx.search(p).count) for p in preds]
    np.testing.assert_array_equal(counts, want)      # fallback stays exact
    st = engine.stats
    assert st.compact_fallbacks == 2
    cap = idx.gather_cap
    # gather telemetry covers both dispatches: the bucket-1 primary slab and
    # the fallback's never-truncating cap
    assert st.gather_slab_pages == 1 + cap
    assert st.table_pages_seen == 2 * idx.table.num_pages
    assert st.selected_pages > 0
    assert 0.0 < st.gather_occupancy <= 1.0
    # slot accounting covers the fallback's padded width (pow2 >= 8)
    assert st.slots_filled == 2 + 2                  # primary batch + fallback
    assert st.pad_slots == (4 - 2) + (8 - 2)
    assert st.occupancy == pytest.approx(4 / 12)


def test_writerless_noop_delete_skips_vacuum():
    """Bugfix regression: the sync (writerless) delete path always ran
    ``index.vacuum()`` — a dispatch that re-summarizes nothing — even when
    ``delete_where`` removed zero rows."""
    rng = np.random.default_rng(22)
    idx = make_index(rng.uniform(0, 1000, 400))
    engine = QueryEngine(idx, batch=4)
    assert engine.delete(5000.0, 6000.0) == 0        # no key in range
    assert idx.counters.vacuums == 0                 # vacuum skipped
    assert engine.stats.deletes == 0
    n = engine.delete(0.0, 100.0)
    assert n > 0 and idx.counters.vacuums == 1       # real deletes still vacuum
    assert engine.run_all([Predicate.between(0, 1000)])[0] == \
        int(idx.search(Predicate.between(0, 1000)).count)


def test_table_dirty_page_counter_tracks_lifecycle():
    """``PagedTable.num_dirty`` backs the O(1) on_depth backlog read: it must
    track delete_where (no double count), clear_dirty (idempotent), and
    truncate_to exactly."""
    from repro.storage.table import PagedTable
    t = PagedTable.from_values(np.arange(64, dtype=np.float32), page_card=8)
    assert t.num_dirty == 0
    t.delete_where(0.0, 9.0)                       # dirties pages 0 and 1
    assert t.num_dirty == 2
    t.delete_where(5.0, 11.0)                      # page 1 already dirty
    assert t.num_dirty == 2
    assert t.num_dirty == int(t.dirty.sum())
    t.clear_dirty(np.asarray([0]))
    assert t.num_dirty == 1
    t.clear_dirty(np.asarray([0]))                 # idempotent
    assert t.num_dirty == 1
    t.clear_dirty(np.asarray([1, 1]))              # duplicate ids: one clear
    assert t.num_dirty == 0
    t.delete_where(60.0, 63.0)                     # dirties the last page
    assert t.num_dirty == 1
    t.truncate_to(4, t.page_card)                  # drops the dirty page too
    assert t.num_dirty == int(t.dirty.sum()) == 0


def test_engine_compact_default_matches_explicit_dense():
    rng = np.random.default_rng(9)
    idx = make_index(np.sort(rng.uniform(0, 1000, 1500)))
    preds = workload(rng, 12)
    default = QueryEngine(idx, batch=8)
    assert default.mode == "compact"
    counts = default.run_all(preds)
    np.testing.assert_array_equal(
        counts, QueryEngine(idx, batch=8, mode="dense").run_all(preds))
    assert default.stats.compact_batches == default.stats.batches
    assert (default.stats.compact_hits + default.stats.compact_fallbacks
            == default.stats.served)
