"""TPC-H workload module: query correctness through the Hippo access path."""
import numpy as np

from repro.storage import tpch


def setup_module(module):
    module.li = tpch.generate_lineitem(30_000, seed=5)
    module.idx = tpch.build_shipdate_index(module.li, resolution=200, density=0.2)


def test_selectivity_window_is_calibrated():
    lo, hi = tpch.selectivity_window(0.01)
    frac = ((li.shipdate >= lo) & (li.shipdate <= hi)).mean()
    assert abs(frac - 0.01) < 0.005


def test_q6_exact_vs_bruteforce():
    lo, hi = tpch.selectivity_window(0.02)
    got = tpch.q6(li, idx, lo, hi)
    m = ((li.shipdate >= lo) & (li.shipdate <= hi) & (li.discount >= 0.05)
         & (li.discount <= 0.07) & (li.quantity < 24))
    want = float((li.extendedprice[m] * li.discount[m]).sum())
    assert abs(got - want) <= 1e-3 * max(abs(want), 1.0)


def test_q15_top_supplier_matches_numpy():
    lo, hi = tpch.selectivity_window(0.05)
    supp, rev = tpch.q15(li, idx, lo, hi)
    m = (li.shipdate >= lo) & (li.shipdate <= hi)
    acc = np.zeros(10_000)
    np.add.at(acc, li.suppkey[m].astype(np.int64),
              (li.extendedprice[m] * (1 - li.discount[m])).astype(np.float64))
    assert supp == int(acc.argmax())
    assert abs(rev - float(acc.max())) < 1e-6 * max(acc.max(), 1.0)


def test_q20_returns_sane_count():
    lo, hi = tpch.selectivity_window(0.05)
    n = tpch.q20(li, idx, lo, hi)
    total = int(((li.shipdate >= lo) & (li.shipdate <= hi)).sum())
    assert 0 <= n <= total
