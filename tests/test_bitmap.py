import numpy as np
import pytest
pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import bitmap as bm


@pytest.mark.parametrize("num_bits", [1, 31, 32, 33, 400, 1600])
def test_pack_unpack_roundtrip(num_bits):
    rng = np.random.default_rng(num_bits)
    bits = rng.random((4, num_bits)) < 0.3
    packed = bm.from_bool(jnp.asarray(bits))
    assert packed.shape == (4, bm.num_words(num_bits))
    out = np.asarray(bm.to_bool(packed, num_bits))
    np.testing.assert_array_equal(out, bits)


@given(st.lists(st.booleans(), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_popcount_matches_sum(bits):
    arr = np.asarray(bits, bool)
    packed = bm.from_bool(jnp.asarray(arr))
    assert int(bm.popcount(packed)) == int(arr.sum())


@given(st.integers(1, 200), st.data())
@settings(max_examples=50, deadline=None)
def test_any_joint_matches_set_intersection(num_bits, data):
    a = data.draw(st.lists(st.booleans(), min_size=num_bits, max_size=num_bits))
    b = data.draw(st.lists(st.booleans(), min_size=num_bits, max_size=num_bits))
    a, b = np.asarray(a, bool), np.asarray(b, bool)
    pa, pb = bm.from_bool(jnp.asarray(a)), bm.from_bool(jnp.asarray(b))
    assert bool(bm.any_joint(pa, pb)) == bool((a & b).any())


def test_set_get_bit():
    x = bm.zeros(100)
    for i in [0, 31, 32, 63, 99]:
        x = bm.set_bit(x, i)
    for i in [0, 31, 32, 63, 99]:
        assert int(bm.get_bit(x, i)) == 1
    assert int(bm.get_bit(x, 50)) == 0
    assert int(bm.popcount(x)) == 5


def test_range_mask():
    m = bm.range_mask(100, 10, 20)
    bits = np.asarray(bm.to_bool(m, 100))
    assert bits[10:21].all() and not bits[:10].any() and not bits[21:].any()


def test_density():
    bits = np.zeros(400, bool)
    bits[:80] = True
    packed = bm.from_bool(jnp.asarray(bits))
    assert abs(float(bm.density(packed, 400)) - 0.2) < 1e-6


@given(st.lists(st.integers(0, 2**32 - 1), min_size=0, max_size=64))
@settings(max_examples=50, deadline=None)
def test_rle_roundtrip(words):
    arr = np.asarray(words, np.uint32)
    out = bm.rle_decompress(bm.rle_compress(arr))
    np.testing.assert_array_equal(out, arr)
