"""Benchmark harness plumbing: the ``--json`` machine-readable output path.

The benchmarks themselves are too slow for the test tier, so these tests
drive ``benchmarks.run`` with a stub suite that emits canned rows and check
the JSON document the repo's ``BENCH_*.json`` trajectory files accumulate.
"""
import json

import pytest

from benchmarks import common
from benchmarks.run import SUITES, main, parse_derived, rows_to_json


def test_parse_derived_coerces_numbers():
    d = parse_derived("qps=123.5;speedup=2;label=hot;empty=")
    assert d == {"qps": 123.5, "speedup": 2, "label": "hot", "empty": ""}
    assert isinstance(d["speedup"], int)
    assert parse_derived("") == {}


def test_rows_to_json_groups_suites_and_parses_derived():
    doc = rows_to_json(
        {"alpha": [("alpha_a", 12.34, "qps=10;note=x")],
         "beta": [("beta_b", 56.0, "")]},
        quick=True)
    assert doc["schema"] == 1 and doc["config"]["quick"] is True
    assert set(doc["suites"]) == {"alpha", "beta"}
    row = doc["suites"]["alpha"][0]
    assert row["name"] == "alpha_a"
    assert row["us_per_call"] == 12.3
    assert row["qps"] == 10 and row["derived"] == {"qps": 10, "note": "x"}
    assert doc["suites"]["beta"][0]["qps"] is None


def test_main_writes_json_for_a_suite(tmp_path, monkeypatch, capsys):
    def stub(quick):
        common.emit("stub_metric", 42.0, qps=100.0, speedup=2.5)
        common.emit("stub_other", 7.0)

    monkeypatch.setitem(SUITES, "stub", stub)
    out = tmp_path / "bench.json"
    main(["--only", "stub", "--json", str(out)])
    doc = json.loads(out.read_text())
    assert list(doc["suites"]) == ["stub"]
    rows = doc["suites"]["stub"]
    assert [r["name"] for r in rows] == ["stub_metric", "stub_other"]
    assert rows[0]["qps"] == 100.0
    assert rows[0]["derived"]["speedup"] == 2.5
    assert doc["config"]["quick"] is False
    # the CSV contract on stdout is unchanged by --json
    assert "stub_metric,42.0,qps=100.0;speedup=2.5" in capsys.readouterr().out


def test_main_only_is_repeatable(monkeypatch):
    calls = []
    monkeypatch.setitem(SUITES, "stub1", lambda quick: calls.append("stub1"))
    monkeypatch.setitem(SUITES, "stub2", lambda quick: calls.append("stub2"))
    main(["--only", "stub1", "--only", "stub2"])
    assert calls == ["stub1", "stub2"]


def test_selectivity_sweep_is_registered():
    assert "selectivity_sweep" in SUITES
    with pytest.raises(SystemExit):
        main(["--only", "not-a-suite"])


def test_drift_sweep_records_in_trajectory_schema(tmp_path, monkeypatch):
    """The drift suite is registered (so ``--json`` runs pick it up) and its
    two-row emit shape round-trips the trajectory schema with the fields the
    sweep's story needs (qps, speedup, sel_ratio, resummarizes)."""
    from benchmarks.run import describe
    assert "drift" in SUITES
    assert len(describe("drift")) > 10

    def stub(quick):
        common.emit("drift_no_resummarize", 100.0, qps=640.0, sel_ratio=0.11)
        common.emit("drift_adaptive", 50.0, qps=1280.0, speedup=2.0,
                    sel_ratio=0.03, resummarizes=16)

    monkeypatch.setitem(SUITES, "drift", stub)
    out = tmp_path / "bench.json"
    main(["--only", "drift", "--json", str(out)])
    doc = json.loads(out.read_text())
    rows = doc["suites"]["drift"]
    assert [r["name"] for r in rows] == ["drift_no_resummarize",
                                        "drift_adaptive"]
    assert rows[1]["qps"] == 1280.0
    assert rows[1]["derived"]["speedup"] == 2.0
    assert rows[1]["derived"]["resummarizes"] == 16
    assert rows[0]["derived"]["sel_ratio"] == 0.11
