"""Benchmark harness plumbing: timing helpers, the ``--json``
machine-readable output path, and the committed ``BENCH_*.json`` baselines.

The benchmarks themselves are too slow for the test tier, so these tests
drive ``benchmarks.run`` with a stub suite that emits canned rows and check
the JSON document the repo's ``BENCH_*.json`` trajectory files accumulate
(strict JSON — the regression gate refuses anything less).
"""
import json
import math
import pathlib

import jax.numpy as jnp
import pytest

from benchmarks import common
from benchmarks.run import SUITES, main, parse_derived, rows_to_json

REPO = pathlib.Path(__file__).resolve().parent.parent
METHOD = common.TIMING_METHOD


# ---------------------------------------------------------------------------
# timing helpers
# ---------------------------------------------------------------------------

def test_block_propagates_device_errors():
    """Regression (PR 6): ``_block`` used to swallow *every* exception from
    ``jax.block_until_ready``, so a benchmark whose device computation
    failed was silently timed as a success. A deleted buffer is the easiest
    real block-time error to conjure on CPU — it must propagate."""
    x = jnp.arange(8.0) + 1.0
    x.delete()
    with pytest.raises(Exception) as err:
        common.timeit(lambda: x, warmup=0, iters=1)
    assert not isinstance(err.value, (AttributeError, TypeError))


def test_block_tolerates_host_side_results():
    # plain host values: nothing to block on, nothing raised
    assert common.timeit(lambda: [1.0, "host", None], warmup=0, iters=1) >= 0
    assert common._block(42.0) == 42.0


def test_measure_interleaves_and_takes_min():
    calls = []

    def a():
        calls.append("a")

    def b():
        calls.append("b")

    us_a, us_b = common.measure(a, b, warmup=1, reps=3)
    # warmup a, warmup b, then interleaved rep pairs — never aab/abb runs
    assert calls == ["a", "b", "a", "b", "a", "b", "a", "b"]
    assert us_a >= 0 and us_b >= 0


def test_timeit_is_measure_of_one():
    us = common.timeit(lambda: jnp.ones(16).sum(), warmup=1, iters=2)
    assert math.isfinite(us) and us > 0


def test_emit_stamps_timing_method(capsys):
    before = len(common.ROWS)
    common.emit("stamped_row", 1.0, qps=2.0)
    name, us, derived = common.ROWS[before]
    assert f"method={METHOD}" in derived
    assert f"stamped_row,1.0,qps=2.0;method={METHOD}" \
        in capsys.readouterr().out
    common.emit("explicit_row", 1.0, method="one_shot")
    assert "method=one_shot" in common.ROWS[before + 1][2]
    del common.ROWS[before:]


# ---------------------------------------------------------------------------
# derived-field parsing + strict JSON
# ---------------------------------------------------------------------------

def test_parse_derived_coerces_numbers():
    d = parse_derived("qps=123.5;speedup=2;label=hot;empty=")
    assert d == {"qps": 123.5, "speedup": 2, "label": "hot", "empty": ""}
    assert isinstance(d["speedup"], int)
    assert parse_derived("") == {}


def test_parse_derived_edge_cases():
    # non-finite numbers sanitize to None (strict JSON, gate-comparable)
    assert parse_derived("qps=nan") == {"qps": None}
    assert parse_derived("qps=inf;lo=-inf") == {"qps": None, "lo": None}
    assert parse_derived("qps=Infinity") == {"qps": None}
    # bools survive as bools, not strings or 1/0
    assert parse_derived("truncated=True;exact=False") == \
        {"truncated": True, "exact": False}
    # scientific notation still parses; stray separators are ignored
    assert parse_derived(";;qps=1e3;;") == {"qps": 1000.0}


def test_rows_to_json_groups_suites_and_parses_derived():
    doc = rows_to_json(
        {"alpha": [("alpha_a", 12.34, "qps=10;note=x")],
         "beta": [("beta_b", 56.0, "")]},
        quick=True)
    assert doc["schema"] == 1 and doc["config"]["quick"] is True
    assert set(doc["suites"]) == {"alpha", "beta"}
    row = doc["suites"]["alpha"][0]
    assert row["name"] == "alpha_a"
    assert row["us_per_call"] == 12.3
    assert row["qps"] == 10 and row["derived"] == {"qps": 10, "note": "x"}
    assert doc["suites"]["beta"][0]["qps"] is None


def test_rows_to_json_is_strict_json_under_nan_inf():
    """A zero timing makes a qps division print inf/nan; the document must
    sanitize those to null so ``json.dump(..., allow_nan=False)`` (what
    --json uses) and the gate's strict loader both accept it."""
    doc = rows_to_json(
        {"s": [("r_nan", float("nan"), "qps=nan;speedup=inf"),
               ("r_inf", float("inf"), "qps=120.0")]},
        quick=False)
    rows = doc["suites"]["s"]
    assert rows[0]["us_per_call"] is None
    assert rows[0]["qps"] is None and rows[0]["derived"]["speedup"] is None
    assert rows[1]["us_per_call"] is None and rows[1]["qps"] == 120.0
    json.dumps(doc, allow_nan=False)  # must not raise


def test_main_writes_json_for_a_suite(tmp_path, monkeypatch, capsys):
    def stub(quick):
        common.emit("stub_metric", 42.0, qps=100.0, speedup=2.5)
        common.emit("stub_other", 7.0)

    monkeypatch.setitem(SUITES, "stub", stub)
    out = tmp_path / "bench.json"
    assert main(["--only", "stub", "--json", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert list(doc["suites"]) == ["stub"]
    rows = doc["suites"]["stub"]
    assert [r["name"] for r in rows] == ["stub_metric", "stub_other"]
    assert rows[0]["qps"] == 100.0
    assert rows[0]["derived"]["speedup"] == 2.5
    assert rows[0]["derived"]["method"] == METHOD
    assert doc["config"]["quick"] is False
    # the CSV contract on stdout is unchanged by --json
    assert f"stub_metric,42.0,qps=100.0;speedup=2.5;method={METHOD}" \
        in capsys.readouterr().out


def test_main_only_is_repeatable(monkeypatch):
    calls = []
    monkeypatch.setitem(SUITES, "stub1", lambda quick: calls.append("stub1"))
    monkeypatch.setitem(SUITES, "stub2", lambda quick: calls.append("stub2"))
    assert main(["--only", "stub1", "--only", "stub2"]) == 0
    assert calls == ["stub1", "stub2"]


def test_selectivity_sweep_is_registered():
    assert "selectivity_sweep" in SUITES
    with pytest.raises(SystemExit):
        main(["--only", "not-a-suite"])


def test_drift_sweep_records_in_trajectory_schema(tmp_path, monkeypatch):
    """The drift suite is registered (so ``--json`` runs pick it up) and its
    two-row emit shape round-trips the trajectory schema with the fields the
    sweep's story needs (qps, speedup, sel_ratio, resummarizes)."""
    from benchmarks.run import describe
    assert "drift" in SUITES
    assert len(describe("drift")) > 10

    def stub(quick):
        common.emit("drift_no_resummarize", 100.0, qps=640.0, sel_ratio=0.11)
        common.emit("drift_adaptive", 50.0, qps=1280.0, speedup=2.0,
                    sel_ratio=0.03, resummarizes=16)

    monkeypatch.setitem(SUITES, "drift", stub)
    out = tmp_path / "bench.json"
    main(["--only", "drift", "--json", str(out)])
    doc = json.loads(out.read_text())
    rows = doc["suites"]["drift"]
    assert [r["name"] for r in rows] == ["drift_no_resummarize",
                                        "drift_adaptive"]
    assert rows[1]["qps"] == 1280.0
    assert rows[1]["derived"]["speedup"] == 2.0
    assert rows[1]["derived"]["resummarizes"] == 16
    assert rows[0]["derived"]["sel_ratio"] == 0.11


# ---------------------------------------------------------------------------
# committed trajectory baselines
# ---------------------------------------------------------------------------

def test_committed_baselines_are_strict_and_well_formed():
    """Every ``BENCH_*.json`` in the repo root must load through the gate's
    strict validator — a baseline with NaN/Infinity or malformed rows would
    poison every future ``--check`` run."""
    from benchmarks import check
    baselines = sorted(REPO.glob("BENCH_*.json"))
    assert baselines, "no committed BENCH_*.json trajectory files"
    for path in baselines:
        doc = check.load_trajectory(str(path))       # raises on bad input
        assert doc.get("schema") == 1, path.name
        assert isinstance(doc.get("config"), dict), path.name


def test_latest_committed_baseline_covers_every_registered_suite():
    """The newest baseline is what ``--check`` gates against, so every
    registered suite must appear in it with at least one gated row
    (``scripts/check_bench.py --coverage`` is the CLI twin) — a new bench
    that never emits qps/achieved_gbps cannot dodge the gate."""
    from benchmarks import check
    latest = sorted(REPO.glob("BENCH_*.json"))[-1]
    doc = check.load_trajectory(str(latest))
    assert check.coverage_problems(doc, set(SUITES)) == [], latest.name
    # the kernel rows specifically carry the roofline statement
    kernel_rows = doc["suites"]["kernels"]
    assert len(kernel_rows) == 5
    for row in kernel_rows:
        assert row["derived"]["achieved_gbps"] > 0, row["name"]
        assert row["derived"]["roofline_frac"] > 0, row["name"]
        assert row["derived"]["method"] == METHOD, row["name"]
