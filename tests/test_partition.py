"""Partition layer: sharded search must be count-identical to the unsharded
index under arbitrary predicates and maintenance histories, shard-boundary
maintenance must stay local and refuse cleanly at capacity, and the engine's
summary-routed dispatch must agree with everything else."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import index as hix
from repro.core.hippo import HippoIndex
from repro.core.partition import (ShardedHippoIndex, ShardSpec, shard_state,
                                  summary_of)
from repro.core.predicate import Predicate, intervals, to_bucket_bitmaps
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable

pytestmark = pytest.mark.shard


def make_pair(values, num_shards=4, page_card=8, resolution=32, density=0.25,
              spare_pages=64, **kw):
    """(unsharded, sharded) indexes over identical tables."""
    t1 = PagedTable.from_values(np.asarray(values).copy(), page_card=page_card,
                                spare_pages=spare_pages)
    t2 = PagedTable.from_values(np.asarray(values).copy(), page_card=page_card,
                                spare_pages=spare_pages)
    idx = HippoIndex.create(t1, resolution=resolution, density=density, **kw)
    sidx = ShardedHippoIndex.create(t2, num_shards=num_shards,
                                    resolution=resolution, density=density, **kw)
    return idx, sidx


def brute_force(table, lo, hi):
    live = table.valid[: table.num_pages]
    keys = table.keys[: table.num_pages]
    return int((live & (keys >= lo) & (keys <= hi)).sum())


def workload(rng, n):
    """Random ranges plus the edge predicates (mirrors test_engine)."""
    preds = []
    for _ in range(n):
        lo = float(rng.uniform(0, 1000))
        preds.append(Predicate.between(lo, lo + float(rng.uniform(0, 300))))
    preds += [
        Predicate(lo=5.0, hi=1.0),            # empty interval (lo > hi)
        Predicate.between(2000, 3000),        # no key in range
        Predicate.between(-1e30, 1e30),       # full table
        Predicate(),                          # unconstrained
        Predicate.equality(float(rng.uniform(0, 1000))),
        Predicate.greater(500.0),
        Predicate.less(100.0),
    ]
    return preds


# ---------------------------------------------------------------------------
# Search parity (the acceptance invariant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_shards", [1, 3, 4])
def test_sharded_counts_match_unsharded(num_shards):
    rng = np.random.default_rng(num_shards)
    idx, sidx = make_pair(rng.uniform(0, 1000, 2000), num_shards=num_shards)
    preds = workload(rng, 16)
    want = np.asarray(idx.search_batch(preds).counts)
    got = np.asarray(sidx.search_batch(preds).counts)
    np.testing.assert_array_equal(got, want)
    # per-shard dispatch sums to the same counts, and pruned (q, s) pairs
    # are exactly count-zero (the routing soundness guarantee)
    match = sidx.shard_match_matrix(preds)
    per = np.stack([np.asarray(sidx.search_batch_shard(s, preds).counts)
                    for s in range(num_shards)])
    np.testing.assert_array_equal(per.sum(axis=0), want)
    for s in range(num_shards):
        assert per[s][~match[:, s]].sum() == 0


@pytest.mark.slow
@pytest.mark.parametrize("dist", ["uniform", "sorted", "skewed", "lowcard"])
def test_sharded_parity_predicate_sweep(dist):
    """Property-style sweep: many random predicates over several data
    distributions, counts bit-identical at every shard count."""
    rng = np.random.default_rng({"uniform": 0, "sorted": 1, "skewed": 2,
                                 "lowcard": 3}[dist])
    n = 3000
    if dist == "uniform":
        values = rng.uniform(0, 1000, n)
    elif dist == "sorted":
        values = np.sort(rng.uniform(0, 1000, n))
    elif dist == "skewed":
        values = rng.exponential(50, n)
    else:
        values = rng.integers(0, 12, n).astype(float)
    preds = workload(rng, 48)
    t0 = PagedTable.from_values(values.copy(), page_card=8, spare_pages=64)
    want = np.asarray(HippoIndex.create(t0, resolution=32,
                                        density=0.25).search_batch(preds).counts)
    truth = [brute_force(t0, *p.selectivity_interval()) for p in preds]
    np.testing.assert_array_equal(want, truth)
    for s in (2, 5):
        t = PagedTable.from_values(values.copy(), page_card=8, spare_pages=64)
        sidx = ShardedHippoIndex.create(t, num_shards=s, resolution=32,
                                        density=0.25)
        got = np.asarray(sidx.search_batch(preds).counts)
        np.testing.assert_array_equal(got, want, err_msg=f"{dist} S={s}")


def test_search_many_sharded_page_mask_global_order():
    """The fused (Q, S) path returns page_mask in global page order and
    covers every truly-qualified page (soundness)."""
    rng = np.random.default_rng(7)
    values = rng.uniform(0, 1000, 1500)
    _, sidx = make_pair(values, num_shards=3)
    pred = Predicate.between(200, 420)
    res = sidx.search_batch([pred])
    t = sidx.table
    qual_pages = (t.valid[: t.num_pages]
                  & (t.keys[: t.num_pages] >= 200)
                  & (t.keys[: t.num_pages] <= 420)).any(axis=1)
    mask = np.asarray(res.page_mask[0])
    assert mask.shape == (t.num_pages,)
    assert not (qual_pages & ~mask).any()


def test_empty_and_single_shard_layouts():
    rng = np.random.default_rng(11)
    values = rng.uniform(0, 100, 200)
    idx, sidx = make_pair(values, num_shards=1)
    for lo, hi in [(0, 100), (30, 35)]:
        assert sidx.count(Predicate.between(lo, hi)) == \
            int(idx.search(Predicate.between(lo, hi)).count)
    # layouts with more shards than pages: trailing shards stay empty
    t = PagedTable.from_values(rng.uniform(0, 100, 20), page_card=8)
    s = ShardedHippoIndex.create(t, num_shards=8, resolution=32, density=0.25)
    assert s.count(Predicate.between(0, 100)) == t.cardinality
    assert (s.shard_entry_counts()[s.spec.owner(t.num_pages - 1) + 1:] == 0).all()


# ---------------------------------------------------------------------------
# Shard-boundary maintenance
# ---------------------------------------------------------------------------

def test_insert_routes_to_owning_shard_and_matches_unsharded():
    rng = np.random.default_rng(21)
    idx, sidx = make_pair(rng.uniform(0, 100, 333), num_shards=3)
    for v in rng.uniform(0, 100, 80):
        idx.insert(float(v))
        sidx.insert(float(v))
    for lo, hi in [(0, 100), (10, 20), (50, 50.5)]:
        want = brute_force(sidx.table, lo, hi)
        assert sidx.count(Predicate.between(lo, hi)) == want
        assert int(idx.search(Predicate.between(lo, hi)).count) == want


def test_insert_crossing_shard_boundary_stays_local():
    """Appends that open the first page of a fresh shard must create entries
    in that shard only — earlier shards' arrays stay untouched."""
    values = np.linspace(0, 99, 64)           # 8 pages of 8
    t = PagedTable.from_values(values, page_card=8, spare_pages=64)
    sidx = ShardedHippoIndex.create(t, num_shards=2, pages_per_shard=10,
                                    resolution=32, density=0.25)
    before = np.asarray(shard_state(sidx.state.shards, 0).bitmaps).copy()
    # fill shard 0's slab (pages 8, 9), then cross into shard 1 (page 10+)
    for v in np.linspace(0, 99, 40):
        sidx.insert(float(v))
    assert sidx.table.num_pages > 10          # crossed the boundary
    assert int(sidx.state.shards.num_entries[1]) > 0
    after_s0 = np.asarray(shard_state(sidx.state.shards, 0).bitmaps)
    changed_rows = (before != after_s0).any(axis=1).sum()
    # shard 0 changed only while its own slab filled; shard-1 pages never
    # touched it — and search stays exact throughout
    assert changed_rows <= int(sidx.state.shards.num_slots[0])
    assert sidx.count(Predicate.between(0, 100)) == brute_force(t, 0, 100)


def test_insert_into_full_shard_layout_refuses_cleanly():
    rng = np.random.default_rng(23)
    t = PagedTable.from_values(rng.uniform(0, 100, 64), page_card=8,
                               spare_pages=64)
    sidx = ShardedHippoIndex.create(t, num_shards=2, pages_per_shard=5,
                                    resolution=32, density=0.25)
    with pytest.raises(RuntimeError, match="shard layout full"):
        for v in np.linspace(0, 90, 100):
            sidx.insert(float(v))
    card = t.cardinality
    # the refusing insert left the table untouched and queries exact
    assert sidx.count(Predicate.between(0, 100)) == card
    with pytest.raises(RuntimeError, match="shard layout full"):
        sidx.insert(1.0)
    assert t.cardinality == card
    # batch insert is atomic: rolls the table back to the pre-batch snapshot
    with pytest.raises(RuntimeError, match="shard layout full"):
        sidx.insert_batch(np.linspace(0, 90, 50))
    assert t.cardinality == card
    assert sidx.count(Predicate.between(0, 100)) == card


def test_insert_at_shard_slot_capacity_refuses_cleanly():
    values = np.linspace(0, 99, 64)
    t = PagedTable.from_values(values, page_card=8, spare_pages=256)
    sidx = ShardedHippoIndex.create(t, num_shards=2, max_slots=12,
                                    resolution=32, density=0.25,
                                    relocate_on_update=True)
    with pytest.raises(RuntimeError, match="slot capacity"):
        for v in np.linspace(0, 99, 500):
            sidx.insert(float(v))
    assert (np.asarray(sidx.state.shards.num_slots) <= sidx.cfg.max_slots).all()
    assert sidx.count(Predicate.between(0, 99)) == brute_force(t, 0, 99)


def test_batch_insert_matches_sequential_across_shards():
    rng = np.random.default_rng(25)
    base = rng.uniform(0, 100, 200)
    extra = rng.uniform(0, 100, 150)
    _, sidx_a = make_pair(base, num_shards=3, relocate_on_update=False,
                          spare_pages=256)
    _, sidx_b = make_pair(base, num_shards=3, relocate_on_update=False,
                          spare_pages=256)
    for v in extra:
        sidx_a.insert(float(v))
    sidx_b.insert_batch(extra)
    for lo, hi in [(0, 100), (25, 30), (77, 77.5)]:
        want = brute_force(sidx_b.table, lo, hi)
        assert sidx_a.count(Predicate.between(lo, hi)) == want
        assert sidx_b.count(Predicate.between(lo, hi)) == want


def test_vacuum_spanning_two_shards():
    """A delete band dirtying pages in two different shards re-summarizes
    entries in both, queries stay exact before and after, and untouched
    shards' bitmaps are left alone."""
    values = np.sort(np.random.default_rng(27).uniform(0, 100, 800))
    _, sidx = make_pair(values, num_shards=4)
    pps = sidx.spec.pages_per_shard
    # sorted keys => a mid-domain band hits pages around the shard-1/2 border
    lo_key = float(values[(2 * pps - 2) * 8])
    hi_key = float(values[(2 * pps + 2) * 8])
    sidx.table.delete_where(lo_key, hi_key)
    dirty = np.flatnonzero(sidx.table.dirty[: sidx.table.num_pages])
    touched = np.unique(dirty // pps)
    assert len(touched) >= 2                  # the band spans a shard boundary
    # exact while deletes are lazy (§5.2)
    assert sidx.count(Predicate.between(0, 100)) == brute_force(sidx.table, 0, 100)
    summaries_before = np.asarray(sidx.state.summaries).copy()
    n = sidx.vacuum()
    assert n > 0
    assert not sidx.table.dirty[: sidx.table.num_pages].any()
    assert sidx.count(Predicate.between(lo_key, hi_key)) == 0
    assert sidx.count(Predicate.between(0, 100)) == brute_force(sidx.table, 0, 100)
    # vacuum stayed local: summaries of untouched shards are unchanged
    after = np.asarray(sidx.state.summaries)
    for s in range(sidx.num_shards):
        if s not in touched:
            np.testing.assert_array_equal(after[s], summaries_before[s])


def test_summaries_track_maintenance_as_superset():
    """Shard summaries must stay supersets of their live entry unions (the
    pruning soundness invariant) across inserts and vacuum."""
    rng = np.random.default_rng(29)
    _, sidx = make_pair(rng.uniform(0, 100, 400), num_shards=3)
    for v in rng.uniform(0, 100, 50):
        sidx.insert(float(v))
    sidx.table.delete_where(20, 40)
    sidx.vacuum()
    for s in range(sidx.num_shards):
        st = shard_state(sidx.state.shards, s)
        true_union = np.asarray(summary_of(st))
        cached = np.asarray(sidx.state.summaries[s])
        assert (cached | true_union == cached).all()


# ---------------------------------------------------------------------------
# Engine sharded mode
# ---------------------------------------------------------------------------

def test_engine_sharded_mode_matches_dense_engine():
    rng = np.random.default_rng(31)
    idx, sidx = make_pair(np.sort(rng.uniform(0, 1000, 2000)), num_shards=4)
    preds = workload(rng, 24)
    dense = QueryEngine(idx, batch=8).run_all(preds)
    # sharded=True selects dense mode's summary-routed per-shard dispatch
    routed = QueryEngine(sidx, batch=8, sharded=True)
    assert routed.sharded and routed.mode == "dense"
    np.testing.assert_array_equal(routed.run_all(preds), dense)
    # fused (Q, S) dense mode on the same sharded index agrees too
    fused = QueryEngine(sidx, batch=8, mode="dense", sharded=False)
    assert not fused.sharded
    np.testing.assert_array_equal(fused.run_all(preds), dense)
    # ... as does the default (compact gather) mode
    compact = QueryEngine(sidx, batch=8)
    assert compact.mode == "compact" and not compact.sharded
    np.testing.assert_array_equal(compact.run_all(preds), dense)
    assert routed.stats.shard_dispatches > 0
    occ = routed.stats.shard_occupancy()
    assert occ and all(0 < v <= 1 for v in occ.values())


def test_engine_stats_never_count_pads_as_served_work():
    rng = np.random.default_rng(33)
    idx, sidx = make_pair(rng.uniform(0, 1000, 500), num_shards=2)
    # dense mode: pads are the free batch slots
    engine = QueryEngine(idx, batch=16)
    engine.submit(Predicate.between(0, 1000))
    engine.submit(Predicate(lo=5.0, hi=1.0))       # real (empty) query
    assert len(engine.run_batch()) == 2
    st = engine.stats
    assert st.slots_filled == 2                    # pads excluded
    assert st.pad_slots == 14
    assert st.served == 2
    assert st.occupancy == pytest.approx(2 / 16)
    # sharded mode: pads are the per-shard bucket roundings actually
    # dispatched, never the undispatched batch remainder
    routed = QueryEngine(sidx, batch=16, sharded=True)
    routed.submit(Predicate.between(0, 1000))
    routed.submit(Predicate(lo=5.0, hi=1.0))
    assert len(routed.run_batch()) == 2
    st = routed.stats
    assert st.served == 2
    assert st.slots_filled == sum(st.shard_queries.values())
    assert st.slots_filled + st.pad_slots == sum(st.shard_slots.values())
    assert 0 < st.occupancy <= 1
    # a fresh engine with nothing dispatched reports zero occupancy
    assert QueryEngine(idx, batch=4).stats.occupancy == 0.0


def test_engine_sharded_requires_partition_surface():
    rng = np.random.default_rng(35)
    idx, _ = make_pair(rng.uniform(0, 1000, 100), num_shards=2)
    with pytest.raises(ValueError, match="sharded"):
        QueryEngine(idx, sharded=True)


# ---------------------------------------------------------------------------
# Device placement (data-axis shardings)
# ---------------------------------------------------------------------------

def test_placed_sharded_state_search_parity():
    from repro.launch.mesh import make_shard_mesh
    from repro.launch.shardings import place_sharded

    rng = np.random.default_rng(41)
    _, sidx = make_pair(rng.uniform(0, 1000, 1000), num_shards=4)
    mesh = make_shard_mesh(sidx.num_shards)
    assert sidx.num_shards % mesh.shape["data"] == 0
    keys, valid = sidx._slabs()
    st, k, v = place_sharded(mesh, sidx.state, keys, valid)
    preds = workload(rng, 8)
    qbms = sidx._query_bitmaps(preds)           # (S, Q, W): per-shard epochs
    los, his = intervals(preds)
    res = hix.search_many_sharded(st.shards, qbms, k, v, los, his)
    want = np.asarray(sidx.search_batch(preds).counts)
    np.testing.assert_array_equal(np.asarray(res.counts), want)


def test_shard_spec_routing_arithmetic():
    spec = ShardSpec(num_shards=3, pages_per_shard=10)
    assert spec.total_pages == 30
    assert spec.owner(0) == 0 and spec.owner(9) == 0
    assert spec.owner(10) == 1 and spec.owner(29) == 2
    assert spec.owner(30) == 3                 # overflow: past the last slab
    assert spec.to_local(23) == 3
    assert spec.page_lo(2) == 20
