"""Self-healing recovery: every registered crash site, supervised.

The acceptance contract of the fault-injection harness
(``repro.runtime.faultinject``) plus the engine supervisor
(``repro.runtime.fault.resilient_serve``):

- A crash injected at **every** site in ``faultinject.SITES`` — journal
  append, drain swap, delta commit, full-snapshot commit, compaction
  fold, journal truncation, and mid-background-save — recovers to exactly
  the acknowledged counts with **no operator action**: the supervisor
  rebuilds the engine from durable state itself (or, for a background
  commit failure, the engine's poison fallback supersedes the broken
  chain with a synchronous full snapshot).
- The workload is resumption-aware (a cursor advances only on
  acknowledged operations), so recovered counts are checked bit-identical
  against the brute-force count over the acknowledged multiset — no
  acknowledged write lost, no record double-applied.
- The watchdog path: a flagged hang tears the step down through the same
  restart machinery a crash uses; the retry budget re-raises once
  exhausted.

Completeness is enforced structurally: the crash-site sweep is
parametrized over ``faultinject.SITES`` itself, so registering a new
crash point without mapping it to an engine configuration here fails the
suite rather than silently going uncovered.
"""
import time

import numpy as np
import pytest

from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.fault import ServeStats, StepWatchdog, resilient_serve
from repro.runtime.faultinject import (SITES, CrashPoints, InjectedCrash,
                                       crash_points)
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable

pytestmark = pytest.mark.fault


@pytest.fixture(autouse=True)
def _clean_crash_points():
    crash_points.reset()
    yield
    crash_points.reset()


def make_sidx(values, num_shards=4):
    table = PagedTable.from_values(np.asarray(values).copy(), page_card=8,
                                   spare_pages=256)
    return ShardedHippoIndex.create(table, num_shards=num_shards,
                                    resolution=32, density=0.25)


def preds():
    return [
        Predicate(lo=5.0, hi=1.0),
        Predicate.between(20.0, 24.0),
        Predicate.between(100.0, 115.0),
        Predicate.between(80.0, 125.0),
        Predicate.between(-1e30, 1e30),
    ]


def value_brute(values, ps):
    v = np.asarray(values, np.float32)
    return np.asarray([((v >= p.lo) & (v <= p.hi)).sum() for p in ps],
                      np.int64)


_ENGINE_KW = dict(batch=8, drain_policy="manual", auto_resummarize=False)

# Site -> the durable-engine configuration whose commit path actually
# executes that site. Parametrizing over SITES itself keeps this mapping
# complete by construction: a newly registered site with no entry here
# fails the sweep instead of going untested.
_SITE_CONFIG = {
    "wal.pre_append": {},
    "drain.pre_swap": {},
    "delta.pre_commit": {},                      # default incremental path
    "snapshot.pre_commit": {"snapshot_mode": "full"},
    "compact.pre_commit": {"compact_every": 2},  # 3rd commit folds the chain
    "truncate.pre": {},
    "persist.in_flight": {"background_save": True},
}

# Sites whose injected crash surfaces in the *foreground* (the serving
# loop sees the exception and must restart). persist.in_flight fires on
# the persister's worker thread: the failure poisons the persister and
# the engine self-heals through the synchronous-full-save fallback with
# no restart at all.
_FOREGROUND_SITES = frozenset(SITES) - {"persist.in_flight"}


def _acked_workload(values, acked, chunk=6):
    """A resumption-aware ingest client: the cursor advances only when a
    write returns (= was acknowledged), exactly what a real client
    replaying un-acked requests does; each step flushes (drain + durable
    commit)."""
    cursor = {"i": 0}

    def workload(eng):
        end = min(cursor["i"] + chunk, len(values))
        while cursor["i"] < end:
            v = values[cursor["i"]]
            eng.write(v)                 # raises => not acknowledged
            acked.append(v)
            cursor["i"] += 1
        eng.flush()
        return cursor["i"] >= len(values)

    return workload


@pytest.mark.parametrize("site", SITES)
def test_crash_at_every_registered_site_self_heals(tmp_path, site):
    """Kill -9 (via InjectedCrash) at each registered crash site; the
    supervisor (or the poison fallback) must land the engine on exactly
    the acknowledged counts with no operator involvement."""
    assert site in _SITE_CONFIG, \
        f"new crash site {site!r} registered without fault-test coverage"
    rng = np.random.default_rng(SITES.index(site))
    base = np.sort(rng.uniform(0, 100, 160))
    root = tmp_path / "dur"
    kw = dict(_ENGINE_KW, **_SITE_CONFIG[site])
    eng = QueryEngine(make_sidx(base), storage_dir=root, **kw)

    writes = [float(v) for v in rng.uniform(100, 130, 36)]
    acked: list[float] = []
    # arm *after* the engine's initial base snapshot so the injected shot
    # lands on the serving loop's path, not engine construction
    crash_points.arm(site, times=1)
    eng2, stats = resilient_serve(
        root, _acked_workload(writes, acked), engine=eng,
        recover_kwargs=dict(kw), max_restarts=6, backoff_base_s=0.001)

    assert crash_points.fired(site) >= 1, \
        f"{site} was never on the executed path — the test proved nothing"
    if site in _FOREGROUND_SITES:
        assert stats.crashes + stats.hangs >= 1
        assert stats.restores >= 1, "the supervisor never rebuilt the engine"
    else:
        # background-save failure: poisoned persister, healed by the
        # engine's synchronous-full-save fallback — no restart needed
        assert stats.restores == 0
        eng2.flush_durable()     # chain superseded: barrier must be clean
    eng2.flush()
    ps = preds()
    np.testing.assert_array_equal(
        eng2.run_all(ps), value_brute(list(base) + acked, ps),
        err_msg=f"recovered counts diverge from acknowledged state "
                f"after a crash at {site}")
    # recovery from disk alone once more: the durable state itself (not
    # the surviving engine object) carries the acknowledged counts
    eng2.close()
    eng3 = QueryEngine.recover(root, snapshot_on_recover=False,
                               wal_sync=False, **_ENGINE_KW)
    eng3.flush()
    np.testing.assert_array_equal(eng3.run_all(ps),
                                  value_brute(list(base) + acked, ps))


def test_watchdog_hang_restarts_through_the_same_path(tmp_path):
    """A watchdog-flagged hang (not an exception) must tear the engine
    down and rebuild from durable state exactly like a crash."""
    rng = np.random.default_rng(11)
    base = np.sort(rng.uniform(0, 100, 120))
    root = tmp_path / "dur"
    eng = QueryEngine(make_sidx(base), storage_dir=root, **_ENGINE_KW)
    writes = [float(v) for v in rng.uniform(100, 120, 8)]
    for v in writes:
        eng.write(v)
    eng.flush()          # acknowledged + durable before the hang

    hung = {"done": False}

    def workload(e):
        # steps are pure sleeps so jit-compile noise cannot skew the
        # watchdog's median; the engine state rides along untouched
        if not hung["done"] and len(wd.times) >= 3:
            hung["done"] = True
            time.sleep(0.5)          # the hang: >> 3x the ~2ms median
        else:
            time.sleep(0.002)
        return hung["done"] and len(wd.times) >= 5

    wd = StepWatchdog(threshold=3.0, window=8, min_samples=3)
    eng2, stats = resilient_serve(root, workload, engine=eng,
                                  recover_kwargs=dict(_ENGINE_KW),
                                  watchdog=wd, max_restarts=3,
                                  backoff_base_s=0.001)
    assert stats.hangs >= 1, "the slow step was never flagged"
    assert stats.restores >= 1, "a flagged hang must restart the engine"
    assert stats.crashes == 0, "a hang is not a crash in the stats"
    ps = preds()
    np.testing.assert_array_equal(eng2.run_all(ps),
                                  value_brute(list(base) + writes, ps))


def test_retry_budget_exhaustion_reraises(tmp_path):
    """A workload that keeps dying must eventually surface its failure
    instead of looping forever."""
    rng = np.random.default_rng(12)
    base = np.sort(rng.uniform(0, 100, 80))
    root = tmp_path / "dur"
    eng = QueryEngine(make_sidx(base), storage_dir=root, **_ENGINE_KW)

    calls = {"n": 0}

    def doomed(e):
        calls["n"] += 1
        raise RuntimeError("unrecoverable workload bug")

    with pytest.raises(RuntimeError, match="unrecoverable"):
        resilient_serve(root, doomed, engine=eng,
                        recover_kwargs=dict(_ENGINE_KW), max_restarts=2,
                        backoff_base_s=0.001)
    assert calls["n"] == 3       # initial attempt + 2 budgeted restarts


def test_backoff_grows_exponentially_and_caps(tmp_path):
    """The restart delay doubles per restart and clamps at the cap; the
    injected sleep records exactly what the supervisor decided."""
    rng = np.random.default_rng(13)
    base = np.sort(rng.uniform(0, 100, 80))
    root = tmp_path / "dur"
    eng = QueryEngine(make_sidx(base), storage_dir=root, **_ENGINE_KW)

    delays: list[float] = []
    remaining = {"n": 4}

    def flaky(e):
        if remaining["n"]:
            remaining["n"] -= 1
            raise RuntimeError("transient")
        return True

    _, stats = resilient_serve(root, flaky, engine=eng,
                               recover_kwargs=dict(_ENGINE_KW),
                               max_restarts=8, backoff_base_s=0.01,
                               backoff_cap_s=0.04, sleep=delays.append)
    assert delays == [0.01, 0.02, 0.04, 0.04]
    assert stats.backoff_s == pytest.approx(sum(delays))
    assert stats.crashes == 4 and stats.restores == 4


# ---------------------------------------------------------------------------
# Harness units: the registry and the watchdog window
# ---------------------------------------------------------------------------

def test_crash_points_arm_fires_exactly_n_times():
    cp = CrashPoints()
    cp.arm("truncate.pre", times=2)
    for _ in range(2):
        with pytest.raises(InjectedCrash) as ei:
            cp.hit("truncate.pre")
        assert ei.value.site == "truncate.pre"
    cp.hit("truncate.pre")        # disarmed: passes through
    assert cp.fired("truncate.pre") == 2
    assert cp.fired("wal.pre_append") == 0


def test_crash_points_rejects_unknown_sites():
    cp = CrashPoints()
    with pytest.raises(ValueError, match="unknown crash site"):
        cp.arm("no.such.site")
    with pytest.raises(ValueError, match="unknown crash site"):
        cp.hit("no.such.site")
    with pytest.raises(ValueError, match=">= 1"):
        cp.arm("truncate.pre", times=0)


def test_crash_points_reset_isolates_tests():
    cp = CrashPoints()
    cp.arm("drain.pre_swap")
    with pytest.raises(InjectedCrash):
        cp.hit("drain.pre_swap")
    cp.reset()
    cp.hit("drain.pre_swap")      # disarmed
    assert cp.fired("drain.pre_swap") == 0


def test_watchdog_window_is_bounded_deque():
    """Satellite regression: the observation window must be a
    maxlen-bounded deque (O(1) admission), never an unbounded list popped
    at the head, and flagging semantics must survive the switch."""
    from collections import deque
    wd = StepWatchdog(threshold=2.0, window=16, min_samples=3)
    assert isinstance(wd.times, deque) and wd.times.maxlen == 16
    for i in range(100):
        wd.observe(i, 0.01)
    assert len(wd.times) == 16        # bounded, oldest evicted
    assert wd.observe(100, 0.05) is True       # 5x the 0.01 median
    assert wd.flagged and wd.flagged[-1][0] == 100
    assert wd.observe(101, 0.012) is False


# ---------------------------------------------------------------------------
# Lock-discipline regressions (hippolint locks pass): the persister's
# counters are mutated on the worker thread while the submitter reads them
# ---------------------------------------------------------------------------

def test_persister_stats_snapshot_is_locked_copy():
    """Regression for the unlocked persister stats reads hippolint found:
    ``stats_snapshot()`` must take the owning lock and hand back a *copy*,
    so a caller-thread read never races the worker's counter bumps and
    never aliases the live counters."""
    import threading
    from repro.runtime.persister import BackgroundPersister
    gate = threading.Event()
    p = BackgroundPersister(lambda job: gate.wait(5.0), max_queue=2)
    try:
        p.submit({"n": 1})
        # the worker is (or is about to be) in flight, parked on the gate;
        # caller-side reads must be consistent mid-commit
        s = p.stats_snapshot()
        assert s.submitted == 1 and s.committed == 0 and s.failed == 0
        assert not p.poisoned
        gate.set()
        p.flush()
        s2 = p.stats_snapshot()
        assert (s2.submitted, s2.committed, s2.failed) == (1, 1, 0)
        s2.committed = 999                 # a copy: internals unaffected
        assert p.stats_snapshot().committed == 1
        assert p.pending == 0
    finally:
        gate.set()
        p.close()


def test_persister_counters_exact_under_concurrent_reads():
    """Hammer the caller-side accessors while the worker commits a stream
    of jobs: every observation must be internally consistent (committed
    never exceeds submitted) and the final counts must land exactly — a
    torn or dropped increment would show up here as an off-by-N."""
    from repro.runtime.persister import BackgroundPersister
    p = BackgroundPersister(lambda job: None, max_queue=2)
    try:
        for i in range(200):
            p.submit(i)
            s = p.stats_snapshot()
            assert s.committed <= s.submitted == i + 1
            assert s.failed == 0 and p.pending >= 0 and not p.poisoned
        p.flush()
        s = p.stats_snapshot()
        assert (s.submitted, s.committed, s.failed) == (200, 200, 0)
        assert p.pending == 0
    finally:
        p.close()
