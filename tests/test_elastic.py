"""Elastic scaling: a checkpoint written under one mesh restores onto a
different device count (subprocess meshes of 4 and 8 virtual devices)."""
import json
import os
import subprocess
import sys
import textwrap
import pytest

pytestmark = pytest.mark.slow

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.checkpointing import restore_checkpoint, save_checkpoint
    from repro.launch.mesh import make_mesh_compat
    from repro.runtime.elastic import reshard_for_mesh, validate_divisibility

    mesh = make_mesh_compat(({d}, {m}), ("data", "model"))
    template = {{"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}}
    if "{phase}" == "save":
        tree = {{"w": jnp.arange(128, dtype=jnp.float32).reshape(8, 16),
                "b": jnp.arange(16, dtype=jnp.float32)}}
        sharded = reshard_for_mesh(tree, {{"w": P("data", "model"),
                                          "b": P("model")}}, mesh)
        save_checkpoint("{ckpt}", 7, sharded)
        print(json.dumps({{"ok": True}}))
    else:
        step, tree = restore_checkpoint("{ckpt}", treedef_like=template)
        tree = reshard_for_mesh(tree, {{"w": P("data", "model"),
                                       "b": P("model")}}, mesh)
        total = float(tree["w"].sum()) + float(tree["b"].sum())
        nshards = len(tree["w"].sharding.device_set)
        print(json.dumps({{"step": step, "total": total,
                          "shards": nshards}}))
""")


def _run(phase, n, d, m, ckpt):
    prog = _PROG.format(phase=phase, n=n, d=d, m=m, ckpt=ckpt)
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             # virtual-device mesh => host platform; without this the child
             # probes for real TPUs (minutes of metadata retries on CI hosts)
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
    assert res.returncode == 0, res.stderr[-1500:]
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_restore_onto_larger_and_smaller_mesh(tmp_path):
    ckpt = str(tmp_path / "ck")
    _run("save", 4, 2, 2, ckpt)                      # written on 4 devices
    out8 = _run("restore", 8, 4, 2, ckpt)            # grow to 8
    assert out8["step"] == 7
    assert out8["total"] == float(sum(range(128)) + sum(range(16)))
    assert out8["shards"] == 8
    out2 = _run("restore", 2, 2, 1, ckpt)            # shrink to 2
    assert out2["total"] == out8["total"]
    assert out2["shards"] == 2
