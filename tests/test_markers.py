"""Marker hygiene: the tier router (``-m`` expressions) only works if every
marker a test module uses is registered in ``tests/conftest.py`` — pytest
merely warns on unknown markers, so a typo silently drops a module out of
its tier. ``scripts/check_markers.py`` is the enforcement; this runs it on
the real suite and proves it catches both typo'd uses and stale conftests.
"""
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

from check_markers import (BUILTIN_MARKERS, declared_markers, find_offenders,
                           main, used_markers)


def test_repo_test_suite_uses_only_declared_markers(capsys):
    assert find_offenders(REPO / "tests") == []
    assert main([str(REPO / "tests")]) == 0
    assert "ok:" in capsys.readouterr().out


def test_conftest_declarations_are_parsed():
    declared = declared_markers(REPO / "tests" / "conftest.py")
    assert {"slow", "shard", "writer", "compact", "drift"} <= declared


def test_undeclared_marker_is_caught(tmp_path, capsys):
    (tmp_path / "conftest.py").write_text(
        'def pytest_configure(config):\n'
        '    config.addinivalue_line("markers", "good: a declared marker")\n')
    (tmp_path / "test_bad.py").write_text(
        'import pytest\n'
        'pytestmark = pytest.mark.shard_typo\n'
        '@pytest.mark.good\n'
        '@pytest.mark.parametrize("x", [1])\n'
        'def test_x(x):\n'
        '    pass\n')
    assert find_offenders(tmp_path) == [("test_bad.py", "shard_typo")]
    assert main([str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "shard_typo" in out and "test_bad.py" in out


def test_used_markers_sees_all_spellings(tmp_path):
    p = tmp_path / "test_spellings.py"
    p.write_text(
        'import pytest\n'
        'pytestmark = [pytest.mark.a, pytest.mark.b]\n'
        '@pytest.mark.c\n'
        'def test_x():\n'
        '    pass\n'
        'CASES = [pytest.param(1, marks=pytest.mark.d)]\n')
    assert used_markers(p) == {"a", "b", "c", "d"}
    assert "parametrize" in BUILTIN_MARKERS
