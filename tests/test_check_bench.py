"""Regression-gate tests: ``benchmarks/check.py`` fed synthetic
baseline/current trajectory pairs, the ``scripts/check_bench.py`` CLI, the
``benchmarks.run --check`` wiring, and (behind the ``bench`` marker) the
real quick-mode gate against the committed ``BENCH_*.json`` baseline."""
import json
import pathlib
import sys

import pytest

from benchmarks import check
from benchmarks import common
from benchmarks.run import SUITES, main

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

import check_bench  # noqa: E402


def doc(suites, quick=True):
    """Minimal trajectory document around {suite: [(name, us, derived)]}."""
    return {
        "schema": 1,
        "config": {"quick": quick},
        "suites": {
            suite: [{"name": name, "us_per_call": us, "derived": derived,
                     "qps": derived.get("qps")}
                    for name, us, derived in rows]
            for suite, rows in suites.items()
        },
    }


BASE = doc({
    "engine": [("engine_q64", 100.0, {"qps": 640.0}),
               ("engine_q8", 50.0, {"qps": 160.0})],
    "kernels": [("kernel_bitmap_and", 1500.0,
                 {"achieved_gbps": 2.5, "roofline_frac": 0.2})],
})


def test_identical_run_passes():
    deltas = check.compare(BASE, BASE)
    assert [d.status for d in deltas] == ["ok"] * 3
    assert check.failures(deltas) == []


def test_small_drop_within_tolerance_passes():
    cur = doc({"engine": [("engine_q64", 110.0, {"qps": 580.0}),
                          ("engine_q8", 50.0, {"qps": 160.0})],
               "kernels": [("kernel_bitmap_and", 1500.0,
                            {"achieved_gbps": 2.1})]})
    assert check.failures(check.compare(BASE, cur)) == []


def test_qps_drop_past_tolerance_fails():
    cur = doc({"engine": [("engine_q64", 300.0, {"qps": 213.0}),
                          ("engine_q8", 50.0, {"qps": 160.0})],
               "kernels": [("kernel_bitmap_and", 1500.0,
                            {"achieved_gbps": 2.5})]})
    bad = check.failures(check.compare(BASE, cur))
    assert [(d.suite, d.name, d.field) for d in bad] == \
        [("engine", "engine_q64", "qps")]
    assert bad[0].drop_frac == pytest.approx(1 - 213 / 640)


def test_gbps_drop_past_tolerance_fails():
    cur = doc({"engine": [("engine_q64", 100.0, {"qps": 640.0}),
                          ("engine_q8", 50.0, {"qps": 160.0})],
               "kernels": [("kernel_bitmap_and", 4000.0,
                            {"achieved_gbps": 0.9})]})
    bad = check.failures(check.compare(BASE, cur))
    assert [(d.name, d.field) for d in bad] == \
        [("kernel_bitmap_and", "achieved_gbps")]


def test_improvement_passes():
    cur = doc({"engine": [("engine_q64", 50.0, {"qps": 1280.0}),
                          ("engine_q8", 25.0, {"qps": 320.0})],
               "kernels": [("kernel_bitmap_and", 700.0,
                            {"achieved_gbps": 5.0})]})
    assert check.failures(check.compare(BASE, cur)) == []


def test_partial_only_run_skips_missing_suite():
    """A --only kernels run must gate kernels and skip (not fail) engine."""
    cur = doc({"kernels": [("kernel_bitmap_and", 1500.0,
                            {"achieved_gbps": 2.5})]})
    deltas = check.compare(BASE, cur)
    assert check.failures(deltas) == []
    skipped = [d for d in deltas if d.status == "skipped"]
    assert {(d.suite, d.name) for d in skipped} == \
        {("engine", "engine_q64"), ("engine", "engine_q8")}


def test_new_suite_and_new_row_pass():
    cur = doc({"engine": [("engine_q64", 100.0, {"qps": 640.0}),
                          ("engine_q8", 50.0, {"qps": 160.0}),
                          ("engine_q256", 400.0, {"qps": 640.0})],
               "kernels": [("kernel_bitmap_and", 1500.0,
                            {"achieved_gbps": 2.5})],
               "soak": [("soak_1m", 9.0, {"qps": 111.0})]})
    deltas = check.compare(BASE, cur)
    assert check.failures(deltas) == []
    assert {(d.suite, d.name) for d in deltas if d.status == "new"} == \
        {("engine", "engine_q256"), ("soak", "soak_1m")}


def test_vanished_gated_metric_fails():
    """The row still runs but no longer reports qps (or it went non-finite
    and was sanitized to null) — that hides a regression, so it IS one."""
    cur = doc({"engine": [("engine_q64", 100.0, {"qps": None}),
                          ("engine_q8", 50.0, {"qps": 160.0})],
               "kernels": [("kernel_bitmap_and", 1500.0,
                            {"achieved_gbps": 2.5})]})
    bad = check.failures(check.compare(BASE, cur))
    assert [(d.name, d.field, d.cur) for d in bad] == \
        [("engine_q64", "qps", None)]


def test_row_tolerance_override_bare_and_qualified():
    cur = doc({"engine": [("engine_q64", 300.0, {"qps": 400.0}),
                          ("engine_q8", 50.0, {"qps": 160.0})],
               "kernels": [("kernel_bitmap_and", 1500.0,
                            {"achieved_gbps": 2.5})]})
    assert check.failures(check.compare(BASE, cur))  # default 20%: fails
    for key in ("engine_q64", "engine/engine_q64"):
        deltas = check.compare(BASE, cur, row_tolerance={key: 0.5})
        assert check.failures(deltas) == [], key
    # qualified key wins over bare
    deltas = check.compare(BASE, cur, row_tolerance={
        "engine_q64": 0.5, "engine/engine_q64": 0.1})
    assert check.failures(deltas)


def test_default_row_tolerances_apply_and_caller_wins():
    """Known-noisy rows ship a committed loose tolerance; any caller key —
    bare (merged over the default) or qualified — takes precedence."""
    assert check.DEFAULT_ROW_TOLERANCES["drift_adaptive"] > \
        check.DEFAULT_TOLERANCE
    base = doc({"drift": [("drift_adaptive", 50.0, {"qps": 1000.0})]})
    cur = doc({"drift": [("drift_adaptive", 90.0, {"qps": 560.0})]})
    # a 44% drop passes under the committed 55% default...
    assert check.failures(check.compare(base, cur, tolerance=0.2)) == []
    # ...but the caller can still tighten it, with either key shape
    for key in ("drift_adaptive", "drift/drift_adaptive"):
        deltas = check.compare(base, cur, row_tolerance={key: 0.2})
        assert check.failures(deltas), key


def test_merge_bench_takes_elementwise_floor(tmp_path):
    """The committed baseline is the slowest-of-N merge: min of each gated
    metric, max us_per_call — a lucky-fast single sweep must not become the
    bar every honest run gets compared against."""
    import merge_bench
    a = doc({"s": [("r", 10.0, {"qps": 1000.0, "note": "x"})]})
    b = doc({"s": [("r", 14.0, {"qps": 800.0}), ("extra", 1.0, {"qps": 5.0})]})
    merged = merge_bench.merge([a, b])
    row = merged["suites"]["s"][0]
    assert row["us_per_call"] == 14.0
    assert row["qps"] == 800.0 and row["derived"]["qps"] == 800.0
    assert row["derived"]["note"] == "x"        # non-gated fields kept
    assert merged["config"]["merged_of"] == 2
    # rows beyond the first document are dropped (first run is the spine)
    assert [r["name"] for r in merged["suites"]["s"]] == ["r"]
    # CLI round trip through the strict loader
    pa, pb, out = tmp_path / "a.json", tmp_path / "b.json", tmp_path / "m.json"
    pa.write_text(json.dumps(a)), pb.write_text(json.dumps(b))
    assert merge_bench.main([str(pa), str(pb), "-o", str(out)]) == 0
    assert check.load_trajectory(str(out))["config"]["merged_of"] == 2


def test_parse_row_tolerances():
    assert check.parse_row_tolerances(["a=0.5", "s/b=0.1"]) == \
        {"a": 0.5, "s/b": 0.1}
    assert check.parse_row_tolerances([]) == {}
    with pytest.raises(ValueError):
        check.parse_row_tolerances(["nonsense"])
    with pytest.raises(ValueError):
        check.parse_row_tolerances(["a=notafloat"])


def test_boolean_derived_is_not_gated():
    """bools must not be treated as numeric gated values."""
    base = doc({"s": [("r", 1.0, {"qps": True})]})
    cur = doc({"s": [("r", 1.0, {"qps": False})]})
    assert check.compare(base, cur) == []


def test_load_trajectory_rejects_malformed(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    with pytest.raises(check.BaselineError):
        check.load_trajectory(str(p))
    p.write_text(json.dumps({"schema": 1}))           # no suites map
    with pytest.raises(check.BaselineError):
        check.load_trajectory(str(p))
    p.write_text(json.dumps({"suites": {"s": [{"name": "r"}]}}))  # no us
    with pytest.raises(check.BaselineError):
        check.load_trajectory(str(p))
    with pytest.raises(check.BaselineError):
        check.load_trajectory(str(tmp_path / "missing.json"))


def test_load_trajectory_rejects_nan_constants(tmp_path):
    """A baseline with a literal NaN must be refused, not compared: NaN
    comparisons are neither pass nor fail."""
    p = tmp_path / "nan.json"
    p.write_text('{"suites": {"s": [{"name": "r", "us_per_call": NaN}]}}')
    with pytest.raises(check.BaselineError, match="non-strict"):
        check.load_trajectory(str(p))


def test_delta_table_reports_every_row_and_summary():
    cur = doc({"engine": [("engine_q64", 300.0, {"qps": 213.0}),
                          ("engine_q8", 50.0, {"qps": 160.0})],
               "kernels": [("kernel_bitmap_and", 1500.0,
                            {"achieved_gbps": 2.5})]})
    table = check.delta_table(check.compare(BASE, cur))
    assert "engine/engine_q64" in table and "FAIL" in table
    assert "-66.7%" in table
    assert "1 fail" in table and "2 ok" in table
    quiet = check.delta_table(check.compare(BASE, cur), verbose=False)
    assert "engine_q8" not in quiet and "FAIL" in quiet


def test_coverage_problems():
    full = doc({"engine": [("e", 1.0, {"qps": 2.0})],
                "cost_model": [("c", 0.0, {"estimated": 5})]})
    assert check.coverage_problems(full, {"engine", "cost_model"}) == []
    # registered suite absent from the trajectory
    probs = check.coverage_problems(full, {"engine", "cost_model", "soak"})
    assert len(probs) == 1 and "soak" in probs[0]
    # timed suite without any gated row
    dodgy = doc({"engine": [("e", 1.0, {"speedup": 2.0})]})
    probs = check.coverage_problems(dodgy, {"engine"})
    assert len(probs) == 1 and "dodge" in probs[0]
    # untimed (model-only) suites are exempt
    assert check.coverage_problems(
        doc({"cost_model": [("c", 0.0, {"estimated": 5})]}),
        {"cost_model"}) == []


# ---------------------------------------------------------------------------
# CLI + run.py wiring
# ---------------------------------------------------------------------------

def _write(tmp_path, name, document):
    p = tmp_path / name
    p.write_text(json.dumps(document))
    return str(p)


def test_check_bench_cli_pass_fail_malformed(tmp_path, capsys):
    base = _write(tmp_path, "base.json", BASE)
    good = _write(tmp_path, "good.json", BASE)
    assert check_bench.main([base, good]) == 0
    bad_doc = doc({"engine": [("engine_q64", 300.0, {"qps": 213.0})],
                   "kernels": [("kernel_bitmap_and", 1500.0,
                                {"achieved_gbps": 2.5})]})
    bad = _write(tmp_path, "bad.json", bad_doc)
    assert check_bench.main([base, bad]) == 1
    out = capsys.readouterr().out
    assert "engine/engine_q64" in out and "FAIL" in out
    malformed = _write(tmp_path, "malformed.json", {"schema": 1})
    assert check_bench.main([malformed, good]) == 2
    assert check_bench.main([base, bad, "--row-tolerance",
                             "engine_q64=0.9"]) == 0


def test_check_bench_cli_coverage(tmp_path, capsys):
    """--coverage audits a trajectory against the real registry."""
    rows = {suite: [(f"{suite}_row", 1.0, {"qps": 10.0})] for suite in SUITES}
    full = _write(tmp_path, "full.json", doc(rows))
    assert check_bench.main([full, "--coverage"]) == 0
    del rows["kernels"]
    partial = _write(tmp_path, "partial.json", doc(rows))
    assert check_bench.main([partial, "--coverage"]) == 1
    assert "kernels" in capsys.readouterr().out


def test_run_main_check_gates_stub_suite(tmp_path, monkeypatch, capsys):
    qps = {"val": 100.0}

    def stub(quick):
        common.emit("stub_metric", 42.0, qps=qps["val"])

    monkeypatch.setitem(SUITES, "stub", stub)
    base = tmp_path / "base.json"
    assert main(["--only", "stub", "--json", str(base)]) == 0

    # same speed: gate passes
    assert main(["--only", "stub", "--check", str(base)]) == 0
    # artificially slowed: gate fails with a per-row delta report
    qps["val"] = 10.0
    assert main(["--only", "stub", "--check", str(base)]) == 1
    assert "stub/stub_metric" in capsys.readouterr().out
    # ... unless this row is allowed to be that noisy
    assert main(["--only", "stub", "--check", str(base),
                 "--row-tolerance", "stub_metric=0.95"]) == 0
    # malformed baseline: distinct exit code, no benches run
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert main(["--only", "stub", "--check", str(bad)]) == 2


# ---------------------------------------------------------------------------
# the real thing (bench tier): quick kernels run vs committed baseline
# ---------------------------------------------------------------------------

@pytest.mark.bench
def test_quick_gate_against_committed_baseline(tmp_path, capsys):
    """End-to-end: a fresh quick kernels-suite run must gate cleanly against
    the committed BENCH_*.json. Tolerance is looser than the CLI default —
    this tier proves the wiring and catches gross regressions; CI boxes are
    noisy neighbors."""
    baselines = sorted(REPO.glob("BENCH_*.json"))
    assert baselines, "no committed BENCH_*.json trajectory in the repo root"
    with_kernels = [p for p in baselines
                    if "kernels" in json.loads(p.read_text())["suites"]]
    assert with_kernels, "no committed baseline covers the kernels suite"
    out = tmp_path / "fresh.json"
    rc = main(["--quick", "--only", "kernels", "--json", str(out),
               "--check", str(with_kernels[-1]), "--tolerance", "0.5"])
    report = capsys.readouterr().out
    assert rc == 0, f"quick kernels gate regressed:\n{report}"
    # all five kernels reported, each with the roofline fields
    fresh = json.loads(out.read_text())
    rows = {r["name"]: r for r in fresh["suites"]["kernels"]}
    assert len(rows) == 5
    for name, row in rows.items():
        assert row["derived"]["achieved_gbps"] > 0, name
        assert row["derived"]["roofline_frac"] > 0, name
