"""Optimizer, data pipeline, checkpointing, and fault-tolerance tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpointing import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.core.predicate import Predicate
from repro.data import HippoDataPipeline, synthesize_corpus
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.runtime import StepWatchdog, resilient_loop


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray([1.0])}


@pytest.mark.slow  # 300 un-jitted optimizer steps
def test_adamw_converges_quadratic():
    params = _quad_params()
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, lr=0.05,
                                        weight_decay=0.0)
    assert float(loss(params)) < 1e-3


@pytest.mark.parametrize("mdt", ["float32", "bfloat16"])
def test_adamw_moment_dtype(mdt):
    params = _quad_params()
    state = adamw_init(params, moment_dtype=mdt)
    assert state.mu["w"].dtype == jnp.dtype(mdt)
    g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
    new_params, new_state, m = adamw_update(g, state, params, lr=0.1)
    assert new_state.mu["w"].dtype == jnp.dtype(mdt)
    assert np.isfinite(float(m["grad_norm"]))
    assert not np.allclose(np.asarray(new_params["w"]), np.asarray(params["w"]))


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e9)}
    _, _, m = adamw_update(huge, state, params, lr=0.1, max_grad_norm=1.0)
    assert float(m["grad_norm"]) > 1e8  # reported pre-clip


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1e-3, warmup_steps=10, total_steps=100))
           for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[99] < lrs[50] < lrs[10]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus():
    return synthesize_corpus(num_seqs=2000, seq_len=33, vocab_size=128,
                             page_card=32, seed=0)


def test_hippo_selection_exact(corpus):
    pipe = HippoDataPipeline.create(corpus, Predicate.between(0.75, 1.0))
    want = np.flatnonzero((corpus.quality >= 0.75) & (corpus.quality <= 1.0))
    np.testing.assert_array_equal(np.sort(pipe.selected_ids), want)
    # the index pruned pages (quality correlates with storage order weakly,
    # but at minimum it must not inspect more than all pages)
    assert pipe.pages_inspected <= corpus.table.num_pages


def test_deterministic_step_batches(corpus):
    pipe = HippoDataPipeline.create(corpus, Predicate.between(0.5, 1.0), seed=7)
    a = pipe.get_batch(12, 8)
    b = pipe.get_batch(12, 8)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = pipe.get_batch(13, 8)
    assert not np.array_equal(a["inputs"], c["inputs"])
    # labels are inputs shifted by one
    np.testing.assert_array_equal(a["inputs"][:, 1:], a["labels"][:, :-1])


def test_prefetch_iterator(corpus):
    pipe = HippoDataPipeline.create(corpus, Predicate.between(0.0, 1.0))
    seen = list(pipe.iter_batches(start_step=5, num_steps=4, batch_size=4))
    assert [s for s, _ in seen] == [5, 6, 7, 8]
    ref = pipe.get_batch(6, 4)
    np.testing.assert_array_equal(seen[1][1]["inputs"], ref["inputs"])


def test_selection_filters_domains(corpus):
    pipe = HippoDataPipeline.create(corpus, Predicate.between(0.75, 1.0))
    doms = corpus.domain[pipe.selected_ids]
    assert set(np.unique(doms)) == {3}   # quality = 0.25*domain + U(0,0.25)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v)}, "step": jnp.int32(v)}


def test_save_restore_roundtrip(tmp_path):
    save_checkpoint(tmp_path, 3, _state(1.5))
    step, tree = restore_checkpoint(tmp_path, treedef_like=_state())
    assert step == 3
    np.testing.assert_allclose(np.asarray(tree["params"]["w"]), 1.5)


def test_commit_protocol_ignores_partial(tmp_path):
    save_checkpoint(tmp_path, 1, _state(1.0))
    # simulate a crash mid-write: step_2 exists but has no COMMITTED sentinel
    (tmp_path / "step_2").mkdir()
    (tmp_path / "step_2" / "manifest.json").write_text("{}")
    step, _ = restore_checkpoint(tmp_path, treedef_like=_state())
    assert step == 1


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    mgr.wait()
    step, tree = mgr.restore_latest(_state())
    assert step == 4
    kept = sorted(d.name for d in tmp_path.iterdir())
    assert kept == ["step_3", "step_4"]


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, _state())
    with pytest.raises(ValueError, match="structure mismatch"):
        restore_checkpoint(tmp_path, treedef_like={"only": jnp.zeros(1)})


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_resilient_loop_recovers_from_injected_faults(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    fail_at = {7, 13}

    def step_fn(step, state):
        if step in fail_at:
            fail_at.discard(step)          # fail once per step
            raise RuntimeError("injected device failure")
        return {"acc": state["acc"] + step}

    def save_fn(step, state):
        mgr.save(step, {"acc": jnp.float32(state["acc"]), "step": jnp.int32(step)})

    def restore_fn():
        step, tree = mgr.restore_latest({"acc": jnp.float32(0), "step": jnp.int32(0)})
        return int(tree["step"]), {"acc": float(tree["acc"])}

    state = {"acc": 0.0}
    save_fn(0, state)
    final, stats = resilient_loop(
        num_steps=20, step_fn=step_fn, state=state, save_fn=save_fn,
        restore_fn=restore_fn, checkpoint_every=5)
    assert stats.failures == 2 and stats.restores == 2
    assert final["acc"] == sum(range(20))  # replay produced the exact result


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=2.0, min_samples=3)
    for s in range(6):
        assert not wd.observe(s, 1.0)
    assert wd.observe(6, 5.0)
    assert wd.flagged[0][0] == 6


@pytest.mark.slow  # 300 un-jitted optimizer steps
def test_adamw_int8_moments_converge():
    """8-bit-Adam moments: quantized-state optimizer still converges and the
    state really is int8 (the 400B dry-run cell depends on this path)."""
    params = _quad_params()
    state = adamw_init(params, moment_dtype="int8")
    assert state.mu["w"]["q"].dtype == jnp.int8
    assert state.mu["w"]["s"].shape == (1,)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, lr=0.05,
                                        weight_decay=0.0)
    assert float(loss(params)) < 1e-2
    assert state.mu["w"]["q"].dtype == jnp.int8


def test_adamw_int8_tracks_fp32():
    """Quantized moments stay close to the fp32 trajectory over short runs."""
    import numpy as np
    pa = _quad_params()
    pb = _quad_params()
    sa = adamw_init(pa)
    sb = adamw_init(pb, moment_dtype="int8")
    loss = lambda p: jnp.sum((p["w"] - 1.0) ** 2)
    for _ in range(20):
        pa, sa, _ = adamw_update(jax.grad(loss)(pa), sa, pa, lr=0.01,
                                 weight_decay=0.0)
        pb, sb, _ = adamw_update(jax.grad(loss)(pb), sb, pb, lr=0.01,
                                 weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]),
                               rtol=0.05, atol=0.02)
