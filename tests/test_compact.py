"""Compact/dense/sharded equivalence sweep for the gather search pipeline.

The contract under test: ``search_compact_many`` counts and row ids are
bit-identical to ``search_many`` wherever ``truncated`` is False — across
selectivities, shard counts, and staged-overlay states — and the engine's
compact mode (the default) serves exactly what dense mode serves, falling
back to the dense-cost cap on truncation but never to a wrong answer.

Marked ``compact`` (see tests/conftest.py): the sweep compiles many distinct
(max_selected, top_k) trace shapes, so it is split out of the fast inner
loop like the ``shard``/``writer`` suites. Run alone with ``-m compact``.
"""
import numpy as np
import pytest

from repro.core import index as hix
from repro.core.hippo import HippoIndex
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate, intervals, to_bucket_bitmaps
from repro.runtime.engine import QueryEngine
from repro.runtime.writer import MaintenanceWriter
from repro.storage.table import PagedTable

pytestmark = pytest.mark.compact

PAGE_CARD = 8
TOP_K = 64


def brute_ids(table: PagedTable, lo: float, hi: float) -> np.ndarray:
    """Qualifying global row ids by brute force, ascending."""
    keys = table.keys[: table.num_pages].reshape(-1)
    valid = table.valid[: table.num_pages].reshape(-1)
    lo, hi = max(lo, -3.4e38), min(hi, 3.4e38)
    return np.flatnonzero(valid & (keys >= lo) & (keys <= hi)).astype(np.int64)


def workload(rng, widths=(2.0, 20.0, 200.0), per_width=3):
    """Ranges at three selectivity decades plus the edge predicates."""
    preds = []
    for w in widths:
        for _ in range(per_width):
            lo = float(rng.uniform(0, 1000 - w))
            preds.append(Predicate.between(lo, lo + w))
    preds += [
        Predicate(lo=5.0, hi=1.0),          # empty interval
        Predicate.between(2000, 3000),      # out of domain
        Predicate.between(-1e30, 1e30),     # full table
        Predicate.equality(float(rng.uniform(0, 1000))),
    ]
    return preds


def make_pair(values, num_shards):
    t1 = PagedTable.from_values(values.copy(), page_card=PAGE_CARD,
                                spare_pages=64)
    idx = HippoIndex.create(t1, resolution=64, density=0.25)
    t2 = PagedTable.from_values(values.copy(), page_card=PAGE_CARD,
                                spare_pages=256)
    sidx = ShardedHippoIndex.create(t2, num_shards=num_shards, resolution=64,
                                    density=0.25)
    return idx, sidx


# ---------------------------------------------------------------------------
# Core equivalence: compact vs dense vs sharded, swept over slab capacities
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["sorted", "uniform"])
@pytest.mark.parametrize("num_shards", [1, 3])
def test_compact_counts_and_row_ids_match_dense_where_untruncated(dist, num_shards):
    rng = np.random.default_rng({"sorted": 0, "uniform": 1}[dist] * 10
                                + num_shards)
    values = rng.uniform(0, 1000, 2000)
    if dist == "sorted":
        values = np.sort(values)
    idx, sidx = make_pair(values, num_shards)
    preds = workload(rng)
    qbms = to_bucket_bitmaps(preds, idx.state.histogram)
    los, his = intervals(preds)
    dense = hix.search_many(idx.state, qbms, idx.table.device_keys(),
                            idx.table.device_valid(), los, his)
    want_counts = np.asarray(dense.counts)
    want_ids = [brute_ids(idx.table, *p.selectivity_interval())[:TOP_K]
                for p in preds]
    full = idx.table.num_pages
    for cap in (4, 32, full):
        res = idx.search_compact_batch(preds, max_selected=cap, top_k=TOP_K)
        trunc = np.asarray(res.truncated)
        counts = np.asarray(res.counts)
        assert (counts[~trunc] == want_counts[~trunc]).all(), cap
        assert (counts <= want_counts).all()     # truncation only ever loses
        np.testing.assert_array_equal(np.asarray(res.pages_inspected),
                                      np.asarray(dense.pages_inspected))
        np.testing.assert_array_equal(np.asarray(res.entries_matched),
                                      np.asarray(dense.entries_matched))
        for q in np.flatnonzero(~trunc):
            ids = np.asarray(res.row_ids[q])
            np.testing.assert_array_equal(ids[ids >= 0], want_ids[q], (cap, q))
        # the sharded gather agrees bit-for-bit where neither truncated
        sres = sidx.search_compact_batch(preds, max_selected=cap, top_k=TOP_K)
        strunc = np.asarray(sres.truncated)
        both = ~trunc & ~strunc
        np.testing.assert_array_equal(np.asarray(sres.counts)[both],
                                      want_counts[both])
        for q in np.flatnonzero(both):
            np.testing.assert_array_equal(np.asarray(sres.row_ids[q]),
                                          np.asarray(res.row_ids[q]), (cap, q))
    # at the never-truncating cap nothing may be flagged
    res = idx.search_compact_batch(preds, max_selected=full, top_k=0)
    assert not np.asarray(res.truncated).any()
    sres = sidx.search_compact_batch(
        preds, max_selected=sidx.spec.pages_per_shard, top_k=0)
    assert not np.asarray(sres.truncated).any()
    np.testing.assert_array_equal(np.asarray(sres.counts), want_counts)


def test_compact_through_maintenance_and_staged_overlay():
    """Compact counts stay bit-identical to the dense path through inserts,
    deletes+vacuum, and — on the sharded index — through the writer's staged
    overlay (rows pending in queues count, without ever appearing in row
    ids, exactly like the dense path's page_mask)."""
    rng = np.random.default_rng(7)
    values = np.sort(rng.uniform(0, 1000, 1500))
    idx, sidx = make_pair(values, 2)
    preds = workload(rng, widths=(5.0, 100.0), per_width=2)

    # maintenance on the unsharded index: eager inserts + delete/vacuum
    for v in rng.uniform(0, 1000, 20):
        idx.insert(float(v))
    idx.table.delete_where(200, 260)
    idx.vacuum()
    dense = idx.search_batch(preds)
    res = idx.search_compact_batch(preds, max_selected=idx.table.num_pages,
                                   top_k=TOP_K)
    np.testing.assert_array_equal(np.asarray(res.counts),
                                  np.asarray(dense.counts))
    for q, p in enumerate(preds):
        ids = np.asarray(res.row_ids[q])
        np.testing.assert_array_equal(
            ids[ids >= 0], brute_ids(idx.table, *p.selectivity_interval())[:TOP_K])

    # staged overlay on the sharded index
    writer = MaintenanceWriter(sidx)
    staged = rng.uniform(0, 1000, 15)
    for v in staged:
        writer.write(float(v))
    cap = sidx.spec.pages_per_shard
    res = sidx.search_compact_batch(preds, max_selected=cap, top_k=TOP_K)
    want = np.asarray(sidx.search_batch(preds).counts)      # staged-aware dense
    np.testing.assert_array_equal(np.asarray(res.counts), want)
    # row ids exclude staged rows: they equal the table-only brute force
    for q, p in enumerate(preds):
        ids = np.asarray(res.row_ids[q])
        np.testing.assert_array_equal(
            ids[ids >= 0],
            brute_ids(sidx.table, *p.selectivity_interval())[:TOP_K])
    # after the drain the staged rows land in pages (and so in row ids)
    writer.flush()
    res = sidx.search_compact_batch(preds, max_selected=cap, top_k=TOP_K)
    np.testing.assert_array_equal(np.asarray(res.counts),
                                  np.asarray(sidx.search_batch(preds).counts))
    for q, p in enumerate(preds):
        ids = np.asarray(res.row_ids[q])
        np.testing.assert_array_equal(
            ids[ids >= 0],
            brute_ids(sidx.table, *p.selectivity_interval())[:TOP_K])


# ---------------------------------------------------------------------------
# Engine compact mode: ladder, fallback, row-id payloads
# ---------------------------------------------------------------------------

def test_engine_compact_mode_is_default_and_matches_dense():
    rng = np.random.default_rng(11)
    idx, sidx = make_pair(np.sort(rng.uniform(0, 1000, 2000)), 2)
    preds = workload(rng)
    dense = QueryEngine(idx, batch=8, mode="dense").run_all(preds)
    for target in (idx, sidx):
        engine = QueryEngine(target, batch=8)
        assert engine.mode == "compact"
        np.testing.assert_array_equal(engine.run_all(preds), dense)
        assert engine.stats.compact_batches > 0
        assert 0 < engine.stats.selected_page_ratio <= 1.0


def test_engine_compact_fallback_never_wrong():
    """A deliberately tiny initial bucket forces truncation: the per-query
    fallback must keep every count bit-identical to dense mode while the
    adaptive bucket widens for later batches."""
    rng = np.random.default_rng(13)
    idx, sidx = make_pair(np.sort(rng.uniform(0, 1000, 2000)), 2)
    preds = workload(rng)
    dense = QueryEngine(idx, batch=8, mode="dense").run_all(preds)
    for target in (idx, sidx):
        engine = QueryEngine(target, batch=8, compact_bucket=1, top_k=8)
        first_bucket = engine._compact_bucket
        np.testing.assert_array_equal(engine.run_all(preds), dense)
        assert engine.stats.compact_fallbacks > 0      # the ladder was walked
        assert engine.stats.compact_hits > 0
        assert engine._compact_bucket > first_bucket   # and the bucket adapted
        # a replay is served without fallbacks at the adapted bucket
        before = engine.stats.compact_fallbacks
        np.testing.assert_array_equal(engine.run_all(preds), dense)
        assert engine.stats.compact_fallbacks == before


def test_engine_row_id_payloads_match_brute_force():
    rng = np.random.default_rng(17)
    idx, _ = make_pair(np.sort(rng.uniform(0, 1000, 1200)), 1)
    engine = QueryEngine(idx, batch=4, top_k=16)
    preds = workload(rng, widths=(3.0, 50.0), per_width=2)
    tickets = [engine.submit(p) for p in preds]
    engine.drain()
    for t, p in zip(tickets, preds):
        want = brute_ids(idx.table, *p.selectivity_interval())
        assert t.count == want.size
        np.testing.assert_array_equal(t.row_ids, want[:16])
        # the payload decodes back to in-range key values
        vals = idx.table.row_values(t.row_ids)
        lo, hi = p.selectivity_interval()
        assert ((vals >= lo) & (vals <= hi)).all()


def test_engine_mode_validation():
    rng = np.random.default_rng(19)
    idx, sidx = make_pair(rng.uniform(0, 1000, 300), 2)
    with pytest.raises(ValueError, match="mode"):
        QueryEngine(idx, mode="bogus")
    with pytest.raises(ValueError, match="compact"):
        QueryEngine(sidx, mode="compact", sharded=True)
    with pytest.raises(ValueError, match="top_k"):
        QueryEngine(idx, mode="dense", top_k=4)
    with pytest.raises(ValueError, match="compact_bucket"):
        QueryEngine(idx, compact_bucket=0)
    # explicit sharded=True still resolves to the routed dense path
    routed = QueryEngine(sidx, sharded=True)
    assert routed.mode == "dense" and routed.sharded
