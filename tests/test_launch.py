"""Launch-layer tests: mesh construction, sharding specs, and a small-mesh
lower+compile of each step kind (subprocess with 8 virtual devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.launch.shardings import param_spec
from jax.sharding import PartitionSpec as P


def test_param_spec_rules():
    cfg = get_config("llama4-maverick-400b-a17b")

    class K:  # fake path keys
        def __init__(self, key):
            self.key = key

    # EP for divisible experts
    spec = param_spec(cfg, (K("units"), K("b1_moe"), K("moe"), K("w_gate")), None)
    assert spec == P(None, "model", "data", None)
    cfg2 = get_config("qwen2-moe-a2.7b")  # 60 experts -> TP inside expert
    spec = param_spec(cfg2, (K("units"), K("b0_moe"), K("moe"), K("w_gate")), None)
    assert spec == P(None, None, "data", "model")
    spec = param_spec(cfg2, (K("units"), K("b0_moe"), K("moe"), K("w_down")), None)
    assert spec == P(None, None, "model", "data")
    # shared experts are dense ffn, not expert-sharded
    spec = param_spec(cfg2, (K("units"), K("b0_moe"), K("moe"), K("shared"),
                             K("w_gate")), None)
    assert spec == P(None, "data", "model")
    # norms replicate
    assert param_spec(cfg, (K("units"), K("b0_attn"), K("norm1"), K("scale")),
                      None) == P()


_SMALL_MESH_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import get_config, SHAPES
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_mesh_compat
    from repro.launch.shardings import (make_opt_shardings,
        make_param_shardings, replicated, train_batch_shardings,
        tree_cache_shardings)

    mesh = make_mesh_compat((2, 4), ("data", "model"))
    cfg = get_config("{arch}").reduced(d_model=128, num_heads=4,
                                       num_kv_heads=4, head_dim=32,
                                       vocab_size=512, d_ff=256)
    out = {{}}
    with mesh:
        p_shape = steps_lib.params_shape(cfg)
        p_sh = make_param_shardings(cfg, mesh, p_shape)
        kind = "{kind}"
        if kind == "train":
            class Shape: seq_len=64; global_batch=8; kind="train"; name="t"
            o_shape = steps_lib.opt_state_shape(cfg, p_shape, "float32")
            o_sh = make_opt_shardings(cfg, mesh, o_shape)
            b_sh = train_batch_shardings(cfg, mesh, 8)
            specs = steps_lib.input_specs(cfg, Shape, "train")
            step = steps_lib.make_train_step(cfg, accum=2)
            c = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                        out_shardings=(p_sh, o_sh, None)
                        ).lower(p_shape, o_shape, specs).compile()
        else:
            class Shape: seq_len=64; global_batch=8; kind="decode"; name="d"
            c_shape = steps_lib.cache_shape(cfg, 8, 64)
            c_sh = tree_cache_shardings(cfg, mesh, c_shape, 8)
            tok_sh = train_batch_shardings(cfg, mesh, 8)["inputs"]
            specs = steps_lib.input_specs(cfg, Shape, "decode")
            step = steps_lib.make_decode_step(cfg)
            c = jax.jit(step,
                        in_shardings=(p_sh, c_sh, tok_sh, replicated(mesh)),
                        out_shardings=(None, c_sh)
                        ).lower(p_shape, c_shape, specs["tokens"],
                                specs["pos"]).compile()
        ca = c.cost_analysis() or {{}}
        if isinstance(ca, (list, tuple)):   # jax 0.4.x returns [dict]
            ca = ca[0] if ca else {{}}
        out["flops"] = float(ca.get("flops", 0))
        out["mem"] = c.memory_analysis().temp_size_in_bytes
    print(json.dumps(out))
""")


@pytest.mark.slow  # subprocess mesh + full lower/compile per arch
@pytest.mark.parametrize("arch,kind", [
    ("yi-6b", "train"),
    ("qwen2-moe-a2.7b", "train"),
    ("recurrentgemma-9b", "decode"),
    ("rwkv6-3b", "decode"),
])
def test_small_mesh_lower_compile(arch, kind):
    """The dry-run machinery works on an 8-device mesh for every step kind
    and block family (full 512-device run lives in repro.launch.dryrun)."""
    prog = _SMALL_MESH_PROG.format(arch=arch, kind=kind)
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             # virtual-device mesh => host platform; without this the child
             # probes for real TPUs (minutes of metadata retries on CI hosts)
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
