"""hippolint: golden seeded violations, suppression grammar, repo-clean gate.

Each pass is exercised against a known-bad snippet in a throwaway repo
layout and must report the violation at its exact file:line; the
end-to-end test then runs every pass over this repository's committed
tree and requires zero error findings — the static invariants
(lock discipline, crash consistency, jit stability, declared markers)
hold on every push, not just on the interleavings the fault tier
happens to sample.
"""
import pathlib
import sys
import textwrap

import pytest

from repro.analysis import PASSES, load_context, run_passes
from repro.analysis.base import SourceFile

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

import lint as lint_cli  # noqa: E402  (scripts/lint.py CLI)


def make_repo(tmp_path, sources):
    """Materialize {relpath: source} as a lintable repo layout."""
    for rel, text in sources.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return load_context(tmp_path)


def run_lint(tmp_path, sources, *names):
    ctx = make_repo(tmp_path, sources)
    return run_passes(ctx, {n: PASSES[n] for n in names})


def line_of(text, needle):
    """1-based line of the first line containing ``needle``."""
    for i, ln in enumerate(textwrap.dedent(text).splitlines(), 1):
        if needle in ln:
            return i
    raise AssertionError(f"snippet does not contain {needle!r}")


def only(findings, check):
    got = [f for f in findings if f.check == check]
    assert got, f"no {check!r} findings in {[f.render() for f in findings]}"
    return got


# ---------------------------------------------------------------------------
# locks pass
# ---------------------------------------------------------------------------

BAD_UNGUARDED = """\
    import threading

    class Worker:
        def __init__(self):
            self._lock = threading.Lock()
            self._count = 0
            self._t = threading.Thread(target=self._run)

        def _run(self):
            self._count += 1

        def read(self):
            return self._count
"""


def test_locks_unguarded_contended_attribute(tmp_path):
    findings = run_lint(tmp_path, {"src/mod.py": BAD_UNGUARDED}, "locks")
    [f] = only(findings, "locks")
    assert f.path == "src/mod.py"
    assert f.line == line_of(BAD_UNGUARDED, "self._count += 1")
    assert "Worker._count" in f.message and "guarded-by" in f.message


BAD_UNLOCKED_READ = """\
    import threading

    class Guarded:
        def __init__(self):
            self._lock = threading.Lock()
            self._n = 0  # guarded-by: _lock
            self._t = threading.Thread(target=self._run)

        def _run(self):
            with self._lock:
                self._n += 1

        def read(self):
            return self._n
"""


def test_locks_guarded_attr_read_outside_lock(tmp_path):
    findings = run_lint(tmp_path, {"src/mod.py": BAD_UNLOCKED_READ}, "locks")
    [f] = only(findings, "locks")
    assert f.line == line_of(BAD_UNLOCKED_READ, "return self._n")
    assert "read of Guarded._n" in f.message and "read()" in f.message


BAD_REQUIRES = """\
    import threading

    class Helper:
        def __init__(self):
            self._lock = threading.Lock()
            self._items = []  # guarded-by: _lock
            self._t = threading.Thread(target=self._run)

        def _run(self):
            with self._lock:
                self._drop()

        def _drop(self):  # requires-lock: _lock
            self._items.clear()

        def bad(self):
            self._drop()  # lock not held
"""


def test_locks_requires_lock_call_site(tmp_path):
    findings = run_lint(tmp_path, {"src/mod.py": BAD_REQUIRES}, "locks")
    [f] = only(findings, "locks")
    assert f.line == line_of(BAD_REQUIRES, "lock not held")
    assert "requires-lock" in f.message and "bad()" in f.message


def test_locks_single_threaded_class_is_exempt(tmp_path):
    src = BAD_UNGUARDED.replace(
        "        self._t = threading.Thread(target=self._run)\n", "")
    findings = run_lint(tmp_path, {"src/mod.py": src}, "locks")
    assert findings == [], [f.render() for f in findings]


# ---------------------------------------------------------------------------
# crash pass
# ---------------------------------------------------------------------------

BAD_RENAME = """\
    import os

    def commit(tmp, dst):
        with open(tmp, "wb") as f:
            f.write(b"payload")
        os.replace(tmp, dst)
"""


def test_crash_rename_without_fsync(tmp_path):
    findings = run_lint(tmp_path, {"src/mod.py": BAD_RENAME}, "crash")
    [f] = only(findings, "crash")
    assert f.line == line_of(BAD_RENAME, "os.replace")
    assert "fsync" in f.message


def test_crash_fsynced_rename_is_clean(tmp_path):
    src = BAD_RENAME.replace(
        "    os.replace",
        "        os.fsync(f.fileno())\n    os.replace")
    findings = run_lint(tmp_path, {"src/mod.py": src}, "crash")
    assert findings == [], [f.render() for f in findings]


BAD_ADMISSION = """\
    class Writer:
        def write(self, v):
            self.staged = v
            self.journal.append_insert(0, v)
"""


def test_crash_admission_before_wal_append(tmp_path):
    findings = run_lint(tmp_path, {"src/mod.py": BAD_ADMISSION}, "crash")
    [f] = only(findings, "crash")
    assert f.line == line_of(BAD_ADMISSION, "self.staged = v")
    assert "journal-before-admission" in f.message


FAKE_REGISTRY = """\
    def _register(*sites):
        return sites

    SITES = _register(
        "used.site",
        "stale.site",
    )
"""

BAD_SITES = """\
    from repro.runtime.faultinject import crashpoint

    def durable_mutation():
        crashpoint("used.site")
        crashpoint("rogue.site")
"""


def test_crash_site_registry_bijectivity(tmp_path):
    findings = run_lint(tmp_path, {
        "src/repro/runtime/faultinject.py": FAKE_REGISTRY,
        "src/repro/runtime/mutator.py": BAD_SITES,
    }, "crash")
    got = only(findings, "crash")
    assert len(got) == 2, [f.render() for f in got]
    rogue = next(f for f in got if "rogue.site" in f.message)
    assert rogue.path == "src/repro/runtime/mutator.py"
    assert rogue.line == line_of(BAD_SITES, "rogue.site")
    assert "not registered" in rogue.message
    stale = next(f for f in got if "stale.site" in f.message)
    assert stale.path == "src/repro/runtime/faultinject.py"
    assert stale.line == line_of(FAKE_REGISTRY, '"stale.site"')
    assert "no crashpoint() call site" in stale.message


def test_duplicate_site_registration_raises_at_import():
    from repro.runtime.faultinject import _register
    with pytest.raises(ValueError, match="duplicate crash site 'a.b'"):
        _register("a.b", "c.d", "a.b")
    assert _register("a.b", "c.d") == ("a.b", "c.d")


# ---------------------------------------------------------------------------
# jit pass
# ---------------------------------------------------------------------------

BAD_NONZERO = """\
    import jax
    import jax.numpy as jnp

    @jax.jit
    def where_positive(x):
        idx = jnp.nonzero(x > 0)
        return idx
"""


def test_jit_nonzero_without_size(tmp_path):
    findings = run_lint(tmp_path, {"src/mod.py": BAD_NONZERO}, "jit")
    [f] = only(findings, "jit")
    assert f.line == line_of(BAD_NONZERO, "jnp.nonzero")
    assert "size=" in f.message


def test_jit_sized_nonzero_is_clean(tmp_path):
    src = BAD_NONZERO.replace("jnp.nonzero(x > 0)",
                              "jnp.nonzero(x > 0, size=4)")
    findings = run_lint(tmp_path, {"src/mod.py": src}, "jit")
    assert findings == [], [f.render() for f in findings]


BAD_COERCE = """\
    import jax
    from functools import partial

    @partial(jax.jit, static_argnames=("k",))
    def topk_mask(x, k):
        n = int(x)
        for i in range(k):
            n += i
        if x > 0:
            n += 1
        return n
"""


def test_jit_coercion_and_control_flow_over_traced(tmp_path):
    findings = run_lint(tmp_path, {"src/mod.py": BAD_COERCE}, "jit")
    got = only(findings, "jit")
    lines = {f.line for f in got}
    assert line_of(BAD_COERCE, "int(x)") in lines
    assert line_of(BAD_COERCE, "if x > 0") in lines
    # range(k) is clean: k is a static argname
    assert line_of(BAD_COERCE, "range(k)") not in lines
    assert len(got) == 2, [f.render() for f in got]


def test_jit_shape_projections_are_static(tmp_path):
    src = """\
        import jax

        @jax.jit
        def rows(x):
            n = int(x.shape[0])
            if len(x) > 0:
                n += x.ndim
            return n
    """
    findings = run_lint(tmp_path, {"src/mod.py": src}, "jit")
    assert findings == [], [f.render() for f in findings]


BAD_JIT_LOOP = """\
    import jax

    def serve(batches, step):
        outs = []
        for b in batches:
            f = jax.jit(step)
            outs.append(f(b))
        return outs
"""


def test_jit_wrapper_inside_loop(tmp_path):
    findings = run_lint(tmp_path, {"src/mod.py": BAD_JIT_LOOP}, "jit")
    [f] = only(findings, "jit")
    assert f.line == line_of(BAD_JIT_LOOP, "jax.jit(step)")
    assert "inside a loop" in f.message


# ---------------------------------------------------------------------------
# markers pass
# ---------------------------------------------------------------------------

FAKE_CONFTEST = """\
    def pytest_configure(config):
        config.addinivalue_line("markers", "declared: a registered tier")
"""

BAD_MARKER = """\
    import pytest

    pytestmark = pytest.mark.declared

    @pytest.mark.undeclared
    def test_something():
        pass
"""


def test_markers_undeclared_marker(tmp_path):
    findings = run_lint(tmp_path, {
        "tests/conftest.py": FAKE_CONFTEST,
        "tests/test_bad.py": BAD_MARKER,
    }, "markers")
    [f] = only(findings, "markers")
    assert f.path == "tests/test_bad.py"
    assert f.line == line_of(BAD_MARKER, "pytest.mark.undeclared")
    assert "'undeclared'" in f.message


# ---------------------------------------------------------------------------
# deadcode pass (report-only)
# ---------------------------------------------------------------------------

def test_deadcode_inventories_unreachable_modules(tmp_path):
    findings = run_lint(tmp_path, {
        "src/repro/core/used.py": "from repro.lost import helper\n",
        "src/repro/lost/helper.py": "LIVE = 1\n",
        "src/repro/lost/dead.py": "DORMANT = 1\n",
        "tests/test_dead.py": "import repro.lost.dead\n",
    }, "deadcode")
    got = only(findings, "deadcode")
    assert all(f.severity == "info" for f in got)
    [f] = [f for f in got if "repro.lost.dead" in f.message]
    assert f.path == "src/repro/lost/dead.py"
    assert "pinned only by tests/" in f.message
    assert not any("repro.lost.helper" in f.message for f in got), \
        "helper is imported by core and must count as reachable"


# ---------------------------------------------------------------------------
# suppression grammar
# ---------------------------------------------------------------------------

def test_suppression_with_reason_silences(tmp_path):
    src = BAD_RENAME.replace(
        "os.replace(tmp, dst)",
        "os.replace(tmp, dst)  # hippolint: disable=crash -- scratch file")
    findings = run_lint(tmp_path, {"src/mod.py": src}, "crash")
    assert findings == [], [f.render() for f in findings]


def test_suppression_without_reason_is_an_error(tmp_path):
    src = BAD_RENAME.replace(
        "os.replace(tmp, dst)",
        "os.replace(tmp, dst)  # hippolint: disable=crash")
    findings = run_lint(tmp_path, {"src/mod.py": src}, "crash")
    [f] = findings
    assert f.check == "suppress" and "justification" in f.message


def test_suppression_unknown_pass_is_an_error(tmp_path):
    src = BAD_RENAME.replace(
        "os.replace(tmp, dst)",
        "os.replace(tmp, dst)  # hippolint: disable=vibes -- because")
    findings = run_lint(tmp_path, {"src/mod.py": src}, "crash")
    assert any(f.check == "suppress" and "unknown pass" in f.message
               for f in findings)


def test_standalone_suppression_applies_to_next_code_line(tmp_path):
    src = BAD_RENAME.replace(
        "    os.replace(tmp, dst)",
        "    # hippolint: disable=crash -- scratch file\n"
        "    os.replace(tmp, dst)")
    findings = run_lint(tmp_path, {"src/mod.py": src}, "crash")
    assert findings == [], [f.render() for f in findings]


# ---------------------------------------------------------------------------
# end-to-end: this repository is clean, and the CLI reports it so
# ---------------------------------------------------------------------------

def test_repo_is_clean_across_all_passes():
    ctx = load_context(REPO)
    findings = run_passes(ctx, PASSES)
    errors = [f for f in findings if f.severity == "error"]
    assert not errors, "the committed tree must lint clean:\n" + \
        "\n".join(f.render() for f in errors)


def test_every_committed_suppression_carries_a_reason():
    ctx = load_context(REPO)
    for sf in ctx.files:
        for s in sf.suppressions:
            assert s.reason, \
                f"{sf.rel}:{s.decl_line}: suppression without justification"


def test_cli_exit_codes(tmp_path, capsys):
    assert lint_cli.main(["--all"]) == 0
    capsys.readouterr()
    make_repo(tmp_path, {"src/mod.py": BAD_RENAME})
    rc = lint_cli.main(["--root", str(tmp_path), "crash"])
    out = capsys.readouterr().out
    line = line_of(BAD_RENAME, "os.replace")
    assert rc == 1
    assert f"src/mod.py:{line}: [crash]" in out
