"""Documentation sanity: the README exists and its module map is honest —
every ``repro.*`` module it names must import cleanly, and every registered
benchmark must describe itself for ``benchmarks/run.py --list``."""
import importlib
import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_readme_exists_and_covers_basics():
    readme = REPO / "README.md"
    assert readme.exists(), "top-level README.md is missing"
    text = readme.read_text()
    for needle in ("quickstart", "pytest", "benchmarks", "module map"):
        assert needle.lower() in text.lower(), f"README.md lacks {needle!r}"


def test_readme_module_map_imports_cleanly():
    text = (REPO / "README.md").read_text()
    modules = sorted(set(re.findall(r"`(repro(?:\.\w+)+)`", text)))
    assert len(modules) >= 8, f"README module map names too few modules: {modules}"
    for mod in modules:
        importlib.import_module(mod)


def test_docs_pages_exist():
    assert (REPO / "docs" / "architecture.md").exists()
    assert (REPO / "docs" / "benchmarks.md").exists()


def test_every_registered_benchmark_self_describes():
    from benchmarks.run import MODULES, SUITES, describe
    assert set(MODULES) == set(SUITES)
    bench_dir = REPO / "benchmarks"
    on_disk = {p.stem for p in bench_dir.glob("bench_*.py")}
    registered = {m.__name__.rsplit(".", 1)[-1] for m in MODULES.values()}
    assert on_disk == registered, (
        f"bench modules on disk and registered in run.py diverge: "
        f"{on_disk ^ registered}")
    benchdoc = (REPO / "docs" / "benchmarks.md").read_text()
    for name in SUITES:
        desc = describe(name)
        assert "missing module docstring" not in desc, name
        assert len(desc) > 10, f"{name}: one-line description too thin: {desc!r}"
        assert name in benchdoc, f"docs/benchmarks.md does not cover {name}"
