"""Prefill + decode must reproduce the full-sequence forward exactly:
logits(decode token t | cache of 0..t-1) == logits(forward(0..t))[:, t].

MoE configs use a large capacity factor here so no tokens are dropped —
capacity truncation legitimately differs between a (B*S)-token prefill and a
B-token decode batch.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from dataclasses import replace

from repro.configs import get_config
from repro.models import serve, transformer

pytestmark = pytest.mark.slow

ARCHS = [
    "yi-6b",                    # GQA + rope
    "stablelm-3b",              # layernorm + partial rotary + MHA
    "qwen2.5-3b",               # qkv bias
    "llama4-maverick-400b-a17b",  # interleaved MoE
    "recurrentgemma-9b",        # RG-LRU + local attention
    "rwkv6-3b",                 # attention-free
    "musicgen-large",           # sinusoidal + frames frontend stub
    "qwen2-vl-7b",              # M-RoPE + patches frontend stub
]

B, S = 2, 12


def setup(arch):
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        cfg = replace(cfg, capacity_factor=8.0)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    if cfg.frontend == "tokens":
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return cfg, params, inputs


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg, params, inputs = setup(arch)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    ref_logits = transformer.forward(cfg, params, inputs, positions, remat=False)

    max_seq = S + 4
    prompt = inputs[:, : S - 3]
    pos_p = positions[:, : S - 3]
    logits, cache = serve.prefill(cfg, params, prompt, pos_p, max_seq)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(ref_logits[:, S - 4], np.float32), rtol=2e-4, atol=2e-4)

    # three decode steps, each must match the teacher-forced forward
    for t in range(S - 3, S):
        tok = inputs[:, t : t + 1]
        logits, cache = serve.decode_step(cfg, params, cache, tok, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, t], np.float32), rtol=2e-4, atol=2e-4,
            err_msg=f"{arch} decode step {t}")


def test_local_window_rolling_buffer():
    """Decode past the window: rolling KV buffer must match full forward
    (local attention only ever sees the last `window` tokens anyway)."""
    cfg = get_config("recurrentgemma-9b").reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    total = cfg.window * 2 + 5   # decode well past the window
    inputs = jax.random.randint(jax.random.PRNGKey(1), (1, total), 0, cfg.vocab_size)
    positions = jnp.arange(total)[None, :]
    ref = transformer.forward(cfg, params, inputs, positions, remat=False)

    s0 = cfg.window + 2
    logits, cache = serve.prefill(cfg, params, inputs[:, :s0], positions[:, :s0],
                                  max_seq=total)
    for t in range(s0, total):
        logits, cache = serve.decode_step(cfg, params, cache,
                                          inputs[:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   np.asarray(ref[:, t], np.float32),
                                   rtol=3e-4, atol=3e-4, err_msg=f"t={t}")


def test_generate_roundtrip():
    cfg = get_config("smollm-360m").reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = serve.generate(cfg, params, prompt, num_steps=6, max_seq=20)
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())
