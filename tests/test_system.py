"""End-to-end system tests: the training driver through the full stack
(Hippo-indexed data -> sharded steps -> checkpoint/restart), asserting loss
decrease and exact restart determinism."""
import numpy as np

from repro.launch import train as train_driver
import pytest

pytestmark = pytest.mark.slow


def test_train_driver_loss_decreases(tmp_path):
    losses = train_driver.main([
        "--arch", "smollm-360m", "--reduced",
        "--steps", "30", "--batch", "8", "--seq", "32",
        "--lr", "3e-3", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "10",
    ])
    assert len(losses) == 30
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_restart_reproduces_trajectory(tmp_path):
    """Kill-and-resume must replay the exact same loss curve: checkpoints +
    the stateless step->batch mapping make restarts bit-deterministic."""
    ck = str(tmp_path / "ck2")
    full = train_driver.main([
        "--arch", "smollm-360m", "--reduced",
        "--steps", "20", "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path / "full"), "--ckpt-every", "50",
    ])
    # run to step 10 under the SAME 20-step schedule (simulated preemption),
    # then resume to 20
    train_driver.main([
        "--arch", "smollm-360m", "--reduced",
        "--steps", "20", "--batch", "4", "--seq", "32",
        "--ckpt-dir", ck, "--ckpt-every", "10", "--stop-after", "10",
    ])
    resumed = train_driver.main([
        "--arch", "smollm-360m", "--reduced",
        "--steps", "20", "--batch", "4", "--seq", "32",
        "--ckpt-dir", ck, "--ckpt-every", "10", "--resume",
    ])
    # resumed run re-executes steps 10..19; compare against the tail of the
    # uninterrupted run
    np.testing.assert_allclose(resumed[-5:], full[-5:], rtol=1e-4)


def test_serve_driver_end_to_end():
    finished = __import__("repro.launch.serve", fromlist=["main"]).main([
        "--arch", "smollm-360m", "--reduced",
        "--requests", "3", "--batch", "2",
        "--prompt-len", "8", "--gen", "6",
    ])
    assert len(finished) == 3
    assert all(len(r.generated) >= 6 for r in finished)
