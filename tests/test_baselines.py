import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.baselines import BPlusTree, FullScan, MinMaxIndex
from repro.core.hippo import HippoIndex
from repro.storage.table import PagedTable
from repro.core.predicate import Predicate


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    values = rng.uniform(0, 1000, 5000)
    table = PagedTable.from_values(values, page_card=50, spare_pages=32)
    return values, table


def test_btree_range_search_exact(data):
    values, table = data
    t = BPlusTree.bulk_load(values, page_card=50, fanout=32)
    for lo, hi in [(0, 1000), (100, 110), (500.5, 500.6), (-10, -5)]:
        got = t.count_range(lo, hi)
        want = int(((values >= lo) & (values <= hi)).sum())
        assert got == want


def test_btree_insert_and_split(data):
    values, _ = data
    t = BPlusTree.bulk_load(values[:500], page_card=50, fanout=16)
    rng = np.random.default_rng(1)
    extra = rng.uniform(0, 1000, 200)
    for i, v in enumerate(extra):
        t.insert(float(v), i)
    assert t.num_keys == 700
    assert t.io.node_splits > 0
    all_vals = np.concatenate([values[:500], extra])
    assert t.count_range(0, 1000) == int(((all_vals >= 0) & (all_vals <= 1000)).sum())


def test_btree_delete(data):
    values, _ = data
    t = BPlusTree.bulk_load(values[:100], page_card=50, fanout=16)
    v = float(np.float32(values[7]))
    assert t.delete(v)
    assert t.num_keys == 99


def test_btree_storage_dominates_hippo(data):
    """Table 1a / Fig 6a: per-tuple B+-Tree entries vs Hippo page summaries."""
    values, table = data
    t = BPlusTree.bulk_load(values, page_card=50, fanout=256)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    assert t.nbytes() > 10 * idx.nbytes()


def test_minmax_exact_but_weak_on_unordered(data):
    values, table = data
    mm = MinMaxIndex.build(table.device_keys(), table.device_valid(), pages_per_range=1)
    hippo = HippoIndex.create(table, resolution=400, density=0.2)
    lo, hi = 500.0, 501.0  # SF ~ 0.1%
    cnt, pages = mm.search(table.device_keys(), table.device_valid(), lo, hi)
    want = int(((values >= lo) & (values <= hi)).sum())
    assert int(cnt) == want
    res = hippo.search(Predicate.between(lo, hi))
    # On unordered data, min-max ranges cover everything -> near full scan,
    # while Hippo prunes (§8's motivating comparison).
    assert int(pages) > 0.9 * table.num_pages
    assert int(res.pages_inspected) < 0.5 * table.num_pages


def test_minmax_strong_on_sorted():
    values = np.sort(np.random.default_rng(2).uniform(0, 1000, 5000))
    table = PagedTable.from_values(values, page_card=50)
    mm = MinMaxIndex.build(table.device_keys(), table.device_valid())
    cnt, pages = mm.search(table.device_keys(), table.device_valid(), 100.0, 110.0)
    assert int(cnt) == int(((values >= 100) & (values <= 110)).sum())
    assert int(pages) < 0.05 * table.num_pages


def test_fullscan(data):
    values, table = data
    cnt, pages = FullScan.search(table.device_keys(), table.device_valid(), 100.0, 200.0)
    assert int(cnt) == int(((values >= 100) & (values <= 200)).sum())
    assert int(pages) == table.num_pages
