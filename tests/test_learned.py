"""Learned summaries (``core.learned``, PR 7): the piecewise-linear CDF fit
and its boundary materialization must (a) satisfy the fit contract — fixed
segment budget, monotone knots, error-bounded against the boundary-allocation
CDF; (b) produce bounds indistinguishable *in correctness* from equal-mass
bounds — counts bit-identical to brute force across selectivity x shard
count x staged overlay, including mid-resummarize mixed epochs; and (c) wire
through the policy surfaces — index ``summary`` knob, writer refit + per-shard
model recording, engine stats — with the equal-mass path as fallback/oracle.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import histogram as hg
from repro.core import learned as ln
from repro.core.partition import SUMMARY_POLICIES, ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime import writer as writer_mod
from repro.runtime.engine import QueryEngine
from repro.runtime.writer import MaintenanceWriter
from repro.storage.table import PagedTable

pytestmark = pytest.mark.learned


def make_sidx(values, num_shards=4, page_card=8, resolution=32, density=0.25,
              spare_pages=256, **kw):
    table = PagedTable.from_values(np.asarray(values).copy(),
                                   page_card=page_card,
                                   spare_pages=spare_pages)
    return ShardedHippoIndex.create(table, num_shards=num_shards,
                                    resolution=resolution, density=density,
                                    **kw)


def brute_force(table, preds) -> np.ndarray:
    live = table.valid[: table.num_pages]
    keys = table.keys[: table.num_pages]
    return np.asarray([(live & (keys >= p.lo) & (keys <= p.hi)).sum()
                       for p in preds], np.int64)


def sweep_preds(values):
    """Selectivity sweep anchored on the data's quantiles: empty, point,
    narrow, medium, wide, full-table."""
    q = np.quantile(values, [0.1, 0.12, 0.5, 0.7, 0.02, 0.98])
    return [
        Predicate(lo=5.0, hi=1.0),                       # empty
        Predicate.equality(float(values[len(values) // 2])),
        Predicate.between(float(q[0]), float(q[1])),     # ~2% band
        Predicate.between(float(q[2]), float(q[3])),     # ~20% band
        Predicate.between(float(q[4]), float(q[5])),     # ~96% band
        Predicate.between(-1e30, 1e30),                  # full table
    ]


# ---------------------------------------------------------------------------
# Fit contract
# ---------------------------------------------------------------------------

def test_fit_cdf_monotone_error_bounded_fixed_shape():
    rng = np.random.default_rng(0)
    sample = rng.lognormal(0.0, 1.5, 20_000).astype(np.float32)
    for segments in (4, 16, 64):
        m = ln.fit_cdf(sample, segments=segments)
        assert m.knots_x.shape == (segments + 1,)     # fixed padded shape
        assert m.knots_y.shape == (segments + 1,)
        assert 2 <= m.n_knots <= segments + 1
        kx, ky = m.knots_x[: m.n_knots], m.knots_y[: m.n_knots]
        assert (np.diff(kx) > 0).all() and (np.diff(ky) >= 0).all()
        assert 0.0 <= ky[0] and ky[-1] == pytest.approx(1.0)
        # achieved error is a true sup-norm bound over the fit points
        x, y = ln._weighted_cdf_points(sample, None)
        assert np.abs(m.cdf(x) - y).max() <= m.max_error + 1e-12
    # more segments never fit worse
    errs = [ln.fit_cdf(sample, segments=s).max_error for s in (4, 16, 64)]
    assert errs[0] >= errs[1] >= errs[2]


def test_fit_cdf_exact_when_budget_covers_the_points():
    x = np.asarray([0.0, 1.0, 2.0, 10.0], np.float32)
    m = ln.fit_cdf(x, segments=8)
    assert m.max_error == pytest.approx(0.0, abs=1e-12)
    assert m.used_segments <= 3


def test_fit_cdf_degenerate_and_validation():
    with pytest.raises(ln.DegenerateSample):
        ln.fit_cdf(np.full(100, 3.0, np.float32))
    with pytest.raises(ln.DegenerateSample):
        ln.fit_cdf(np.zeros(0, np.float32))
    with pytest.raises(ValueError, match="segments"):
        ln.fit_cdf(np.asarray([1.0, 2.0]), segments=0)
    with pytest.raises(ValueError, match="weights shape"):
        ln.fit_cdf(np.asarray([1.0, 2.0]), np.asarray([1.0]))
    with pytest.raises(ValueError, match="positive total"):
        ln.fit_cdf(np.asarray([1.0, 2.0]), np.asarray([0.0, 0.0]))


def test_mass_clamp_water_fills_heavy_hitters():
    """The boundary-allocation correction: per-key mass caps at the clamp,
    total stays 1, and the freed mass redistributes proportionally; when
    every key saturates the allocation goes uniform."""
    mass = np.asarray([0.6, 0.2, 0.1, 0.05, 0.05])
    out = ln._clamp_masses(mass, 0.25)
    assert out.sum() == pytest.approx(1.0)
    assert out.max() <= 0.25 + 1e-12
    assert out[0] == pytest.approx(0.25)          # heavy hitter capped
    assert (np.diff(out[1:]) <= 1e-12).all()      # order preserved below cap
    # unclamped distributions pass through untouched
    np.testing.assert_array_equal(ln._clamp_masses(np.full(8, 0.125), 0.25),
                                  np.full(8, 0.125))
    # fewer distinct keys than buckets: uniform is the fixed point
    np.testing.assert_allclose(
        ln._clamp_masses(np.asarray([0.9, 0.1]), 0.05), [0.5, 0.5])


def test_boundaries_strict_and_writer_drain_valid():
    """Materialized bounds always satisfy the writer's drain validation:
    (H+1,) float32, strictly increasing — even from duplicate-heavy and
    large-magnitude samples."""
    rng = np.random.default_rng(1)
    samples = [
        rng.zipf(1.3, 30_000).astype(np.float32),
        # float32 ulp at 1e9 is 64: ~150 distinct values < H=400, so the
        # materialized grid must fall back on whole-ulp separation
        (1e9 + rng.uniform(0, 1e4, 5000)).astype(np.float32),
        np.asarray([1.0, 1.0, 1.0, 2.0], np.float32),
    ]
    for sample in samples:
        for resolution in (8, 64, 400):
            hist, model = ln.build_histogram(sample, resolution)
            b = np.asarray(hist.bounds)
            assert b.shape == (resolution + 1,) and b.dtype == np.float32
            assert (np.diff(b) > 0).all()
            assert model is not None


def test_build_histogram_fallback_on_degenerate_sample():
    hist, model = ln.build_histogram(np.full(100, 7.0, np.float32), 16)
    assert model is None
    b = np.asarray(hist.bounds)
    assert b.shape == (17,) and (np.diff(b) > 0).all()


def test_learned_bounds_use_more_buckets_on_duplicate_heavy_keys():
    """The pruning mechanism the benchmark measures: equal-mass quantiles
    tie on heavy values and ladder into empty stripes; the learned fit
    clamps per-key mass and spends those boundaries where tuples are."""
    rng = np.random.default_rng(2)
    z = rng.zipf(1.3, 100_000).astype(np.float64)
    z = z[z < 20_000].astype(np.float32)
    H = 400

    def occupied(hist):
        ids = np.asarray(hg.bucketize(hist, jnp.asarray(z)))
        return np.unique(ids).size

    eq = occupied(hg.build(jnp.asarray(z), H))
    lr = occupied(ln.build_histogram(z, H)[0])
    assert lr >= 1.3 * eq, (eq, lr)


def test_learned_rebuild_favors_reservoir_resolution():
    """The drift-refit lever: the reservoir carries 1 - OLD_MASS_FRACTION
    of the boundary budget, strictly more than rebuild's equal-mass half."""
    rng = np.random.default_rng(3)
    base = hg.build(jnp.asarray(rng.uniform(0, 1e5, 65536)), 100)
    res = rng.uniform(3e5, 3.1e5, 4096).astype(np.float32)
    learned_b = np.asarray(ln.learned_rebuild(base, res, 100)[0].bounds)
    eq_b = np.asarray(hg.rebuild(base, res, 100).bounds)

    def in_window(b):
        return int(((b >= 3e5) & (b <= 3.11e5)).sum())

    assert in_window(learned_b) > in_window(eq_b)
    assert (np.diff(learned_b) > 0).all()
    with pytest.raises(ValueError, match="non-empty sample"):
        ln.learned_rebuild(base, np.zeros(0))
    with pytest.raises(ValueError, match="old_mass"):
        ln.learned_rebuild(base, res, old_mass=1.0)


# ---------------------------------------------------------------------------
# The acceptance invariant: learned bounds never change a count
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_shards", [1, 4])
@pytest.mark.parametrize("staged", [False, True])
def test_learned_counts_bit_identical(num_shards, staged):
    """Counts vs brute force across selectivity x shard count x staged
    overlay on the compact, fused-dense, and routed paths — under learned
    build-time bounds, then through a learned drift refit (remap drained
    alone, rows still staged), then fully drained."""
    rng = np.random.default_rng(5 * num_shards + staged)
    base = np.sort(np.concatenate([
        rng.uniform(0, 100, 240),
        rng.choice(np.asarray([20.0, 50.0], np.float32), 60),  # heavy ties
    ]))
    aidx = make_sidx(base, num_shards=num_shards, summary="learned")
    assert aidx.summary == "learned"
    assert all(m is not None for m in aidx.summary_models)
    engine = QueryEngine(aidx, batch=8, drain_policy="manual",
                         auto_resummarize=False)
    drained = rng.uniform(100, 130, 48)
    for v in drained:
        engine.write(float(v))
    engine.flush()
    pending = rng.uniform(125, 140, 12) if staged else np.zeros(0)
    for v in pending:
        engine.write(float(v))

    preds = sweep_preds(base) + [Predicate.between(105.0, 112.0)]
    want = brute_force(aidx.table, preds) + np.asarray(
        [((pending >= p.lo) & (pending <= p.hi)).sum() for p in preds])

    def check_all_paths(msg):
        np.testing.assert_array_equal(engine.run_all(preds), want, err_msg=msg)
        np.testing.assert_array_equal(
            np.asarray(aidx.search_batch(preds).counts), want, err_msg=msg)
        routed = QueryEngine(aidx, batch=8, mode="dense",
                             drain_policy="manual", writer=engine.writer)
        np.testing.assert_array_equal(routed.run_all(preds), want, err_msg=msg)

    check_all_paths("learned build-time bounds")
    w = engine.writer
    w.schedule_resummarize()                  # index policy: learned refit
    assert w.stats.learned_refits == 1 and w._pending_model is not None
    w.drain(max_units=num_shards)             # remap first, rows stay staged
    assert w.queue_depth == pending.size
    assert list(aidx.bounds_epochs) == [1] * num_shards
    assert all(m is not None for m in aidx.summary_models)
    check_all_paths("after learned resummarize, rows still staged")
    engine.flush()
    want = brute_force(aidx.table, preds)
    check_all_paths("after learned resummarize + drain")


def test_learned_mixed_epochs_serve_exactly():
    """A partially drained learned remap: some shards on the fitted bounds,
    some on the old — per-shard predicate conversion keeps every path exact,
    and models swap in per shard, not wholesale."""
    rng = np.random.default_rng(17)
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 400)), summary="learned")
    writer = MaintenanceWriter(aidx)
    for v in rng.uniform(100, 120, 32):
        writer.write(float(v))
    writer.flush()
    preds = sweep_preds(np.asarray(
        aidx.table.keys[: aidx.table.num_pages]).ravel())
    want = brute_force(aidx.table, preds)
    build_models = list(aidx.summary_models)
    writer.schedule_resummarize()
    writer.drain(max_units=2)
    assert list(aidx.bounds_epochs) == [1, 1, 0, 0]
    assert aidx.summary_models[0] is not build_models[0]    # refit swapped in
    assert aidx.summary_models[3] is build_models[3]        # still the old one
    np.testing.assert_array_equal(
        np.asarray(aidx.search_batch(preds).counts), want)
    engine = QueryEngine(aidx, batch=8, drain_policy="manual", writer=writer)
    np.testing.assert_array_equal(engine.run_all(preds), want)
    writer.flush()
    refit = aidx.summary_models[0]
    assert all(m is refit for m in aidx.summary_models)
    np.testing.assert_array_equal(engine.run_all(preds), want)


# ---------------------------------------------------------------------------
# Policy plumbing: knobs, stats, fallback
# ---------------------------------------------------------------------------

def test_summary_policy_validation():
    rng = np.random.default_rng(19)
    vals = rng.uniform(0, 100, 100)
    with pytest.raises(ValueError, match="summary"):
        make_sidx(vals, summary="nope")
    aidx = make_sidx(vals)
    assert aidx.summary == "equal_mass"
    assert aidx.summary_models == [None] * aidx.num_shards
    with pytest.raises(ValueError, match="summary"):
        QueryEngine(aidx, summary="nope")
    writer = MaintenanceWriter(aidx)
    with pytest.raises(ValueError, match="policy"):
        writer.schedule_resummarize(policy="nope")
    assert "equal_mass" in SUMMARY_POLICIES and "learned" in SUMMARY_POLICIES


def test_engine_summary_knob_overrides_index_policy():
    """An equal-mass index driven by an engine with summary='learned' refits
    learned (and vice versa): the engine knob wins over the index default."""
    rng = np.random.default_rng(23)
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 300)))    # equal_mass index
    engine = QueryEngine(aidx, batch=8, drain_policy="manual",
                         auto_resummarize=False, summary="learned")
    for v in rng.uniform(100, 120, 32):
        engine.write(float(v))
    engine.resummarize()
    assert engine.stats.learned_refits == 1
    assert engine.stats.learned_fallbacks == 0
    assert all(m is not None for m in aidx.summary_models)
    # and the reverse: learned index, engine forces the equal-mass oracle
    lidx = make_sidx(np.sort(rng.uniform(0, 100, 300)), summary="learned")
    oracle = QueryEngine(lidx, batch=8, drain_policy="manual",
                         auto_resummarize=False, summary="equal_mass")
    for v in rng.uniform(100, 120, 32):
        oracle.write(float(v))
    oracle.resummarize()
    assert oracle.stats.learned_refits == 0
    assert all(m is None for m in lidx.summary_models)


def test_auto_resummarize_uses_index_policy():
    """The drift auto-trigger inherits the learned policy from the index:
    no engine configuration needed for a learned index to stay learned."""
    rng = np.random.default_rng(29)
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 200)), summary="learned")
    engine = QueryEngine(aidx, batch=4, drift_threshold=0.5,
                         drift_min_observed=8)
    for v in rng.uniform(100, 115, 16):
        engine.write(float(v))
    assert engine.writer.stats.learned_refits == 1
    while engine.writer.pending_units:
        engine.run_all([Predicate.between(0.0, 1e9)])
    assert engine.stats.learned_refits == 1
    assert all(m is not None for m in aidx.summary_models)


def test_learned_fallback_records_stat_and_equal_mass_bounds(monkeypatch):
    """When the learned fit declines (degenerate reservoir), the schedule
    falls back to equal-mass bounds, counts stay exact, models record None,
    and ``learned_fallbacks`` — not ``learned_refits`` — ticks."""
    rng = np.random.default_rng(31)
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 300)), summary="learned")
    writer = MaintenanceWriter(aidx)
    for v in rng.uniform(100, 120, 32):
        writer.write(float(v))
    writer.flush()

    def degenerate(hist, sample, *a, **kw):
        return hg.rebuild(hist, sample), None

    monkeypatch.setattr(writer_mod.ln, "learned_rebuild", degenerate)
    preds = sweep_preds(np.asarray(
        aidx.table.keys[: aidx.table.num_pages]).ravel())
    want = brute_force(aidx.table, preds)
    writer.schedule_resummarize()
    assert writer.stats.learned_fallbacks == 1
    assert writer.stats.learned_refits == 0
    writer.flush()
    assert all(m is None for m in aidx.summary_models)
    np.testing.assert_array_equal(
        np.asarray(aidx.search_batch(preds).counts), want)


def test_explicit_bounds_clear_pending_model():
    """A manual-bounds schedule is policy-free: whatever the index policy,
    the drained shards record no model (the bounds came from the caller)."""
    rng = np.random.default_rng(37)
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 300)), summary="learned")
    writer = MaintenanceWriter(aidx)
    writer.schedule_resummarize(
        np.linspace(-1.0, 101.0, aidx.cfg.resolution + 1))
    writer.flush()
    assert all(m is None for m in aidx.summary_models)
    assert writer.stats.learned_refits == 0
