"""Drift-adaptive re-summarization: a remap onto new histogram bounds must
never change a single count — before, during (partially drained, mixed
bounds epochs), or after — on every query path (fused dense, routed
dispatch, compact gather, staged overlay); a refused remap must roll back
cleanly with the old bounds still serving; and the auto trigger must
schedule and drain through the normal policies."""
import numpy as np
import pytest

from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.runtime.writer import MaintenanceWriter
from repro.storage.table import PagedTable

pytestmark = pytest.mark.drift


def make_sidx(values, num_shards=4, page_card=8, resolution=32, density=0.25,
              spare_pages=256, **kw):
    table = PagedTable.from_values(np.asarray(values).copy(),
                                   page_card=page_card,
                                   spare_pages=spare_pages)
    return ShardedHippoIndex.create(table, num_shards=num_shards,
                                    resolution=resolution, density=density,
                                    **kw)


def brute_force(table, preds) -> np.ndarray:
    live = table.valid[: table.num_pages]
    keys = table.keys[: table.num_pages]
    return np.asarray([(live & (keys >= p.lo) & (keys <= p.hi)).sum()
                       for p in preds], np.int64)


def drift_preds():
    """A small selectivity sweep: empty, point, narrow-in-base,
    narrow-in-drifted-region, spanning, and full-table predicates."""
    return [
        Predicate(lo=5.0, hi=1.0),              # empty
        Predicate.equality(50.0),               # point (may be 0: still exact)
        Predicate.between(20.0, 24.0),          # narrow, pre-drift region
        Predicate.between(108.0, 114.0),        # narrow, drifted region
        Predicate.between(80.0, 125.0),         # spans the old range boundary
        Predicate.between(-1e30, 1e30),         # full table
    ]


# ---------------------------------------------------------------------------
# The acceptance invariant: counts bit-identical around a remap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_shards", [1, 4])
@pytest.mark.parametrize("staged", [False, True])
def test_resummarize_counts_bit_identical(num_shards, staged):
    """Counts against brute force across selectivity x shard count x
    staged-overlay, on the compact, fused-dense, and routed paths, before a
    remap, after the remap alone (staged rows still queued), and after the
    rows drain."""
    rng = np.random.default_rng(3 * num_shards + staged)
    base = np.sort(rng.uniform(0, 100, 300))
    aidx = make_sidx(base, num_shards=num_shards)
    engine = QueryEngine(aidx, batch=8, drain_policy="manual",
                         auto_resummarize=False)
    drained = rng.uniform(100, 130, 48)          # drift beyond the base range
    for v in drained:
        engine.write(float(v))
    engine.flush()                               # landed under the old bounds
    pending = rng.uniform(125, 140, 12) if staged else np.zeros(0)
    for v in pending:
        engine.write(float(v))

    preds = drift_preds()
    want = brute_force(aidx.table, preds) + np.asarray(
        [((pending >= p.lo) & (pending <= p.hi)).sum() for p in preds])

    def check_all_paths(msg):
        np.testing.assert_array_equal(engine.run_all(preds), want, err_msg=msg)
        np.testing.assert_array_equal(
            np.asarray(aidx.search_batch(preds).counts), want, err_msg=msg)
        routed = QueryEngine(aidx, batch=8, mode="dense",
                             drain_policy="manual", writer=engine.writer)
        np.testing.assert_array_equal(routed.run_all(preds), want, err_msg=msg)

    check_all_paths("before resummarize")
    w = engine.writer
    w.schedule_resummarize()
    w.drain(max_units=num_shards)        # remap units drain first, rows stay
    assert w.queue_depth == pending.size
    assert list(aidx.bounds_epochs) == [1] * num_shards
    check_all_paths("after resummarize, rows still staged")
    engine.flush()
    assert w.queue_depth == 0
    want = brute_force(aidx.table, preds)
    check_all_paths("after resummarize + drain")


def test_partial_resummarize_serves_mixed_epochs_exactly():
    """A partially drained remap leaves shards on different bounds epochs;
    every path must stay exact through the mix (per-shard conversion)."""
    rng = np.random.default_rng(17)
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 400)))
    writer = MaintenanceWriter(aidx)
    for v in rng.uniform(100, 120, 32):
        writer.write(float(v))
    writer.flush()
    preds = drift_preds()
    want = brute_force(aidx.table, preds)
    writer.schedule_resummarize()
    writer.drain(max_units=2)
    assert list(aidx.bounds_epochs) == [1, 1, 0, 0]    # mid-transition
    np.testing.assert_array_equal(
        np.asarray(aidx.search_batch(preds).counts), want)
    engine = QueryEngine(aidx, batch=8, drain_policy="manual", writer=writer)
    np.testing.assert_array_equal(engine.run_all(preds), want)
    routed = QueryEngine(aidx, batch=8, mode="dense", drain_policy="manual",
                         writer=writer)
    np.testing.assert_array_equal(routed.run_all(preds), want)
    writer.flush()
    assert list(aidx.bounds_epochs) == [1, 1, 1, 1]
    np.testing.assert_array_equal(engine.run_all(preds), want)


def test_resummarize_refusal_rolls_back():
    """A remap that refuses at drain time (invalid pending bounds) releases
    the swap guard, keeps the old bounds serving exactly, and leaves the
    unit pending for a corrected schedule."""
    rng = np.random.default_rng(23)
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 200)))
    writer = MaintenanceWriter(aidx)
    preds = drift_preds()
    want = brute_force(aidx.table, preds)
    writer.schedule_resummarize(np.linspace(0.0, 100.0, 10))   # wrong length
    with pytest.raises(RuntimeError, match="resummarize refused"):
        writer.flush()
    assert aidx.swap_in_flight is None                 # guard released
    assert list(aidx.bounds_epochs) == [0, 0, 0, 0]    # old bounds serving
    assert len(writer.pending_resummarize_shards()) == aidx.num_shards
    assert writer.stats.resummarizes == 0
    np.testing.assert_array_equal(
        np.asarray(aidx.search_batch(preds).counts), want)
    # rescheduling replaces the pending bounds; the retry drains cleanly
    # (the refused round consumed no epoch: nothing was applied under it)
    writer.schedule_resummarize(
        np.linspace(-1.0, 101.0, aidx.cfg.resolution + 1))
    writer.flush()
    assert list(aidx.bounds_epochs) == [1, 1, 1, 1]
    np.testing.assert_array_equal(
        np.asarray(aidx.search_batch(preds).counts), want)


# ---------------------------------------------------------------------------
# Policy: the auto trigger and the pruning payoff
# ---------------------------------------------------------------------------

def test_auto_resummarize_triggers_and_drains_via_policy():
    """Drifting writes cross the edge-overflow threshold -> a remap is
    scheduled automatically and the between-batches policy drains it off the
    query path, counts exact at every step; completion rearms the tracker."""
    rng = np.random.default_rng(29)
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 200)))
    engine = QueryEngine(aidx, batch=4, drift_threshold=0.5,
                         drift_min_observed=8)     # between_batches default
    for v in rng.uniform(100, 115, 16):            # all beyond the old range
        engine.write(float(v))
    assert engine.writer.pending_resummarize_shards() == [0, 1, 2, 3]
    assert engine.stats.edge_overflow_ratio == 1.0
    preds = drift_preds()
    while engine.writer.pending_units:
        got = engine.run_all(preds)
        want = (brute_force(aidx.table, preds)
                + engine.writer.staged_counts(
                    [p.lo for p in preds], [p.hi for p in preds]).sum(axis=1))
        np.testing.assert_array_equal(got, want)
    assert engine.stats.resummarizes == aidx.num_shards
    assert engine.stats.edge_overflow_ratio == 0.0   # tracker rearmed
    assert not engine.writer.pending_resummarize_shards()
    # in-range writes never re-trigger
    for v in rng.uniform(50, 115, 16):
        engine.write(float(v))
    assert not engine.writer.pending_resummarize_shards()


def test_resummarize_restores_pruning_quality():
    """The perf mechanism: after monotone drift, a remap gives the drifted
    region real bucket resolution, so a narrow query there inspects far
    fewer pages than under the clamped build-time bounds (counts equal brute
    force on both)."""
    rng = np.random.default_rng(31)
    base = np.sort(rng.uniform(0, 100, 800))
    drift = np.sort(rng.uniform(100, 120, 160))    # append-ordered drift keys
    engines = {}
    for adaptive in (False, True):
        aidx = make_sidx(base, resolution=64, density=0.1)
        engine = QueryEngine(aidx, batch=4, drain_policy="manual",
                             auto_resummarize=False)
        for v in drift:
            engine.write(float(v))
        if adaptive:
            engine.resummarize()     # remap first, then the rows drain
        else:
            engine.flush()
        engines[adaptive] = engine
    pred = Predicate.between(108.0, 111.0)
    insp = {k: int(np.asarray(e.index.search_batch([pred]).pages_inspected)[0])
            for k, e in engines.items()}
    for e in engines.values():
        np.testing.assert_array_equal(
            e.run_all([pred]), brute_force(e.index.table, [pred]))
    assert insp[True] < insp[False], insp
    # window measurement around the remap landed in the stats
    st = engines[True].stats
    assert st.resummarizes == 4
    assert st.pruning_before_resummarize == 0.0    # no batches ran before it


@pytest.mark.parametrize("reservoir", ["constant", "duplicate_heavy",
                                       "single_point_drift"])
def test_resummarize_remap_bit_identical_under_adversarial_bounds(reservoir):
    """Satellite invariant: bounds rebuilt from a degenerate reservoir
    (constant, duplicate-heavy, single far point) still pass the drain's
    strictness validation, and the remap changes no count on any path —
    the epsilon-laddered buckets are empty, not wrong."""
    rng = np.random.default_rng(41)
    samples = {
        "constant": np.full(256, 42.0, np.float32),
        "duplicate_heavy": rng.choice(
            np.asarray([10.0, 20.0, 30.0], np.float32), 256),
        "single_point_drift": np.full(256, 1e6, np.float32),
    }
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 300)))
    writer = MaintenanceWriter(aidx)
    preds = drift_preds()
    want = brute_force(aidx.table, preds)
    from repro.core import histogram as hg
    bounds = np.asarray(hg.rebuild(aidx.histogram,
                                   samples[reservoir]).bounds)
    assert (np.diff(bounds) > 0).all()
    writer.schedule_resummarize(bounds)
    writer.flush()                     # a refusal would raise here
    assert list(aidx.bounds_epochs) == [1] * aidx.num_shards
    np.testing.assert_array_equal(
        np.asarray(aidx.search_batch(preds).counts), want)
    engine = QueryEngine(aidx, batch=8, drain_policy="manual", writer=writer)
    np.testing.assert_array_equal(engine.run_all(preds), want)


def test_engine_drift_knob_validation():
    rng = np.random.default_rng(37)
    aidx = make_sidx(rng.uniform(0, 100, 100))
    with pytest.raises(ValueError, match="drift_threshold"):
        QueryEngine(aidx, drift_threshold=0.0)
    with pytest.raises(RuntimeError, match="writer-backed"):
        QueryEngine(aidx, drain_policy="sync").resummarize()
    writer = MaintenanceWriter(aidx)
    with pytest.raises(RuntimeError, match="no drift sample"):
        writer.schedule_resummarize()
