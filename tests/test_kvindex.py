"""HippoKV (beyond-paper): page-pruned decode attention quality bounds."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.kvindex import (KVIndexConfig, build_kv_index,
                                hippo_kv_attention, query_page_mask)

B, S, H, HD = 2, 512, 4, 32


@pytest.fixture(scope="module")
def cache():
    key = jax.random.PRNGKey(0)
    kk, kv, kq = jax.random.split(key, 3)
    # clustered keys: pages have locality (like real prompts)
    centers = jax.random.normal(kk, (S // 64, 1, H, HD))
    keys = (jnp.repeat(centers, 64, axis=0).reshape(S, 1, H, HD)
            .transpose(1, 0, 2, 3))
    keys = jnp.broadcast_to(keys, (B, S, H, HD)) \
        + 0.3 * jax.random.normal(kv, (B, S, H, HD))
    values = jax.random.normal(kv, (B, S, H, HD))
    q = jax.random.normal(kq, (B, H, HD))
    return keys, values, q


def test_index_structure(cache):
    keys, _, _ = cache
    cfg = KVIndexConfig(page_size=64, num_channels=8, resolution=16)
    idx = build_kv_index(cfg, keys)
    assert idx.bitmaps.shape[:3] == (B, H, S // 64)
    # summaries are tiny relative to the cache itself
    assert idx.nbytes() < 0.25 * keys.size * 2


def test_full_keep_equals_exact(cache):
    keys, values, q = cache
    all_pages = jnp.ones((B, H, S // 64), bool)
    out, mass = hippo_kv_attention(q, keys, values, all_pages, 64)
    scale = 1.0 / np.sqrt(HD)
    scores = jnp.einsum("bhd,bshd->bhs", q, keys) * scale
    ref = jnp.einsum("bhs,bshd->bhd", jax.nn.softmax(scores, -1), values)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(mass), 1.0, rtol=1e-5)


def test_pruning_keeps_mass_and_bounds_error(cache):
    keys, values, q = cache
    cfg = KVIndexConfig(page_size=64, num_channels=8, resolution=16,
                        keep_buckets=4)
    idx = build_kv_index(cfg, keys)
    mask = query_page_mask(idx, q, min_channels=3)
    frac = float(mask.mean())
    assert frac < 0.95                        # actually prunes something
    out, mass = hippo_kv_attention(q, keys, values, mask, 64)
    all_pages = jnp.ones_like(mask)
    ref, _ = hippo_kv_attention(q, keys, values, all_pages, 64)
    # kept softmax mass stays high on clustered data
    assert float(mass.min()) > 0.5
    err = np.abs(np.asarray(out) - np.asarray(ref)).max()
    assert err < 1.0                          # bounded deviation
    # and on average the output is close
    rel = np.linalg.norm(np.asarray(out - ref)) / np.linalg.norm(np.asarray(ref))
    assert rel < 0.5


def test_more_buckets_monotone_quality(cache):
    keys, values, q = cache
    masses = []
    for kb in (2, 6, 12):
        cfg = KVIndexConfig(page_size=64, num_channels=8, resolution=16,
                            keep_buckets=kb)
        idx = build_kv_index(cfg, keys)
        mask = query_page_mask(idx, q)
        _, mass = hippo_kv_attention(q, keys, values, mask, 64)
        masses.append(float(mass.mean()))
    assert masses[0] <= masses[1] <= masses[2] + 1e-6
