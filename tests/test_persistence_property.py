"""Property tests for the snapshot binary layout (hypothesis-driven).

The hypothesis half of satellite coverage for ``checkpointing.layout``:
arbitrary section dicts — any supported dtype, any shape up to the format's
8-dim limit, any section count — must round-trip byte-exactly through
``pack_sections``/``unpack_sections``, and *any* truncation of a valid blob
must raise ``CorruptSnapshotError`` rather than construct arrays. The
container has no pip dependency on hypothesis: this module skips cleanly
where it is absent (the seeded non-hypothesis sweep in
``tests/test_persistence.py`` still runs everywhere).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpointing.layout import (CorruptSnapshotError,  # noqa: E402
                                        pack_sections, unpack_sections)

pytestmark = pytest.mark.persist

_DTYPES = st.sampled_from(["float32", "float64", "int32", "int64",
                           "uint8", "uint16", "uint32", "bool"])
_SHAPES = st.lists(st.integers(min_value=0, max_value=5),
                   min_size=0, max_size=4).map(tuple)
_NAMES = st.text(alphabet=st.characters(min_codepoint=33, max_codepoint=126),
                 min_size=1, max_size=24)


@st.composite
def _sections(draw):
    names = draw(st.lists(_NAMES, min_size=1, max_size=6, unique=True))
    out = {}
    for name in names:
        dt = np.dtype(draw(_DTYPES))
        shape = draw(_SHAPES)
        n = int(np.prod(shape, dtype=np.int64))
        raw = draw(st.binary(min_size=n * dt.itemsize,
                             max_size=n * dt.itemsize))
        out[name] = np.frombuffer(raw, dtype=dt, count=n).reshape(shape).copy()
    return out


@settings(max_examples=60, deadline=None)
@given(_sections())
def test_arbitrary_sections_round_trip_byte_exactly(sections):
    back = unpack_sections(pack_sections(sections), origin="hypothesis")
    assert set(back) == set(sections)
    for name, arr in sections.items():
        got = back[name]
        assert got.dtype == arr.dtype
        assert got.shape == arr.shape
        assert got.tobytes() == arr.tobytes()


@settings(max_examples=60, deadline=None)
@given(_sections(), st.data())
def test_any_truncation_raises_clean_corruption_error(sections, data):
    blob = pack_sections(sections)
    cut = data.draw(st.integers(min_value=0, max_value=len(blob) - 1))
    with pytest.raises(CorruptSnapshotError):
        unpack_sections(blob[:cut], origin="truncated")


@settings(max_examples=40, deadline=None)
@given(_sections(), st.data())
def test_any_version_bump_is_refused(sections, data):
    blob = bytearray(pack_sections(sections))
    bad = data.draw(st.integers(min_value=2, max_value=2**32 - 1))
    blob[8:12] = bad.to_bytes(4, "little")   # header version field
    with pytest.raises(CorruptSnapshotError, match="version"):
        unpack_sections(bytes(blob), origin="version-bump")
