"""Property-based tests: Hippo's exactness invariant (§2 "guarantees the
query result accuracy") must hold for arbitrary data, parameters, predicates,
and maintenance histories."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable


def brute_force(table, lo, hi):
    live = table.valid[: table.num_pages]
    keys = table.keys[: table.num_pages]
    return int((live & (keys >= lo) & (keys <= hi)).sum())


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(5, 400),
    page_card=st.sampled_from([4, 8, 16]),
    resolution=st.sampled_from([8, 32, 64]),
    density=st.sampled_from([0.1, 0.25, 0.5, 0.9]),
    bounds=st.tuples(st.floats(-100, 100), st.floats(-100, 100)),
)
@settings(max_examples=25, deadline=None)
def test_search_always_exact(seed, n, page_card, resolution, density, bounds):
    rng = np.random.default_rng(seed)
    values = rng.uniform(-100, 100, n)
    table = PagedTable.from_values(values, page_card=page_card, spare_pages=8)
    idx = HippoIndex.create(table, resolution=resolution, density=density)
    lo, hi = min(bounds), max(bounds)
    res = idx.search(Predicate.between(lo, hi))
    assert int(res.count) == brute_force(table, lo, hi)
    # Soundness: every truly-qualified page is inspected (no false negatives).
    qual_pages = (
        table.valid[: table.num_pages]
        & (table.keys[: table.num_pages] >= lo)
        & (table.keys[: table.num_pages] <= hi)
    ).any(axis=1)
    inspected = np.asarray(res.page_mask)
    assert not (qual_pages & ~inspected).any()


@given(
    seed=st.integers(0, 2**31 - 1),
    ops=st.lists(
        st.one_of(
            st.tuples(st.just("insert"), st.floats(-50, 50, allow_nan=False)),
            st.tuples(st.just("delete"), st.floats(-50, 50, allow_nan=False)),
            st.tuples(st.just("vacuum"), st.just(0.0)),
        ),
        min_size=1,
        max_size=12,
    ),
)
@settings(max_examples=15, deadline=None)
def test_maintenance_history_preserves_exactness(seed, ops):
    rng = np.random.default_rng(seed)
    values = rng.uniform(-50, 50, 120)
    table = PagedTable.from_values(values, page_card=8, spare_pages=64)
    idx = HippoIndex.create(table, resolution=16, density=0.3)
    for op, arg in ops:
        if op == "insert":
            idx.insert(float(arg))
        elif op == "delete":
            table.delete_where(float(arg) - 2.0, float(arg) + 2.0)
        else:
            idx.vacuum()
        res = idx.search(Predicate.between(-10.0, 10.0))
        assert int(res.count) == brute_force(table, -10.0, 10.0)
