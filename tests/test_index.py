import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import histogram as hg
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable


def make_index(values, page_card=8, resolution=32, density=0.25, **kw):
    table = PagedTable.from_values(values, page_card=page_card, spare_pages=64)
    return HippoIndex.create(table, resolution=resolution, density=density, **kw)


def brute_force(table, lo, hi):
    live = table.valid[: table.num_pages]
    keys = table.keys[: table.num_pages]
    return int((live & (keys >= lo) & (keys <= hi)).sum())


def test_build_structure_invariants():
    rng = np.random.default_rng(0)
    values = rng.uniform(0, 1000, size=2000)
    idx = make_index(values)
    starts, ends, bitmaps = idx.entries_host()
    # Entries partition [0, num_pages-1] contiguously and in order.
    assert starts[0] == 0
    assert ends[-1] == idx.table.num_pages - 1
    np.testing.assert_array_equal(starts[1:], ends[:-1] + 1)
    assert (ends >= starts).all()
    # Each entry bitmap is non-empty; all but the trailing entry exceeded D.
    pops = np.asarray(bm.popcount(jnp.asarray(bitmaps)))
    assert (pops > 0).all()
    dens = pops / idx.cfg.resolution
    assert (dens[:-1] > idx.cfg.density).all()


def test_entry_bitmap_matches_page_contents():
    rng = np.random.default_rng(1)
    values = rng.uniform(0, 100, size=600)
    idx = make_index(values)
    hist = idx.state.histogram
    starts, ends, bitmaps = idx.entries_host()
    keys = idx.table.keys[: idx.table.num_pages]
    valid = idx.table.valid[: idx.table.num_pages]
    ids = np.asarray(hg.bucketize(hist, jnp.asarray(keys.ravel()))).reshape(keys.shape)
    for s, e, packed in zip(starts, ends, bitmaps):
        expect = np.zeros(idx.cfg.resolution, bool)
        blk = ids[s : e + 1][valid[s : e + 1]]
        expect[blk] = True
        got = np.asarray(bm.to_bool(jnp.asarray(packed), idx.cfg.resolution))
        np.testing.assert_array_equal(got, expect)


@pytest.mark.parametrize("dist", ["uniform", "skewed", "sorted", "lowcard"])
def test_search_exact_vs_bruteforce(dist):
    rng = np.random.default_rng(2)
    n = 3000
    if dist == "uniform":
        values = rng.uniform(0, 1000, n)
    elif dist == "skewed":
        values = rng.exponential(50, n)
    elif dist == "sorted":
        values = np.sort(rng.uniform(0, 1000, n))
    else:
        values = rng.integers(0, 12, n).astype(float)
    idx = make_index(values)
    for lo, hi in [(0, 1000), (100, 110), (500, 500), (-5, -1), (900, 2000)]:
        res = idx.search(Predicate.between(lo, hi))
        assert int(res.count) == brute_force(idx.table, lo, hi), (dist, lo, hi)


def test_search_compact_matches_dense():
    rng = np.random.default_rng(3)
    values = rng.uniform(0, 100, 1500)
    idx = make_index(values)
    pred = Predicate.between(10, 20)
    dense = idx.search(pred)
    count, inspected, truncated = idx.search_compact(pred)
    assert int(count) == int(dense.count)
    assert int(inspected) == int(dense.pages_inspected)
    assert not bool(truncated)
    # undersized capacity must flag truncation rather than silently undercount
    _, _, trunc2 = idx.search_compact(pred, max_selected=1)
    assert bool(trunc2)


def test_search_compact_truncation_flag_parity():
    """Sweep max_selected across the truncation boundary: whenever the flag
    is clear the compact count must equal the dense count, and the flag must
    be set exactly when capacity fell short of the pages selected."""
    rng = np.random.default_rng(6)
    values = rng.uniform(0, 100, 1200)
    idx = make_index(values)
    pred = Predicate.between(30, 45)
    dense = idx.search(pred)
    n_sel = int(dense.pages_inspected)
    assert n_sel > 1  # the sweep below must cross the boundary
    for cap in [n_sel - 1, n_sel, idx.table.num_pages]:
        count, inspected, truncated = idx.search_compact(pred, max_selected=cap)
        assert int(inspected) == n_sel
        assert bool(truncated) == (n_sel > cap)
        if not truncated:
            assert int(count) == int(dense.count)


def test_search_compact_fill_value_never_undercounts_silently():
    """Regression for the gather fill-value hazard: selection pads with
    ``fill_value=num_pages`` and gathers with ``mode="fill"``. A full-table
    match that overflows ``max_selected`` must set ``truncated`` (so callers
    fall back) — the pads themselves must never masquerade as real pages or
    push the count below what the gathered slab actually holds."""
    rng = np.random.default_rng(21)
    values = rng.uniform(0, 100, 800)
    idx = make_index(values)
    full = Predicate.between(-1e30, 1e30)
    n_sel = int(idx.search(full).pages_inspected)
    assert n_sel == idx.table.num_pages          # full-table match
    for cap in (1, 7, n_sel - 1):
        count, inspected, truncated = idx.search_compact(full, max_selected=cap)
        assert bool(truncated), cap
        assert int(inspected) == n_sel, cap
        # the slab holds exactly cap real pages => their tuples and no more
        assert int(count) == int(np.sum(
            idx.table.valid[:cap]
            & (idx.table.keys[:cap] >= -3.4e38)
            & (idx.table.keys[:cap] <= 3.4e38))), cap
    # at exactly n_sel the flag clears and the count is exact
    count, _, truncated = idx.search_compact(full, max_selected=n_sel)
    assert not bool(truncated)
    assert int(count) == idx.table.cardinality


def test_search_compact_rejects_zero_capacity():
    """max_selected=0 would turn every slab row into a pad and silently
    count 0 — both gather entry points must refuse it outright."""
    rng = np.random.default_rng(22)
    idx = make_index(rng.uniform(0, 100, 200))
    pred = Predicate.between(0, 50)
    with pytest.raises(ValueError, match="max_selected"):
        idx.search_compact(pred, max_selected=0)
    with pytest.raises(ValueError, match="max_selected"):
        idx.search_compact_batch([pred], max_selected=0)
    with pytest.raises(ValueError, match="top_k"):
        idx.search_compact_batch([pred], max_selected=4, top_k=-1)


def test_search_compact_many_matches_search_many():
    """Quick (unmarked) batched-gather parity check; the full selectivity x
    shards x staged sweep lives in tests/test_compact.py (-m compact)."""
    rng = np.random.default_rng(23)
    idx = make_index(np.sort(rng.uniform(0, 100, 1000)))
    preds = [Predicate.between(10, 12), Predicate.between(40, 80),
             Predicate(lo=5.0, hi=1.0), Predicate.between(-1e30, 1e30)]
    dense = idx.search_batch(preds)
    res = idx.search_compact_batch(preds, max_selected=idx.table.num_pages,
                                   top_k=8)
    assert not np.asarray(res.truncated).any()
    np.testing.assert_array_equal(np.asarray(res.counts),
                                  np.asarray(dense.counts))
    np.testing.assert_array_equal(np.asarray(res.pages_inspected),
                                  np.asarray(dense.pages_inspected))
    # row ids: first 8 qualifying rows of each predicate, ascending
    keys = idx.table.keys[: idx.table.num_pages].reshape(-1)
    valid = idx.table.valid[: idx.table.num_pages].reshape(-1)
    for q, p in enumerate(preds):
        lo, hi = max(p.lo, -3.4e38), min(p.hi, 3.4e38)
        want = np.flatnonzero(valid & (keys >= lo) & (keys <= hi))[:8]
        ids = np.asarray(res.row_ids[q])
        np.testing.assert_array_equal(ids[ids >= 0], want, q)


def test_false_positive_filtering_is_effective():
    # Sorted data => contiguous buckets per entry => small range predicates
    # should prune most pages (the paper's headline search behaviour).
    values = np.linspace(0, 1000, 4000)
    idx = make_index(values, resolution=64, density=0.2)
    res = idx.search(Predicate.between(10, 20))
    assert int(res.count) == brute_force(idx.table, 10, 20)
    assert int(res.pages_inspected) < idx.table.num_pages * 0.2


def test_equality_and_open_predicates():
    rng = np.random.default_rng(4)
    values = rng.uniform(0, 100, 1000)
    idx = make_index(values)
    v = float(values[123])
    res = idx.search(Predicate.equality(v))
    assert int(res.count) == brute_force(idx.table, v, v)
    res = idx.search(Predicate.greater(50.0))
    assert int(res.count) == int((values > 50.0).sum())
    res = idx.search(Predicate.less(50.0).and_(Predicate.greater(25.0)))
    assert int(res.count) == int(((values < 50.0) & (values > 25.0)).sum())


def test_density_threshold_controls_entry_count():
    rng = np.random.default_rng(5)
    values = rng.uniform(0, 1000, 8000)
    sizes = {}
    for d in (0.2, 0.4, 0.8):
        idx = make_index(values, resolution=400, density=d, page_card=50)
        sizes[d] = idx.num_entries
    # §6.2 Observation 1: higher density => fewer entries.
    assert sizes[0.2] > sizes[0.4] > sizes[0.8]
