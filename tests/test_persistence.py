"""Durable storage: snapshot round-trips, WAL crash recovery, layout safety.

The acceptance contract of ``repro.checkpointing``'s index persistence:

- ``save_index``/``load_index`` round-trip a sharded index *bit-identically*
  — counts and row ids against the live index and brute force, across shard
  count x summary policy x staged-overlay state x mixed bounds epochs.
- A crash at any injected drain point (pre journal append, post-append
  pre-swap, post-swap pre-truncate) recovers via ``QueryEngine.recover`` —
  last committed snapshot + journal replay — to exactly the acknowledged
  state: no acknowledged write lost, no record double-applied, and an
  uncommitted partial snapshot directory is never loaded.
- The binary section container refuses corruption (truncation, version
  bumps, flipped payload bytes) with ``CorruptSnapshotError`` instead of
  constructing arrays from garbage; arbitrary dtypes/shapes round-trip
  byte-exactly (the hypothesis twin lives in
  ``tests/test_persistence_property.py``).
- ``checkpointing.save_checkpoint`` publishes its ``COMMITTED`` sentinel
  only after every payload file is fsynced, via fsync-then-atomic-rename.

Crash simulation note: the writer's in-memory rollback never touches disk,
so raising from an injected hook and then recovering *from disk alone*
(fresh objects, nothing reused) faithfully models a kill -9 at that point.
"""
import struct

import numpy as np
import pytest

import repro.runtime.writer as writer_mod
from repro.checkpointing.checkpoint import restore_checkpoint, save_checkpoint
from repro.checkpointing.layout import (CorruptSnapshotError, pack_sections,
                                        read_section_file, unpack_sections,
                                        write_section_file)
import repro.checkpointing.snapshot as snap_mod
from repro.checkpointing.snapshot import (delta_chain, disk_usage,
                                          latest_delta_seq, latest_epoch,
                                          load_index, recover_index,
                                          save_delta, save_index)
from repro.checkpointing.wal import Journal
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.runtime.writer import MaintenanceWriter
from repro.storage.table import PagedTable

pytestmark = pytest.mark.persist


def make_sidx(values, num_shards=4, page_card=8, resolution=32, density=0.25,
              spare_pages=256, **kw):
    table = PagedTable.from_values(np.asarray(values).copy(),
                                   page_card=page_card,
                                   spare_pages=spare_pages)
    return ShardedHippoIndex.create(table, num_shards=num_shards,
                                    resolution=resolution, density=density,
                                    **kw)


def preds():
    """Empty, point, narrow, drifted-region, spanning, and full-table."""
    return [
        Predicate(lo=5.0, hi=1.0),
        Predicate.equality(50.0),
        Predicate.between(20.0, 24.0),
        Predicate.between(108.0, 114.0),
        Predicate.between(80.0, 125.0),
        Predicate.between(-1e30, 1e30),
    ]


def value_brute(values, ps) -> np.ndarray:
    """Counts straight off the acknowledged value multiset — independent of
    the table/staging split, so it checks recovered engines in any drain
    state."""
    v = np.asarray(values, np.float32)
    return np.asarray([((v >= p.lo) & (v <= p.hi)).sum() for p in ps],
                      np.int64)


def engine_counts_and_rows(index, writer, ps, top_k=16):
    """Counts + row ids through a compact engine over ``index``."""
    eng = QueryEngine(index, batch=8, drain_policy="manual",
                      auto_resummarize=False, top_k=top_k, writer=writer)
    tickets = [eng.submit(p) for p in ps]
    eng.drain()
    counts = np.asarray([t.count for t in tickets], np.int64)
    rows = [np.asarray(t.row_ids) for t in tickets]
    return counts, rows


# ---------------------------------------------------------------------------
# Satellite 1: save/load round-trip equivalence sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_shards", [1, 4])
@pytest.mark.parametrize("summary", ["equal_mass", "learned"])
@pytest.mark.parametrize("staged", [False, True])
def test_round_trip_counts_and_rows_bit_identical(tmp_path, num_shards,
                                                  summary, staged):
    """The full sweep: a recovered index answers every predicate with
    counts and row ids bit-identical to the live index it was saved from,
    under mixed bounds epochs and (optionally) a staged overlay."""
    rng = np.random.default_rng(7 * num_shards + staged)
    base = np.sort(rng.uniform(0, 100, 300))
    idx = make_sidx(base, num_shards=num_shards, summary=summary)
    writer = MaintenanceWriter(idx)
    drained = rng.uniform(100, 130, 48)
    for v in drained:
        writer.write(float(v))
    writer.flush()
    # mixed bounds epochs: schedule a remap of every shard but drain only
    # half the units — the snapshot must carry both the bumped and the
    # unbumped epochs plus the still-pending remap
    writer.schedule_resummarize()
    writer.drain(max_units=max(1, num_shards // 2))
    pending = rng.uniform(125, 140, 12) if staged else np.zeros(0)
    for v in pending:
        writer.write(float(v))

    live = np.concatenate([base, drained, pending]).astype(np.float32)
    ps = preds()
    want_counts, want_rows = engine_counts_and_rows(idx, writer, ps)
    np.testing.assert_array_equal(want_counts, value_brute(live, ps))

    idx.save(tmp_path)
    idx2, writer2, _ = recover_index(tmp_path, wal_sync=False)
    got_counts, got_rows = engine_counts_and_rows(idx2, writer2, ps)
    np.testing.assert_array_equal(got_counts, want_counts)
    for g, w in zip(got_rows, want_rows):
        np.testing.assert_array_equal(g, w)
    np.testing.assert_array_equal(idx2.bounds_epochs, idx.bounds_epochs)
    if num_shards > 1:
        assert len(set(idx.bounds_epochs.tolist())) > 1, \
            "sweep lost its mixed-epoch shape (test setup rot)"
    assert idx2.summary == idx.summary
    assert (writer2.queue_depth, writer2.staged_rows) == \
        (writer.queue_depth, writer.staged_rows)
    assert writer2.pending_resummarize_shards() == \
        writer.pending_resummarize_shards()
    # the pending remap drains identically on the recovered side
    writer.flush()
    writer2.flush()
    np.testing.assert_array_equal(idx2.bounds_epochs, idx.bounds_epochs)
    g2, _ = engine_counts_and_rows(idx2, writer2, ps)
    np.testing.assert_array_equal(g2, value_brute(live, ps))


def test_writerless_load_matches_saved_counts(tmp_path):
    """``ShardedHippoIndex.load`` (no journal, no writer) round-trips a
    drained index exactly, counters and config included."""
    rng = np.random.default_rng(11)
    idx = make_sidx(np.sort(rng.uniform(0, 100, 240)))
    ps = preds()
    want = np.asarray(idx.search_batch(ps).counts)
    idx.save(tmp_path)
    idx2 = ShardedHippoIndex.load(tmp_path)
    np.testing.assert_array_equal(np.asarray(idx2.search_batch(ps).counts),
                                  want)
    assert idx2.cfg == idx.cfg
    assert idx2.counters == idx.counters
    assert idx2.nbytes() == idx.nbytes()


# ---------------------------------------------------------------------------
# Satellite 2: crash-injection recovery (snapshot + journal replay)
# ---------------------------------------------------------------------------

def _durable_engine(root, base):
    idx = make_sidx(base, num_shards=4)
    return QueryEngine(idx, batch=8, drain_policy="manual",
                       auto_resummarize=False, storage_dir=root)


def _recover(root):
    return QueryEngine.recover(root, drain_policy="manual",
                               auto_resummarize=False)


class _Boom(RuntimeError):
    pass


def test_crash_pre_append_loses_only_the_unacknowledged_write(
        tmp_path, monkeypatch):
    """A journal append that dies leaves the write unacknowledged and
    unstaged; recovery serves exactly the writes acknowledged before it."""
    rng = np.random.default_rng(0)
    base = np.sort(rng.uniform(0, 100, 200))
    root = tmp_path / "dur"
    eng = _durable_engine(root, base)
    acked = [float(v) for v in rng.uniform(100, 130, 20)]
    for v in acked:
        eng.write(v)

    def boom(self, shard, value):
        raise _Boom("torn journal append")
    monkeypatch.setattr(Journal, "append_insert", boom)
    with pytest.raises(_Boom):
        eng.write(999.0)
    assert eng.writer.queue_depth == len(acked), \
        "a failed append must stage nothing"
    monkeypatch.undo()

    del eng   # kill -9: disk is all that survives
    eng2 = _recover(root)
    eng2.flush()
    ps = preds()
    np.testing.assert_array_equal(
        eng2.run_all(ps), value_brute(np.concatenate([base, acked]), ps))


def test_crash_mid_drain_pre_swap_recovers_every_acknowledged_write(
        tmp_path, monkeypatch):
    """Dying at the swap (post-append, pre-publish) rolls nothing onto disk;
    recovery replays the journal suffix over the last committed snapshot and
    no acknowledged write is lost."""
    rng = np.random.default_rng(1)
    base = np.sort(rng.uniform(0, 100, 200))
    root = tmp_path / "dur"
    eng = _durable_engine(root, base)
    first = [float(v) for v in rng.uniform(100, 115, 16)]
    for v in first:
        eng.write(v)
    eng.flush()            # drained + snapshotted: the committed base
    second = [float(v) for v in rng.uniform(115, 130, 16)]
    for v in second:
        eng.write(v)
    eng.delete(10.0, 12.0)  # journaled delete rides the same recovery

    def boom(shards, s, st):
        raise _Boom("killed at the swap")
    monkeypatch.setattr(writer_mod, "set_shard", boom)
    with pytest.raises(_Boom):
        eng.flush()
    monkeypatch.undo()

    survivors = np.concatenate([base[(base < 10.0) | (base > 12.0)],
                                first, second])
    del eng
    eng2 = _recover(root)
    eng2.flush()
    ps = preds()
    np.testing.assert_array_equal(eng2.run_all(ps),
                                  value_brute(survivors, ps))


def test_crash_post_swap_pre_truncate_never_double_applies(
        tmp_path, monkeypatch):
    """Dying between the post-drain snapshot commit and the journal
    truncation leaves every drained record still in the journal; the
    snapshot's wal watermark must keep replay from applying them twice."""
    rng = np.random.default_rng(2)
    base = np.sort(rng.uniform(0, 100, 200))
    root = tmp_path / "dur"
    eng = _durable_engine(root, base)
    writes = [float(v) for v in rng.uniform(100, 130, 24)]
    for v in writes:
        eng.write(v)

    def boom(self):
        raise _Boom("killed before journal truncation")
    monkeypatch.setattr(Journal, "reset", boom)
    with pytest.raises(_Boom):
        eng.flush()        # drain + snapshot commit succeed, truncate dies
    monkeypatch.undo()
    assert Journal(root, 4, sync=False).replay(), \
        "setup rot: the journal should still hold the drained records"

    expected = np.concatenate([base, writes])
    del eng
    eng2 = _recover(root)
    eng2.flush()
    ps = preds()
    np.testing.assert_array_equal(eng2.run_all(ps),
                                  value_brute(expected, ps))
    full = Predicate.between(-1e30, 1e30)
    assert eng2.run_all([full])[0] == expected.size, \
        "double-applied journal records inflated the full-table count"


def test_partial_uncommitted_snapshot_is_never_loaded(tmp_path):
    """A snapshot directory without the COMMITTED sentinel — a crash
    mid-save — must be invisible to epoch listing, load, and recovery,
    whatever garbage it holds."""
    rng = np.random.default_rng(3)
    base = np.sort(rng.uniform(0, 100, 200))
    root = tmp_path / "dur"
    eng = _durable_engine(root, base)
    for v in rng.uniform(100, 120, 8):
        eng.write(float(v))
    eng.flush()
    committed = latest_epoch(root)
    ps = preds()
    want = eng.run_all(ps)

    partial = root / f"snap_{committed + 5}"
    partial.mkdir()
    (partial / "index.bin").write_bytes(b"\x00garbage, never to be read")
    assert latest_epoch(root) == committed
    del eng
    eng2 = _recover(root)
    np.testing.assert_array_equal(eng2.run_all(ps), want)


def test_fresh_dir_guard_refuses_existing_durable_state(tmp_path):
    """A new engine pointed at a directory that already holds durable state
    must refuse — adopting it silently would shadow the acknowledged
    history that only recover() replays."""
    rng = np.random.default_rng(4)
    base = np.sort(rng.uniform(0, 100, 160))
    root = tmp_path / "dur"
    eng = _durable_engine(root, base)
    eng.write(105.0)
    del eng
    with pytest.raises(ValueError, match="recover"):
        _durable_engine(root, base)


# ---------------------------------------------------------------------------
# Satellite 3 (seeded half): binary layout round-trip + corruption refusal
# ---------------------------------------------------------------------------

_DTYPES = ["float32", "float64", "int32", "int64", "uint8", "uint32", "bool"]


def _arbitrary_sections(rng, n):
    out = {}
    for i in range(n):
        dt = np.dtype(_DTYPES[int(rng.integers(len(_DTYPES)))])
        ndim = int(rng.integers(0, 4))
        shape = tuple(int(rng.integers(0, 5)) for _ in range(ndim))
        raw = rng.integers(0, 256, size=(int(np.prod(shape, dtype=np.int64))
                                         * max(dt.itemsize, 1),),
                           dtype=np.uint8)
        out[f"sec_{i}/d{dt.name}"] = raw.view(np.uint8)[
            : int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        ].copy().view(dt).reshape(shape)
    return out


def test_layout_round_trips_arbitrary_dtypes_byte_exactly(tmp_path):
    rng = np.random.default_rng(5)
    for trial in range(20):
        sections = _arbitrary_sections(rng, int(rng.integers(1, 8)))
        back = unpack_sections(pack_sections(sections), origin="test")
        assert set(back) == set(sections)
        for name, arr in sections.items():
            got = back[name]
            assert got.dtype == arr.dtype and got.shape == arr.shape
            assert got.tobytes() == arr.tobytes(), \
                f"trial {trial}: section {name} not byte-exact"
        path = tmp_path / f"t{trial}.bin"
        write_section_file(path, sections)
        back2 = read_section_file(path)
        for name, arr in sections.items():
            assert back2[name].tobytes() == arr.tobytes()


def test_layout_refuses_truncation_everywhere(tmp_path):
    rng = np.random.default_rng(6)
    data = pack_sections({"a": rng.standard_normal(64).astype(np.float32),
                          "b": rng.integers(0, 9, 33).astype(np.int64)})
    for cut in (0, 7, 32, 63, 64, len(data) // 2, len(data) - 1):
        with pytest.raises(CorruptSnapshotError):
            unpack_sections(data[:cut], origin=f"cut@{cut}")


def test_layout_refuses_version_bump_and_bad_magic(tmp_path):
    data = bytearray(pack_sections({"a": np.arange(8, dtype=np.float32)}))
    bumped = bytearray(data)
    bumped[8:12] = struct.pack("<I", 2)    # version field of the header
    with pytest.raises(CorruptSnapshotError, match="version"):
        unpack_sections(bytes(bumped), origin="version-bump")
    nomagic = bytearray(data)
    nomagic[0] ^= 0xFF
    with pytest.raises(CorruptSnapshotError):
        unpack_sections(bytes(nomagic), origin="bad-magic")


def test_layout_refuses_flipped_payload_byte(tmp_path):
    data = bytearray(pack_sections({"a": np.arange(64, dtype=np.float32)}))
    data[-5] ^= 0x40                        # deep in the last payload
    with pytest.raises(CorruptSnapshotError, match="crc|checksum|CRC"):
        unpack_sections(bytes(data), origin="bitflip")


def test_load_index_surfaces_corruption_cleanly(tmp_path):
    """A committed snapshot whose payload rotted on disk must raise
    CorruptSnapshotError from load, not construct a wrong index."""
    rng = np.random.default_rng(8)
    idx = make_sidx(np.sort(rng.uniform(0, 100, 160)))
    snap = idx.save(tmp_path)
    f = snap / "index.bin"
    blob = bytearray(f.read_bytes())
    blob[len(blob) // 2] ^= 0x01
    f.write_bytes(bytes(blob))
    with pytest.raises(CorruptSnapshotError):
        load_index(tmp_path)


def test_disk_usage_splits_table_from_index(tmp_path):
    rng = np.random.default_rng(9)
    idx = make_sidx(np.sort(rng.uniform(0, 100, 160)))
    u = disk_usage(save_index(tmp_path, idx))
    assert u["table"] > 0 and u["index"] > 0
    assert u["table"] + u["index"] == u["total"]


# ---------------------------------------------------------------------------
# Satellite 4: checkpoint sentinel durability regression
# ---------------------------------------------------------------------------

def test_save_checkpoint_fsyncs_payload_before_sentinel(tmp_path, monkeypatch):
    """The async-writer commit protocol: every leaf and the manifest are
    fsynced strictly before the COMMITTED sentinel is published, and the
    sentinel lands via the fsync-then-atomic-rename helper (a bare touch()
    could surface after a crash with torn leaves behind it)."""
    import repro.checkpointing.checkpoint as ckpt_mod
    events = []
    real_fsync, real_commit = ckpt_mod.fsync_file, ckpt_mod.commit_sentinel
    monkeypatch.setattr(ckpt_mod, "fsync_file",
                        lambda p: (events.append(("fsync", p.name)),
                                   real_fsync(p))[1])
    monkeypatch.setattr(ckpt_mod, "commit_sentinel",
                        lambda d: (events.append(("commit", d.name)),
                                   real_commit(d))[1])
    tree = {"w": np.arange(6, dtype=np.float32),
            "b": np.zeros((2, 3), np.float32)}
    t = save_checkpoint(tmp_path, 3, tree, async_write=True)
    t.join()
    kinds = [k for k, _ in events]
    assert kinds[-1] == "commit" and kinds.count("commit") == 1
    synced = {n for k, n in events if k == "fsync"}
    assert {"leaf_0.npy", "leaf_1.npy", "manifest.json"} <= synced
    assert (tmp_path / "step_3" / "COMMITTED").exists()
    step, back = restore_checkpoint(tmp_path, treedef_like=tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(back["w"]), tree["w"])


# ---------------------------------------------------------------------------
# WAL unit coverage: framing, torn tails, watermarks
# ---------------------------------------------------------------------------

def test_journal_replay_is_exact_and_ordered(tmp_path):
    j = Journal(tmp_path, 4, sync=False)
    j.append_insert(1, 10.5)
    j.append_delete(3.0, 4.0)
    j.append_insert(0, -2.0)
    bounds = np.linspace(0.0, 1.0, 9).astype(np.float32)
    j.append_resummarize(bounds, "learned")
    recs = j.replay()
    assert [r.kind for r in recs] == [1, 2, 1, 3]
    assert [r.seqno for r in recs] == [1, 2, 3, 4]
    assert (recs[0].shard, recs[0].value) == (1, 10.5)
    assert (recs[1].lo, recs[1].hi) == (3.0, 4.0)
    assert recs[3].policy == "learned"
    np.testing.assert_array_equal(recs[3].bounds, bounds)
    assert [r.seqno for r in j.replay(after=2)] == [3, 4]


def test_journal_ignores_torn_tail_and_keeps_seqnos_monotonic(tmp_path):
    j = Journal(tmp_path, 2, sync=False)
    for i in range(5):
        j.append_insert(i % 2, float(i))
    log = tmp_path / "wal" / "shard_1.log"
    log.write_bytes(log.read_bytes()[:-3])      # torn final record
    j2 = Journal(tmp_path, 2, sync=False)
    survivors = j2.replay()
    assert len(survivors) == 4, "only the torn record may be dropped"
    j2.reset()
    j2.append_insert(0, 9.0)
    assert j2.replay()[0].seqno > 5, \
        "seqnos must keep increasing across reset() or watermarks break"


def test_truncate_through_drops_only_at_or_below_watermark(tmp_path):
    """The background persister's watermark-aware journal GC: records past
    the watermark survive byte-identically (a fresh Journal re-reads them
    and resumes seqnos after them); records at or below it are gone."""
    j = Journal(tmp_path, 2, sync=False)
    for i in range(6):
        j.append_insert(i % 2, float(i))
    j.append_delete(1.0, 2.0)                                   # seqno 7
    bounds = np.linspace(0.0, 1.0, 9).astype(np.float32)
    j.append_resummarize(bounds, "learned")                     # seqno 8
    j.truncate_through(5)
    assert [r.seqno for r in j.replay()] == [6, 7, 8]
    j2 = Journal(tmp_path, 2, sync=False)       # fresh scan of the rewrite
    recs = j2.replay()
    assert [r.seqno for r in recs] == [6, 7, 8]
    assert j2.last_seqno == 8, "seqno allocation must resume after survivors"
    assert (recs[1].lo, recs[1].hi) == (1.0, 2.0)
    assert recs[2].policy == "learned"
    np.testing.assert_array_equal(recs[2].bounds, bounds)
    j2.truncate_through(100)
    assert j2.replay() == [], "a watermark past everything empties the logs"


# ---------------------------------------------------------------------------
# Incremental snapshots: delta chains, compaction, tombstone pruning
# ---------------------------------------------------------------------------

def test_delta_round_trip_counts_and_rows_bit_identical(tmp_path):
    """A full snapshot + one delta capturing the drained/vacuumed shards
    loads to exactly the live index's counts and row ids — and to brute
    force over the surviving value multiset."""
    rng = np.random.default_rng(21)
    base = np.sort(rng.uniform(0, 100, 300))
    idx = make_sidx(base)
    w = MaintenanceWriter(idx)
    save_index(tmp_path, idx, wal_seqno=0)
    vals = [float(v) for v in base]
    for v in rng.uniform(100.0, 128.0, 40):
        w.write(float(v))
        vals.append(float(v))
    w.flush()
    w.delete(10.0, 14.0)       # validity flips outside the drained shards
    vals = [v for v in vals if not 10.0 <= v <= 14.0]
    w.flush()
    shards = w.dirty_checkpoint_shards()
    assert shards, "drains and deletes must mark their shards dirty"
    save_delta(tmp_path, idx, shards=shards)
    assert latest_delta_seq(tmp_path, latest_epoch(tmp_path)) == 1

    idx2, meta = load_index(tmp_path)
    assert meta["deltas"] == 1
    ps = preds()
    counts1, rows1 = engine_counts_and_rows(idx, w, ps)
    counts2, rows2 = engine_counts_and_rows(idx2, None, ps)
    np.testing.assert_array_equal(counts2, counts1)
    for a, b in zip(rows1, rows2):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(counts2, value_brute(vals, ps))


def test_delta_chain_gap_is_refused(tmp_path):
    """A committed delta k without every committed delta below it means a
    skipped commit; replaying across the hole would silently lose shards —
    loading must refuse."""
    import shutil
    rng = np.random.default_rng(22)
    idx = make_sidx(np.sort(rng.uniform(0, 100, 200)))
    w = MaintenanceWriter(idx)
    save_index(tmp_path, idx, wal_seqno=0)
    for k in range(2):
        for v in rng.uniform(100.0, 120.0, 8):
            w.write(float(v))
        w.flush()
        save_delta(tmp_path, idx, shards=w.dirty_checkpoint_shards())
        w.clear_checkpoint_dirty()
    assert latest_delta_seq(tmp_path, 1) == 2
    shutil.rmtree(tmp_path / "delta_1_1")
    with pytest.raises(CorruptSnapshotError, match="delta chain"):
        load_index(tmp_path)
    with pytest.raises(CorruptSnapshotError, match="delta chain"):
        delta_chain(tmp_path, 1)


def test_prune_renames_to_tombstone_before_rmtree(tmp_path, monkeypatch):
    """Satellite regression: pruning must rename a doomed snapshot to
    ``*.tombstone`` *before* deleting it, so a crash mid-prune (simulated
    by an rmtree that never runs) leaves no discoverable directory that
    still carries a COMMITTED sentinel — and the next save sweeps the
    leftover tombstone."""
    rng = np.random.default_rng(23)
    idx = make_sidx(np.sort(rng.uniform(0, 100, 160)))
    save_index(tmp_path, idx, keep=1)          # snap_1
    monkeypatch.setattr(snap_mod.shutil, "rmtree",
                        lambda *a, **k: None)   # crash: delete never lands
    save_index(tmp_path, idx, keep=1)          # snap_2 prunes snap_1
    monkeypatch.undo()

    tomb = tmp_path / "snap_1.tombstone"
    assert tomb.exists(), "prune must rename before any rmtree"
    assert (tomb / "COMMITTED").exists(), \
        "setup rot: the crash should leave the sentinel inside the tombstone"
    assert not (tmp_path / "snap_1").exists()
    assert latest_epoch(tmp_path) == 2, \
        "a tombstoned COMMITTED sentinel must be invisible to discovery"
    idx2, _ = load_index(tmp_path)             # loads snap_2, not the tomb
    ps = preds()
    c1, _ = engine_counts_and_rows(idx, None, ps)
    c2, _ = engine_counts_and_rows(idx2, None, ps)
    np.testing.assert_array_equal(c2, c1)

    save_index(tmp_path, idx, keep=1)          # snap_3: sweeps the leftover
    assert not tomb.exists(), "the next save must sweep crash tombstones"


def test_prune_drops_a_superseded_base_with_its_delta_chain(tmp_path):
    """Compaction hygiene: when an old full base falls out of ``keep``,
    its deltas go with it — they are unreadable without their base."""
    rng = np.random.default_rng(24)
    idx = make_sidx(np.sort(rng.uniform(0, 100, 200)))
    w = MaintenanceWriter(idx)
    save_index(tmp_path, idx, keep=1)          # snap_1
    for v in rng.uniform(100.0, 120.0, 8):
        w.write(float(v))
    w.flush()
    save_delta(tmp_path, idx, shards=w.dirty_checkpoint_shards())
    w.clear_checkpoint_dirty()
    save_index(tmp_path, idx, keep=1, compact=True)   # snap_2 folds chain
    names = {p.name for p in tmp_path.iterdir()}
    assert "snap_2" in names
    assert "snap_1" not in names and "delta_1_1" not in names, \
        "a pruned base must take its delta chain with it"


def test_incremental_engine_builds_chain_then_compacts(tmp_path):
    """Engine e2e on the default incremental mode: each drain commits a
    delta ≪ the full base, the K policy folds the chain into a fresh full
    snapshot, and recovery off the chain is bit-identical to brute force."""
    rng = np.random.default_rng(25)
    base = np.sort(rng.uniform(0, 100, 200))
    root = tmp_path / "dur"
    idx = make_sidx(base)
    eng = QueryEngine(idx, batch=8, drain_policy="manual",
                      auto_resummarize=False, storage_dir=root,
                      compact_every=3, compact_ratio=1e9)  # isolate K policy
    vals = [float(v) for v in base]
    for step in range(4):
        for v in rng.uniform(100.0, 130.0, 8):
            eng.write(float(v))
            vals.append(float(v))
        eng.flush()
    names = {p.name for p in root.iterdir() if p.is_dir()}
    assert {"snap_1", "delta_1_1", "delta_1_2", "delta_1_3",
            "snap_2"} <= names, f"unexpected chain layout: {sorted(names)}"
    full = (root / "snap_1" / "index.bin").stat().st_size
    for k in range(1, 4):
        d = (root / f"delta_1_{k}" / "index.bin").stat().st_size
        assert d < full, \
            f"delta_{k} ({d}B) should be smaller than its base ({full}B)"
    assert eng.stats.persists == 5          # initial full + 3 deltas + fold
    assert eng.stats.persist_lag == 0

    del eng
    eng2 = _recover(root)
    eng2.flush()
    ps = preds()
    np.testing.assert_array_equal(eng2.run_all(ps), value_brute(vals, ps))


def test_background_save_poison_falls_back_to_sync_full(tmp_path,
                                                        monkeypatch):
    """A failed background commit poisons the persister (queued commits
    must not leapfrog a hole in the chain); flush_durable surfaces it, and
    the next drain commit self-heals through a synchronous full snapshot
    that supersedes the broken chain and re-enables background saves."""
    from repro.runtime.persister import PersisterPoisoned
    rng = np.random.default_rng(26)
    base = np.sort(rng.uniform(0, 100, 200))
    root = tmp_path / "dur"
    eng = QueryEngine(make_sidx(base), batch=8, drain_policy="manual",
                      auto_resummarize=False, storage_dir=root,
                      background_save=True)
    vals = [float(v) for v in base]

    def boom(*a, **k):
        raise _Boom("disk full")
    monkeypatch.setattr(snap_mod, "write_delta_snapshot", boom)
    for v in rng.uniform(100.0, 120.0, 8):
        eng.write(float(v))
        vals.append(float(v))
    eng.flush()                      # delta job fails on the worker thread
    with pytest.raises(PersisterPoisoned):
        eng.flush_durable()
    assert eng._persister.stats_snapshot().failed == 1
    monkeypatch.undo()

    for v in rng.uniform(120.0, 130.0, 8):
        eng.write(float(v))
        vals.append(float(v))
    eng.flush()                      # poisoned submit -> sync full fallback
    eng.flush_durable()              # clean: the chain was superseded
    assert not eng._persister.poisoned

    eng.close()
    eng2 = _recover(root)
    eng2.flush()
    ps = preds()
    np.testing.assert_array_equal(eng2.run_all(ps), value_brute(vals, ps))


# ---------------------------------------------------------------------------
# hippolint regressions: journal-before-admission in schedule_resummarize,
# and the durable watermark crossing the persister/foreground thread line
# ---------------------------------------------------------------------------

def test_resummarize_journals_before_admission(tmp_path):
    """Regression for the crash-pass finding in schedule_resummarize: the
    learned model, fallback/refit counters, and pending bounds were
    admitted *before* the WAL append. A crash at the append (kill -9
    stand-in) must leave the writer exactly as it was — the operation was
    never acknowledged, so no trace of it may survive."""
    from repro.runtime.faultinject import InjectedCrash, crash_points
    rng = np.random.default_rng(31)
    base = np.sort(rng.uniform(0, 100, 200))
    idx = make_sidx(base, summary="learned")
    writer = MaintenanceWriter(idx)
    writer.journal = Journal(tmp_path, idx.spec.num_shards, sync=False)
    for v in rng.uniform(0, 100, 64):
        writer.write(float(v))
    writer.flush()

    def state():
        return (writer._pending_model, writer._pending_bounds,
                writer.stats.learned_refits, writer.stats.learned_fallbacks,
                writer.pending_resummarize_shards())

    before = state()
    wm = writer.journal.last_seqno
    crash_points.arm("wal.pre_append", times=1)
    try:
        with pytest.raises(InjectedCrash):
            writer.schedule_resummarize()
    finally:
        crash_points.reset()
    assert state() == before, \
        "a crashed (unacknowledged) resummarize left writer state behind"
    assert writer.journal.last_seqno == wm, "nothing may have been appended"
    # and with the crash gone, the same call goes through whole
    writer.schedule_resummarize()
    assert writer.journal.last_seqno == wm + 1
    assert writer.pending_resummarize_shards()


def test_background_watermark_advances_under_lock(tmp_path):
    """Regression for the locks-pass finding on _durable_watermark: the
    persister's commit callback advances it on the worker thread while
    the foreground derives persist_lag from it. After the flush barrier
    the locked read must equal the journal watermark exactly."""
    rng = np.random.default_rng(33)
    base = np.sort(rng.uniform(0, 100, 200))
    root = tmp_path / "dur"
    eng = QueryEngine(make_sidx(base), batch=8, drain_policy="manual",
                      auto_resummarize=False, storage_dir=root,
                      background_save=True)
    for v in rng.uniform(100, 120, 8):
        eng.write(float(v))
    eng.flush()                       # drain -> background delta commit
    eng.flush_durable()
    with eng._durable_lock:
        wm = eng._durable_watermark
    assert wm == eng.journal.last_seqno > 0
    eng._sync_writer_stats()
    assert eng.stats.persist_lag == 0
    assert eng.stats.persist_pending == 0
    eng.close()
