"""Async maintenance writer: interleaved write/delete/query/vacuum through
the engine must produce counts bit-identical to a fully-synchronous oracle,
drains must stay shard-local and atomic (refusals roll back cleanly), and
queries during a mid-flight shard swap must refuse loudly."""
import numpy as np
import pytest

from repro.core.partition import ShardedHippoIndex, shard_state
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.runtime.writer import MaintenanceWriter
from repro.storage.table import PagedTable

pytestmark = pytest.mark.writer


def make_sidx(values, num_shards=4, page_card=8, resolution=32, density=0.25,
              spare_pages=256, **kw):
    table = PagedTable.from_values(np.asarray(values).copy(),
                                   page_card=page_card,
                                   spare_pages=spare_pages)
    return ShardedHippoIndex.create(table, num_shards=num_shards,
                                    resolution=resolution, density=density,
                                    **kw)


def brute_force(table, lo, hi):
    live = table.valid[: table.num_pages]
    keys = table.keys[: table.num_pages]
    return int((live & (keys >= lo) & (keys <= hi)).sum())


def workload(rng, n):
    preds = []
    for _ in range(n):
        lo = float(rng.uniform(0, 100))
        preds.append(Predicate.between(lo, lo + float(rng.uniform(0, 30))))
    preds += [
        Predicate(lo=5.0, hi=1.0),            # empty interval
        Predicate.between(-1e30, 1e30),       # full table
        Predicate.equality(float(rng.uniform(0, 100))),
    ]
    return preds


# ---------------------------------------------------------------------------
# The acceptance invariant: staged == synchronous, at every query point
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["between_batches", "on_depth", "manual"])
def test_interleaved_ops_match_sync_oracle(policy):
    """Random write/delete/query streams: the async engine's counts equal a
    fully-synchronous ShardedHippoIndex oracle after every single query,
    whether staged rows are still queued or already drained."""
    rng = np.random.default_rng({"between_batches": 0, "on_depth": 1,
                                 "manual": 2}[policy])
    base = rng.uniform(0, 100, 300)
    sync = make_sidx(base)
    aidx = make_sidx(base)
    engine = QueryEngine(aidx, batch=8, drain_policy=policy, drain_depth=16)
    preds = workload(rng, 5)
    for step in range(30):
        op = rng.choice(["write", "write", "write", "delete", "query"])
        if op == "write":
            v = float(rng.uniform(0, 100))
            sync.insert(v)
            engine.write(v)
        elif op == "delete":
            lo = float(rng.uniform(0, 90))
            sync.table.delete_where(lo, lo + 3.0)
            sync.vacuum()
            engine.delete(lo, lo + 3.0)
        else:
            got = engine.run_all(preds)
            want = np.asarray(sync.search_batch(preds).counts, np.int64)
            np.testing.assert_array_equal(got, want, err_msg=f"step {step}")
    engine.flush()
    assert engine.writer.queue_depth == 0
    got = engine.run_all(preds)
    want = np.asarray(sync.search_batch(preds).counts, np.int64)
    np.testing.assert_array_equal(got, want)
    truth = [brute_force(aidx.table, *p.selectivity_interval()) for p in preds]
    np.testing.assert_array_equal(got, truth)


def test_write_query_vacuum_query_sequence():
    """The ISSUE's canonical sequence: write -> query -> vacuum -> query,
    staged and synchronous paths bit-identical throughout."""
    rng = np.random.default_rng(7)
    base = rng.uniform(0, 100, 400)
    sync = make_sidx(base)
    aidx = make_sidx(base)
    engine = QueryEngine(aidx, batch=8)        # default: between_batches
    preds = workload(rng, 8)

    for v in rng.uniform(0, 100, 40):
        sync.insert(float(v))
        engine.write(float(v))
    np.testing.assert_array_equal(
        engine.run_all(preds), np.asarray(sync.search_batch(preds).counts))

    sync.table.delete_where(30, 45)
    sync.vacuum()
    engine.delete(30, 45)
    np.testing.assert_array_equal(
        engine.run_all(preds), np.asarray(sync.search_batch(preds).counts))

    engine.flush()                              # drains remaining vacuums too
    assert not aidx.table.dirty[: aidx.table.num_pages].any()
    np.testing.assert_array_equal(
        engine.run_all(preds), np.asarray(sync.search_batch(preds).counts))


def test_counts_exact_while_rows_still_staged():
    """The never-stale contract: queries see staged rows before any drain,
    on both the fused dense path and the summary-routed dispatch."""
    rng = np.random.default_rng(11)
    aidx = make_sidx(rng.uniform(0, 100, 200))
    writer = MaintenanceWriter(aidx)
    card = aidx.table.cardinality
    for v in [10.0, 10.5, 11.0, 95.0]:
        writer.write(v)
    assert writer.queue_depth == 4
    # fused (Q, S) dense path via the index surface
    assert int(aidx.search_batch([Predicate.between(-1e30, 1e30)]).counts[0]) \
        == card + 4
    assert int(aidx.search_batch([Predicate.between(10, 11)]).counts[0]) \
        == brute_force(aidx.table, 10, 11) + 3
    # summary-routed engine dispatch (staged rows can't be pruned away)
    engine = QueryEngine(aidx, batch=4, drain_policy="manual", writer=writer)
    got = engine.run_all([Predicate.between(10, 11),
                          Predicate.between(-1e30, 1e30)])
    np.testing.assert_array_equal(
        got, [brute_force(aidx.table, 10, 11) + 3, card + 4])
    assert writer.queue_depth == 4              # manual policy: still staged


def test_delete_kills_staged_rows_before_they_land():
    rng = np.random.default_rng(13)
    aidx = make_sidx(rng.uniform(0, 100, 150))
    engine = QueryEngine(aidx, batch=4, drain_policy="manual")
    for v in [25.0, 26.0, 27.0, 95.0]:
        engine.write(v)
    deleted_in_table = brute_force(aidx.table, 20, 30)
    n = engine.delete(20, 30)
    assert n == deleted_in_table + 3                   # n includes staged kills
    assert engine.writer.staged_rows == 1              # only 95.0 survives
    assert engine.writer.queue_depth == 4              # dead rows still queued
    want = brute_force(aidx.table, 0, 100) + 1
    assert engine.run_all([Predicate.between(0, 100)])[0] == want
    engine.flush()
    # dead staged rows reached the table as invalid tuples: counts unchanged
    assert brute_force(aidx.table, 0, 100) == want
    assert engine.run_all([Predicate.between(0, 100)])[0] == want


# ---------------------------------------------------------------------------
# Drain mechanics: policies, locality, atomicity
# ---------------------------------------------------------------------------

def test_between_batches_policy_drains_incrementally():
    rng = np.random.default_rng(17)
    aidx = make_sidx(rng.uniform(0, 100, 200))
    engine = QueryEngine(aidx, batch=4, drain_policy="between_batches",
                         drain_units=1)
    for v in rng.uniform(0, 100, 20):
        engine.write(float(v))
    assert engine.stats.queue_depth == 20
    assert engine.stats.drains == 0             # nothing drained at write time
    engine.run_all(workload(rng, 3))
    assert engine.stats.drains > 0
    assert engine.stats.queue_depth < 20
    while engine.writer.pending_units:
        engine.run_batch()                      # empty batches keep draining
    assert engine.writer.queue_depth == 0
    assert engine.stats.drained_rows + engine.writer.stats.killed == 20
    assert engine.stats.drain_us > 0
    assert engine.stats.peak_queue_depth == 20


def test_on_depth_policy_triggers_at_threshold():
    rng = np.random.default_rng(19)
    aidx = make_sidx(rng.uniform(0, 100, 200))
    engine = QueryEngine(aidx, batch=4, drain_policy="on_depth",
                         drain_depth=8)
    for v in rng.uniform(0, 100, 7):
        engine.write(float(v))
    assert engine.stats.drains == 0 and engine.stats.queue_depth == 7
    engine.write(50.0)                          # depth hits 8: full drain
    assert engine.writer.queue_depth == 0
    assert engine.stats.drained_rows == 8


def test_manual_policy_only_flush_drains():
    rng = np.random.default_rng(23)
    aidx = make_sidx(rng.uniform(0, 100, 200))
    engine = QueryEngine(aidx, batch=4, drain_policy="manual")
    for v in rng.uniform(0, 100, 10):
        engine.write(float(v))
    engine.run_all(workload(rng, 6))
    assert engine.stats.drains == 0 and engine.writer.queue_depth == 10
    assert engine.flush() == 10
    assert engine.writer.queue_depth == 0


def test_drain_swaps_only_the_drained_shard():
    """A drain rebuilds exactly one shard's slice: every other shard's
    bitmaps/entry arrays are bit-identical before and after the swap."""
    rng = np.random.default_rng(29)
    aidx = make_sidx(np.sort(rng.uniform(0, 100, 300)))
    writer = MaintenanceWriter(aidx)
    for v in rng.uniform(0, 100, 12):
        writer.write(float(v))
    pending = writer.pending_shards()
    assert len(pending) == 1                    # tail appends: one shard
    s = pending[0]
    before = {t: np.asarray(shard_state(aidx.state.shards, t).bitmaps).copy()
              for t in range(aidx.num_shards)}
    summaries_before = np.asarray(aidx.state.summaries).copy()
    assert writer.drain(max_units=1) == 12
    for t in range(aidx.num_shards):
        after = np.asarray(shard_state(aidx.state.shards, t).bitmaps)
        if t != s:
            np.testing.assert_array_equal(after, before[t], err_msg=f"shard {t}")
            np.testing.assert_array_equal(np.asarray(aidx.state.summaries[t]),
                                          summaries_before[t])


def test_drain_patches_slab_cache_in_place():
    """After a drain the table's sharded device view is patched (fresh, key
    advanced) rather than left stale for a full (S, PPS, C) rebuild."""
    rng = np.random.default_rng(31)
    aidx = make_sidx(rng.uniform(0, 100, 200))
    engine = QueryEngine(aidx, batch=4, drain_policy="manual")
    engine.run_all([Predicate.between(0, 50)])  # builds the slab cache
    t = aidx.table
    assert t._dev_shard is not None and not t._dev_shard_stale
    for v in rng.uniform(0, 100, 10):
        engine.write(float(v))
    engine.flush()
    assert t._dev_shard is not None
    assert not t._dev_shard_stale               # patched, not invalidated
    assert t._dev_shard[0][2] == t.num_pages    # key tracks the new tail
    got = engine.run_all([Predicate.between(-1e30, 1e30)])
    assert got[0] == brute_force(t, -1e30, 1e30)


def test_write_refuses_rows_the_layout_cannot_hold():
    rng = np.random.default_rng(37)
    aidx = make_sidx(rng.uniform(0, 100, 64), num_shards=2,
                     pages_per_shard=5, spare_pages=64)
    engine = QueryEngine(aidx, batch=4, drain_policy="manual")
    with pytest.raises(RuntimeError, match="shard layout full"):
        for v in np.linspace(0, 90, 100):
            engine.write(float(v))
    # whatever was staged before the refusal still serves exactly
    got = engine.run_all([Predicate.between(0, 100)])
    assert got[0] == brute_force(aidx.table, 0, 100) + engine.writer.staged_rows
    engine.flush()
    got = engine.run_all([Predicate.between(0, 100)])
    assert got[0] == brute_force(aidx.table, 0, 100)


def test_drain_slot_capacity_refusal_rolls_back():
    """A drain that hits shard slot capacity restores the table snapshot,
    requeues the staged rows, clears the swap guard, and keeps every count
    exact through the staging overlay."""
    aidx = make_sidx(np.linspace(0, 99, 64), num_shards=2, max_slots=12,
                     relocate_on_update=True)
    engine = QueryEngine(aidx, batch=4, drain_policy="manual")
    for v in np.linspace(0, 99, 300):
        engine.write(float(v))
    t = aidx.table
    snap = (t.num_pages, t.fill, engine.writer.queue_depth)
    want = brute_force(t, 0, 99) + engine.writer.staged_rows
    with pytest.raises(RuntimeError, match="slot capacity"):
        engine.flush()
    assert aidx.swap_in_flight is None
    assert (t.num_pages, t.fill, engine.writer.queue_depth) == snap
    assert engine.run_all([Predicate.between(0, 99)])[0] == want


# ---------------------------------------------------------------------------
# Mid-swap refusal (regression: silent wrong counts -> loud error)
# ---------------------------------------------------------------------------

def test_queries_and_maintenance_refuse_mid_swap():
    """Regression: a query racing a shard swap used to be representable only
    as silent wrong counts; every query/maintenance surface must now refuse
    with a clear error while ``swap_in_flight`` is set."""
    rng = np.random.default_rng(41)
    aidx = make_sidx(rng.uniform(0, 100, 200))
    engine = QueryEngine(aidx, batch=4)
    pred = Predicate.between(0, 50)
    aidx.swap_in_flight = 2
    for attempt in (lambda: aidx.search_batch([pred]),
                    lambda: aidx.plan_batch([pred]),
                    lambda: aidx.search_batch_shard(0, [pred]),
                    lambda: aidx.insert(1.0),
                    lambda: aidx.insert_batch(np.asarray([1.0])),
                    lambda: aidx.vacuum(),
                    lambda: aidx.vacuum_shard(0),
                    lambda: engine.run_all([pred]),
                    lambda: engine.write(1.0),
                    lambda: engine.delete(0.0, 1.0)):
        with pytest.raises(RuntimeError, match="swap in flight"):
            attempt()
    aidx.swap_in_flight = None
    engine.queue.clear()
    engine.slots = [None] * engine.batch
    got = engine.run_all([pred])
    assert got[0] == brute_force(aidx.table, 0, 50)


def test_direct_insert_refused_while_rows_staged():
    """Direct ``ShardedHippoIndex.insert`` under a pending writer queue would
    shift the table tail out from under the staged page routing — it must
    refuse instead."""
    rng = np.random.default_rng(43)
    aidx = make_sidx(rng.uniform(0, 100, 100))
    writer = MaintenanceWriter(aidx)
    writer.write(5.0)
    with pytest.raises(RuntimeError, match="staged rows pending"):
        aidx.insert(1.0)
    with pytest.raises(RuntimeError, match="staged rows pending"):
        aidx.insert_batch(np.asarray([1.0, 2.0]))
    writer.flush()
    aidx.insert(1.0)                            # queue empty: direct is fine


def test_vacuum_drains_only_dirty_shard():
    """Vacuum drain units are shard-local: draining one dirty shard clears
    its dirty notes only, leaving other shards' vacuum work queued."""
    values = np.sort(np.random.default_rng(47).uniform(0, 100, 800))
    aidx = make_sidx(values)
    writer = MaintenanceWriter(aidx)
    pps = aidx.spec.pages_per_shard
    lo_key = float(values[(2 * pps - 2) * 8])
    hi_key = float(values[(2 * pps + 2) * 8])
    writer.delete(lo_key, hi_key)               # dirties two shards
    pending = writer.pending_vacuum_shards()
    assert len(pending) >= 2
    writer.drain(max_units=1)
    assert writer.pending_vacuum_shards() == pending[1:]
    writer.flush()
    assert not writer.pending_vacuum_shards()
    assert not aidx.table.dirty[: aidx.table.num_pages].any()
    assert int(aidx.search_batch([Predicate.between(lo_key, hi_key)]).counts[0]) == 0


def test_second_writer_refused_while_rows_staged():
    """Attaching a new writer would detach the old one's overlay and drop
    its staged rows from every count — refuse while rows are pending, and
    refuse staging through a writer that did get replaced."""
    rng = np.random.default_rng(53)
    aidx = make_sidx(rng.uniform(0, 100, 100))
    w1 = MaintenanceWriter(aidx)
    w2 = MaintenanceWriter(aidx)        # empty: replacement is fine
    assert aidx.staging is w2
    with pytest.raises(RuntimeError, match="detached"):
        w1.write(1.0)                   # stale handle refuses loudly
    w2.write(2.0)
    with pytest.raises(RuntimeError, match="staged rows pending"):
        MaintenanceWriter(aidx)
    with pytest.raises(RuntimeError, match="staged rows pending"):
        QueryEngine(aidx, batch=4)      # implicit writer hits the same guard
    w2.flush()
    engine = QueryEngine(aidx, batch=4)
    assert aidx.staging is engine.writer


def test_noop_delete_keeps_device_caches():
    rng = np.random.default_rng(59)
    aidx = make_sidx(rng.uniform(0, 100, 200))
    engine = QueryEngine(aidx, batch=4, drain_policy="manual")
    engine.run_all([Predicate.between(0, 50)])      # builds the slab cache
    t = aidx.table
    assert not t._dev_shard_stale
    assert engine.delete(500.0, 600.0) == 0         # no key in range
    assert not t._dev_shard_stale                   # cache survived the no-op


def test_routed_overlay_reads_the_attached_writer():
    """The routed dispatch must take the overlay from ``index.staging`` (the
    single source of truth), so a sync-policy engine on an index with a
    staged writer still returns exact counts."""
    rng = np.random.default_rng(61)
    aidx = make_sidx(rng.uniform(0, 100, 200))
    writer = MaintenanceWriter(aidx)
    writer.write(42.0)
    sync_engine = QueryEngine(aidx, batch=4, drain_policy="sync")
    assert sync_engine.writer is None
    got = sync_engine.run_all([Predicate.between(-1e30, 1e30)])
    assert got[0] == brute_force(aidx.table, -1e30, 1e30) + 1
    writer.flush()


def test_engine_rejects_writer_bound_elsewhere():
    rng = np.random.default_rng(67)
    a = make_sidx(rng.uniform(0, 100, 100))
    b = make_sidx(rng.uniform(0, 100, 100))
    w = MaintenanceWriter(a)
    with pytest.raises(ValueError, match="different index"):
        QueryEngine(b, batch=4, drain_policy="manual", writer=w)


def test_drain_stats_count_partial_progress():
    """Bugfix regression: a drain that applies two units and then refuses on
    the third used to record zero drains and zero drain time (the stats were
    only written after the loop), and ``engine._sync_writer_stats``
    propagated the lie. Units and wall time must land as they apply."""
    aidx = make_sidx(np.linspace(0, 99, 64), num_shards=2, max_slots=12,
                     relocate_on_update=True)
    engine = QueryEngine(aidx, batch=4, drain_policy="manual",
                         auto_resummarize=False)
    writer = engine.writer
    # unit 3: an insert queue that refuses at slot capacity (one shard's
    # worth of distinct relocating values) ...
    for v in np.linspace(0, 99, 100):
        engine.write(float(v))
    # ... units 1+2: one valid remap per shard, drained first
    writer.schedule_resummarize(np.linspace(-1.0, 101.0,
                                            aidx.cfg.resolution + 1))
    assert writer.pending_units == 3
    with pytest.raises(RuntimeError, match="slot capacity"):
        engine.flush()
    assert writer.stats.drains == 2              # the two applied remaps
    assert writer.stats.resummarizes == 2
    assert writer.stats.last_drain_us > 0
    assert writer.stats.total_drain_us > 0
    # the engine saw the partial progress despite the raise
    assert engine.stats.drains == 2
    assert engine.stats.drain_us > 0
    assert engine.stats.resummarizes == 2
    # recovery: counts stay exact through the overlay, discard re-arms
    want = brute_force(aidx.table, 0, 99) + writer.staged_rows
    assert engine.run_all([Predicate.between(0, 99)])[0] == want


def test_on_depth_policy_triggers_on_delete_backlog():
    """Bugfix regression: a delete-heavy stream under on_depth used to
    accumulate vacuum work forever — deletes add no queue depth and
    ``delete()`` never checked the trigger. The trigger now measures staged
    tuples + dirty pages, on writes and deletes alike."""
    values = np.sort(np.random.default_rng(73).uniform(0, 100, 400))
    aidx = make_sidx(values)
    engine = QueryEngine(aidx, batch=4, drain_policy="on_depth",
                         drain_depth=6)
    steps = 0
    for i in range(30):                      # narrow disjoint deletes only
        engine.delete(i * 3.0, i * 3.0 + 1.5)
        steps += 1
        if engine.stats.drains:
            break
    assert engine.stats.drains > 0, \
        "delete-only stream never drained its vacuums"
    assert engine.writer.stats.vacuums > 0
    assert not aidx.table.dirty[: aidx.table.num_pages].any()
    assert steps < 30                        # triggered by backlog, not luck
    got = engine.run_all([Predicate.between(0, 100)])
    assert got[0] == brute_force(aidx.table, 0, 100)


def test_drain_refusal_suspends_auto_drain_and_discard_recovers():
    """A refused between-batches drain raises once, then queries keep
    serving exactly via the overlay instead of re-raising forever;
    ``writer.discard()`` drops the unappliable rows and re-arms."""
    aidx = make_sidx(np.linspace(0, 99, 64), num_shards=2, max_slots=12,
                     relocate_on_update=True)
    engine = QueryEngine(aidx, batch=4, drain_policy="between_batches")
    for v in np.linspace(0, 99, 300):
        engine.write(float(v))
    want = brute_force(aidx.table, 0, 99) + engine.writer.staged_rows
    with pytest.raises(RuntimeError, match="slot capacity"):
        engine.run_all([Predicate.between(0, 99)])
    engine.queue.clear()
    engine.slots = [None] * engine.batch
    got = engine.run_all([Predicate.between(0, 99)])    # no re-raise
    assert got[0] == want
    dropped = engine.writer.discard()
    assert dropped == 300 and engine.writer.queue_depth == 0
    got = engine.run_all([Predicate.between(0, 99)])
    assert got[0] == brute_force(aidx.table, 0, 99)
    engine.write(50.0)                                  # staging works again
    engine.flush()
    assert brute_force(aidx.table, 0, 99) == got[0] + 1


def test_vacuum_counter_consistent_across_entry_points():
    """counters.vacuums counts shard-vacuums that did work, identically
    through vacuum(), vacuum_shard(), and the writer's drain."""
    def dirty_two_shards():
        values = np.sort(np.random.default_rng(71).uniform(0, 100, 800))
        idx = make_sidx(values)
        pps = idx.spec.pages_per_shard
        idx.table.delete_where(float(values[(2 * pps - 2) * 8]),
                               float(values[(2 * pps + 2) * 8]))
        return idx

    a = dirty_two_shards()
    a.vacuum()
    b = dirty_two_shards()
    for s in b.dirty_shards():
        b.vacuum_shard(int(s))
    c = dirty_two_shards()
    MaintenanceWriter(c).flush()
    assert a.counters.vacuums == b.counters.vacuums == c.counters.vacuums >= 2


def test_writer_requires_partition_surface():
    from repro.core.hippo import HippoIndex
    table = PagedTable.from_values(np.linspace(0, 9, 80), page_card=8)
    idx = HippoIndex.create(table, resolution=32, density=0.25)
    with pytest.raises(ValueError, match="ShardedHippoIndex"):
        MaintenanceWriter(idx)
    with pytest.raises(ValueError, match="drain_policy"):
        QueryEngine(idx, drain_policy="bogus")
    with pytest.raises(ValueError, match="sync"):
        QueryEngine(idx, drain_policy="between_batches")
