"""Shared pytest configuration: test tiers.

Tier-1 (everything): ``PYTHONPATH=src python -m pytest -x -q``
Fast inner loop:     ``PYTHONPATH=src python -m pytest -x -q -m "not slow"``

``slow`` marks the model/launch/system modules that compile transformer steps
or fork subprocess meshes; the core index/kernel/maintenance suite stays in
the fast tier and finishes in well under a minute.
"""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: model/launch/system tests that compile large jit programs or "
        "spawn subprocess meshes; deselect with -m \"not slow\"")
