"""Shared pytest configuration: test tiers.

Tier-1 (everything): ``PYTHONPATH=src python -m pytest -x -q``
Fast inner loop:     ``PYTHONPATH=src python -m pytest -x -q -m "not slow and not shard"``
Partition suite:     ``PYTHONPATH=src python -m pytest -x -q -m shard``

``slow`` marks the model/launch/system modules that compile transformer steps
or fork subprocess meshes; ``shard`` marks the partition-layer suite (many
distinct stacked-state jit shapes, so it compiles for ~40s). Excluding both
keeps the core index/kernel/maintenance inner loop well under a minute.
"""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: model/launch/system tests that compile large jit programs or "
        "spawn subprocess meshes; deselect with -m \"not slow\"")
    config.addinivalue_line(
        "markers",
        "shard: partition-layer tests (core.partition / sharded engine); "
        "excluded from the fast inner loop (-m \"not slow and not shard\") "
        "to keep it under a minute — run just these with -m shard")
