"""Shared pytest configuration: test tiers.

Tier-1 (everything): ``PYTHONPATH=src python -m pytest -x -q``
Fast inner loop:     ``PYTHONPATH=src python -m pytest -x -q -m "not slow and not shard and not writer and not compact and not drift and not bench and not learned and not persist and not fault"``
Partition suite:     ``PYTHONPATH=src python -m pytest -x -q -m shard``
Writer suite:        ``PYTHONPATH=src python -m pytest -x -q -m writer``
Compact suite:       ``PYTHONPATH=src python -m pytest -x -q -m compact``
Drift suite:         ``PYTHONPATH=src python -m pytest -x -q -m drift``
Bench gate:          ``PYTHONPATH=src python -m pytest -x -q -m bench``
Learned suite:       ``PYTHONPATH=src python -m pytest -x -q -m learned``
Persistence suite:   ``PYTHONPATH=src python -m pytest -x -q -m persist``
Fault suite:         ``PYTHONPATH=src python -m pytest -x -q -m fault``

``slow`` marks the model/launch/system modules that compile transformer steps
or fork subprocess meshes; ``shard`` marks the partition-layer suite (many
distinct stacked-state jit shapes, so it compiles for ~40s); ``writer`` marks
the async-maintenance suite (stacked-state + drain traces, similar compile
cost); ``compact`` marks the gather-path equivalence sweep
(``tests/test_compact.py`` — selectivity x shard count x staged rows, many
distinct (max_selected, top_k) trace shapes); ``drift`` marks the
re-summarization equivalence sweep (``tests/test_drift.py`` — remap/epoch
traces over several shard counts); ``bench`` marks the perf regression
gate's end-to-end invocation (a quick ``benchmarks.run`` sweep checked
against the committed ``BENCH_*.json`` baseline — real benchmark work, so
it stays out of the inner loop); ``learned`` marks the learned-summary
equivalence sweep (``tests/test_learned.py`` — learned bounds bit-identical
to brute force across selectivity x shards x staged overlay, plus the
writer/engine policy integration — stacked-state traces like the drift
suite); ``persist`` marks the durable-storage suite
(``tests/test_persistence.py`` — snapshot round-trip equivalence, WAL
crash-injection recovery, binary-layout corruption handling — builds and
recovers full sharded engines, so it compiles stacked-state traces and
does real disk I/O); ``fault`` marks the self-healing supervisor suite
(``tests/test_fault_recovery.py`` — a crash injected at every registered
``faultinject.SITES`` crash point recovers via ``resilient_serve`` with no
operator action — same stacked-state compile + disk I/O cost as the
persist suite). Excluding all nine keeps the core
index/kernel/maintenance inner loop well under a minute. The markers are documented in README.md, and
``scripts/check_markers.py`` fails the build if a test module uses a marker
that is not registered below.
"""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: model/launch/system tests that compile large jit programs or "
        "spawn subprocess meshes; deselect with -m \"not slow\"")
    config.addinivalue_line(
        "markers",
        "shard: partition-layer tests (core.partition / sharded engine); "
        "excluded from the fast inner loop (-m \"not slow and not shard\") "
        "to keep it under a minute — run just these with -m shard")
    config.addinivalue_line(
        "markers",
        "writer: async-maintenance tests (runtime.writer staged queues, "
        "drain/swap lifecycle, staleness refusal); compiles stacked-state "
        "traces like the shard suite — run just these with -m writer")
    config.addinivalue_line(
        "markers",
        "compact: gather-path equivalence sweep (tests/test_compact.py — "
        "compact vs dense vs sharded vs staged-overlay, bit-identical "
        "counts/row ids wherever untruncated); compiles many "
        "(max_selected, top_k) trace shapes — run just these with -m compact")
    config.addinivalue_line(
        "markers",
        "drift: drift re-summarization sweep (tests/test_drift.py — remap "
        "onto new histogram bounds never changes counts, across shard "
        "counts, staged overlays, and mixed bounds epochs); compiles "
        "stacked-state traces like the writer suite — run just these with "
        "-m drift")
    config.addinivalue_line(
        "markers",
        "bench: perf regression gate end-to-end (tests/test_check_bench.py "
        "— a quick kernels-suite benchmarks.run gated against the committed "
        "BENCH_*.json baseline); runs real benchmark timing loops — run "
        "just these with -m bench")
    config.addinivalue_line(
        "markers",
        "learned: learned-summary sweep (tests/test_learned.py — "
        "piecewise-linear CDF fit properties, learned bounds bit-identical "
        "counts across selectivity x shards x staged overlay incl. mixed "
        "epochs, writer/engine summary-policy integration); compiles "
        "stacked-state traces like the drift suite — run just these with "
        "-m learned")
    config.addinivalue_line(
        "markers",
        "persist: durable-storage tests (tests/test_persistence.py — "
        "save/load round-trip equivalence across shards x summary policy x "
        "staged overlay x mixed epochs, crash-injected drain recovery via "
        "snapshot + journal replay, section-container corruption handling); "
        "builds full sharded engines and does real disk I/O — run just "
        "these with -m persist")
    config.addinivalue_line(
        "markers",
        "fault: self-healing recovery tests (tests/test_fault_recovery.py "
        "— crashes injected at every faultinject.SITES crash point, "
        "watchdog hang-restart, retry-budget exhaustion, background-"
        "persister poisoning; resilient_serve must recover to exactly the "
        "acknowledged counts with no operator action); builds and "
        "re-recovers durable engines repeatedly — run just these with "
        "-m fault")
