"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.batch_filter.ops import batch_filter, batch_filter_sharded
from repro.kernels.batch_filter.ref import (batch_filter_ref,
                                            batch_filter_sharded_ref)
from repro.kernels.bitmap_and.ops import bitmap_and_any
from repro.kernels.bitmap_and.ref import bitmap_and_any_ref
from repro.kernels.compact_inspect.ops import compact_inspect
from repro.kernels.compact_inspect.ref import compact_inspect_ref
from repro.kernels.bucketize.ops import bucketize_values
from repro.kernels.bucketize.ref import bucketize_ref
from repro.kernels.page_inspect.ops import page_inspect
from repro.kernels.page_inspect.ref import page_inspect_ref


# ---------------------------------------------------------------------------
# bitmap_and
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_entries", [1, 7, 512, 513, 2048])
@pytest.mark.parametrize("words", [1, 13, 50, 128])
def test_bitmap_and_shapes(num_entries, words):
    rng = np.random.default_rng(num_entries * 1000 + words)
    entries = rng.integers(0, 2**32, (num_entries, words), dtype=np.uint32)
    # sparse query so matches are non-trivial
    query = (rng.integers(0, 2**32, (words,), dtype=np.uint32)
             & rng.integers(0, 2**32, (words,), dtype=np.uint32))
    got = bitmap_and_any(jnp.asarray(entries), jnp.asarray(query))
    want = bitmap_and_any_ref(jnp.asarray(entries), jnp.asarray(query))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitmap_and_all_zero_query():
    entries = jnp.ones((64, 4), jnp.uint32)
    query = jnp.zeros((4,), jnp.uint32)
    assert int(bitmap_and_any(entries, query).sum()) == 0


# ---------------------------------------------------------------------------
# batch_filter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_queries,num_entries,words", [
    (1, 1, 1),        # all dims below one tile
    (7, 127, 13),     # all dims need padding (H=400 -> 13 words)
    (8, 128, 13),     # exact tile multiples in q and e
    (9, 129, 13),     # one past the tile boundary
    (64, 300, 1),     # single-word bitmaps
    (16, 256, 128),   # multiple tiles on every axis, lane-exact words
])
def test_batch_filter_shapes(num_queries, num_entries, words):
    rng = np.random.default_rng(num_queries * 10000 + num_entries * 10 + words)
    entries = rng.integers(0, 2**32, (num_entries, words), dtype=np.uint32)
    queries = (rng.integers(0, 2**32, (num_queries, words), dtype=np.uint32)
               & rng.integers(0, 2**32, (num_queries, words), dtype=np.uint32))
    got = batch_filter(jnp.asarray(queries), jnp.asarray(entries))
    want = batch_filter_ref(jnp.asarray(queries), jnp.asarray(entries))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batch_filter_rows_match_bitmap_and():
    """Each row of the batched kernel equals the single-query kernel."""
    rng = np.random.default_rng(9)
    entries = jnp.asarray(rng.integers(0, 2**32, (200, 13), dtype=np.uint32))
    queries = jnp.asarray(rng.integers(0, 2**32, (5, 13), dtype=np.uint32))
    batched = np.asarray(batch_filter(queries, entries))
    for q in range(queries.shape[0]):
        row = np.asarray(bitmap_and_any(entries, queries[q]))
        np.testing.assert_array_equal(batched[q], row)


def test_batch_filter_zero_and_dense_queries():
    entries = jnp.ones((64, 4), jnp.uint32)
    queries = jnp.stack([jnp.zeros((4,), jnp.uint32),
                         jnp.full((4,), 0xFFFFFFFF, jnp.uint32)])
    out = np.asarray(batch_filter(queries, entries))
    assert out[0].sum() == 0 and out[1].sum() == 64


@pytest.mark.shard
@pytest.mark.parametrize("num_shards,num_queries,num_entries,words", [
    (1, 1, 1, 1),       # all dims below one tile
    (3, 7, 127, 13),    # every axis needs padding
    (4, 8, 128, 13),    # exact tile multiples in q and e
    (2, 9, 129, 13),    # one past the tile boundary
    (5, 16, 64, 128),   # lane-exact words, several shards
])
def test_batch_filter_sharded_shapes(num_shards, num_queries, num_entries, words):
    rng = np.random.default_rng(
        num_shards * 100000 + num_queries * 1000 + num_entries * 10 + words)
    entries = rng.integers(0, 2**32, (num_shards, num_entries, words),
                           dtype=np.uint32)
    queries = (rng.integers(0, 2**32, (num_queries, words), dtype=np.uint32)
               & rng.integers(0, 2**32, (num_queries, words), dtype=np.uint32))
    got = batch_filter_sharded(jnp.asarray(queries), jnp.asarray(entries))
    want = batch_filter_sharded_ref(jnp.asarray(queries), jnp.asarray(entries))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.shard
def test_batch_filter_sharded_slices_match_unsharded():
    """Each shard's (Q, E) slice equals the unsharded kernel on that shard's
    entry table — the kernel analogue of the count-reduce parity."""
    rng = np.random.default_rng(13)
    entries = jnp.asarray(rng.integers(0, 2**32, (3, 100, 13), dtype=np.uint32))
    queries = jnp.asarray(rng.integers(0, 2**32, (5, 13), dtype=np.uint32))
    out = np.asarray(batch_filter_sharded(queries, entries))
    for s in range(3):
        np.testing.assert_array_equal(out[s],
                                      np.asarray(batch_filter(queries, entries[s])))


# ---------------------------------------------------------------------------
# bucketize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 100, 1024, 1025, 5000])
@pytest.mark.parametrize("resolution", [8, 100, 400, 1600])
def test_bucketize_shapes(n, resolution):
    rng = np.random.default_rng(n * 7 + resolution)
    bounds = np.sort(rng.uniform(0, 1000, resolution + 1)).astype(np.float32)
    bounds += np.arange(resolution + 1, dtype=np.float32) * 1e-3  # strict
    values = rng.uniform(-100, 1100, n).astype(np.float32)
    got = bucketize_values(jnp.asarray(values), jnp.asarray(bounds), resolution)
    want = bucketize_ref(jnp.asarray(values), jnp.asarray(bounds), resolution)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.float64])
def test_bucketize_input_dtypes(dtype):
    rng = np.random.default_rng(3)
    bounds = np.linspace(0, 100, 33).astype(np.float32)
    values = rng.uniform(0, 100, 300).astype(dtype)
    got = bucketize_values(jnp.asarray(values).astype(jnp.float32),
                           jnp.asarray(bounds), 32)
    want = bucketize_ref(jnp.asarray(values).astype(jnp.float32),
                         jnp.asarray(bounds), 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bucketize_boundary_values():
    bounds = jnp.asarray(np.linspace(0.0, 10.0, 11), jnp.float32)
    values = jnp.asarray([0.0, 1.0, 9.999, 10.0, -1.0, 11.0], jnp.float32)
    got = bucketize_values(values, bounds, 10)
    want = bucketize_ref(values, bounds, 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # clamping: below-range -> bucket 0, above-range -> bucket H-1
    assert int(got[4]) == 0 and int(got[5]) == 9


# ---------------------------------------------------------------------------
# page_inspect
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pages,card", [(1, 1), (10, 50), (64, 128), (65, 130), (200, 7)])
def test_page_inspect_shapes(pages, card):
    rng = np.random.default_rng(pages * 31 + card)
    keys = rng.uniform(0, 100, (pages, card)).astype(np.float32)
    valid = rng.random((pages, card)) < 0.9
    mask = rng.random((pages,)) < 0.5
    lo, hi = 25.0, 75.0
    qual, counts = page_inspect(jnp.asarray(keys), jnp.asarray(valid),
                                jnp.asarray(mask), lo, hi)
    qual_ref, counts_ref = page_inspect_ref(jnp.asarray(keys), jnp.asarray(valid),
                                            jnp.asarray(mask), lo, hi)
    np.testing.assert_array_equal(np.asarray(qual), np.asarray(qual_ref))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_ref))


def test_page_inspect_empty_interval():
    keys = jnp.ones((8, 16), jnp.float32)
    valid = jnp.ones((8, 16), bool)
    mask = jnp.ones((8,), bool)
    qual, counts = page_inspect(keys, valid, mask, 5.0, 4.0)
    assert int(counts.sum()) == 0 and not bool(qual.any())


# ---------------------------------------------------------------------------
# compact_inspect
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("queries,pages,card", [
    (1, 1, 1),         # all dims below one tile
    (5, 37, 50),       # all dims need padding
    (8, 64, 128),      # exact tile multiples
    (9, 65, 130),      # one past every tile boundary
    (16, 200, 7),      # multiple tiles, narrow pages
])
def test_compact_inspect_shapes(queries, pages, card):
    rng = np.random.default_rng(queries * 10000 + pages * 10 + card)
    keys = rng.uniform(0, 100, (pages, card)).astype(np.float32)
    valid = rng.random((pages, card)) < 0.9
    sel = rng.random((queries, pages)) < 0.5
    los = rng.uniform(0, 60, queries).astype(np.float32)
    his = (los + rng.uniform(0, 40, queries)).astype(np.float32)
    got = compact_inspect(jnp.asarray(keys), jnp.asarray(valid),
                          jnp.asarray(sel), jnp.asarray(los), jnp.asarray(his))
    want = compact_inspect_ref(jnp.asarray(keys), jnp.asarray(valid),
                               jnp.asarray(sel), los, his)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_compact_inspect_empty_interval_and_mask():
    keys = jnp.ones((8, 16), jnp.float32)
    valid = jnp.ones((8, 16), bool)
    sel = jnp.ones((4, 8), bool)
    los = jnp.asarray([5.0, 0.0, 0.0, 2.0], jnp.float32)
    his = jnp.asarray([4.0, 2.0, 2.0, 1.0], jnp.float32)   # rows 0 and 3 empty
    counts = np.asarray(compact_inspect(keys, valid, sel, los, his))
    assert counts[0].sum() == 0 and counts[3].sum() == 0
    assert (counts[1] == 16).all() and (counts[2] == 16).all()
    # an all-false selected mask zeroes everything regardless of interval
    none = np.asarray(compact_inspect(keys, valid, jnp.zeros((4, 8), bool),
                                      los, his))
    assert none.sum() == 0


def test_compact_inspect_matches_search_compact_many():
    """The kernel's per-(query, slab page) counts agree with the gather
    search path when fed the same slab and selected masks."""
    from repro.core import index as hix
    from repro.core.hippo import HippoIndex
    from repro.core.predicate import (Predicate, intervals,
                                      to_bucket_bitmaps)
    from repro.storage.table import PagedTable

    rng = np.random.default_rng(14)
    values = np.sort(rng.uniform(0, 1000, 4000))
    table = PagedTable.from_values(values, page_card=50)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    preds = [Predicate.between(float(lo), float(lo) + 30.0)
             for lo in rng.uniform(0, 1000, 8)]
    preds.append(Predicate(lo=5.0, hi=1.0))
    qbms = to_bucket_bitmaps(preds, idx.state.histogram)
    los, his = intervals(preds)
    keys, valid = table.device_keys(), table.device_valid()
    res = hix.search_compact_many(idx.state, qbms, keys, valid, los, his,
                                  max_selected=table.num_pages, top_k=0)
    assert not np.asarray(res.truncated).any()
    # rebuild the slab + selected masks exactly as the search does
    dense = hix.search_many(idx.state, qbms, keys, valid, los, his)
    page_mask = np.asarray(dense.page_mask)
    union = page_mask.any(axis=0)
    sel = np.flatnonzero(union)
    slab_keys = np.asarray(keys)[sel]
    slab_valid = np.asarray(valid)[sel]
    sel_mask = page_mask[:, sel]
    counts = compact_inspect(jnp.asarray(slab_keys), jnp.asarray(slab_valid),
                             jnp.asarray(sel_mask), los, his)
    np.testing.assert_array_equal(np.asarray(counts).sum(axis=1),
                                  np.asarray(res.counts))


# ---------------------------------------------------------------------------
# kernels against the index search (end-to-end agreement)
# ---------------------------------------------------------------------------

def test_kernelized_filter_matches_index_search():
    from repro.core.hippo import HippoIndex
    from repro.core.predicate import Predicate, to_bucket_bitmap
    from repro.storage.table import PagedTable

    rng = np.random.default_rng(11)
    values = rng.uniform(0, 1000, 4000)
    table = PagedTable.from_values(values, page_card=50)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    pred = Predicate.between(100, 105)
    res = idx.search(pred)
    qbm = to_bucket_bitmap(pred, idx.state.histogram)
    s = idx.cfg.max_slots
    live = np.asarray(idx.state.slot_live) & (np.arange(s) < int(idx.state.num_slots))
    match_kernel = np.asarray(bitmap_and_any(idx.state.bitmaps, qbm)).astype(bool) & live
    assert match_kernel.sum() == int(res.entries_matched)
    # inspect with the kernel too
    qual, counts = page_inspect(table.device_keys(), table.device_valid(),
                                jnp.asarray(res.page_mask), pred.lo, pred.hi)
    assert int(counts.sum()) == int(res.count)


def test_batch_filter_matches_search_many():
    """The fused kernel's (Q, E) match matrix agrees with the entry-match
    step of the batched search path (entries_matched per query)."""
    from repro.core.hippo import HippoIndex
    from repro.core.predicate import Predicate, to_bucket_bitmaps
    from repro.storage.table import PagedTable

    rng = np.random.default_rng(12)
    values = rng.uniform(0, 1000, 4000)
    table = PagedTable.from_values(values, page_card=50)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    preds = [Predicate.between(float(lo), float(lo) + 40.0)
             for lo in rng.uniform(0, 1000, 16)]
    preds.append(Predicate(lo=5.0, hi=1.0))        # all-zero query row
    qbms = to_bucket_bitmaps(preds, idx.state.histogram)
    res = idx.search_batch(preds)
    s = idx.cfg.max_slots
    live = np.asarray(idx.state.slot_live) & (np.arange(s) < int(idx.state.num_slots))
    match = np.asarray(batch_filter(qbms, idx.state.bitmaps)).astype(bool) & live[None, :]
    np.testing.assert_array_equal(match.sum(axis=1),
                                  np.asarray(res.entries_matched))
