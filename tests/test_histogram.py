"""Equi-depth histogram properties (§4.1): balance, monotonicity, bucketize
agreement with searchsorted semantics."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import histogram as hg


def test_equi_depth_balance():
    rng = np.random.default_rng(0)
    sample = rng.exponential(10.0, 50_000)   # heavily skewed
    hist = hg.build(jnp.asarray(sample), resolution=100)
    ids = np.asarray(hg.bucketize(hist, jnp.asarray(sample)))
    counts = np.bincount(ids, minlength=100)
    # height-balanced: every bucket within 3x of the mean occupancy
    assert counts.max() < 3 * counts.mean()
    assert counts.min() > counts.mean() / 3


def test_bounds_strictly_increasing_with_ties():
    sample = np.repeat([1.0, 2.0, 3.0], 1000)   # massive ties
    hist = hg.build(jnp.asarray(sample), resolution=32)
    b = np.asarray(hist.bounds)
    assert (np.diff(b) > 0).all()


@given(st.integers(2, 128), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_bucketize_in_range(resolution, seed):
    rng = np.random.default_rng(seed)
    sample = rng.normal(0, 10, 500)
    hist = hg.build(jnp.asarray(sample), resolution=resolution)
    probes = rng.normal(0, 30, 200)  # includes out-of-range values
    ids = np.asarray(hg.bucketize(hist, jnp.asarray(probes)))
    assert (ids >= 0).all() and (ids < resolution).all()


def test_hit_bucket_range_covers_predicate():
    hist = hg.build_uniform(0.0, 100.0, 10)
    b_lo, b_hi = hg.hit_bucket_range(hist, 25.0, 55.0)
    # buckets are [0,10) [10,20) ... -> 25 in bucket 2, 55 in bucket 5
    assert int(b_lo) == 2 and int(b_hi) == 5


def test_uniform_histogram_boundaries():
    hist = hg.build_uniform(0.0, 100.0, 4)
    np.testing.assert_allclose(np.asarray(hist.bounds), [0, 25, 50, 75, 100])
