"""Drift telemetry + boundary rebuild unit tests (``core.histogram``,
PR 5). A separate module from test_histogram.py on purpose: that module is
gated on ``hypothesis`` (importorskip skips it wholesale where the package
is absent), and these tests must run everywhere — they guard the lifecycle
the writer's re-summarization scheduling depends on. Fast tier (no marker):
host-side numpy plus one small device histogram build."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import histogram as hg


def test_drift_tracker_hits_and_edge_ratio():
    hist = hg.build_uniform(0.0, 100.0, 10)
    tr = hg.DriftTracker(hist)
    assert tr.edge_overflow_ratio == 0.0
    tr.observe([5.0, 15.0, 95.0])                # buckets 0, 1, 9
    assert tr.observed == 3 and tr.out_of_range == 0
    assert tr.hits[0] == 1 and tr.hits[1] == 1 and tr.hits[9] == 1
    assert tr.edge_overflow_ratio == pytest.approx(2 / 3)
    tr.observe(np.full(7, 250.0))                # clamp into bucket 9
    assert tr.out_of_range == 7
    assert tr.edge_overflow_ratio == pytest.approx(9 / 10)
    tr.rearm(hg.build_uniform(0.0, 300.0, 10))   # new bounds: fresh telemetry
    assert tr.observed == 0 and tr.sample().size == 0
    assert tr.edge_overflow_ratio == 0.0


def test_drift_tracker_reservoir_caps_and_samples_stream():
    hist = hg.build_uniform(0.0, 1.0, 4)
    tr = hg.DriftTracker(hist, reservoir_size=64)
    stream = np.linspace(10.0, 20.0, 1000)
    tr.observe(stream)
    s = tr.sample()
    assert s.size == 64                          # capped
    assert ((s >= 10.0) & (s <= 20.0)).all()     # only observed values
    assert np.unique(s).size > 32                # spread over the stream
    tr.observe(0.5)                              # scalar observe path
    assert tr.observed == 1001


def test_rebuild_covers_blended_range_and_stays_balanced():
    rng = np.random.default_rng(0)
    old = rng.uniform(0.0, 100.0, 20_000)
    hist = hg.build(jnp.asarray(old), resolution=64)
    drifted = rng.uniform(100.0, 200.0, 4096)
    new = hg.rebuild(hist, drifted)
    b = np.asarray(new.bounds)
    assert new.resolution == 64
    assert (np.diff(b) > 0).all()                # strictly monotone
    assert b[0] <= old.min() + 1e-3 and b[-1] >= drifted.max() - 1e-3
    # equal-mass default: the drifted region gets about half the buckets
    in_drift = ((b >= 99.0) & (b <= 201.0)).sum()
    assert 20 <= in_drift <= 45, in_drift
    # count-weighted blending shifts the budget toward the heavier side
    light = hg.rebuild(hist, drifted, old_count=20_000, new_count=1_000)
    in_drift_light = ((np.asarray(light.bounds) >= 99.0)).sum()
    assert in_drift_light < in_drift


def test_rebuild_validates_inputs():
    hist = hg.build_uniform(0.0, 100.0, 8)
    with pytest.raises(ValueError, match="non-empty sample"):
        hg.rebuild(hist, np.zeros(0))
    out = hg.rebuild(hist, np.asarray([150.0, 160.0]), resolution=16)
    assert out.resolution == 16


def test_rebuild_bounds_strictly_increase_in_float32():
    """Regression: large-magnitude, narrow-span keys — the tie-separating
    epsilon collapses below the float32 ulp, and tied bounds would wedge the
    writer (every remap drain refuses them, and staged inserts never land).
    Strictness must hold in the float32 the histogram actually stores."""
    rng = np.random.default_rng(0)
    hist = hg.build(jnp.asarray(rng.uniform(1e9, 1e9 + 10, 5000)),
                    resolution=400)
    drifted = rng.uniform(1e9 + 10, 1e9 + 20, 1000)
    new = hg.rebuild(hist, drifted)
    b = np.asarray(new.bounds)
    assert b.dtype == np.float32
    assert (np.diff(b) > 0).all()
