"""Drift telemetry + boundary rebuild unit tests (``core.histogram``,
PR 5). A separate module from test_histogram.py on purpose: that module is
gated on ``hypothesis`` (importorskip skips it wholesale where the package
is absent), and these tests must run everywhere — they guard the lifecycle
the writer's re-summarization scheduling depends on. Fast tier (no marker):
host-side numpy plus one small device histogram build."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import histogram as hg


def test_drift_tracker_hits_and_edge_ratio():
    hist = hg.build_uniform(0.0, 100.0, 10)
    tr = hg.DriftTracker(hist)
    assert tr.edge_overflow_ratio == 0.0
    tr.observe([5.0, 15.0, 95.0])                # buckets 0, 1, 9
    assert tr.observed == 3 and tr.out_of_range == 0
    assert tr.hits[0] == 1 and tr.hits[1] == 1 and tr.hits[9] == 1
    assert tr.edge_overflow_ratio == pytest.approx(2 / 3)
    tr.observe(np.full(7, 250.0))                # clamp into bucket 9
    assert tr.out_of_range == 7
    assert tr.edge_overflow_ratio == pytest.approx(9 / 10)
    tr.rearm(hg.build_uniform(0.0, 300.0, 10))   # new bounds: fresh telemetry
    assert tr.observed == 0 and tr.sample().size == 0
    assert tr.edge_overflow_ratio == 0.0


def test_drift_tracker_reservoir_caps_and_samples_stream():
    hist = hg.build_uniform(0.0, 1.0, 4)
    tr = hg.DriftTracker(hist, reservoir_size=64)
    stream = np.linspace(10.0, 20.0, 1000)
    tr.observe(stream)
    s = tr.sample()
    assert s.size == 64                          # capped
    assert ((s >= 10.0) & (s <= 20.0)).all()     # only observed values
    assert np.unique(s).size > 32                # spread over the stream
    tr.observe(0.5)                              # scalar observe path
    assert tr.observed == 1001


def test_rebuild_covers_blended_range_and_stays_balanced():
    rng = np.random.default_rng(0)
    old = rng.uniform(0.0, 100.0, 20_000)
    hist = hg.build(jnp.asarray(old), resolution=64)
    drifted = rng.uniform(100.0, 200.0, 4096)
    new = hg.rebuild(hist, drifted)
    b = np.asarray(new.bounds)
    assert new.resolution == 64
    assert (np.diff(b) > 0).all()                # strictly monotone
    assert b[0] <= old.min() + 1e-3 and b[-1] >= drifted.max() - 1e-3
    # equal-mass default: the drifted region gets about half the buckets
    in_drift = ((b >= 99.0) & (b <= 201.0)).sum()
    assert 20 <= in_drift <= 45, in_drift
    # count-weighted blending shifts the budget toward the heavier side
    light = hg.rebuild(hist, drifted, old_count=20_000, new_count=1_000)
    in_drift_light = ((np.asarray(light.bounds) >= 99.0)).sum()
    assert in_drift_light < in_drift


def test_rebuild_validates_inputs():
    hist = hg.build_uniform(0.0, 100.0, 8)
    with pytest.raises(ValueError, match="non-empty sample"):
        hg.rebuild(hist, np.zeros(0))
    out = hg.rebuild(hist, np.asarray([150.0, 160.0]), resolution=16)
    assert out.resolution == 16


def test_rebuild_bounds_strictly_increase_in_float32():
    """Regression: large-magnitude, narrow-span keys — the tie-separating
    epsilon collapses below the float32 ulp, and tied bounds would wedge the
    writer (every remap drain refuses them, and staged inserts never land).
    Strictness must hold in the float32 the histogram actually stores."""
    rng = np.random.default_rng(0)
    hist = hg.build(jnp.asarray(rng.uniform(1e9, 1e9 + 10, 5000)),
                    resolution=400)
    drifted = rng.uniform(1e9 + 10, 1e9 + 20, 1000)
    new = hg.rebuild(hist, drifted)
    b = np.asarray(new.bounds)
    assert b.dtype == np.float32
    assert (np.diff(b) > 0).all()


def _adversarial_reservoirs():
    """Reservoir shapes that historically degenerate quantile rebuilds."""
    rng = np.random.default_rng(7)
    return {
        "constant": np.full(512, 42.0, np.float32),
        "duplicate_heavy": rng.choice(
            np.asarray([1.0, 2.0, 3.0], np.float32), 512),
        "single_point_drift": np.full(512, 1e6, np.float32),
        "two_distinct_far": np.asarray([0.5] * 500 + [1e7] * 12, np.float32),
        "large_magnitude_narrow": (1e9 + rng.uniform(0, 1e-3, 512)
                                   ).astype(np.float32),
    }


@pytest.mark.parametrize("name", sorted(_adversarial_reservoirs()))
@pytest.mark.parametrize("resolution", [8, 64, 400])
def test_rebuild_strict_under_adversarial_reservoirs(name, resolution):
    """Property sweep: whatever the reservoir collapses to — one value, a
    handful of heavy duplicates, a far-away point mass — ``rebuild`` must
    return (H+1,) strictly-increasing float32 bounds covering the blended
    span, because the writer's remap drain refuses anything less and the
    refusal would wedge re-summarization forever."""
    sample = _adversarial_reservoirs()[name]
    for base in (hg.build_uniform(0.0, 100.0, resolution),
                 hg.build(jnp.asarray(np.full(64, 7.0)), resolution)):
        new = hg.rebuild(base, sample)
        b = np.asarray(new.bounds)
        assert b.shape == (resolution + 1,) and b.dtype == np.float32
        assert (np.diff(b) > 0).all(), (name, resolution)
        lo = min(float(np.asarray(base.bounds)[0]), float(sample.min()))
        assert b[0] <= lo + max(abs(lo) * 1e-5, 1e-3)


def test_strict_float32_bounds_properties():
    """The shared finalizer: nondecreasing in, strictly-increasing f32 out,
    already-strict inputs pass through unchanged."""
    flat = hg.strict_float32_bounds(np.zeros(33))
    assert (np.diff(flat) > 0).all()
    wobble = hg.strict_float32_bounds(
        np.asarray([0.0, 1.0, 1.0 - 1e-9, 2.0, 2.0]))
    assert (np.diff(wobble) > 0).all()
    big = hg.strict_float32_bounds(np.full(401, 1e9))
    assert (np.diff(big) > 0).all()
    clean = np.linspace(0.0, 100.0, 11, dtype=np.float32)
    # the span-proportional ladder perturbs below f32 resolution here
    np.testing.assert_allclose(hg.strict_float32_bounds(clean), clean,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# hit_bucket_range: out-of-domain predicates prune completely (PR 7)
# ---------------------------------------------------------------------------

def test_hit_bucket_range_outside_domain_is_empty():
    """A predicate entirely below or above the summary domain, or an empty
    one (lo > hi), reports the empty bucket range (b_lo > b_hi) instead of
    clamping both endpoints into an edge bucket — clamping selected every
    page summarized under that bucket for a provably matchless query."""
    hist = hg.build_uniform(0.0, 100.0, 10)
    for lo, hi in [(-50.0, -10.0), (120.0, 400.0), (5.0, 1.0)]:
        b_lo, b_hi = hg.hit_bucket_range(hist, lo, hi)
        assert int(b_lo) > int(b_hi), (lo, hi)


def test_hit_bucket_range_straddling_domain_still_clamps():
    """Partial overlap keeps the clamp: out-of-domain *tuples* land in edge
    buckets at insert time (§4.1), so a predicate reaching past one edge
    must still report that edge bucket."""
    hist = hg.build_uniform(0.0, 100.0, 10)
    b_lo, b_hi = hg.hit_bucket_range(hist, -50.0, 15.0)
    assert (int(b_lo), int(b_hi)) == (0, 1)
    b_lo, b_hi = hg.hit_bucket_range(hist, 95.0, 500.0)
    assert (int(b_lo), int(b_hi)) == (9, 9)
    b_lo, b_hi = hg.hit_bucket_range(hist, -1e30, 1e30)
    assert (int(b_lo), int(b_hi)) == (0, 9)


# ---------------------------------------------------------------------------
# Batched observe: one call == the sequential semantics (PR 7)
# ---------------------------------------------------------------------------

def test_observe_batched_counters_match_sequential():
    """Counters (hits, observed, out_of_range, edge ratio) are
    order-exact: one batched call equals per-value calls equals any split
    of the stream into chunks."""
    hist = hg.build_uniform(0.0, 100.0, 10)
    rng = np.random.default_rng(11)
    stream = rng.uniform(-20.0, 140.0, 3000).astype(np.float32)
    one = hg.DriftTracker(hist)
    one.observe(stream)
    per = hg.DriftTracker(hist)
    for v in stream:
        per.observe(v)
    chunked = hg.DriftTracker(hist)
    for part in np.array_split(stream, 7):
        chunked.observe(part)
    for tr in (per, chunked):
        assert tr.observed == one.observed == stream.size
        assert tr.out_of_range == one.out_of_range
        np.testing.assert_array_equal(tr.hits, one.hits)
        assert tr.edge_overflow_ratio == one.edge_overflow_ratio
    one.observe(np.zeros(0))                     # empty batch: no-op
    assert one.observed == stream.size


def test_observe_batched_reservoir_admission_is_unbiased():
    """The vectorized algorithm-R admission: the fill prefix is the stream
    prefix exactly, the reservoir never exceeds its size, holds only
    observed values, and stays representative of the whole stream (values
    from the late tail appear at roughly their fair share)."""
    hist = hg.build_uniform(0.0, 1.0, 4)
    tr = hg.DriftTracker(hist, reservoir_size=128)
    head = np.linspace(0.0, 1.0, 100, dtype=np.float32)
    tr.observe(head)
    np.testing.assert_array_equal(tr.sample(), head)   # prefix fill, in order
    tail = np.linspace(100.0, 200.0, 10_000, dtype=np.float32)
    tr.observe(tail)
    s = tr.sample()
    assert s.size == 128
    full = np.concatenate([head, tail])
    assert np.isin(s, full).all()
    # ~99% of the stream is tail, so the reservoir should be mostly tail
    assert (s >= 100.0).sum() > 100
