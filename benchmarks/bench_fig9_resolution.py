"""Fig. 9 + Table 3 (resolution rows): histogram resolution sweep.

H in {400, 800, 1600} at SF=0.1%: higher resolution => fewer entries but
each bitmap is physically larger (moderate net size decrease, Table 3);
query time varies because the predicate hits more buckets (Fig. 9).
"""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable
from repro.storage import tpch

CARD = 200_000
PAGE_CARD = 50


def run(card=CARD) -> None:
    li = tpch.generate_lineitem(card)
    lo, hi = tpch.selectivity_window(0.001)
    pred = Predicate.between(lo, hi)
    for h in (400, 800, 1600):
        us_init = timeit(lambda: HippoIndex.create(
            PagedTable.from_values(li.shipdate, PAGE_CARD),
            resolution=h, density=0.2), warmup=1, iters=3)
        idx = HippoIndex.create(PagedTable.from_values(li.shipdate, PAGE_CARD),
                                resolution=h, density=0.2)
        us_q = timeit(lambda: idx.search(pred).count)
        res = idx.search(pred)
        emit(f"fig9_resolution{h}", us_q,
             qps=round(1e6 / us_q, 1),
             init_us=round(us_init, 1), size_bytes=idx.nbytes(),
             rle_bytes=idx.nbytes(compressed=True), entries=idx.num_entries,
             pages_inspected=int(res.pages_inspected),
             total_pages=idx.table.num_pages)


if __name__ == "__main__":
    run()
