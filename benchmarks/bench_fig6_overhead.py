"""Fig. 6 / Table 1a: indexing overhead vs workload scale.

(a) index size, (b) initialization time, (c) maintenance (insert 0.1%) —
Hippo vs B+-Tree at three scales. The paper's headline: Hippo is ~25x (up to
two orders of magnitude) smaller and >=1.5x faster to build; maintenance is
up to three orders of magnitude cheaper in I/O terms.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.baselines import BPlusTree
from repro.core.hippo import HippoIndex
from repro.storage.table import PagedTable
from repro.storage import tpch

SCALES = (20_000, 100_000, 400_000)
PAGE_CARD = 50


def run(scales=SCALES) -> None:
    for card in scales:
        li = tpch.generate_lineitem(card)
        values = li.partkey

        us_hippo = timeit(lambda: HippoIndex.create(
            PagedTable.from_values(values, PAGE_CARD, spare_pages=1024)),
            warmup=1, iters=3)
        idx = HippoIndex.create(PagedTable.from_values(values, PAGE_CARD,
                                                       spare_pages=1024))
        us_btree = timeit(lambda: BPlusTree.bulk_load(values, PAGE_CARD),
                          warmup=1, iters=3)
        bt = BPlusTree.bulk_load(values, PAGE_CARD)

        hippo_b = idx.nbytes()
        hippo_cb = idx.nbytes(compressed=True)
        btree_b = bt.nbytes()
        emit(f"fig6a_size_card{card}", 0.0,
             hippo_bytes=hippo_b, hippo_rle_bytes=hippo_cb, btree_bytes=btree_b,
             ratio=round(btree_b / hippo_b, 1),
             ratio_rle=round(btree_b / hippo_cb, 1),
             entries=idx.num_entries)
        # qps here = index builds per second (the gate's rate metric for
        # this suite: init-time regressions drop it)
        emit(f"fig6b_init_card{card}", us_hippo,
             qps=round(1e6 / us_hippo, 2),
             btree_us=round(us_btree, 1),
             speedup=round(us_btree / us_hippo, 2))

        # (c) maintenance: TPC-H refresh = insert 0.1% new tuples.
        # Indexes are built once; only the insert work is timed. I/O-op
        # accounting is the paper's metric (wall-clock on this host measures
        # per-call dispatch for Hippo vs in-memory pointer chasing for the
        # B+-Tree, which is not the disk trade-off the paper measures).
        import math

        n_new = max(1, card // 1000)
        new_vals = tpch.generate_lineitem(n_new, seed=7).partkey

        i2 = HippoIndex.create(PagedTable.from_values(values, PAGE_CARD,
                                                      spare_pages=4096))
        i2.insert(float(new_vals[0]))  # compile the insert path
        us_h = timeit(lambda: [i2.insert(float(v)) for v in new_vals],
                      warmup=0, iters=1)
        i3 = HippoIndex.create(PagedTable.from_values(values, PAGE_CARD,
                                                      spare_pages=4096))
        i3.insert_batch(new_vals)  # compile both batch variants (same shape)
        i3.insert_batch(new_vals)
        us_hb = timeit(lambda: i3.insert_batch(new_vals), warmup=0, iters=1)

        b2 = BPlusTree.bulk_load(values, PAGE_CARD)
        r0, w0 = b2.io.node_reads, b2.io.node_writes
        us_b = timeit(lambda: [b2.insert(float(v), j)
                               for j, v in enumerate(new_vals)],
                      warmup=0, iters=1)
        btree_ios = (b2.io.node_reads - r0) + (b2.io.node_writes - w0)

        # paper's models (Formula 8 vs log(Card)) + measured node touches
        hippo_ios = n_new * (math.log2(max(2, i2.num_entries)) + 4)
        btree_model_ios = n_new * math.log2(card)
        emit(f"fig6c_insert_card{card}", us_h,
             batch_us=round(us_hb, 1), btree_us=round(us_b, 1),
             hippo_model_ios=round(hippo_ios),
             btree_model_ios=round(btree_model_ios),
             btree_node_touches=btree_ios,
             model_io_ratio=round(btree_model_ios / max(hippo_ios, 1), 2))


if __name__ == "__main__":
    run()
