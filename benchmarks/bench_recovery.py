"""Crash recovery: incremental delta-commit bytes and recovery-time-objective.

The self-healing serving claim has two measurable halves:

1. **Commit bytes.** With ``snapshot_mode="incremental"`` a drain commits a
   delta of only the shards that round changed, so the durable write per
   drain is a small fraction of a full snapshot. This suite builds a real
   durable engine, runs ingest rounds whose final commit is killed by the
   fault-injection harness (``crash_points.arm("truncate.pre")`` — the
   delta lands, the journal truncation does not, exactly a kill -9 between
   the two), and reports the committed delta sizes straight off disk
   against the full base snapshot. The ratio is asserted in-bench: a delta
   re-serializes its dirty shards' index sections *and* their table page
   regions, so its size tracks the dirty shards' page spans rather than
   the table (measured ~3x at quick scale where per-shard overhead looms,
   ~10x at full scale); the floor is a conservative 2x at both scales.

2. **RTO.** ``QueryEngine.recover`` on two crashed directories holding the
   *same acknowledged state*: one with base + delta chain + journal suffix
   (the incremental path), one with only the initial base + the entire
   journal (``snapshot_on_drain=False`` — every acknowledged write rides
   the WAL). Both recoveries are checked bit-identical against the
   brute-force count over the acknowledged multiset before being timed.

Rows:

  recovery_commit_bytes    — untimed: mean/max committed delta bytes vs
                             the full base snapshot bytes;
                             ``ratio_full_vs_delta`` carries the claim
                             (asserted >= 2x).
  recovery_rto_incremental — time to rebuild a serving-ready engine from
                             base + K deltas + journal suffix, gated via
                             achieved_gbps (durable bytes read / RTO).
  recovery_rto_wal_replay  — same acknowledged state recovered from the
                             initial base + full-journal replay; the
                             incremental row's ``rto_speedup`` over this
                             is asserted >= 1.3x (loose — measured ~2-5x;
                             host-noise margin), the gate rides
                             achieved_gbps.

  PYTHONPATH=src python -m benchmarks.bench_recovery [--quick]
"""
from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.runtime.faultinject import InjectedCrash, crash_points
from repro.storage.table import PagedTable

CARD = 120_000
ROUNDS = 10              # durable commits (deltas) before the injected crash
WRITES_PER_ROUND = 360
PAGE_CARD = 64
SHARDS = 4
ASSERT_MIN_RATIO = 2.0       # full-snapshot bytes / mean delta bytes
ASSERT_MIN_RTO_SPEEDUP = 1.3  # chain recovery vs WAL-only replay (meas. ~2-5x)

_ENGINE_KW = dict(batch=8, drain_policy="manual", auto_resummarize=False)
_RECOVER_KW = dict(snapshot_on_recover=False, wal_sync=False, **_ENGINE_KW)


def _preds() -> list[Predicate]:
    return [
        Predicate.between(2_000.0, 9_000.0),
        Predicate.between(40_000.0, 41_500.0),
        Predicate.between(99_000.0, 100_500.0),
        Predicate(lo=5.0, hi=1.0),
        Predicate.between(-1e30, 1e30),
    ]


def _brute(values: np.ndarray, ps: list[Predicate]) -> np.ndarray:
    v = np.asarray(values, np.float32)
    return np.asarray([((v >= p.lo) & (v <= p.hi)).sum() for p in ps],
                      np.int64)


def _make_index(base: np.ndarray, spare_pages: int) -> ShardedHippoIndex:
    table = PagedTable.from_values(base.copy(), page_card=PAGE_CARD,
                                   spare_pages=spare_pages)
    return ShardedHippoIndex.create(table, num_shards=SHARDS, resolution=32)


def _ingest(eng: QueryEngine, rng, rounds: int, per_round: int,
            *, crash_last_commit: bool) -> list[float]:
    """Acknowledged ingest: ``rounds`` write+flush cycles; optionally kill
    the *last* flush between its delta commit and the journal truncation
    (the acknowledged rows are all durable — delta or journal — either way).
    """
    acked: list[float] = []
    for r in range(rounds):
        for v in rng.uniform(0.0, 100_000.0, per_round):
            eng.write(float(v))
            acked.append(float(v))
        if crash_last_commit and r == rounds - 1:
            crash_points.arm("truncate.pre", times=1)
            try:
                eng.flush()
            except InjectedCrash:
                pass
            finally:
                crash_points.reset()
        else:
            eng.flush()
    return acked


def _durable_bytes(root: Path) -> int:
    """Every byte recovery may read: snapshots, delta chain, journal."""
    return sum(f.stat().st_size for f in root.rglob("*") if f.is_file())


def _check_recovery(root: Path, expect: np.ndarray, ps: list[Predicate],
                    label: str) -> None:
    eng = QueryEngine.recover(root, **_RECOVER_KW)
    try:
        got = eng.run_all(ps)
    finally:
        eng.close()
    np.testing.assert_array_equal(
        got, expect, err_msg=f"{label}: recovered counts diverge from the "
                             f"acknowledged multiset")


def _timed_recover(root: Path) -> None:
    QueryEngine.recover(root, **_RECOVER_KW).close()


def run(card: int = CARD, rounds: int = ROUNDS,
        writes_per_round: int = WRITES_PER_ROUND) -> None:
    rng = np.random.default_rng(0)
    base = np.sort(rng.uniform(0.0, 100_000.0, card)).astype(np.float32)
    spare = 2 * (rounds * writes_per_round // PAGE_CARD + SHARDS + 1)
    ps = _preds()

    with tempfile.TemporaryDirectory() as tmp:
        # -- incremental scenario: base + delta chain + journal suffix ------
        root_inc = Path(tmp) / "inc"
        eng = QueryEngine(_make_index(base, spare), storage_dir=root_inc,
                          wal_sync=False, snapshot_mode="incremental",
                          compact_every=rounds + 2, compact_ratio=1e9,
                          **_ENGINE_KW)
        acked = _ingest(eng, np.random.default_rng(1), rounds,
                        writes_per_round, crash_last_commit=True)
        eng.close()

        deltas = sorted(root_inc.glob("delta_*"),
                        key=lambda d: int(d.name.rsplit("_", 1)[1]))
        assert len(deltas) == rounds, \
            f"expected {rounds} committed deltas, found {len(deltas)}"
        delta_sizes = [(d / "index.bin").stat().st_size for d in deltas]
        full_bytes = (root_inc / "snap_1" / "index.bin").stat().st_size
        ratio = full_bytes / (sum(delta_sizes) / len(delta_sizes))
        emit("recovery_commit_bytes", 0.0,
             delta_bytes_mean=round(sum(delta_sizes) / len(delta_sizes), 1),
             delta_bytes_max=max(delta_sizes),
             full_snapshot_bytes=full_bytes,
             ratio_full_vs_delta=round(ratio, 2),
             deltas=len(deltas), card=card, shards=SHARDS,
             writes_per_commit=writes_per_round)

        # -- WAL-only scenario: same acknowledged state, full-journal replay
        root_wal = Path(tmp) / "wal"
        eng2 = QueryEngine(_make_index(base, spare), storage_dir=root_wal,
                           wal_sync=False, snapshot_on_drain=False,
                           **_ENGINE_KW)
        acked2 = _ingest(eng2, np.random.default_rng(1), rounds,
                         writes_per_round, crash_last_commit=False)
        eng2.close()
        assert acked2 == acked, "scenarios diverged: the RTO rows would " \
                                "not recover the same acknowledged state"

        # correctness first, timing second: both crashed dirs must land on
        # exactly the acknowledged counts before their RTO means anything
        expect = _brute(np.concatenate([base,
                                        np.asarray(acked, np.float32)]), ps)
        _check_recovery(root_inc, expect, ps, "incremental")
        _check_recovery(root_wal, expect, ps, "wal_replay")

        inc_bytes = _durable_bytes(root_inc)
        wal_bytes = _durable_bytes(root_wal)
        us_inc = timeit(lambda: _timed_recover(root_inc), warmup=1, iters=3)
        us_wal = timeit(lambda: _timed_recover(root_wal), warmup=1, iters=3)

    emit("recovery_rto_incremental", us_inc,
         achieved_gbps=round(inc_bytes / us_inc / 1000.0, 4),
         rto_ms=round(us_inc / 1000.0, 2),
         durable_kb=round(inc_bytes / 1e3, 1), deltas=len(delta_sizes),
         rto_speedup=round(us_wal / us_inc, 2),
         card=card, acked_writes=len(acked))
    emit("recovery_rto_wal_replay", us_wal,
         achieved_gbps=round(wal_bytes / us_wal / 1000.0, 4),
         rto_ms=round(us_wal / 1000.0, 2),
         durable_kb=round(wal_bytes / 1e3, 1),
         wal_records=len(acked), card=card)

    assert ratio >= ASSERT_MIN_RATIO, (
        f"mean committed delta is only {ratio:.2f}x smaller than the full "
        f"base snapshot (card={card}, S={SHARDS}, "
        f"{writes_per_round} writes/commit) — need >= {ASSERT_MIN_RATIO}x")
    assert us_wal >= ASSERT_MIN_RTO_SPEEDUP * us_inc, (
        f"delta-chain recovery ({us_inc / 1e3:.1f} ms) is not meaningfully "
        f"faster than WAL-only replay ({us_wal / 1e3:.1f} ms) of the same "
        f"acknowledged state — need >= {ASSERT_MIN_RTO_SPEEDUP}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        run(card=30_000, rounds=4, writes_per_round=120)
    else:
        run()
