"""Shared benchmark helpers: timing + CSV emission.

Every benchmark prints rows:  name,us_per_call,derived
``derived`` is a ';'-separated key=value list (sizes, ratios, counts).
"""
from __future__ import annotations

import time

import jax

ROWS: list[tuple[str, float, str]] = []


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (block on jax outputs)."""
    for _ in range(warmup):
        _block(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _block(out):
    try:
        jax.block_until_ready(out)
    except Exception:  # noqa: BLE001 — host-side results
        pass
    return out


def emit(name: str, us: float, **derived) -> None:
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    ROWS.append((name, us, d))
    print(f"{name},{us:.1f},{d}", flush=True)
