"""Shared benchmark helpers: timing + CSV emission.

Every benchmark prints rows:  name,us_per_call,derived
``derived`` is a ';'-separated key=value list (sizes, ratios, counts).

Timing methodology (one helper, every suite): interleaved min-of-reps.
``measure(*fns)`` rotates through the candidate callables rep by rep and
keeps each one's best wall time, so a throttling or noisy-neighbor window
hits every contender instead of whichever happened to run inside it, and
the regression gate (``benchmarks/check.py``) compares like with like
across runs. ``emit`` stamps the method into each row's derived fields.
"""
from __future__ import annotations

import math
import time

import jax

ROWS: list[tuple[str, float, str]] = []

# Stamped into every row so trajectory files self-describe how they were
# timed; bump the name if the methodology ever changes again.
TIMING_METHOD = "interleaved_min_of_reps"


def measure(*fns, warmup: int = 1, reps: int = 3) -> list[float]:
    """Best wall time per call in microseconds for each callable.

    All callables are warmed first, then timed interleaved: rep 1 times each
    fn once, then rep 2, ... — min over reps per fn (blocking on jax
    outputs). Interleaving is what makes A-vs-B speedups honest; min is the
    right estimator for a fixed-work benchmark where every source of error
    is additive noise.
    """
    for fn in fns:
        for _ in range(warmup):
            _block(fn())
    best = [math.inf] * len(fns)
    for _ in range(max(1, reps)):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            _block(fn())
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e6 for b in best]


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Single-callable convenience wrapper over ``measure`` (min of
    ``iters`` reps after ``warmup`` warm calls)."""
    return measure(fn, warmup=warmup, reps=iters)[0]


def _block(out):
    """Block until device work behind ``out`` is done.

    Only the "not a jax value" complaints are swallowed (host-side results:
    plain lists/floats/objects have no buffers to wait on). Real device
    errors — a failed computation surfacing at block time — must propagate,
    or a benchmark whose kernel crashes gets timed as a success.
    """
    try:
        jax.block_until_ready(out)
    except (AttributeError, TypeError):  # host-side result, nothing to block on
        pass
    return out


def emit(name: str, us: float, **derived) -> None:
    derived.setdefault("method", TIMING_METHOD)
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    ROWS.append((name, us, d))
    print(f"{name},{us:.1f},{d}", flush=True)
