"""Trajectory regression gate: compare a fresh benchmark run against the
last committed ``BENCH_*.json`` and fail on throughput drops.

The gated fields are ``qps`` and ``achieved_gbps`` (higher is better, both
parsed out of each row's derived fields). A row regresses when a gated
metric drops more than its tolerance below the baseline value — 20% by
default, overridable per row for known-noisy configs. Rows/suites only in
the baseline (a partial ``--only`` run, or a quick-vs-full row-set
difference) are reported as skipped, not failed: partial runs gate what
they ran. Suites or rows only in the current run are new and pass.

Used by ``benchmarks/run.py --check BASELINE.json`` (compares the run it
just finished) and ``scripts/check_bench.py`` (compares two files, and
hosts the ``--coverage`` enforcement that every registered suite emits at
least one gated row so new benches can't dodge the gate).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass

GATED_FIELDS = ("qps", "achieved_gbps")
DEFAULT_TOLERANCE = 0.2

# Known-noisy rows at quick scale: measured bimodal across process runs on
# shared CPU hosts (up to ~45% swings that rep counts don't smooth — the
# modes are process-state, not per-call jitter). The loose tolerance still
# catches genuine breakage (a 2-3x regression); CLI --row-tolerance
# overrides these, and the spreads are documented in docs/benchmarks.md.
DEFAULT_ROW_TOLERANCES = {
    # bare row names so any caller key — bare (merged over these) or
    # suite-qualified (checked first) — takes precedence
    "drift_no_resummarize": 0.55,
    "drift_adaptive": 0.55,
    # learned-summary A/B rows: same engine run_all timing loops as the
    # drift pair, same process-state bimodality at quick scale
    "learned_zipf_equal_mass": 0.5,
    "learned_zipf_learned": 0.5,
    "learned_lognormal_equal_mass": 0.5,
    "learned_lognormal_learned": 0.5,
    "learned_drift_equal_mass": 0.5,
    "learned_drift_learned": 0.5,
    "sweep_dense_sel0.5": 0.4,
    "sweep_compact_sel0.5": 0.6,
    "sweep_compact_sel0.01": 0.4,
    "async_maint_staged": 0.4,
    # durable-storage throughput rows: fsync latency on shared hosts is
    # the dominant term and swings with unrelated disk traffic; the bytes
    # claim itself is asserted in-bench, these only guard gross breakage
    "storage_save": 0.6,
    "storage_load": 0.6,
    # crash-recovery RTO rows: same disk-noise profile as the storage pair
    # (snapshot + delta + journal reads, engine rebuild); the commit-bytes
    # ratio is asserted in-bench, these only guard gross breakage
    "recovery_rto_incremental": 0.6,
    "recovery_rto_wal_replay": 0.6,
    # sub-100ms kernel rows: min-of-15 still swings ~35-40% when a host
    # noise stretch outlasts the whole rep window
    "kernel_bitmap_and_64k": 0.45,
    "kernel_page_inspect_16kpages": 0.45,
    "kernel_compact_inspect_q64_2kslab": 0.45,
    "kernel_batch_filter_q64_16k": 0.3,
    # Q=8 contrast rows: milliseconds of dispatch-dominated work per call;
    # the Q=64+ rows carry the 20% gate for these suites
    "engine_loop_q8": 0.5,
    "engine_search_many_q8": 0.5,
    "engine_run_all_q8": 0.5,
}


class BaselineError(Exception):
    """The baseline file is unreadable or not a trajectory document."""


def _reject_constant(name: str):
    raise BaselineError(
        f"baseline contains non-strict JSON constant {name!r} — regenerate "
        "it with benchmarks.run --json (which sanitizes nan/inf to null)")


def load_trajectory(path: str) -> dict:
    """Load + validate a ``BENCH_*.json`` document, strictly: NaN/Infinity
    constants, a missing suites map, or malformed rows all raise
    ``BaselineError`` instead of feeding the gate garbage."""
    try:
        with open(path) as f:
            doc = json.load(f, parse_constant=_reject_constant)
    except BaselineError:
        raise
    except (OSError, ValueError) as e:
        raise BaselineError(f"cannot load baseline {path}: {e}") from e
    validate_trajectory(doc, origin=path)
    return doc


def validate_trajectory(doc, *, origin: str = "<doc>") -> None:
    if not isinstance(doc, dict) or not isinstance(doc.get("suites"), dict):
        raise BaselineError(f"{origin}: not a trajectory document "
                            "(missing 'suites' map)")
    for suite, rows in doc["suites"].items():
        if not isinstance(rows, list):
            raise BaselineError(f"{origin}: suite {suite!r} rows are not a list")
        for row in rows:
            if not isinstance(row, dict) or "name" not in row \
                    or "us_per_call" not in row:
                raise BaselineError(
                    f"{origin}: suite {suite!r} has a malformed row "
                    f"(need name + us_per_call): {row!r}")


@dataclass(frozen=True)
class Delta:
    """One gated comparison: a (suite, row, field) triple's verdict."""
    suite: str
    name: str
    field: str
    base: float | None
    cur: float | None
    tolerance: float
    status: str          # ok | fail | new | skipped

    @property
    def drop_frac(self) -> float | None:
        if self.base and self.cur is not None:
            return (self.base - self.cur) / self.base
        return None


def _gated(row: dict) -> dict[str, float]:
    """The row's finite gated metrics (from the parsed derived fields)."""
    derived = row.get("derived") or {}
    out = {}
    for field in GATED_FIELDS:
        val = derived.get(field, row.get(field))
        if isinstance(val, (int, float)) and not isinstance(val, bool) \
                and math.isfinite(val) and val > 0:
            out[field] = float(val)
    return out


def compare(baseline: dict, current: dict, *,
            tolerance: float = DEFAULT_TOLERANCE,
            row_tolerance: dict[str, float] | None = None) -> list[Delta]:
    """Every gated (suite, row, field) verdict, baseline-driven.

    ``row_tolerance`` overrides the default per row, keyed by bare row name
    or ``suite/name`` (the qualified key wins). ``DEFAULT_ROW_TOLERANCES``
    seeds the map for known-noisy rows; caller-provided entries win.
    """
    row_tolerance = {**DEFAULT_ROW_TOLERANCES, **(row_tolerance or {})}
    deltas: list[Delta] = []
    cur_suites = current.get("suites", {})
    for suite, base_rows in baseline.get("suites", {}).items():
        cur_rows = {r["name"]: r for r in cur_suites.get(suite, [])}
        for brow in base_rows:
            name = brow["name"]
            tol = row_tolerance.get(f"{suite}/{name}",
                                    row_tolerance.get(name, tolerance))
            base_metrics = _gated(brow)
            crow = cur_rows.get(name)
            for field, base_val in sorted(base_metrics.items()):
                if crow is None:
                    # suite not run (--only partial) or row set changed
                    deltas.append(Delta(suite, name, field, base_val, None,
                                        tol, "skipped"))
                    continue
                cur_val = _gated(crow).get(field)
                if cur_val is None:
                    # the row ran but its gated metric vanished/went non-
                    # finite — that IS a regression, not a skip
                    deltas.append(Delta(suite, name, field, base_val, None,
                                        tol, "fail"))
                    continue
                ok = cur_val >= base_val * (1.0 - tol)
                deltas.append(Delta(suite, name, field, base_val, cur_val,
                                    tol, "ok" if ok else "fail"))
            if crow is not None and not base_metrics and _gated(crow):
                # baseline row predates the gated fields; now it has them
                for field in sorted(_gated(crow)):
                    deltas.append(Delta(suite, name, field, None,
                                        _gated(crow)[field], tol, "new"))
    # suites/rows only in the current run: new, never a failure
    base_suites = baseline.get("suites", {})
    for suite, rows in cur_suites.items():
        base_names = {r["name"] for r in base_suites.get(suite, [])}
        for row in rows:
            if row["name"] in base_names:
                continue
            for field, val in sorted(_gated(row).items()):
                deltas.append(Delta(suite, row["name"], field, None, val,
                                    tolerance, "new"))
    return deltas


def failures(deltas: list[Delta]) -> list[Delta]:
    return [d for d in deltas if d.status == "fail"]


def _fmt(val: float | None) -> str:
    return "-" if val is None else f"{val:,.1f}"


def delta_table(deltas: list[Delta], *, verbose: bool = True) -> str:
    """Human-readable per-row delta report (every gated comparison when
    ``verbose``, failures-only otherwise) plus a one-line summary."""
    shown = deltas if verbose else failures(deltas)
    width = max([len(f"{d.suite}/{d.name}") for d in shown] + [20])
    lines = [f"{'suite/row':<{width}} {'field':<13} {'baseline':>12} "
             f"{'current':>12} {'delta':>8} {'tol':>5}  status"]
    for d in shown:
        drop = d.drop_frac
        delta_s = "-" if drop is None else f"{-drop:+.1%}"
        lines.append(
            f"{d.suite + '/' + d.name:<{width}} {d.field:<13} "
            f"{_fmt(d.base):>12} {_fmt(d.cur):>12} {delta_s:>8} "
            f"{d.tolerance:>5.0%}  {d.status.upper()}")
    counts = {s: sum(1 for d in deltas if d.status == s)
              for s in ("ok", "fail", "new", "skipped")}
    lines.append(
        f"gate: {counts['ok']} ok, {counts['fail']} fail, "
        f"{counts['new']} new, {counts['skipped']} skipped "
        f"(gated fields: {', '.join(GATED_FIELDS)})")
    return "\n".join(lines)


def parse_row_tolerances(items: list[str]) -> dict[str, float]:
    """Parse repeated ``--row-tolerance name=frac`` CLI values."""
    out = {}
    for item in items or []:
        name, sep, frac = item.rpartition("=")
        if not sep or not name:
            raise ValueError(
                f"--row-tolerance wants ROW=FRAC (e.g. drift_adaptive=0.5), "
                f"got {item!r}")
        out[name] = float(frac)
    return out


def coverage_problems(doc: dict, registered: set[str]) -> list[str]:
    """Why this trajectory cannot serve as a full gate baseline: registered
    suites it lacks, and suites that time work but expose no gated metric
    (those benches would dodge the gate entirely). Suites whose every row
    is untimed (``us_per_call`` 0 — closed-form model checks like
    ``cost_model``) have nothing perf-gateable and are exempt."""
    problems = []
    suites = doc.get("suites", {})
    for suite in sorted(registered - set(suites)):
        problems.append(f"suite {suite!r} is registered but absent from the "
                        "trajectory (partial run?)")
    for suite in sorted(registered & set(suites)):
        timed = any(r.get("us_per_call") for r in suites[suite])
        if timed and not any(_gated(r) for r in suites[suite]):
            problems.append(
                f"suite {suite!r} times work but emits no row with a gated "
                f"metric ({' or '.join(GATED_FIELDS)}) — it would dodge "
                "the regression gate")
    return problems
