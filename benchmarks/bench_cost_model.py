"""§6 cost-model validation: estimated vs measured entries / inspection
probability / insert I/Os on uniform data (the model's assumption)."""
from __future__ import annotations

import math

from benchmarks.common import emit
from repro.core import cost
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable
from repro.storage import tpch

CARD = 200_000
PAGE_CARD = 50


def run(card=CARD) -> None:
    li = tpch.generate_lineitem(card)
    for h, d in ((400, 0.2), (400, 0.4), (800, 0.2)):
        idx = HippoIndex.create(PagedTable.from_values(li.shipdate, PAGE_CARD),
                                resolution=h, density=d)
        est_entries = cost.num_entries(card, h, d)
        emit(f"cost_entries_h{h}_d{int(d*100)}", 0.0,
             measured=idx.num_entries, estimated=round(est_entries, 1),
             rel_err=round(abs(idx.num_entries - est_entries) / est_entries, 3))

        sf = 0.001
        lo, hi = tpch.selectivity_window(sf)
        res = idx.search(Predicate.between(lo, hi))
        measured_prob = int(res.pages_inspected) / idx.table.num_pages
        est_prob = cost.prob_inspect(sf, h, d)
        emit(f"cost_prob_h{h}_d{int(d*100)}", 0.0,
             measured=round(measured_prob, 3), estimated=round(est_prob, 3))

        est_ios = cost.insert_time_ios(card, h, d)
        btree_ios = cost.btree_insert_time_ios(card)
        emit(f"cost_insert_ios_h{h}_d{int(d*100)}", 0.0,
             hippo=round(est_ios, 1), btree=round(btree_ios, 1),
             advantage=round(btree_ios / est_ios, 2))

    # coupon-collector worked examples from §6.2
    emit("cost_T_h1000_d10", 0.0, estimated=round(cost.tuples_per_entry(1000, 0.1), 1),
         paper=105.3)
    emit("cost_T_h10000_d20", 0.0, estimated=round(cost.tuples_per_entry(10000, 0.2)),
         paper=2230)


if __name__ == "__main__":
    run()
