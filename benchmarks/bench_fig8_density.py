"""Fig. 8 + Table 3 (density rows): partial histogram density sweep.

D in {20%, 40%, 80%} at SF=0.1%: higher density => smaller index & init
(Table 3) but more possible-qualified pages => slower queries (Fig. 8).
"""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable
from repro.storage import tpch

CARD = 200_000
PAGE_CARD = 50


def run(card=CARD) -> None:
    li = tpch.generate_lineitem(card)
    lo, hi = tpch.selectivity_window(0.001)
    pred = Predicate.between(lo, hi)
    base = None
    for d in (0.2, 0.4, 0.8):
        us_init = timeit(lambda: HippoIndex.create(
            PagedTable.from_values(li.shipdate, PAGE_CARD),
            resolution=400, density=d), warmup=1, iters=3)
        idx = HippoIndex.create(PagedTable.from_values(li.shipdate, PAGE_CARD),
                                resolution=400, density=d)
        us_q = timeit(lambda: idx.search(pred).count)
        res = idx.search(pred)
        size = idx.nbytes()
        if base is None:
            base = size
        emit(f"fig8_density{int(d*100)}", us_q,
             qps=round(1e6 / us_q, 1),
             init_us=round(us_init, 1), size_bytes=size,
             size_vs_d20=round(size / base, 3), entries=idx.num_entries,
             pages_inspected=int(res.pages_inspected),
             total_pages=idx.table.num_pages)


if __name__ == "__main__":
    run()
