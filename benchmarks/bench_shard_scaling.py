"""Shard-scaling throughput: the sharded engine vs shard count on one CPU.

The partition layer's CPU win is *work avoidance*, not device parallelism:
with S shards, the engine's summary routing sends each admitted query only to
the shards whose bucket unions it can match, so one batch becomes S narrow
dispatches of ~Q/S queries over P/S pages instead of one Q x P program.
Keys are sorted (the time-ordered append workload page grouping itself is
built for), so page ranges correlate with value ranges and routing is
selective; on uniform shuffled keys every shard matches every query and
sharding only helps once shards sit on separate devices.

Counts are asserted bit-identical between every shard count and the
unsharded ``HippoIndex`` path before timing. The ``speedup`` field is
queries/sec vs the S=1 engine (acceptance: S=4 >= 2x S=1 at Q=64).

  PYTHONPATH=src python -m benchmarks.bench_shard_scaling [--quick]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.hippo import HippoIndex
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable

CARD = 400_000
SHARDS = (1, 2, 4, 8)
Q = 64


def _workload(rng, q: int) -> list[Predicate]:
    """Narrow-to-medium ranges over the sorted key domain."""
    preds = []
    for _ in range(q):
        lo = float(rng.uniform(0, 1e6))
        width = float(rng.choice([500.0, 2000.0, 8000.0]))
        preds.append(Predicate.between(lo, lo + width))
    return preds


def run(card: int = CARD, shards=SHARDS) -> None:
    rng = np.random.default_rng(0)
    values = np.sort(rng.uniform(0, 1e6, card))
    preds = _workload(rng, Q)

    ref_table = PagedTable.from_values(values.copy(), page_card=50)
    ref = HippoIndex.create(ref_table, resolution=400, density=0.2)
    want = np.asarray(ref.search_batch(preds).counts, np.int64)

    base_qps = None
    for s in shards:
        table = PagedTable.from_values(values.copy(), page_card=50)
        sidx = ShardedHippoIndex.create(table, num_shards=s,
                                        resolution=400, density=0.2)
        # sharded=True pins the summary-routed dispatch this bench measures
        # (the engine's default mode is now the compact gather path)
        engine = QueryEngine(sidx, batch=Q, sharded=True)
        counts = engine.run_all(preds)        # also warms every trace width
        assert (counts == want).all(), \
            f"sharded counts diverge from the unsharded path at S={s}"

        us = timeit(lambda: QueryEngine(sidx, batch=Q, sharded=True)
                    .run_all(preds), warmup=1, iters=3)
        qps = Q / (us / 1e6)
        if base_qps is None:
            base_qps = qps
        emit(f"shard_scaling_s{s}_q{Q}", us, qps=round(qps, 1),
             speedup=round(qps / base_qps, 2),
             dispatches=engine.stats.shard_dispatches,
             pruned=engine.stats.shards_pruned,
             occupancy=round(engine.stats.occupancy, 3))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(card=100_000 if args.quick else CARD,
        shards=(1, 2, 4) if args.quick else SHARDS)
