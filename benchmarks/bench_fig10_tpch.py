"""Fig. 10: TPC-H standard queries 6, 15 and 20 (analogues) on l_shipdate at
SF = 0.1% (one week), Hippo vs B+-Tree access path vs full scan.

Q15 invokes the range view twice, which is where the paper sees the larger
index-time difference.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.baselines import BPlusTree
from repro.storage import tpch

CARD = 200_000


def run(card=CARD) -> None:
    li = tpch.generate_lineitem(card)
    idx = tpch.build_shipdate_index(li)
    bt = BPlusTree.bulk_load(li.shipdate, 50)
    lo, hi = tpch.selectivity_window(0.001)

    def btree_mask():
        tids = bt.range_search(lo, hi)
        mask = np.zeros(card, bool)
        rows = (np.asarray(tids, np.int64) >> 16) * 50 \
            + (np.asarray(tids, np.int64) & 0xFFFF)
        mask[rows[rows < card]] = True
        return mask

    for qname, qfn in (("q6", tpch.q6), ("q15", tpch.q15), ("q20", tpch.q20)):
        us_hippo = timeit(lambda: qfn(li, idx, lo, hi), warmup=1, iters=3)

        def via_btree():
            mask = btree_mask()
            if qname == "q6":
                m = mask & (li.discount >= 0.05) & (li.discount <= 0.07) \
                    & (li.quantity < 24)
                return float((li.extendedprice[m] * li.discount[m]).sum())
            return mask.sum()

        us_btree = timeit(via_btree, warmup=1, iters=3)
        emit(f"fig10_{qname}", us_hippo, qps=round(1e6 / us_hippo, 1),
             btree_us=round(us_btree, 1), sf=0.001)

    # sanity: Q6 via Hippo equals Q6 via brute force
    brute = (li.shipdate >= lo) & (li.shipdate <= hi) & (li.discount >= 0.05) \
        & (li.discount <= 0.07) & (li.quantity < 24)
    want = float((li.extendedprice[brute] * li.discount[brute]).sum())
    got = tpch.q6(li, idx, lo, hi)
    assert abs(got - want) < 1e-3 * max(abs(want), 1.0), (got, want)
    emit("fig10_q6_exactness", 0.0, hippo=round(got, 2), brute=round(want, 2))


if __name__ == "__main__":
    run()
