"""§5 maintenance: eager insert (tuple-at-a-time vs vectorized batch), and
lazy delete + vacuum (entries re-summarized stay localized)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable
from repro.storage import tpch

CARD = 100_000
PAGE_CARD = 50


def run(card=CARD) -> None:
    li = tpch.generate_lineitem(card)
    new_vals = tpch.generate_lineitem(card // 1000, seed=3).partkey

    def fresh():
        return HippoIndex.create(PagedTable.from_values(li.partkey, PAGE_CARD,
                                                        spare_pages=2048))

    idx = fresh()
    us_one = timeit(lambda: idx.insert(float(new_vals[0])), warmup=1, iters=5)

    idx2 = fresh()
    idx2.insert_batch(new_vals)  # compile both batch variants
    idx2.insert_batch(new_vals)
    us_batch_total = timeit(lambda: idx2.insert_batch(new_vals), warmup=0, iters=1)
    # qps = eager tuple inserts per second (the paper's maintenance-overhead
    # headline, and this suite's gated rate metric)
    emit("maint_insert_eager", us_one,
         qps=round(1e6 / us_one, 1),
         batch_total_us=round(us_batch_total, 1),
         batch_per_tuple_us=round(us_batch_total / len(new_vals), 1),
         n_batch=len(new_vals),
         speedup=round(us_one * len(new_vals) / us_batch_total, 1))

    # lazy delete + vacuum (compile the vacuum path on a sibling index first)
    warm = fresh()
    warm.table.delete_where(1000.0, 3000.0)
    warm.vacuum()
    idx3 = fresh()
    n_del = idx3.table.delete_where(1000.0, 3000.0)
    us_vacuum = timeit(lambda: idx3.vacuum() or 1, warmup=0, iters=1)
    emit("maint_vacuum", us_vacuum, deleted=n_del,
         entries_resummarized=idx3.counters.entries_resummarized,
         total_entries=idx3.num_entries)
    res = idx3.search(Predicate.between(1000.0, 3000.0))
    emit("maint_vacuum_exact", 0.0, count_after=int(res.count))


if __name__ == "__main__":
    run()
