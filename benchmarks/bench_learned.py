"""Learned per-shard summaries: pages gathered under skewed and drifting keys.

An equi-depth histogram spends boundary budget proportional to *mass*, but a
summary boundary only prunes where it separates tuples: any single key's mass
beyond 1/H is dead weight (all its duplicates bucketize identically). On a
duplicate-heavy attribute the quantile grid drops whole runs of boundaries
inside heavy-hitter ties — after the strictness ladder those buckets are
epsilon-wide and empty — while the long tail, where distinct keys actually
spread over pages, is left coarse. ``core.learned`` fits an error-bounded
piecewise-linear model to the *clamped* CDF (per-key mass capped at 1/H,
overhang water-filled back over the separating regions) and materializes
boundaries from its inverse, so the same H buys finer resolution exactly
where pruning happens; on drift refits (``learned_rebuild``) it additionally
tilts the budget toward the reservoir (75/25 vs ``rebuild``'s 50/50 blend).

Three scenarios, each timing two otherwise-identical compact engines
(S=4, 64 queries in batches of 8, equal H) that differ only in the index
summary policy
(``summary="equal_mass"`` vs ``summary="learned"``), with counts asserted
bit-identical to brute force for both — the boundaries change pruning, never
results:

  zipf       — duplicate-heavy build-time skew on a key-clustered table;
               narrow quantile-anchored range queries. The headline:
               ``page_gain`` (equal-mass pages inspected over learned,
               per-query) >= 1.3x is asserted at the full configuration.
  lognormal  — continuous skew, no duplicates: the mass clamp never engages
               and both policies land near parity. Kept as the honest
               control row (not asserted, expect gain ~1.0x).
  drift      — rounds of clustered zipf-alphabet inserts marching upward,
               each followed by an explicit ``engine.resummarize()`` refit
               under the index's policy; queries chase the freshest window.
               Learned refits clamp the duplicate-heavy reservoir *and*
               keep 75% of the budget on it; >= 1.3x page_gain asserted at
               the full configuration.

  PYTHONPATH=src python -m benchmarks.bench_learned [--quick]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, measure
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable

CARD = 100_000
PAGE_CARD = 50
SHARDS = 4
Q = 64
BATCH = 8              # small batches: the gather slab (an adaptive power
                       # of two) then tracks per-query pruning quality; at
                       # batch=Q the 64 narrow windows tile the skewed
                       # region and both policies union to the same slab
RESOLUTION = 400
DENSITY = 0.02
MAX_SLOTS = 512        # right-sized: the match phase scans every slot
SPAN = 50              # query width in tuples (~0.05% selectivity)
ZIPF_KEYS = 2000       # distinct-key alphabet for the skewed scenarios
ZIPF_A = 1.4
ROUNDS = 3             # drift scenario: insert windows
INSERTS = 6000         # per round, zipf-drawn inside the window
BASE_DOMAIN = 1e5
STEP = 1e4
ASSERT_MIN_GAIN = 1.3  # acceptance floor: equal-mass sel_ratio / learned


def _zipf_values(rng, card: int, n_keys: int = ZIPF_KEYS) -> np.ndarray:
    """Duplicate-heavy draw from a finite zipf-weighted alphabet: the head
    keys repeat across many pages, the tail spreads distinct keys thin."""
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    mass = ranks ** -ZIPF_A
    return rng.choice(ranks, size=card, p=mass / mass.sum())


MAX_QUERY_MASS = 0.005  # reject candidate windows matching > 0.5% of tuples


def _quantile_preds(rng, sorted_values: np.ndarray, q: int, span: int):
    """Narrow windows in tuple (quantile) space: each predicate covers
    ``span`` consecutive tuples of the sorted key column, anchors uniform
    over the table. Candidates whose *true* match mass exceeds
    ``MAX_QUERY_MASS`` are rejected — a window that lands on a heavy
    hitter matches every duplicate and stops being narrow; such queries
    cost the same under any summary and would only dilute the comparison."""
    v = sorted_values
    span = min(span, v.size - 1)
    cap = max(MAX_QUERY_MASS * v.size, 2 * span)
    preds = []
    for i in rng.integers(0, v.size - span, 200 * q):
        lo, hi = float(v[i]), float(v[i + span])
        mass = (np.searchsorted(v, hi, side="right")
                - np.searchsorted(v, lo, side="left"))
        if mass <= cap:
            preds.append(Predicate.between(lo, hi))
            if len(preds) == q:
                return preds
    raise AssertionError(
        f"could not draw {q} narrow windows (got {len(preds)}): "
        "the key distribution is heavier than the benchmark assumes")


def _brute(table, preds) -> np.ndarray:
    live = table.valid[: table.num_pages]
    keys = table.keys[: table.num_pages]
    return np.asarray([(live & (keys >= p.lo) & (keys <= p.hi)).sum()
                       for p in preds], np.int64)


def _pages_inspected(engine: QueryEngine, preds) -> int:
    """Total pages the index selects for inspection across the predicate
    set, one query at a time — the per-query pruning-quality metric (the
    engine's ``sel_ratio`` is the *batch union*, which saturates once Q
    narrow windows tile the table)."""
    insp = np.asarray(engine.index.search_batch(preds).pages_inspected)
    return int(insp.sum())


def _make_engine(values: np.ndarray, policy: str) -> QueryEngine:
    table = PagedTable.from_values(values.copy(), page_card=PAGE_CARD)
    sidx = ShardedHippoIndex.create(table, num_shards=SHARDS,
                                    resolution=RESOLUTION, density=DENSITY,
                                    max_slots=MAX_SLOTS,
                                    relocate_on_update=False, summary=policy)
    return QueryEngine(sidx, batch=BATCH, drain_policy="manual",
                       auto_resummarize=False)


def _static_scenario(name: str, values: np.ndarray, rng) -> float:
    """Build-time comparison on a key-clustered (sorted) table; returns the
    pages-inspected gain (equal_mass / learned)."""
    values = np.sort(values)
    engines = {p: _make_engine(values, p) for p in ("equal_mass", "learned")}
    preds = _quantile_preds(rng, values, Q, SPAN)
    want = None
    for policy, eng in engines.items():
        got = eng.run_all(preds)
        want = _brute(eng.index.table, preds) if want is None else want
        np.testing.assert_array_equal(
            got, want, err_msg=f"{name}/{policy}: counts diverge from brute")
    us_eq, us_lr = measure(lambda: engines["equal_mass"].run_all(preds),
                           lambda: engines["learned"].run_all(preds),
                           warmup=1, reps=9)
    return _emit_pair(name, engines, preds, us_eq, us_lr)


def _drift_mode(values: np.ndarray, plan, policy: str) -> QueryEngine:
    """One drift sweep: per round, clustered zipf writes land, an explicit
    refit under ``policy`` remaps every shard, then the round's queries are
    checked against brute force. Returns the sweep-end engine."""
    engine = _make_engine(values, policy)
    for writes, preds in plan:
        for v in writes:
            engine.write(float(v))
        engine.resummarize()   # refit onto the round's reservoir + drain
        engine.flush()
        np.testing.assert_array_equal(
            engine.run_all(preds), _brute(engine.index.table, preds),
            err_msg=f"drift/{policy}: counts diverge from brute force")
    return engine


def _drift_scenario(rng, card: int, rounds: int, inserts: int) -> float:
    """Moving-window skewed inserts + per-round learned vs equal-mass refit;
    returns the pages-inspected gain (equal_mass / learned) on the final
    round's queries."""
    values = np.sort(rng.uniform(0, BASE_DOMAIN, card))
    plan = []
    span = max(8, int(SPAN * inserts / CARD))
    for r in range(rounds):
        w_lo = BASE_DOMAIN + r * STEP
        alphabet = np.sort(rng.uniform(w_lo, w_lo + STEP, ZIPF_KEYS // 4))
        ranks = np.arange(1, alphabet.size + 1, dtype=np.float64)
        mass = ranks ** -ZIPF_A
        writes = np.sort(rng.choice(alphabet, inserts, p=mass / mass.sum()))
        plan.append((writes, _quantile_preds(rng, writes, Q, span)))
    engines = {p: _drift_mode(values, plan, p)
               for p in ("equal_mass", "learned")}
    assert engines["learned"].stats.learned_refits == rounds
    assert engines["equal_mass"].stats.learned_refits == 0
    final_preds = plan[-1][1]
    us_eq, us_lr = measure(lambda: engines["equal_mass"].run_all(final_preds),
                           lambda: engines["learned"].run_all(final_preds),
                           warmup=1, reps=9)
    return _emit_pair("drift", engines, final_preds, us_eq, us_lr,
                      rounds=rounds, inserts=rounds * inserts)


_LAST_SPEEDUPS: dict[str, float] = {}


def _emit_pair(name: str, engines: dict, preds, us_eq: float, us_lr: float,
               **extra) -> float:
    insp_eq = _pages_inspected(engines["equal_mass"], preds)
    insp_lr = _pages_inspected(engines["learned"], preds)
    gain = insp_eq / insp_lr if insp_lr > 0 else float("inf")
    qps_eq, qps_lr = Q / (us_eq / 1e6), Q / (us_lr / 1e6)
    emit(f"learned_{name}_equal_mass", us_eq, qps=round(qps_eq, 1),
         pages_inspected=insp_eq,
         sel_ratio=round(engines["equal_mass"].stats.selected_page_ratio, 4),
         **extra)
    emit(f"learned_{name}_learned", us_lr, qps=round(qps_lr, 1),
         pages_inspected=insp_lr,
         sel_ratio=round(engines["learned"].stats.selected_page_ratio, 4),
         page_gain=round(gain, 2), speedup=round(qps_lr / qps_eq, 2), **extra)
    _LAST_SPEEDUPS[name] = qps_lr / qps_eq
    return gain


def run(card: int = CARD, rounds: int = ROUNDS, inserts: int = INSERTS) -> None:
    rng = np.random.default_rng(0)
    gain_zipf = _static_scenario("zipf", _zipf_values(rng, card), rng)
    _static_scenario("lognormal", rng.lognormal(0.0, 1.0, card), rng)
    gain_drift = _drift_scenario(rng, card, rounds, inserts)
    if card >= CARD:
        # acceptance floor at the full configuration; --quick shrinks the
        # table, which coarsens pages-per-bucket and with it the gap
        assert gain_zipf >= ASSERT_MIN_GAIN, (
            f"learned summaries cut zipf selected pages only {gain_zipf:.2f}x "
            f"vs equal-mass at equal H (need >= {ASSERT_MIN_GAIN}x)")
        assert _LAST_SPEEDUPS["zipf"] >= 1.05, (
            f"learned zipf compact throughput {_LAST_SPEEDUPS['zipf']:.2f}x "
            "equal-mass — the pages-gathered cut no longer shows up as q/s")
        assert gain_drift >= ASSERT_MIN_GAIN, (
            f"learned refits cut drift selected pages only {gain_drift:.2f}x "
            f"vs equal-mass rebuild at equal H (need >= {ASSERT_MIN_GAIN}x)")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(card=10_000 if args.quick else CARD,
        rounds=2 if args.quick else ROUNDS,
        inserts=1200 if args.quick else INSERTS)
