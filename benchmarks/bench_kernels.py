"""Kernel-path microbenchmarks for all five Hippo kernels with roofline
derived fields (achieved GB/s and fraction-of-roofline per row).

On this CPU host the jnp reference path is the execution path (Pallas runs
in interpret mode for validation only — see tests/test_kernels.py). Each row
carries the analytic mandatory-traffic model from ``repro.roofline``
(``bytes``/``ops``), the achieved bandwidth of the timed run against the
detected hardware-table row (measured STREAM on CPU, HBM on TPU), and the
v5e projection (``tpu_roofline_us``) so the TPU roofline can be read off a
CPU trajectory. ``roofline_frac`` > 1 means the working set fit in cache —
the model counts main-memory traffic only.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels.batch_filter.ref import batch_filter_ref
from repro.kernels.bitmap_and.ref import bitmap_and_any_ref
from repro.kernels.bucketize.ref import bucketize_ref
from repro.kernels.compact_inspect.ref import compact_inspect_ref
from repro.kernels.page_inspect.ref import page_inspect_ref
from repro.roofline import KERNELS, TPU_V5E, hardware, roofline


def _emit_kernel(name: str, kernel: str, us: float, **shape) -> None:
    """One kernel row: analytic traffic + achieved-vs-roofline fields."""
    hw = hardware()
    cost = KERNELS[kernel](**shape)
    rl = roofline(cost, us / 1e6, hw)
    tpu = roofline(cost, us / 1e6, TPU_V5E)
    emit(name, us,
         bytes=int(cost.bytes_moved), ops=int(cost.ops),
         hardware=hw.name,
         achieved_gbps=round(rl["achieved_gbps"], 3),
         roofline_frac=round(rl["roofline_frac"], 4),
         bound=rl["bound"],
         tpu_roofline_us=round(tpu["roofline_us"], 2))


def run() -> None:
    rng = np.random.default_rng(0)

    # §3.2 single-query bitmap AND: 64k entries, H=400 -> 13 words
    e, w = 65_536, 13
    entries = jnp.asarray(rng.integers(0, 2**32, (e, w), dtype=np.uint32))
    query = jnp.asarray(rng.integers(0, 2**32, (w,), dtype=np.uint32))
    us = timeit(lambda: bitmap_and_any_ref(entries, query), warmup=3, iters=15)
    _emit_kernel("kernel_bitmap_and_64k", "bitmap_and", us, e=e, w=w)

    # PR 1 fused batch filter: Q=64 predicates against 16k entries
    q, e2 = 64, 16_384
    qbms = jnp.asarray(rng.integers(0, 2**32, (q, w), dtype=np.uint32))
    ents = jnp.asarray(rng.integers(0, 2**32, (e2, w), dtype=np.uint32))
    us = timeit(lambda: batch_filter_ref(qbms, ents), warmup=2, iters=11)
    _emit_kernel("kernel_batch_filter_q64_16k", "batch_filter", us,
                 q=q, e=e2, w=w)

    # §4.2 bucketize probe: 1M values into H=400 buckets
    n, h = 1_048_576, 400
    bounds = jnp.asarray(np.linspace(0, 1e6, h + 1), jnp.float32)
    values = jnp.asarray(rng.uniform(0, 1e6, n), jnp.float32)
    us = timeit(lambda: bucketize_ref(values, bounds, h), warmup=2, iters=11)
    _emit_kernel("kernel_bucketize_1m", "bucketize", us, n=n, h=h)

    # §3.3 page inspection: 16k pages x 128 tuples, 30% possible-qualified
    p, c = 16_384, 128
    keys = jnp.asarray(rng.uniform(0, 1e6, (p, c)), jnp.float32)
    valid = jnp.asarray(rng.random((p, c)) < 0.95)
    mask = jnp.asarray(rng.random(p) < 0.3)
    us = timeit(lambda: page_inspect_ref(keys, valid, mask, 1e5, 2e5)[1],
                warmup=3, iters=15)
    _emit_kernel("kernel_page_inspect_16kpages", "page_inspect", us, p=p, c=c)

    # PR 4 gather-slab inspect: Q=64 queries over a 2k-page gathered slab
    m = 2_048
    skeys = jnp.asarray(rng.uniform(0, 1e6, (m, c)), jnp.float32)
    svalid = jnp.asarray(rng.random((m, c)) < 0.95)
    sel = jnp.asarray(rng.random((q, m)) < 0.4)
    los = jnp.asarray(rng.uniform(0, 5e5, q), jnp.float32)
    his = los + 2e5
    us = timeit(lambda: compact_inspect_ref(skeys, svalid, sel, los, his),
                warmup=2, iters=11)
    _emit_kernel("kernel_compact_inspect_q64_2kslab", "compact_inspect", us,
                 q=q, m=m, c=c)


if __name__ == "__main__":
    run()
