"""Kernel-path microbenchmarks: the §3.2 bitmap AND filter, the §4.2
bucketize probe, and §3.3 page inspection.

On this CPU host the jnp reference path is the execution path (Pallas runs in
interpret mode for validation only — see tests/test_kernels.py); derived
fields report the arithmetic/bytes so the TPU roofline for each kernel can be
read off: bitmap_and moves E*W*4 bytes per query (memory-bound on VPU).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import bitmap as bm
from repro.kernels.bitmap_and.ref import bitmap_and_any_ref
from repro.kernels.bucketize.ref import bucketize_ref
from repro.kernels.page_inspect.ref import page_inspect_ref

V5E_HBM = 819e9


def run() -> None:
    rng = np.random.default_rng(0)

    e, w = 65_536, 13           # 64k entries, H=400 -> 13 words
    entries = jnp.asarray(rng.integers(0, 2**32, (e, w), dtype=np.uint32))
    query = jnp.asarray(rng.integers(0, 2**32, (w,), dtype=np.uint32))
    us = timeit(lambda: bitmap_and_any_ref(entries, query), warmup=2, iters=5)
    nbytes = e * w * 4
    emit("kernel_bitmap_and_64k", us, bytes=nbytes,
         tpu_roofline_us=round(nbytes / V5E_HBM * 1e6, 2))

    n, h = 1_048_576, 400
    bounds = jnp.asarray(np.linspace(0, 1e6, h + 1), jnp.float32)
    values = jnp.asarray(rng.uniform(0, 1e6, n), jnp.float32)
    us = timeit(lambda: bucketize_ref(values, bounds, h), warmup=2, iters=5)
    emit("kernel_bucketize_1m", us, values=n,
         tpu_roofline_us=round(n * 4 / V5E_HBM * 1e6, 2))

    p, c = 16_384, 128
    keys = jnp.asarray(rng.uniform(0, 1e6, (p, c)), jnp.float32)
    valid = jnp.asarray(rng.random((p, c)) < 0.95)
    mask = jnp.asarray(rng.random(p) < 0.3)
    us = timeit(lambda: page_inspect_ref(keys, valid, mask, 1e5, 2e5)[1],
                warmup=2, iters=5)
    nbytes = p * c * 5
    emit("kernel_page_inspect_16kpages", us, bytes=nbytes,
         tpu_roofline_us=round(nbytes / V5E_HBM * 1e6, 2))


if __name__ == "__main__":
    run()
