"""Fig. 7: query time vs selectivity factor (0.001%..1%), Hippo vs B+-Tree
vs sequential scan. Prediction from the cost model (§6.1 with H=400, D=0.2):
the first three SFs cost ~0.2*Card inspected tuples, 1% costs ~0.8*Card.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import cost
from repro.core.baselines import BPlusTree, FullScan
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable
from repro.storage import tpch

CARD = 200_000
PAGE_CARD = 50
SFS = (0.00001, 0.0001, 0.001, 0.01)


def run(card=CARD) -> None:
    li = tpch.generate_lineitem(card)
    table = PagedTable.from_values(li.shipdate, PAGE_CARD)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    bt = BPlusTree.bulk_load(li.shipdate, PAGE_CARD)
    keys, valid = table.device_keys(), table.device_valid()

    for sf in SFS:
        lo, hi = tpch.selectivity_window(sf)
        pred = Predicate.between(lo, hi)

        us_hippo = timeit(lambda: idx.search(pred).count)
        res = idx.search(pred)
        us_btree = timeit(lambda: bt.count_range(lo, hi))
        us_scan = timeit(lambda: FullScan.search(keys, valid, lo, hi)[0])

        est = cost.query_time_tuples(sf, 400, 0.2, card)
        emit(f"fig7_sf{sf:g}", us_hippo,
             qps=round(1e6 / us_hippo, 1),
             btree_us=round(us_btree, 1), scan_us=round(us_scan, 1),
             pages_inspected=int(res.pages_inspected),
             total_pages=table.num_pages,
             inspected_frac=round(int(res.pages_inspected) / table.num_pages, 3),
             model_tuples=round(est), count=int(res.count))


if __name__ == "__main__":
    run()
