"""Batched query-engine throughput: ``search_many`` vs a per-query loop.

The production metric for an index serving many users is queries/second, not
single-query latency. This benchmark submits Q identical workloads both ways:

  loop    — Q separate ``index.search`` dispatches (the seed's only path)
  batched — one ``search_many`` device program over all Q predicates
  engine  — ``QueryEngine.run_all`` (batched path + submit/slot bookkeeping)

Counts are asserted bit-identical between the paths before timing; the
``speedup`` derived field is loop_qps vs batched_qps at each Q.

  PYTHONPATH=src python -m benchmarks.bench_engine_throughput [--quick]
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, measure, timeit
from repro.core import index as hix
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate, intervals, to_bucket_bitmaps
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable

CARD = 200_000
BATCHES = (8, 64, 256)


def _workload(rng, q: int) -> list[Predicate]:
    """Mixed selectivities: point-ish, 1%-ish, and broad range predicates."""
    preds = []
    for i in range(q):
        lo = float(rng.uniform(0, 1e6))
        width = float(rng.choice([100.0, 1e4, 2e5]))
        preds.append(Predicate.between(lo, lo + width))
    return preds


def run(card: int = CARD, batches=BATCHES) -> None:
    rng = np.random.default_rng(0)
    values = rng.uniform(0, 1e6, card)
    table = PagedTable.from_values(values, page_card=50)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    keys, valid = table.device_keys(), table.device_valid()

    for q in batches:
        preds = _workload(rng, q)

        def loop():
            return [idx.search(p).count for p in preds]

        def batched():
            # starts from Predicate objects, like the loop: conversion is paid
            qbms = to_bucket_bitmaps(preds, idx.state.histogram)
            los, his = intervals(preds)
            return hix.search_many(idx.state, qbms, keys, valid, los, his).counts

        loop_counts = np.asarray(jax.device_get(loop()))
        batch_counts = np.asarray(batched())
        assert (loop_counts == batch_counts).all(), \
            f"batched counts diverge from the per-query loop at Q={q}"

        # interleaved so a noise window hits both contenders; the loop path
        # is all Python dispatch overhead and needs the extra reps to settle
        us_loop, us_batch = measure(loop, batched, warmup=2, reps=7)
        qps_loop = q / (us_loop / 1e6)
        qps_batch = q / (us_batch / 1e6)
        emit(f"engine_loop_q{q}", us_loop, qps=round(qps_loop, 1))
        emit(f"engine_search_many_q{q}", us_batch, qps=round(qps_batch, 1),
             speedup=round(qps_batch / qps_loop, 2))

        # mode="dense" pins the engine to the same batched program as the
        # raw path above, so this row isolates submit/slot bookkeeping cost
        # (the compact default is measured in bench_selectivity_sweep)
        engine = QueryEngine(idx, batch=q, mode="dense")
        engine.run_all(preds)  # warm the trace before timing
        us_eng = timeit(lambda: engine.run_all(preds), warmup=1, iters=5)
        emit(f"engine_run_all_q{q}", us_eng,
             qps=round(q / (us_eng / 1e6), 1),
             occupancy=round(engine.stats.occupancy, 3))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(card=50_000 if args.quick else CARD,
        batches=(8, 64) if args.quick else BATCHES)
