"""Async maintenance writer: query throughput under a mixed read/write load,
staged per-shard drains vs. synchronous Algorithm 3 on the query path.

The paper's §5/Fig. 6c claim is that Hippo maintenance is cheap enough to
keep up with inserts; this benchmark measures what that costs the *readers*.
A mixed 80/20 stream (Q=64 range queries, then W=16 writes, repeated) runs
twice through the same sharded engine API:

  sync   — ``drain_policy="sync"``: every write runs Algorithm 3 + a slab
           view invalidation before the next query batch can start
  async  — ``drain_policy="between_batches"``: writes stage into per-shard
           queues (host list append), queries overlay the staged rows, and
           one shard queue drains as a fused batch between query batches

Counts are asserted identical between the two runs (the never-stale
contract) before timing. ``speedup`` is async queries/sec over sync
(acceptance: >= 1.5x at S=4, Q=64 on CPU — in practice the gap is larger
because sync pays one jit dispatch per tuple plus a full (S, PPS, C) slab
re-upload per write burst, while async pays one fused drain per batch and a
single-slab patch).

  PYTHONPATH=src python -m benchmarks.bench_async_maintenance [--quick]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable

CARD = 200_000
SHARDS = 4
Q = 64          # queries per round
W = 16          # writes per round (80/20 read/write mix)
ROUNDS = 6


def _workload(rng, rounds: int):
    """Per-round (queries, writes): narrow-to-medium ranges over sorted keys
    plus fresh uniform inserts."""
    plan = []
    for _ in range(rounds):
        preds = []
        for _ in range(Q):
            lo = float(rng.uniform(0, 1e6))
            width = float(rng.choice([500.0, 2000.0, 8000.0]))
            preds.append(Predicate.between(lo, lo + width))
        writes = rng.uniform(0, 1e6, W)
        plan.append((preds, writes))
    return plan


def _run_mode(values, plan, policy: str) -> tuple[float, np.ndarray]:
    """One full mixed-load pass; returns (seconds, every query count)."""
    table = PagedTable.from_values(values.copy(), page_card=50,
                                   spare_pages=4096)
    sidx = ShardedHippoIndex.create(table, num_shards=SHARDS,
                                    resolution=400, density=0.2)
    engine = QueryEngine(sidx, batch=Q, drain_policy=policy)
    # Warm every trace the steady state uses by replaying the whole plan
    # once untimed: sync compiles insert_tuple/insert_batch paths, async
    # compiles the drain batch, page-opener, and staged-overlay traces, and
    # both see the routed dispatch widths the workload produces.
    for preds, writes in plan:
        for v in writes:
            engine.write(float(v))
        engine.run_all(preds)
    if engine.writer is not None:
        engine.flush()

    counts = []
    t0 = time.perf_counter()
    for preds, writes in plan:
        for v in writes:
            engine.write(float(v))
        counts.append(engine.run_all(preds))
    dt = time.perf_counter() - t0
    if engine.writer is not None:
        engine.flush()
    # post-timing exactness check against the final table contents
    final = np.asarray(engine.run_all(plan[-1][0]), np.int64)
    counts.append(final)
    return dt, np.concatenate(counts)


def run(card: int = CARD, rounds: int = ROUNDS) -> None:
    rng = np.random.default_rng(0)
    values = np.sort(rng.uniform(0, 1e6, card))
    plan = _workload(rng, rounds)

    dt_sync, counts_sync = _run_mode(values, plan, "sync")
    dt_async, counts_async = _run_mode(values, plan, "between_batches")
    assert (counts_sync == counts_async).all(), \
        "async counts diverge from the synchronous path"

    n_queries = rounds * Q
    qps_sync = n_queries / dt_sync
    qps_async = n_queries / dt_async
    speedup = qps_async / qps_sync
    emit("async_maint_sync", dt_sync / n_queries * 1e6,
         qps=round(qps_sync, 1), writes=rounds * W)
    emit("async_maint_staged", dt_async / n_queries * 1e6,
         qps=round(qps_async, 1), writes=rounds * W,
         speedup=round(speedup, 2))
    if card >= CARD:
        # acceptance floor holds at the full configuration (S=4, Q=64,
        # card=200k); --quick shrinks the table, which shrinks exactly the
        # slab re-upload cost the sync path pays per write burst
        assert speedup >= 1.5, \
            f"async maintenance speedup {speedup:.2f}x < 1.5x acceptance floor"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(card=50_000 if args.quick else CARD,
        rounds=3 if args.quick else ROUNDS)
