"""Storage footprint: real serialized index bytes per tuple, Hippo vs baselines.

The paper's headline storage claim (Sec. 1, Fig. 1) is that Hippo occupies
~25-30x less space than a B+-tree because it stores one histogram-bitmap
entry per *page range* instead of one (key, tid) pair per *tuple*. This
suite measures that claim in real bytes, not model estimates: the Hippo
figure is the index portion of an actual committed snapshot
(``checkpointing.snapshot.save_index`` + ``disk_usage`` — container
headers, bounds, summary metadata and all), and the B+-tree figure is the
same serialization container packing the tree's materialized key/tid
arrays (``checkpointing.layout.pack_sections``), i.e. both sides pay the
same on-disk format tax.

Rows (all untimed except the save/load pair):

  storage_<data>_h<H>  — index bytes/tuple for Hippo, serialized B+-tree,
                         in-memory B+-tree (node accounting), and the
                         kvindex cache analogue at matching page size;
                         ``ratio_vs_btree`` is serialized-btree / hippo.
                         data in {shipdate (TPC-H lineitem), uniform},
                         H in {400, 800} at page_card=150 — the 8KB heap
                         page analogue the paper benches against (~54B
                         lineitem tuples -> ~150 tuples/page).
  storage_save         — durable snapshot write throughput (fsync + rename
                         commit included), gated via achieved_gbps.
  storage_load         — snapshot load + full index reconstruction
                         throughput, gated via achieved_gbps.

Acceptance floor, asserted in-bench: at the paper-default config
(shipdate, H=400, page_card=150, full card=200k) Hippo's serialized index
is >= 20x smaller per tuple than the serialized B+-tree. --quick shrinks
the table to 50k tuples, which inflates Hippo's fixed per-shard overhead
(bounds + metadata amortize over fewer entries); the floor scales to 12x
there so the claim stays guarded at both scales.

  PYTHONPATH=src python -m benchmarks.bench_storage [--quick]
"""
from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from benchmarks.common import emit, timeit
from repro.checkpointing.layout import pack_sections
from repro.checkpointing.snapshot import disk_usage, load_index, save_index
from repro.core.baselines.btree import BPlusTree
from repro.core.kvindex import KVIndexConfig, build_kv_index
from repro.core.partition import ShardedHippoIndex
from repro.storage.table import PagedTable
from repro.storage.tpch import generate_lineitem

CARD = 200_000
PAGE_CARD = 150          # 8KB heap page / ~54B lineitem tuple ≈ 150 tuples
SHARDS = 4
RESOLUTIONS = (400, 800)
DATASETS = ("shipdate", "uniform")
ASSERT_MIN_RATIO = 20.0  # paper-default config at full card
QUICK_MIN_RATIO = 12.0   # 50k-tuple floor (measured ~19x; overhead-inflated)


def _dataset(name: str, card: int, rng) -> np.ndarray:
    if name == "shipdate":
        return generate_lineitem(card, seed=0).shipdate.astype(np.float32)
    return rng.uniform(0.0, 1e6, card).astype(np.float32)


def _hippo_index(keys: np.ndarray, resolution: int) -> ShardedHippoIndex:
    table = PagedTable.from_values(keys.copy(), page_card=PAGE_CARD)
    return ShardedHippoIndex.create(table, num_shards=SHARDS,
                                    resolution=resolution)


def _hippo_snapshot_bytes(idx: ShardedHippoIndex) -> int:
    """Index bytes of a real committed snapshot (table payload excluded —
    the heap belongs to the table under any index)."""
    with tempfile.TemporaryDirectory() as tmp:
        return disk_usage(save_index(tmp, idx))["index"]


def _btree_serialized_bytes(keys: np.ndarray) -> int:
    """The B+-tree's irreducible per-tuple payload — sorted f32 keys plus
    i64 tids — through the *same* section container Hippo pays for."""
    order = np.argsort(keys, kind="stable")
    tids = (order // PAGE_CARD).astype(np.int64) << 16 | (order % PAGE_CARD)
    return len(pack_sections({"keys": np.sort(keys).astype(np.float32),
                              "ptrs": tids}))


def _kv_bytes_per_tuple(keys: np.ndarray) -> float:
    """kvindex cache-analogue footprint at the same page granularity."""
    pad = (-len(keys)) % PAGE_CARD
    padded = np.concatenate([keys, np.full(pad, keys[-1], np.float32)])
    cfg = KVIndexConfig(page_size=PAGE_CARD, num_channels=1, resolution=16)
    kv = build_kv_index(cfg, padded.reshape(1, -1, 1, 1))
    return kv.nbytes() / len(keys)


def run(card: int = CARD) -> None:
    rng = np.random.default_rng(0)
    ratios: dict[tuple[str, int], float] = {}
    timed_idx = None
    for data in DATASETS:
        keys = _dataset(data, card, rng)
        btree_bytes = _btree_serialized_bytes(keys)
        btree_mem = BPlusTree.bulk_load(keys, page_card=PAGE_CARD).nbytes()
        kv_bpt = _kv_bytes_per_tuple(keys)
        for resolution in RESOLUTIONS:
            idx = _hippo_index(keys, resolution)
            hippo_bytes = _hippo_snapshot_bytes(idx)
            ratio = btree_bytes / hippo_bytes
            ratios[(data, resolution)] = ratio
            emit(f"storage_{data}_h{resolution}", 0.0,
                 hippo_bytes_per_tuple=round(hippo_bytes / card, 4),
                 btree_bytes_per_tuple=round(btree_bytes / card, 3),
                 btree_mem_bytes_per_tuple=round(btree_mem / card, 3),
                 kv_bytes_per_tuple=round(kv_bpt, 3),
                 ratio_vs_btree=round(ratio, 2),
                 card=card, page_card=PAGE_CARD, resolution=resolution,
                 shards=SHARDS)
            if data == "shipdate" and resolution == RESOLUTIONS[0]:
                timed_idx = idx

    # Durable save/load throughput on the paper-default index: the gated
    # rows (achieved_gbps) — fsync + atomic-rename commit included in the
    # save path, full index reconstruction included in the load path.
    assert timed_idx is not None
    with tempfile.TemporaryDirectory() as tmp:
        snap = save_index(tmp, timed_idx)
        total = disk_usage(snap)["total"]
        us_save = timeit(lambda: save_index(tmp, timed_idx), warmup=1, iters=5)
        us_load = timeit(lambda: load_index(tmp), warmup=1, iters=5)
    for name, us in (("storage_save", us_save), ("storage_load", us_load)):
        emit(name, us, achieved_gbps=round(total / us / 1000.0, 4),
             snapshot_kb=round(total / 1e3, 1), card=card,
             page_card=PAGE_CARD, resolution=RESOLUTIONS[0])

    floor = ASSERT_MIN_RATIO if card >= CARD else QUICK_MIN_RATIO
    got = ratios[("shipdate", RESOLUTIONS[0])]
    assert got >= floor, (
        f"Hippo serialized index only {got:.1f}x smaller than the "
        f"serialized B+-tree at the paper-default config (shipdate, "
        f"H={RESOLUTIONS[0]}, page_card={PAGE_CARD}, card={card}) — "
        f"need >= {floor}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(card=50_000 if args.quick else CARD)
