"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
Scale with --quick for CI-speed runs.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    bench_cost_model,
    bench_engine_throughput,
    bench_fig6_overhead,
    bench_fig7_selectivity,
    bench_fig8_density,
    bench_fig9_resolution,
    bench_fig10_tpch,
    bench_kernels,
    bench_maintenance,
    bench_shard_scaling,
)

SUITES = {
    "fig6": lambda quick: bench_fig6_overhead.run(
        scales=(20_000, 100_000) if quick else bench_fig6_overhead.SCALES),
    "fig7": lambda quick: bench_fig7_selectivity.run(
        card=50_000 if quick else bench_fig7_selectivity.CARD),
    "fig8": lambda quick: bench_fig8_density.run(
        card=50_000 if quick else bench_fig8_density.CARD),
    "fig9": lambda quick: bench_fig9_resolution.run(
        card=50_000 if quick else bench_fig9_resolution.CARD),
    "fig10": lambda quick: bench_fig10_tpch.run(
        card=50_000 if quick else bench_fig10_tpch.CARD),
    "cost_model": lambda quick: bench_cost_model.run(
        card=50_000 if quick else bench_cost_model.CARD),
    "maintenance": lambda quick: bench_maintenance.run(
        card=50_000 if quick else bench_maintenance.CARD),
    "kernels": lambda quick: bench_kernels.run(),
    "engine": lambda quick: bench_engine_throughput.run(
        card=50_000 if quick else bench_engine_throughput.CARD,
        batches=(8, 64) if quick else bench_engine_throughput.BATCHES),
    "shard_scaling": lambda quick: bench_shard_scaling.run(
        card=100_000 if quick else bench_shard_scaling.CARD,
        shards=(1, 2, 4) if quick else bench_shard_scaling.SHARDS),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, choices=sorted(SUITES))
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        fn(args.quick)
    print(f"# total_wall_s={time.time()-t0:.1f}", file=sys.stderr)


if __name__ == "__main__":
    main()
