"""Benchmark harness: one module per paper table/figure or subsystem claim.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
Scale with --quick for CI-speed runs; ``--list`` prints every registered
benchmark with the one-line description from its module docstring;
``--json out.json`` additionally writes the machine-readable result set
(per-suite rows with parsed derived fields plus the run config, strict
JSON — nan/inf sanitized to null) so the repo can accumulate
``BENCH_*.json`` trajectory files across PRs; ``--check BASELINE.json``
turns the run into a regression gate — the fresh rows are compared against
the committed trajectory and the process exits nonzero with a per-row
delta table when any suite's ``qps`` or ``achieved_gbps`` drops more than
the tolerance (benchmarks/check.py; default 20%, per-row overridable).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7] [--list]
      [--json out.json] [--check BENCH_baseline.json] [--tolerance 0.2]
      [--row-tolerance drift_adaptive=0.5]
"""
from __future__ import annotations

import argparse
import json
import math
import platform
import sys
import time

from benchmarks import (
    bench_async_maintenance,
    bench_cost_model,
    bench_drift,
    bench_engine_throughput,
    bench_fig6_overhead,
    bench_fig7_selectivity,
    bench_fig8_density,
    bench_fig9_resolution,
    bench_fig10_tpch,
    bench_kernels,
    bench_learned,
    bench_maintenance,
    bench_recovery,
    bench_selectivity_sweep,
    bench_shard_scaling,
    bench_storage,
)
from benchmarks import check, common

# One registry: suite name -> (module, quick-aware runner). The module half
# feeds --list (its docstring) and tests/test_docs.py's coverage check.
REGISTRY = {
    "fig6": (bench_fig6_overhead, lambda quick: bench_fig6_overhead.run(
        scales=(20_000, 100_000) if quick else bench_fig6_overhead.SCALES)),
    "fig7": (bench_fig7_selectivity, lambda quick: bench_fig7_selectivity.run(
        card=50_000 if quick else bench_fig7_selectivity.CARD)),
    "fig8": (bench_fig8_density, lambda quick: bench_fig8_density.run(
        card=50_000 if quick else bench_fig8_density.CARD)),
    "fig9": (bench_fig9_resolution, lambda quick: bench_fig9_resolution.run(
        card=50_000 if quick else bench_fig9_resolution.CARD)),
    "fig10": (bench_fig10_tpch, lambda quick: bench_fig10_tpch.run(
        card=50_000 if quick else bench_fig10_tpch.CARD)),
    "cost_model": (bench_cost_model, lambda quick: bench_cost_model.run(
        card=50_000 if quick else bench_cost_model.CARD)),
    "maintenance": (bench_maintenance, lambda quick: bench_maintenance.run(
        card=50_000 if quick else bench_maintenance.CARD)),
    "kernels": (bench_kernels, lambda quick: bench_kernels.run()),
    "engine": (bench_engine_throughput,
               lambda quick: bench_engine_throughput.run(
                   card=50_000 if quick else bench_engine_throughput.CARD,
                   batches=(8, 64) if quick else bench_engine_throughput.BATCHES)),
    "shard_scaling": (bench_shard_scaling,
                      lambda quick: bench_shard_scaling.run(
                          card=100_000 if quick else bench_shard_scaling.CARD,
                          shards=(1, 2, 4) if quick else bench_shard_scaling.SHARDS)),
    "async_maintenance": (bench_async_maintenance,
                          lambda quick: bench_async_maintenance.run(
                              card=50_000 if quick else bench_async_maintenance.CARD,
                              rounds=3 if quick else bench_async_maintenance.ROUNDS)),
    "selectivity_sweep": (bench_selectivity_sweep,
                          lambda quick: bench_selectivity_sweep.run(
                              card=100_000 if quick else bench_selectivity_sweep.CARD,
                              selectivities=(0.01, 0.5) if quick
                              else bench_selectivity_sweep.SELECTIVITIES)),
    "drift": (bench_drift,
              lambda quick: bench_drift.run(
                  card=10_000 if quick else bench_drift.CARD,
                  rounds=3 if quick else bench_drift.ROUNDS,
                  inserts=600 if quick else bench_drift.INSERTS)),
    "learned": (bench_learned,
                lambda quick: bench_learned.run(
                    card=10_000 if quick else bench_learned.CARD,
                    rounds=2 if quick else bench_learned.ROUNDS,
                    inserts=1200 if quick else bench_learned.INSERTS)),
    "storage": (bench_storage, lambda quick: bench_storage.run(
        card=50_000 if quick else bench_storage.CARD)),
    "recovery": (bench_recovery, lambda quick: bench_recovery.run(
        card=30_000 if quick else bench_recovery.CARD,
        rounds=4 if quick else bench_recovery.ROUNDS,
        writes_per_round=120 if quick
        else bench_recovery.WRITES_PER_ROUND)),
}

MODULES = {name: mod for name, (mod, _) in REGISTRY.items()}
SUITES = {name: fn for name, (_, fn) in REGISTRY.items()}


def describe(name: str) -> str:
    """First line of the bench module's docstring (enforced non-empty by
    tests/test_docs.py and the --list path)."""
    doc = MODULES[name].__doc__ or ""
    first = doc.strip().splitlines()[0].strip() if doc.strip() else ""
    return first or f"<{name}: missing module docstring>"


def parse_derived(derived: str) -> dict:
    """Parse a row's ';'-separated ``key=value`` derived field, coercing
    values to int/float/bool where they parse (the JSON half of the CSV
    contract in benchmarks/common.py). Non-finite numbers (a qps division
    on a zero timing prints ``nan``/``inf``) become ``None`` so the JSON
    document stays strict and the regression gate is never fed a value
    that compares as neither pass nor fail."""
    out = {}
    for item in derived.split(";"):
        if not item:
            continue
        key, _, val = item.partition("=")
        if val in ("True", "False"):
            out[key] = val == "True"
            continue
        for cast in (int, float):
            try:
                num = cast(val)
                break
            except ValueError:
                continue
        else:
            out[key] = val
            continue
        out[key] = num if math.isfinite(num) else None
    return out


def _finite(val):
    """Strict-JSON scalar: non-finite floats become None."""
    if isinstance(val, float) and not math.isfinite(val):
        return None
    return val


def rows_to_json(suite_rows: dict[str, list], *, quick: bool) -> dict:
    """Machine-readable result document for ``--json``: every emitted row
    grouped by suite, derived fields parsed, plus the run configuration —
    the schema the repo's ``BENCH_*.json`` trajectory files accumulate.
    Strict JSON throughout: every non-finite value is sanitized to null so
    any consumer (the regression gate first) can parse with allow_nan off."""
    return {
        "schema": 1,
        "generated_unix_s": int(time.time()),
        "config": {
            "quick": quick,
            "python": platform.python_version(),
            "platform": platform.platform(),
        },
        "suites": {
            suite: [{"name": name,
                     "us_per_call": _finite(round(us, 1)),
                     "qps": parse_derived(derived).get("qps"),
                     "derived": parse_derived(derived)}
                    for name, us, derived in rows]
            for suite, rows in suite_rows.items()
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, choices=sorted(SUITES),
                    action="append",
                    help="run only this suite (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="print each registered benchmark and its one-line "
                         "description, then exit")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the run's rows as machine-readable JSON "
                         "(per-suite, derived fields parsed) to OUT")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="after the run, gate the fresh rows against this "
                         "committed BENCH_*.json trajectory: exit 1 when "
                         "any qps/achieved_gbps drops past tolerance")
    ap.add_argument("--tolerance", type=float,
                    default=check.DEFAULT_TOLERANCE,
                    help="allowed fractional drop per gated metric "
                         "(default %(default)s)")
    ap.add_argument("--row-tolerance", action="append", default=[],
                    metavar="ROW=FRAC",
                    help="per-row tolerance override (repeatable; bare row "
                         "name or suite/row)")
    args = ap.parse_args(argv)

    if args.list:
        width = max(len(n) for n in SUITES)
        for name in SUITES:
            print(f"{name:<{width}}  {describe(name)}")
        return 0

    # fail fast on an unreadable baseline / bad override before benching
    try:
        row_tol = check.parse_row_tolerances(args.row_tolerance)
        baseline = check.load_trajectory(args.check) if args.check else None
    except (check.BaselineError, ValueError) as e:
        print(f"# {e}", file=sys.stderr)
        return 2

    print("name,us_per_call,derived")
    t0 = time.time()
    suite_rows: dict[str, list] = {}
    for name, fn in SUITES.items():
        if args.only and name not in args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        before = len(common.ROWS)
        fn(args.quick)
        suite_rows[name] = common.ROWS[before:]
    print(f"# total_wall_s={time.time()-t0:.1f}", file=sys.stderr)
    doc = rows_to_json(suite_rows, quick=args.quick)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True, allow_nan=False)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    if baseline is not None:
        if baseline.get("config", {}).get("quick") != args.quick:
            print("# WARNING: baseline quick flag differs from this run — "
                  "rows time different scales; refresh the baseline at the "
                  "matching scale", file=sys.stderr)
        deltas = check.compare(baseline, doc, tolerance=args.tolerance,
                               row_tolerance=row_tol)
        print(check.delta_table(deltas))
        if check.failures(deltas):
            print(f"# REGRESSION vs {args.check}", file=sys.stderr)
            return 1
        print(f"# gate ok vs {args.check}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
