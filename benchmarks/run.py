"""Benchmark harness: one module per paper table/figure or subsystem claim.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
Scale with --quick for CI-speed runs; ``--list`` prints every registered
benchmark with the one-line description from its module docstring.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7] [--list]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    bench_async_maintenance,
    bench_cost_model,
    bench_engine_throughput,
    bench_fig6_overhead,
    bench_fig7_selectivity,
    bench_fig8_density,
    bench_fig9_resolution,
    bench_fig10_tpch,
    bench_kernels,
    bench_maintenance,
    bench_shard_scaling,
)

# One registry: suite name -> (module, quick-aware runner). The module half
# feeds --list (its docstring) and tests/test_docs.py's coverage check.
REGISTRY = {
    "fig6": (bench_fig6_overhead, lambda quick: bench_fig6_overhead.run(
        scales=(20_000, 100_000) if quick else bench_fig6_overhead.SCALES)),
    "fig7": (bench_fig7_selectivity, lambda quick: bench_fig7_selectivity.run(
        card=50_000 if quick else bench_fig7_selectivity.CARD)),
    "fig8": (bench_fig8_density, lambda quick: bench_fig8_density.run(
        card=50_000 if quick else bench_fig8_density.CARD)),
    "fig9": (bench_fig9_resolution, lambda quick: bench_fig9_resolution.run(
        card=50_000 if quick else bench_fig9_resolution.CARD)),
    "fig10": (bench_fig10_tpch, lambda quick: bench_fig10_tpch.run(
        card=50_000 if quick else bench_fig10_tpch.CARD)),
    "cost_model": (bench_cost_model, lambda quick: bench_cost_model.run(
        card=50_000 if quick else bench_cost_model.CARD)),
    "maintenance": (bench_maintenance, lambda quick: bench_maintenance.run(
        card=50_000 if quick else bench_maintenance.CARD)),
    "kernels": (bench_kernels, lambda quick: bench_kernels.run()),
    "engine": (bench_engine_throughput,
               lambda quick: bench_engine_throughput.run(
                   card=50_000 if quick else bench_engine_throughput.CARD,
                   batches=(8, 64) if quick else bench_engine_throughput.BATCHES)),
    "shard_scaling": (bench_shard_scaling,
                      lambda quick: bench_shard_scaling.run(
                          card=100_000 if quick else bench_shard_scaling.CARD,
                          shards=(1, 2, 4) if quick else bench_shard_scaling.SHARDS)),
    "async_maintenance": (bench_async_maintenance,
                          lambda quick: bench_async_maintenance.run(
                              card=50_000 if quick else bench_async_maintenance.CARD,
                              rounds=3 if quick else bench_async_maintenance.ROUNDS)),
}

MODULES = {name: mod for name, (mod, _) in REGISTRY.items()}
SUITES = {name: fn for name, (_, fn) in REGISTRY.items()}


def describe(name: str) -> str:
    """First line of the bench module's docstring (enforced non-empty by
    tests/test_docs.py and the --list path)."""
    doc = MODULES[name].__doc__ or ""
    first = doc.strip().splitlines()[0].strip() if doc.strip() else ""
    return first or f"<{name}: missing module docstring>"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, choices=sorted(SUITES))
    ap.add_argument("--list", action="store_true",
                    help="print each registered benchmark and its one-line "
                         "description, then exit")
    args = ap.parse_args(argv)

    if args.list:
        width = max(len(n) for n in SUITES)
        for name in SUITES:
            print(f"{name:<{width}}  {describe(name)}")
        return

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        fn(args.quick)
    print(f"# total_wall_s={time.time()-t0:.1f}", file=sys.stderr)


if __name__ == "__main__":
    main()
