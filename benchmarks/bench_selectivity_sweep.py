"""Selectivity-proportional serving: compact (gather) vs dense engine mode.

The compact pipeline's claim is that inspect cost tracks what the batch
*selects*, not the table: the per-query page masks are unioned, the union
gathered once into a shared slab, and every query inspected against it
(``core.index.search_compact_many``), while dense mode materializes the full
(Q, P, C) tensor regardless of selectivity. This sweep serves the same
hot-spot workload (Q=64 range queries around a handful of popular centers —
the skewed access pattern of real serving) through both modes of one
S=4 sharded index at several selectivities:

  dense    QueryEngine(mode="dense", sharded=False) — the fused full-table
           (S, Q, PPS, C) program
  compact  QueryEngine(mode="compact") — the default gather path, adaptive
           power-of-two slab bucketing + dense fallback on truncation

The index runs a serving-tuned configuration (H=1600, D=0.01, right-sized
``max_slots``): fig8/fig9's density/resolution tradeoff pushed toward query
speed, so each entry summarizes ~1% of the key domain and
``pages_inspected`` actually tracks selectivity (at the paper-default D=0.2
every query inspects ~20% of the table no matter how narrow it is, and the
batch union saturates). ``max_slots`` matters for both modes equally: the
bitmap filter scans every physical slot, so a capacity 40x the live entry
count would turn the match phase into the floor both paths share.

Counts are asserted bit-identical between the modes at every selectivity
before timing. The expected trend: the compact mode's q/s advantage widens
as selectivity drops (≥3x at ~1% on CPU; asserted ≥1.5x at the lowest
selectivity of the sweep) and shrinks toward parity at 50% where the union
covers the table; ``sel_ratio`` (the engine's measured selected-page ratio)
makes the mechanism visible in the derived fields.

  PYTHONPATH=src python -m benchmarks.bench_selectivity_sweep [--quick]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable

CARD = 400_000
SELECTIVITIES = (0.01, 0.1, 0.5)
SHARDS = 4
Q = 64
HOT_SPOTS = 4
DOMAIN = 1e6
RESOLUTION = 1600      # serving-tuned: finer buckets ...
DENSITY = 0.01         # ... and finer entries than the paper defaults
MAX_SLOTS = 512        # per-shard slot capacity sized to the entry count
ASSERT_MIN_SPEEDUP = 1.5


def _workload(rng, q: int, selectivity: float) -> list[Predicate]:
    """Q ranges of width ``selectivity * DOMAIN`` jittered around a few hot
    centers — skewed multi-user traffic over sorted (append-ordered) keys.
    Centers are stratified across the domain (one per equal slice, jittered)
    so the hot regions spread over the shards instead of piling into one."""
    width = selectivity * DOMAIN
    step = DOMAIN / HOT_SPOTS
    centers = np.asarray([
        min((i + 0.5) * step + float(rng.uniform(-0.1, 0.1)) * step,
            DOMAIN - width)
        for i in range(HOT_SPOTS)])
    preds = []
    for _ in range(q):
        lo = float(rng.choice(centers)) + float(rng.uniform(-0.1, 0.1)) * width
        lo = min(max(lo, 0.0), DOMAIN - width)
        preds.append(Predicate.between(lo, lo + width))
    return preds


def run(card: int = CARD, selectivities=SELECTIVITIES) -> None:
    rng = np.random.default_rng(0)
    values = np.sort(rng.uniform(0, DOMAIN, card))
    table = PagedTable.from_values(values, page_card=50)
    sidx = ShardedHippoIndex.create(table, num_shards=SHARDS,
                                    resolution=RESOLUTION, density=DENSITY,
                                    max_slots=MAX_SLOTS)

    speedups = {}
    for sel in selectivities:
        preds = _workload(rng, Q, sel)

        dense = QueryEngine(sidx, batch=Q, mode="dense", sharded=False)
        compact = QueryEngine(sidx, batch=Q)          # default: compact mode
        dense_counts = dense.run_all(preds)           # also warms the traces
        compact_counts = compact.run_all(preds)       # ... and the bucket
        assert (compact_counts == dense_counts).all(), \
            f"compact counts diverge from dense mode at selectivity {sel}"

        us_dense = timeit(lambda: dense.run_all(preds), warmup=2, iters=5)
        us_compact = timeit(lambda: compact.run_all(preds), warmup=2, iters=5)
        qps_dense = Q / (us_dense / 1e6)
        qps_compact = Q / (us_compact / 1e6)
        speedups[sel] = qps_compact / qps_dense
        st = compact.stats
        emit(f"sweep_dense_sel{sel}", us_dense, qps=round(qps_dense, 1))
        emit(f"sweep_compact_sel{sel}", us_compact,
             qps=round(qps_compact, 1),
             speedup=round(speedups[sel], 2),
             sel_ratio=round(st.selected_page_ratio, 4),
             gather_occ=round(st.gather_occupancy, 3),
             bucket=compact._compact_bucket,
             fallbacks=st.compact_fallbacks)

    lowest = min(speedups)
    assert speedups[lowest] >= ASSERT_MIN_SPEEDUP, (
        f"compact mode only {speedups[lowest]:.2f}x dense at selectivity "
        f"{lowest} (need >= {ASSERT_MIN_SPEEDUP}x)")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(card=100_000 if args.quick else CARD,
        selectivities=(0.01, 0.5) if args.quick else SELECTIVITIES)
