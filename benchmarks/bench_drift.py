"""Drift-adaptive re-summarization: query throughput under a drifting insert stream.

The complete histogram is never rebuilt on local updates (§4.1), so an
append-only workload whose keys migrate upward clamps every new tuple into
the top edge bucket: new pages' partial histograms converge toward that one
bucket, the density rule keeps extending one ever-growing entry over them,
and any query touching the drifted region matches *all* drifted pages —
partition pruning and the compact gather path degrade toward full scans of
the new data. The drift pipeline (PR 5) fixes this off the query path:
the writer's ``DriftTracker`` watches staged inserts, and when the
edge-bucket overflow ratio crosses the engine's ``drift_threshold`` a
re-summarization is scheduled — one remap drain unit per shard onto bounds
rebuilt from the insert reservoir (``histogram.rebuild``), applied under the
same swap discipline as insert drains, *before* the staged rows land so they
group well from their first page.

This benchmark drives ``ROUNDS`` rounds of upward-drifting inserts through
two otherwise-identical compact-mode engines (S=4, Q=64):

  baseline  — ``drift_threshold=None``: summaries stay on the build-time
              bounds; each round's queries (ranges inside the freshest
              insert window) inspect every drifted page so far
  adaptive  — auto resummarize: each round's remap seals the previous
              windows into their own buckets, so fresh-window queries
              inspect ~one round's pages

Counts are asserted bit-identical to brute force for both engines after
every round (the remap never changes results, only pruning). The headline is
final-round queries/sec: adaptive >= 1.5x baseline is asserted at the full
configuration (CPU, S=4, Q=64); the ``sel_ratio`` derived fields show the
mechanism (baseline's selected-page ratio grows with the drift, adaptive's
stays flat) alongside ``resummarizes`` and the closing ``edge_ratio``.

  PYTHONPATH=src python -m benchmarks.bench_drift [--quick]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, measure
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable

CARD = 100_000
PAGE_CARD = 50
SHARDS = 4
Q = 64
ROUNDS = 4
INSERTS = 3000         # per round; keys drift one window upward each round
BASE_DOMAIN = 1e5      # base keys uniform in [0, BASE_DOMAIN)
STEP = 1e4             # round r inserts uniform in BASE + [(r-1)*STEP, r*STEP)
QUERY_WIDTH = 0.25     # query range width as a fraction of the window
RESOLUTION = 400
DENSITY = 0.02
MAX_SLOTS = 256        # right-sized: the match phase scans every slot
ASSERT_MIN_SPEEDUP = 1.5


def _workload(rng, rounds: int, inserts: int):
    """Per-round (writes, preds): an upward-drifting insert window plus Q
    range queries chasing it — the freshest data is the hottest, the access
    pattern that makes histogram drift hurt."""
    plan = []
    for r in range(rounds):
        w_lo = BASE_DOMAIN + r * STEP
        writes = rng.uniform(w_lo, w_lo + STEP, inserts)
        width = QUERY_WIDTH * STEP
        preds = []
        for _ in range(Q):
            lo = w_lo + float(rng.uniform(0, STEP - width))
            preds.append(Predicate.between(lo, lo + width))
        plan.append((writes, preds))
    return plan


def _brute(table, preds) -> np.ndarray:
    live = table.valid[: table.num_pages]
    keys = table.keys[: table.num_pages]
    return np.asarray([(live & (keys >= p.lo) & (keys <= p.hi)).sum()
                       for p in preds], np.int64)


def _run_mode(values, plan, adaptive: bool):
    """One full drift sweep (writes staged + drained, queries checked against
    brute force each round); returns the engine in its sweep-end state."""
    table = PagedTable.from_values(values.copy(), page_card=PAGE_CARD)
    sidx = ShardedHippoIndex.create(table, num_shards=SHARDS,
                                    resolution=RESOLUTION, density=DENSITY,
                                    max_slots=MAX_SLOTS,
                                    relocate_on_update=False)
    engine = QueryEngine(sidx, batch=Q, drain_policy="manual",
                         drift_threshold=0.5 if adaptive else None,
                         drift_min_observed=128)
    for writes, preds in plan:
        for v in writes:
            engine.write(float(v))
        engine.flush()     # remap (if scheduled) + insert drains, off-path
        counts = engine.run_all(preds)
        np.testing.assert_array_equal(
            counts, _brute(table, preds),
            err_msg=f"adaptive={adaptive}: counts diverge from brute force")
    return engine


def run(card: int = CARD, rounds: int = ROUNDS, inserts: int = INSERTS) -> None:
    rng = np.random.default_rng(0)
    values = np.sort(rng.uniform(0, BASE_DOMAIN, card))
    plan = _workload(rng, rounds, inserts)
    eng_base = _run_mode(values, plan, adaptive=False)
    eng_adpt = _run_mode(values, plan, adaptive=True)
    assert eng_base.stats.resummarizes == 0
    assert eng_adpt.stats.resummarizes >= SHARDS, \
        "drift sweep never triggered a re-summarization"

    # Time the two sweep-end engines interleaved (shared min-of-reps helper)
    # so a throttling or noisy-neighbor window hits both modes, not one.
    final_preds = plan[-1][1]
    us_base, us_adpt = measure(lambda: eng_base.run_all(final_preds),
                               lambda: eng_adpt.run_all(final_preds),
                               warmup=1, reps=9)
    qps_base = Q / (us_base / 1e6)
    qps_adpt = Q / (us_adpt / 1e6)
    speedup = qps_adpt / qps_base
    emit("drift_no_resummarize", us_base, qps=round(qps_base, 1),
         rounds=rounds, inserts=rounds * inserts,
         sel_ratio=round(eng_base.stats.selected_page_ratio, 4))
    emit("drift_adaptive", us_adpt, qps=round(qps_adpt, 1),
         rounds=rounds, inserts=rounds * inserts,
         speedup=round(speedup, 2),
         sel_ratio=round(eng_adpt.stats.selected_page_ratio, 4),
         resummarizes=eng_adpt.stats.resummarizes,
         edge_ratio=round(eng_adpt.stats.edge_overflow_ratio, 3))
    if card >= CARD:
        # acceptance floor at the full configuration (CPU, S=4, Q=64);
        # --quick shrinks the table, which shrinks the drifted-page pile the
        # baseline pays for and with it the measurable gap
        assert speedup >= ASSERT_MIN_SPEEDUP, (
            f"adaptive resummarize only {speedup:.2f}x the no-resummarize "
            f"baseline at sweep end (need >= {ASSERT_MIN_SPEEDUP}x)")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(card=10_000 if args.quick else CARD,
        rounds=3 if args.quick else ROUNDS,
        inserts=600 if args.quick else INSERTS)
