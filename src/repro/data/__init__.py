from repro.data.corpus import PagedCorpus, synthesize_corpus  # noqa: F401
from repro.data.pipeline import HippoDataPipeline  # noqa: F401
