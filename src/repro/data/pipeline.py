"""Hippo-indexed data pipeline: predicate-filtered, deterministic, prefetched.

Selection runs Algorithm 1 over the corpus metadata table: the quality-range
predicate is AND-filtered against the page summaries, only possible-qualified
pages are inspected, and the exact qualifying sequence set comes back. The
pipeline then samples batches from that set with a *stateless* step->batch
mapping (a counter-based RNG keyed on (seed, step)), so restarts and elastic
re-sharding reproduce the exact same batch for any step — the checkpoint only
needs to store the step number (see runtime/fault.py).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field

import numpy as np

from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.data.corpus import PagedCorpus


@dataclass
class HippoDataPipeline:
    corpus: PagedCorpus
    index: HippoIndex
    predicate: Predicate
    seed: int = 0
    selected_ids: np.ndarray = field(default=None)
    pages_inspected: int = 0

    @staticmethod
    def create(corpus: PagedCorpus, predicate: Predicate, *, resolution: int = 128,
               density: float = 0.15, seed: int = 0) -> "HippoDataPipeline":
        index = HippoIndex.create(corpus.table, resolution=resolution,
                                  density=density)
        pipe = HippoDataPipeline(corpus=corpus, index=index, predicate=predicate,
                                 seed=seed)
        pipe.refresh_selection()
        return pipe

    # -- selection (the paper's access path) ---------------------------------

    def refresh_selection(self) -> None:
        res = self.index.search(self.predicate)
        qual = np.asarray(res.qualified)              # (pages, page_card) bool
        flat = qual.ravel()[: self.corpus.num_seqs]
        self.selected_ids = np.flatnonzero(flat)
        self.pages_inspected = int(res.pages_inspected)
        if self.selected_ids.size == 0:
            raise ValueError("predicate selects no sequences")

    # -- deterministic batching ------------------------------------------------

    def batch_ids(self, step: int, batch_size: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        return rng.choice(self.selected_ids, size=batch_size,
                          replace=self.selected_ids.size < batch_size)

    def get_batch(self, step: int, batch_size: int) -> dict:
        ids = self.batch_ids(step, batch_size)
        toks = self.corpus.tokens[ids]
        b, s = toks.shape
        return {
            "inputs": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "positions": np.broadcast_to(np.arange(s - 1, dtype=np.int32)[None],
                                         (b, s - 1)).copy(),
        }

    # -- prefetch -----------------------------------------------------------------

    def iter_batches(self, start_step: int, num_steps: int, batch_size: int,
                     prefetch: int = 2):
        """Background-thread prefetched iterator (host-side input pipeline)."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = object()

        def producer():
            for s in range(start_step, start_step + num_steps):
                q.put((s, self.get_batch(s, batch_size)))
            q.put(stop)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item
