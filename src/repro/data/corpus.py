"""Paged training corpus with per-sequence metadata.

The corpus is stored exactly like a Hippo-indexed table: sequences live in
fixed-size *pages* (``page_card`` sequences per page), and a metadata key
(quality score) is the indexed attribute. This is the paper's structure
deployed as the training data plane: sample-selection predicates ("quality in
[0.8, 1]") run through the Hippo access path instead of a corpus scan.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.storage.table import PagedTable


@dataclass
class PagedCorpus:
    tokens: np.ndarray          # (num_seqs, seq_len) int32
    quality: np.ndarray         # (num_seqs,) float32 — the indexed attribute
    domain: np.ndarray          # (num_seqs,) int32
    table: PagedTable           # quality scores in paged layout
    page_card: int

    @property
    def num_seqs(self) -> int:
        return self.tokens.shape[0]

    def seq_ids_for_pages(self, page_ids: np.ndarray) -> np.ndarray:
        """Sequence ids stored in the given pages (page p holds sequences
        [p*page_card, (p+1)*page_card))."""
        ids = (page_ids[:, None] * self.page_card
               + np.arange(self.page_card)[None, :]).ravel()
        return ids[ids < self.num_seqs]


def synthesize_corpus(num_seqs: int, seq_len: int, vocab_size: int,
                      page_card: int = 64, seed: int = 0,
                      shard_run: int = 512) -> PagedCorpus:
    """Synthetic corpus with a learnable structure per domain, plus a quality
    score correlated with domain.

    Sequences arrive in *shard runs* (``shard_run`` contiguous sequences per
    domain), the way crawl dumps and curated subsets land in real ingestion —
    this storage locality is what lets a page-range index prune (the same
    assumption behind BRIN/zone maps; Hippo additionally tolerates the
    within-run skew via histograms)."""
    rng = np.random.default_rng(seed)
    n_runs = (num_seqs + shard_run - 1) // shard_run
    run_domain = rng.integers(0, 4, n_runs)
    domain = np.repeat(run_domain, shard_run)[:num_seqs].astype(np.int32)
    quality = (0.25 * domain + rng.uniform(0, 0.25, num_seqs)).astype(np.float32)
    base = rng.integers(0, vocab_size, (num_seqs, seq_len), dtype=np.int32)
    # cheap structure: domain d walks tokens with stride d+1
    stride = (domain[:, None] + 1).astype(np.int32)
    ramp = np.arange(seq_len, dtype=np.int32)[None, :]
    tokens = (base[:, :1] + stride * ramp) % vocab_size
    table = PagedTable.from_values(quality, page_card=page_card, spare_pages=16)
    return PagedCorpus(tokens=tokens.astype(np.int32), quality=quality,
                       domain=domain, table=table, page_card=page_card)
