"""hippolint core: findings, source loading, suppressions, pass registry.

The analyzer is a set of *passes* over a shared parse of the tree. Each
pass is a function ``run(ctx) -> list[Finding]``; ``scripts/lint.py``
selects passes, runs them, filters suppressed findings, and reports the
rest as ``path:line: [pass] message``.

Suppression grammar (enforced here, not per pass)::

    # hippolint: disable=<pass>[,<pass>] -- <justification>

A disable comment applies to findings on its own line, or — when the
comment stands alone on a line — to the next line that carries code. The
justification is *mandatory*: a disable without ``-- <reason>`` is itself
an error finding (``suppress`` pass), so every silenced invariant in the
tree carries a written explanation next to it.

Annotations read by individual passes use the same comment channel::

    self._handles = {}        # guarded-by: _lock
    def truncate_through(..): # thread: worker
    def _close_locked(..):    # requires-lock: _lock

See ``docs/analysis.md`` for the pass-by-pass semantics.
"""
from __future__ import annotations

import ast
import io
import pathlib
import re
import tokenize
from dataclasses import dataclass, field

# Passes register themselves here at import time (see __init__.py).
PASS_NAMES = ("locks", "crash", "jit", "deadcode", "markers")

_SUPPRESS_RE = re.compile(
    r"hippolint:\s*disable=([A-Za-z_,\s]+?)(?:\s*--\s*(.*))?$")
_GUARDED_RE = re.compile(r"guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")
_THREAD_RE = re.compile(r"thread:\s*worker\b")
_REQUIRES_RE = re.compile(r"requires-lock:\s*([A-Za-z_][A-Za-z0-9_]*)")


@dataclass(frozen=True)
class Finding:
    """One analyzer result, anchored to a source line.

    ``severity`` is ``"error"`` (fails the lint) or ``"info"``
    (report-only — the dead-seed audit)."""
    path: str          # repo-relative, for display
    line: int
    check: str         # pass name
    message: str
    severity: str = "error"

    def render(self) -> str:
        tag = self.check if self.severity == "error" else f"{self.check}/info"
        return f"{self.path}:{self.line}: [{tag}] {self.message}"

    def sort_key(self):
        return (self.path, self.line, self.check, self.message)


@dataclass
class Suppression:
    passes: frozenset[str]
    reason: str
    decl_line: int     # where the comment sits
    target_line: int   # the code line it silences


@dataclass
class SourceFile:
    """One parsed module: AST plus the comment side-channel."""
    path: pathlib.Path
    rel: str
    text: str
    tree: ast.Module
    comments: dict[int, str] = field(default_factory=dict)
    code_lines: set[int] = field(default_factory=set)
    suppressions: list[Suppression] = field(default_factory=list)

    @classmethod
    def load(cls, path: pathlib.Path, repo_root: pathlib.Path) -> "SourceFile":
        text = path.read_text()
        rel = str(path.relative_to(repo_root)) if path.is_relative_to(
            repo_root) else str(path)
        sf = cls(path=path, rel=rel, text=text, tree=ast.parse(text, str(path)))
        _scan_tokens(sf)
        _bind_suppressions(sf)
        return sf

    # -- comment annotations (used by the passes) ----------------------------

    def comment_near(self, line: int) -> str:
        """The comment on ``line``, or a standalone comment on the line
        above (the two placements every annotation accepts)."""
        out = self.comments.get(line, "")
        above = line - 1
        if above in self.comments and above not in self.code_lines:
            out = self.comments[above] + " " + out
        return out

    def guarded_by(self, line: int) -> str | None:
        m = _GUARDED_RE.search(self.comment_near(line))
        return m.group(1) if m else None

    def is_worker(self, line: int) -> bool:
        return bool(_THREAD_RE.search(self.comment_near(line)))

    def requires_lock(self, line: int) -> str | None:
        m = _REQUIRES_RE.search(self.comment_near(line))
        return m.group(1) if m else None

    def suppressed(self, line: int, check: str) -> bool:
        return any(s.target_line == line and check in s.passes
                   for s in self.suppressions)


def _scan_tokens(sf: SourceFile) -> None:
    skip = {tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE, tokenize.INDENT,
            tokenize.DEDENT, tokenize.ENCODING, tokenize.ENDMARKER}
    for tok in tokenize.generate_tokens(io.StringIO(sf.text).readline):
        if tok.type == tokenize.COMMENT:
            line = tok.start[0]
            body = tok.string.lstrip("#").strip()
            prev = sf.comments.get(line)
            sf.comments[line] = body if prev is None else prev + " " + body
        elif tok.type not in skip:
            for ln in range(tok.start[0], tok.end[0] + 1):
                sf.code_lines.add(ln)


def _bind_suppressions(sf: SourceFile) -> None:
    for line, comment in sorted(sf.comments.items()):
        m = _SUPPRESS_RE.search(comment)
        if not m:
            continue
        passes = frozenset(p.strip() for p in m.group(1).split(",")
                           if p.strip())
        reason = (m.group(2) or "").strip()
        target = line
        if line not in sf.code_lines:  # standalone comment: next code line
            later = [ln for ln in sf.code_lines if ln > line]
            target = min(later) if later else line
        sf.suppressions.append(Suppression(passes=passes, reason=reason,
                                           decl_line=line, target_line=target))


@dataclass
class Context:
    """What every pass gets: the repo root and the parsed target files
    (``src/**/*.py`` + ``scripts/*.py`` by default)."""
    repo_root: pathlib.Path
    files: list[SourceFile]

    def file(self, rel: str) -> SourceFile | None:
        for sf in self.files:
            if sf.rel == rel:
                return sf
        return None


def default_targets(repo_root: pathlib.Path) -> list[pathlib.Path]:
    out = []
    if (repo_root / "src").is_dir():
        out += sorted((repo_root / "src").rglob("*.py"))
    if (repo_root / "scripts").is_dir():
        out += sorted((repo_root / "scripts").glob("*.py"))
    return out


def load_context(repo_root: pathlib.Path,
                 paths: list[pathlib.Path] | None = None) -> Context:
    paths = default_targets(repo_root) if paths is None else paths
    files = [SourceFile.load(p, repo_root) for p in paths]
    return Context(repo_root=repo_root, files=files)


def suppression_findings(ctx: Context) -> list[Finding]:
    """Malformed disables are themselves errors: a silence must name a
    real pass and carry a justification."""
    out = []
    for sf in ctx.files:
        for s in sf.suppressions:
            unknown = s.passes - set(PASS_NAMES)
            if unknown:
                out.append(Finding(
                    sf.rel, s.decl_line, "suppress",
                    f"disable names unknown pass(es) "
                    f"{', '.join(sorted(unknown))}; known: "
                    f"{', '.join(PASS_NAMES)}"))
            if not s.reason:
                out.append(Finding(
                    sf.rel, s.decl_line, "suppress",
                    "suppression without a justification — write "
                    "'# hippolint: disable=<pass> -- <why this is safe>'"))
    return out


def run_passes(ctx: Context, passes: dict[str, object]) -> list[Finding]:
    """Run the selected passes, drop suppressed findings, and append
    malformed-suppression errors. Returns findings sorted by location."""
    findings = list(suppression_findings(ctx))
    by_rel = {sf.rel: sf for sf in ctx.files}
    for run in passes.values():
        for f in run(ctx):
            sf = by_rel.get(f.path)
            if sf is not None and sf.suppressed(f.line, f.check):
                continue
            findings.append(f)
    return sorted(findings, key=Finding.sort_key)
