"""Jit-stability pass: trace/recompile hazards inside jitted functions.

A function is *jitted* when it is decorated ``@jax.jit`` /
``@partial(jax.jit, ...)`` / ``@jax.jit(...)``, or wrapped at module
scope (``f = jax.jit(g)``). Parameters named in ``static_argnames`` are
concrete at trace time; every other parameter is a tracer.

Flagged inside jitted bodies:

- ``jnp.nonzero``/``jnp.unique`` without ``size=`` — data-dependent
  output shape, a guaranteed trace error or silent recompile trap.
- ``int()``/``bool()`` coercion or ``.item()`` on an expression that
  references a traced parameter — forces a concrete value out of a
  tracer (``ConcretizationTypeError`` at best).
- ``if``/``while`` tests and ``range()`` iteration over traced
  parameters — Python control flow burns the traced value into the
  trace. ``.shape``/``.ndim``/``.dtype``/``.size`` projections and
  ``len()`` are static under trace and exempt.

Flagged anywhere: a ``jax.jit(...)``/``partial(jax.jit, ...)`` call
lexically inside a ``for``/``while`` body — a fresh jit wrapper per
iteration retraces every call (cache keyed on wrapper identity).

The pass is name-local by design: values *derived* from traced
parameters are not tracked through assignments. That keeps false
positives near zero on numeric kernel code at the cost of missing
second-order flows — the documented trade (docs/analysis.md).
"""
from __future__ import annotations

import ast

from repro.analysis.base import Context, Finding, SourceFile

CHECK = "jit"

_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}


def _is_jax_jit(node: ast.expr) -> bool:
    if isinstance(node, ast.Name):
        return node.id == "jit"
    if isinstance(node, ast.Attribute):
        return node.attr == "jit"
    return False


def _static_argnames(call: ast.Call) -> set[str]:
    out: set[str] = set()
    for kw in call.keywords:
        if kw.arg not in ("static_argnames", "static_argnums"):
            continue
        v = kw.value
        elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
        for e in elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.add(e.value)
    return out


def _jit_decoration(fn) -> tuple[bool, set[str]]:
    """(is jitted, static argnames) from a def's decorator list."""
    for dec in fn.decorator_list:
        if _is_jax_jit(dec):
            return True, set()
        if isinstance(dec, ast.Call):
            if _is_jax_jit(dec.func):
                return True, _static_argnames(dec)
            # partial(jax.jit, static_argnames=...)
            fname = dec.func.attr if isinstance(dec.func, ast.Attribute) \
                else (dec.func.id if isinstance(dec.func, ast.Name) else "")
            if fname == "partial" and dec.args and _is_jax_jit(dec.args[0]):
                return True, _static_argnames(dec)
    return False, set()


def _wrapped_defs(tree: ast.AST) -> dict[str, set[str]]:
    """``f = jax.jit(g, ...)`` at any scope → {g: static argnames}."""
    out: dict[str, set[str]] = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and isinstance(node.value,
                                                            ast.Call)):
            continue
        call = node.value
        if _is_jax_jit(call.func) and call.args \
                and isinstance(call.args[0], ast.Name):
            out[call.args[0].id] = _static_argnames(call)
    return out


class _Names(ast.NodeVisitor):
    """Free names in an expression, skipping statically-safe projections
    (``x.shape...``, ``len(x)``) whose concreteness survives tracing."""

    def __init__(self):
        self.names: set[str] = set()

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if node.attr in _STATIC_ATTRS:
            return
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Name) and node.func.id == "len":
            return
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        self.names.add(node.id)


def _traced_refs(expr: ast.expr, traced: set[str]) -> set[str]:
    v = _Names()
    v.visit(expr)
    return v.names & traced


def _body_findings(sf: SourceFile, fn, traced: set[str]) -> list[Finding]:
    out: list[Finding] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute)
                    and f.attr in ("nonzero", "unique")
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "jnp"
                    and not any(kw.arg == "size" for kw in node.keywords)):
                out.append(Finding(
                    sf.rel, node.lineno, CHECK,
                    f"jnp.{f.attr}() without size= inside jitted "
                    f"{fn.name}() — data-dependent output shape cannot "
                    f"trace"))
            if (isinstance(f, ast.Name) and f.id in ("int", "bool")
                    and node.args):
                hits = _traced_refs(node.args[0], traced)
                if hits:
                    out.append(Finding(
                        sf.rel, node.lineno, CHECK,
                        f"{f.id}() coerces traced value(s) "
                        f"{', '.join(sorted(hits))} inside jitted "
                        f"{fn.name}() — concretization error under trace"))
            if isinstance(f, ast.Attribute) and f.attr == "item":
                hits = _traced_refs(f.value, traced)
                if hits:
                    out.append(Finding(
                        sf.rel, node.lineno, CHECK,
                        f".item() on traced value(s) "
                        f"{', '.join(sorted(hits))} inside jitted "
                        f"{fn.name}()"))
        elif isinstance(node, (ast.If, ast.While)):
            hits = _traced_refs(node.test, traced)
            if hits:
                kw = "if" if isinstance(node, ast.If) else "while"
                out.append(Finding(
                    sf.rel, node.lineno, CHECK,
                    f"Python {kw} over traced value(s) "
                    f"{', '.join(sorted(hits))} inside jitted {fn.name}() "
                    f"— use jnp.where/lax.cond or mark the arg static"))
        elif isinstance(node, ast.For):
            it = node.iter
            if (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                    and it.func.id == "range"):
                hits = set()
                for a in it.args:
                    hits |= _traced_refs(a, traced)
                if hits:
                    out.append(Finding(
                        sf.rel, node.lineno, CHECK,
                        f"range() over traced value(s) "
                        f"{', '.join(sorted(hits))} inside jitted "
                        f"{fn.name}() — loop extent burns into the trace"))
    return out


class _JitInLoop(ast.NodeVisitor):
    """``jax.jit(...)`` constructed lexically inside a loop body."""

    def __init__(self, sf: SourceFile):
        self.sf = sf
        self.depth = 0
        self.findings: list[Finding] = []

    def _loop(self, node) -> None:
        self.depth += 1
        self.generic_visit(node)
        self.depth -= 1

    def visit_For(self, node):
        self._loop(node)

    def visit_While(self, node):
        self._loop(node)

    def visit_Call(self, node: ast.Call) -> None:
        if self.depth > 0:
            is_jit_ctor = _is_jax_jit(node.func)
            if not is_jit_ctor and isinstance(node.func, (ast.Name,
                                                          ast.Attribute)):
                fname = node.func.id if isinstance(node.func, ast.Name) \
                    else node.func.attr
                is_jit_ctor = fname == "partial" and node.args \
                    and _is_jax_jit(node.args[0])
            if is_jit_ctor:
                self.findings.append(Finding(
                    self.sf.rel, node.lineno, CHECK,
                    "jit wrapper constructed inside a loop — a fresh "
                    "wrapper per iteration retraces on every call; hoist "
                    "the jax.jit() out of the loop"))
        self.generic_visit(node)


def run(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for sf in ctx.files:
        wrapped = _wrapped_defs(sf.tree)
        for node in ast.walk(sf.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            jitted, static = _jit_decoration(node)
            if not jitted and node.name in wrapped:
                jitted, static = True, wrapped[node.name]
            if not jitted:
                continue
            params = {a.arg for a in (node.args.posonlyargs + node.args.args
                                      + node.args.kwonlyargs)}
            findings.extend(_body_findings(sf, node, params - static))
        loop_scan = _JitInLoop(sf)
        loop_scan.visit(sf.tree)
        findings.extend(loop_scan.findings)
    return findings
