"""Dead-seed audit (report-only): seed modules the product never imports.

Builds the import graph of ``src/repro`` and walks reachability from the
product surface: every module under ``repro.core`` / ``repro.runtime`` /
``repro.checkpointing``, plus whatever ``benchmarks/``, ``scripts/``, and
``examples/`` import. What is left unreached is seed-era code (the
dormant transformer ``models/``, ``optim/``, ``launch/train.py``, ...)
that future PRs should prune or revive *deliberately* — so this pass
reports at ``info`` severity and never fails the lint. Modules whose only
inbound edge is from ``tests/`` are annotated: deleting them means
deleting their tests too.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.base import Context, Finding

CHECK = "deadcode"

_SEED_PACKAGES = ("repro.core", "repro.runtime", "repro.checkpointing",
                  "repro.analysis")
# examples/ are deliberately NOT roots: the seed-era demo scripts
# (train_lm.py, serve_decode.py) pin the dormant transformer stack, and
# the whole point of this audit is to see through that pin.
_ENTRY_DIRS = ("benchmarks", "scripts")


def _module_name(path: pathlib.Path, src_root: pathlib.Path) -> str:
    rel = path.relative_to(src_root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _module_map(src_root: pathlib.Path) -> dict[str, pathlib.Path]:
    return {_module_name(p, src_root): p
            for p in sorted(src_root.rglob("*.py"))}


def _imports(tree: ast.AST, current: str,
             modules: set[str]) -> set[str]:
    """repro.* modules a parsed file imports (absolute + relative)."""
    out: set[str] = set()

    def add(name: str) -> None:
        # `from repro.core import index` names either a module or a
        # symbol; resolve to the longest prefix that is a real module
        while name and name not in modules:
            name = name.rpartition(".")[0]
        if name:
            out.add(name)

    pkg_parts = current.split(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == "repro":
                    add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level == 0:
                base = node.module or ""
                if base.split(".")[0] != "repro":
                    continue
            else:
                # relative: resolve against the importing module's package
                base_parts = pkg_parts[:max(0, len(pkg_parts) - node.level)]
                base = ".".join(base_parts + ([node.module]
                                              if node.module else []))
            add(base)
            for alias in node.names:
                add(f"{base}.{alias.name}")
    return out


def _external_imports(dirpath: pathlib.Path,
                      modules: set[str]) -> set[str]:
    out: set[str] = set()
    if not dirpath.is_dir():
        return out
    for path in sorted(dirpath.rglob("*.py")):
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError:
            continue
        out |= _imports(tree, "", modules)
    return out


def run(ctx: Context) -> list[Finding]:
    src_root = ctx.repo_root / "src"
    if not src_root.is_dir():
        return []
    mod_map = _module_map(src_root)
    modules = set(mod_map)
    deps = {name: _imports(ast.parse(path.read_text()), name, modules)
            for name, path in mod_map.items()}

    seeds = {m for m in modules
             if any(m == p or m.startswith(p + ".") for p in _SEED_PACKAGES)}
    for d in _ENTRY_DIRS:
        seeds |= _external_imports(ctx.repo_root / d, modules)
    test_pins = _external_imports(ctx.repo_root / "tests", modules)
    example_pins = _external_imports(ctx.repo_root / "examples", modules)

    reachable: set[str] = set()
    work = sorted(seeds)
    while work:
        m = work.pop()
        if m in reachable or m not in modules:
            continue
        reachable.add(m)
        # importing a.b.c imports a and a.b
        parent = m.rpartition(".")[0]
        if parent:
            work.append(parent)
        work.extend(sorted(deps.get(m, ())))

    findings = []
    for name in sorted(modules - reachable):
        pins = [p for p, pinned in (("tests/", test_pins),
                                    ("examples/", example_pins))
                if name in pinned]
        note = f" (pinned only by {' and '.join(pins)} — those go with it)" \
            if pins else ""
        findings.append(Finding(
            str(mod_map[name].relative_to(ctx.repo_root)), 1, CHECK,
            f"seed module {name} is unreachable from "
            f"core/runtime/checkpointing or any benchmark/script "
            f"entrypoint{note} — prune or revive deliberately",
            severity="info"))
    return findings
