"""hippolint — repo-wide static invariant checker.

Five passes over the tree (``scripts/lint.py --all``):

- ``locks``    — lock discipline on the threaded classes (guarded-by
  declarations, worker-thread reachability, held-lock scoping)
- ``crash``    — crash consistency (fsync-before-rename, WAL
  append-before-admission, crash-site registry bijectivity)
- ``jit``      — trace/recompile hazards inside jitted functions
- ``deadcode`` — report-only audit of unreachable seed modules
- ``markers``  — every pytest marker a test uses must be declared

See ``docs/analysis.md`` and ``repro.analysis.base`` for the framework
(findings, suppressions, comment annotations).
"""
from __future__ import annotations

from repro.analysis import base, crash, deadcode, jit, locks, markers
from repro.analysis.base import (Context, Finding, SourceFile,  # noqa: F401
                                 load_context, run_passes)

PASSES = {
    "locks": locks.run,
    "crash": crash.run,
    "jit": jit.run,
    "deadcode": deadcode.run,
    "markers": markers.run,
}

assert tuple(PASSES) == base.PASS_NAMES
