"""Crash-consistency pass: fsync domination, WAL ordering, site registry.

Three checks, each structural counterparts of the recovery contract the
``persist``/``fault`` test tiers sample dynamically:

1. **fsync-before-rename.** An ``os.replace``/``os.rename`` is the commit
   instant of the atomic-publish idiom; renaming a payload that was never
   fsynced publishes bytes the kernel may not have written. Every such
   call must be preceded (same function) by an fsync-family call
   (``os.fsync``, ``fsync_file``, ``fsync_dir``, ...).

2. **WAL append-before-admission.** A function that journals
   (``append_insert``/``append_delete``/``append_resummarize``) must not
   mutate ``self`` state before the append: an op admitted before its
   record exists is lost by a crash in between, which is precisely the
   acknowledged-write-loss the WAL exists to prevent.

3. **Crash-site bijectivity.** ``runtime/faultinject.py``'s ``SITES``
   registry and the ``crashpoint("<site>")`` call sites in source must
   match exactly: an unregistered call raises only at runtime (and only
   if executed), a stale registry entry makes
   ``tests/test_fault_recovery.py``'s every-site sweep vacuous for that
   site, and a non-literal site argument defeats the audit entirely.
"""
from __future__ import annotations

import ast

from repro.analysis.base import Context, Finding, SourceFile

CHECK = "crash"

_APPENDS = {"append_insert", "append_delete", "append_resummarize"}
_RENAMES = {"replace", "rename"}


def _call_name(node: ast.Call) -> str:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _is_os_rename(node: ast.Call) -> bool:
    fn = node.func
    return (isinstance(fn, ast.Attribute) and fn.attr in _RENAMES
            and isinstance(fn.value, ast.Name) and fn.value.id == "os")


def _self_store_root(target: ast.expr) -> str | None:
    node = target
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        parent = node.value
        if (isinstance(node, ast.Attribute)
                and isinstance(parent, ast.Name) and parent.id == "self"):
            return node.attr
        node = parent
    return None


def _walk_shallow(fn):
    """Walk a function body without descending into nested def/lambda —
    those are analyzed as functions of their own."""
    work = list(ast.iter_child_nodes(fn))
    while work:
        node = work.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            work.extend(ast.iter_child_nodes(node))


def _function_findings(sf: SourceFile, fn) -> list[Finding]:
    out: list[Finding] = []
    renames: list[ast.Call] = []
    fsync_lines: list[int] = []
    append_first: int | None = None
    stores: list[tuple[str, int]] = []

    for node in _walk_shallow(fn):
        if isinstance(node, ast.Call):
            name = _call_name(node)
            if _is_os_rename(node):
                renames.append(node)
            if "fsync" in name or name == "commit_sentinel":
                fsync_lines.append(node.lineno)
            if name in _APPENDS:
                if append_first is None or node.lineno < append_first:
                    append_first = node.lineno
        elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else \
                [node.target]
            for t in targets:
                root = _self_store_root(t)
                if root is not None:
                    stores.append((root, t.lineno))

    for call in renames:
        if not any(line < call.lineno for line in fsync_lines):
            out.append(Finding(
                sf.rel, call.lineno, CHECK,
                f"os.{_call_name(call)} commit in {fn.name}() has no "
                f"preceding fsync of the payload in the same function — "
                f"a rename publishes bytes the kernel may not have written"))

    if append_first is not None:
        for attr, line in stores:
            if line < append_first:
                out.append(Finding(
                    sf.rel, line, CHECK,
                    f"self.{attr} is mutated at line {line} before the WAL "
                    f"append at line {append_first} in {fn.name}() — "
                    f"journal-before-admission is violated; a crash in "
                    f"between loses an acknowledged operation"))
    return out


def _registered_sites(sf: SourceFile) -> dict[str, int]:
    """Parse ``SITES = (...)`` or ``SITES = _register(...)``."""
    out: dict[str, int] = {}
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "SITES"):
            continue
        elts: list[ast.expr] = []
        if isinstance(node.value, ast.Tuple):
            elts = node.value.elts
        elif isinstance(node.value, ast.Call):
            elts = list(node.value.args)
        for e in elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.setdefault(e.value, e.lineno)
    return out


def _site_literals(node: ast.expr) -> list[str] | None:
    """Constant site names an argument can evaluate to; None if opaque."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, ast.IfExp):
        a, b = _site_literals(node.body), _site_literals(node.orelse)
        if a is not None and b is not None:
            return a + b
    return None


def _registry_findings(ctx: Context) -> list[Finding]:
    reg_file = next((sf for sf in ctx.files
                     if sf.rel.endswith("runtime/faultinject.py")), None)
    if reg_file is None:
        return []
    registered = _registered_sites(reg_file)
    out: list[Finding] = []

    called: dict[str, int] = {}
    for sf in ctx.files:
        if sf is reg_file:
            continue    # the registry module defines crashpoint, not sites
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call)
                    and _call_name(node) == "crashpoint" and node.args):
                continue
            sites = _site_literals(node.args[0])
            if sites is None:
                out.append(Finding(
                    sf.rel, node.lineno, CHECK,
                    "crashpoint() with a non-literal site argument — the "
                    "registry bijectivity audit cannot see this site"))
                continue
            for site in sites:
                called.setdefault(site, node.lineno)
                if site not in registered:
                    out.append(Finding(
                        sf.rel, node.lineno, CHECK,
                        f"crashpoint({site!r}) is not registered in "
                        f"faultinject.SITES — it will raise ValueError at "
                        f"runtime and the fault tier cannot arm it"))
    for site, line in sorted(registered.items()):
        if site not in called:
            out.append(Finding(
                reg_file.rel, line, CHECK,
                f"registered crash site {site!r} has no crashpoint() call "
                f"site in source — a stale entry makes the every-site "
                f"recovery sweep vacuous for it"))
    return out


def run(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for sf in ctx.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings.extend(_function_findings(sf, node))
    findings.extend(_registry_findings(ctx))
    return findings
