"""Lock-discipline pass: guarded attributes, worker threads, held locks.

Model, per class:

- **Worker entries** — methods a second thread runs: inferred from
  ``threading.Thread(target=self.m)`` anywhere in the class, or declared
  with ``# thread: worker`` on the ``def`` (commit callbacks and other
  cross-object entrypoints the AST cannot see).
- **W** = intra-class call-graph closure from the worker entries; **C** =
  closure from every other method (the caller-thread surface). A method
  can be in both.
- **Contended attribute** — accessed in W *and* in C, and mutated outside
  ``__init__`` (rebound, or stored through: ``self.stats.x += 1`` counts).
  Attributes only ever *called into* (``self._q.put(...)``) are exempt —
  that is the queue/Lock idiom, where the object carries its own
  synchronization. Every contended attribute must carry a
  ``# guarded-by: <lock>`` declaration on its ``__init__`` assignment.
- **Guarded access** — any non-``__init__`` access to a declared
  attribute must sit under ``with self.<lock>:`` or inside a method
  declared ``# requires-lock: <lock>`` (whose own call sites must then
  hold the lock — checked too).

Classes with no worker entries have one thread by construction and are
skipped entirely; attributes assigned ``threading.Lock()``/``RLock()``
are the locks themselves and exempt.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analysis.base import Context, Finding, SourceFile

CHECK = "locks"


@dataclass
class _Access:
    attr: str
    line: int
    store: bool           # rebound or stored-through (mutation)
    held: frozenset[str]  # locks held via enclosing `with self.<lock>:`
    in_init: bool


@dataclass
class _Call:
    method: str
    line: int
    held: frozenset[str]


@dataclass
class _Method:
    name: str
    line: int
    worker: bool
    requires: str | None
    accesses: list[_Access] = field(default_factory=list)
    calls: list[_Call] = field(default_factory=list)


def _self_attr_chain(node: ast.expr) -> str | None:
    """For ``self.X[...].Y`` style expressions, the root attribute ``X``
    when the expression is rooted at ``self``; else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        parent = node.value
        if (isinstance(node, ast.Attribute)
                and isinstance(parent, ast.Name) and parent.id == "self"):
            return node.attr
        node = parent
    return None


def _is_lock_ctor(node: ast.expr) -> bool:
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else "")
    return name in ("Lock", "RLock", "Condition")


class _MethodScanner(ast.NodeVisitor):
    """Collect self-attribute accesses and self-method calls in one method
    body, tracking the set of ``with self.<lock>:`` scopes in force.

    Nested ``def``/``lambda`` bodies are scanned with an *empty* held set:
    a closure created under a lock does not run under it."""

    def __init__(self, method: _Method, in_init: bool):
        self.m = method
        self.in_init = in_init
        self.held: tuple[str, ...] = ()

    def _add(self, attr: str, line: int, store: bool) -> None:
        self.m.accesses.append(_Access(
            attr=attr, line=line, store=store,
            held=frozenset(self.held), in_init=self.in_init))

    # -- scope tracking ------------------------------------------------------

    def visit_With(self, node: ast.With) -> None:
        added = []
        for item in node.items:
            attr = _self_attr_chain(item.context_expr)
            if attr is not None:
                self._add(attr, item.context_expr.lineno, store=False)
                added.append(attr)
            else:
                self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        self.held = self.held + tuple(added)
        for stmt in node.body:
            self.visit(stmt)
        self.held = self.held[:len(self.held) - len(added)]

    def _visit_nested(self, node) -> None:
        saved, self.held = self.held, ()
        for stmt in node.body if isinstance(node.body, list) else [node.body]:
            self.visit(stmt)
        self.held = saved

    def visit_FunctionDef(self, node):
        self._visit_nested(node)

    def visit_AsyncFunctionDef(self, node):
        self._visit_nested(node)

    def visit_Lambda(self, node):
        self._visit_nested(node)

    # -- accesses ------------------------------------------------------------

    def _record_target(self, target: ast.expr) -> bool:
        attr = _self_attr_chain(target)
        if attr is not None:
            self._add(attr, target.lineno, store=True)
            # the inner chain (`self.stats` in `self.stats.x = 1`) is also
            # a read; fall through to generic_visit for it
            if isinstance(target, (ast.Attribute, ast.Subscript)):
                self.visit(target.value)
                if isinstance(target, ast.Subscript):
                    self.visit(target.slice)
            return True
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            if not self._record_target(t):
                self.visit(t)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if not self._record_target(node.target):
            self.visit(node.target)
        self.visit(node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if not self._record_target(node.target):
            self.visit(node.target)
        if node.value is not None:
            self.visit(node.value)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            if not self._record_target(t):
                self.visit(t)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if (isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name)
                and fn.value.id == "self"):
            # self.m(...): an intra-class call, not an attribute access —
            # resolved against the method table by the checker
            self.m.calls.append(_Call(method=fn.attr, line=node.lineno,
                                      held=frozenset(self.held)))
        else:
            self.visit(fn)
        for a in node.args:
            self.visit(a)
        for kw in node.keywords:
            self.visit(kw.value)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            self._add(node.attr, node.lineno, store=False)
        else:
            self.visit(node.value)


def _thread_targets(tree: ast.AST) -> dict[str, int]:
    """``threading.Thread(target=self.m)`` → {m: line} within a class."""
    out: dict[str, int] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else "")
        if name != "Thread":
            continue
        for kw in node.keywords:
            if kw.arg == "target":
                attr = _self_attr_chain(kw.value)
                if attr is not None:
                    out.setdefault(attr, node.lineno)
    return out


def _closure(methods: dict[str, _Method], seeds: set[str]) -> set[str]:
    reach, work = set(), [s for s in seeds if s in methods]
    while work:
        name = work.pop()
        if name in reach:
            continue
        reach.add(name)
        for call in methods[name].calls:
            if call.method in methods and call.method not in reach:
                work.append(call.method)
    return reach


def _scan_class(sf: SourceFile, cls: ast.ClassDef) -> list[Finding]:
    methods: dict[str, _Method] = {}
    lock_attrs: set[str] = set()
    guarded: dict[str, str] = {}   # attr -> lock name
    decl_lines: dict[str, int] = {}

    for node in cls.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        m = _Method(name=node.name, line=node.lineno,
                    worker=sf.is_worker(node.lineno),
                    requires=sf.requires_lock(node.lineno))
        scanner = _MethodScanner(m, in_init=(node.name == "__init__"))
        for stmt in node.body:
            scanner.visit(stmt)
        methods[node.name] = m
        # lock attributes + guarded-by declarations live on assignments
        # (plain or annotated)
        for sub in ast.walk(node):
            if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                target, value = sub.targets[0], sub.value
            elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
                target, value = sub.target, sub.value
            else:
                continue
            attr = _self_attr_chain(target)
            if attr is None or not isinstance(target, ast.Attribute):
                continue
            if _is_lock_ctor(value):
                lock_attrs.add(attr)
            lock = sf.guarded_by(sub.lineno)
            if lock is not None:
                guarded[attr] = lock
                decl_lines[attr] = sub.lineno

    inferred = _thread_targets(cls)
    worker_entries = {n for n, m in methods.items() if m.worker}
    worker_entries |= {n for n in inferred if n in methods}
    if not worker_entries:
        return []   # single-threaded class: nothing to check

    W = _closure(methods, worker_entries)
    C = _closure(methods, set(methods) - worker_entries - {"__init__"})

    findings: list[Finding] = []

    def held_ok(access: _Access, m: _Method, lock: str) -> bool:
        return lock in access.held or m.requires == lock

    # 1. guarded accesses must hold the declared lock
    for m in methods.values():
        for a in m.accesses:
            if a.in_init or a.attr not in guarded:
                continue
            lock = guarded[a.attr]
            if not held_ok(a, m, lock):
                kind = "write to" if a.store else "read of"
                findings.append(Finding(
                    sf.rel, a.line, CHECK,
                    f"{kind} {cls.name}.{a.attr} (guarded-by {lock}) "
                    f"outside 'with self.{lock}' in {m.name}()"))

    # 2. requires-lock methods may only be called with the lock held
    for m in methods.values():
        for call in m.calls:
            callee = methods.get(call.method)
            if callee is None or callee.requires is None:
                continue
            lock = callee.requires
            if lock not in call.held and m.requires != lock \
                    and m.name != "__init__":
                findings.append(Finding(
                    sf.rel, call.line, CHECK,
                    f"call to {cls.name}.{call.method}() (requires-lock "
                    f"{lock}) without holding self.{lock} in {m.name}()"))

    # 3. contended attributes must be declared guarded
    side: dict[str, dict[str, int]] = {}   # attr -> {"W": line, "C": line}
    mutated: set[str] = set()
    for name, m in methods.items():
        for a in m.accesses:
            if a.in_init or a.attr in lock_attrs:
                continue
            if a.store:
                mutated.add(a.attr)
            entry = side.setdefault(a.attr, {})
            if name in W:
                entry.setdefault("W", a.line)
            if name in C:
                entry.setdefault("C", a.line)
    for attr in sorted(side):
        entry = side[attr]
        if "W" in entry and "C" in entry and attr in mutated \
                and attr not in guarded:
            findings.append(Finding(
                sf.rel, entry["W"], CHECK,
                f"{cls.name}.{attr} is reachable from a worker thread and "
                f"the caller thread and is mutated outside __init__, but "
                f"carries no '# guarded-by: <lock>' declaration"))
    return findings


def run(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for sf in ctx.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(_scan_class(sf, node))
    return findings
