"""Markers pass: every pytest marker a test uses must be declared.

The tiered suite routes on markers (slow / shard / writer / ... ,
registered in ``tests/conftest.py``), and pytest only *warns* on an
unknown marker — so a typo'd or undeclared marker silently drops a
module out of every ``-m`` tier and the mistake rots. This pass walks
every ``tests/*.py`` module's AST for ``pytest.mark.<name>`` uses
(decorators, ``pytestmark`` assignments, ``pytest.param`` marks alike)
and compares them against the markers declared via
``config.addinivalue_line("markers", ...)``, plus pytest's built-ins.

This began life as ``scripts/check_markers.py``; that script is now a
thin re-exporting wrapper, and ``declared_markers`` / ``used_markers`` /
``find_offenders`` / ``main`` keep their original signatures for it and
for ``tests/test_markers.py``.
"""
from __future__ import annotations

import ast
import pathlib
import sys

from repro.analysis.base import Context, Finding

CHECK = "markers"

# Markers pytest itself defines; always allowed.
BUILTIN_MARKERS = {
    "parametrize", "skip", "skipif", "xfail", "usefixtures",
    "filterwarnings", "tryfirst", "trylast",
}


def declared_markers(conftest_path: pathlib.Path) -> set[str]:
    """Markers registered via ``config.addinivalue_line("markers", "<name>:
    <description>")`` in a conftest, extracted from its AST."""
    tree = ast.parse(conftest_path.read_text())
    out: set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "addinivalue_line"
                and len(node.args) == 2
                and isinstance(node.args[0], ast.Constant)
                and node.args[0].value == "markers"
                and isinstance(node.args[1], ast.Constant)):
            decl = str(node.args[1].value)
            out.add(decl.split(":", 1)[0].strip().split("(", 1)[0].strip())
    return out


def used_marker_lines(test_path: pathlib.Path) -> dict[str, int]:
    """Every ``pytest.mark.<name>`` chain in a module's AST, with the
    first line it appears on."""
    tree = ast.parse(test_path.read_text())
    out: dict[str, int] = {}
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Attribute)
                and node.value.attr == "mark"
                and isinstance(node.value.value, ast.Name)
                and node.value.value.id == "pytest"):
            prev = out.get(node.attr)
            out[node.attr] = node.lineno if prev is None \
                else min(prev, node.lineno)
    return out


def used_markers(test_path: pathlib.Path) -> set[str]:
    """Every ``pytest.mark.<name>`` attribute chain in a module's AST."""
    return set(used_marker_lines(test_path))


def find_offenders(tests_dir: pathlib.Path) -> list[tuple[str, str]]:
    """(file, marker) pairs for every undeclared, non-builtin marker use."""
    allowed = BUILTIN_MARKERS | declared_markers(tests_dir / "conftest.py")
    offenders = []
    for path in sorted(tests_dir.glob("*.py")):
        for marker in sorted(used_markers(path) - allowed):
            offenders.append((path.name, marker))
    return offenders


def main(argv: list[str] | None = None) -> int:
    args = sys.argv[1:] if argv is None else argv
    tests_dir = pathlib.Path(args[0]) if args else _default_tests_dir()
    offenders = find_offenders(tests_dir)
    for name, marker in offenders:
        print(f"{name}: marker {marker!r} is not declared in conftest.py "
              f"(register it in pytest_configure or fix the typo)")
    if offenders:
        return 1
    print(f"ok: every marker under {tests_dir} is declared")
    return 0


def _default_tests_dir() -> pathlib.Path:
    # src/repro/analysis/markers.py -> repo root -> tests/
    return pathlib.Path(__file__).resolve().parents[3] / "tests"


def run(ctx: Context) -> list[Finding]:
    tests_dir = ctx.repo_root / "tests"
    if not (tests_dir / "conftest.py").exists():
        return []
    allowed = BUILTIN_MARKERS | declared_markers(tests_dir / "conftest.py")
    findings = []
    for path in sorted(tests_dir.glob("*.py")):
        lines = used_marker_lines(path)
        for marker in sorted(set(lines) - allowed):
            findings.append(Finding(
                str(path.relative_to(ctx.repo_root)), lines[marker], CHECK,
                f"marker {marker!r} is not declared in tests/conftest.py — "
                f"pytest only warns, so the module silently drops out of "
                f"every -m tier"))
    return findings
