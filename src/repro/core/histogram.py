"""Complete height-balanced (equi-depth) histogram (§4.1).

PostgreSQL maintains an equi-depth histogram per attribute; Hippo retrieves it
and keeps it on disk (§7.1). Here we build it explicitly from a sample of the
indexed attribute and keep the bucket *boundaries* as a device array.

Bucket convention: ``H`` buckets with boundaries ``bounds`` of shape (H+1,).
Bucket ``i`` covers the half-open interval [bounds[i], bounds[i+1]) except the
last bucket, which is closed on the right. ``bucketize`` maps values to bucket
ids in [0, H-1]; out-of-range values clamp to the edge buckets (a new tuple
beyond the observed range still hits the edge bucket, matching the paper's
assumption that the complete histogram is never rebuilt on local updates, §4.1).

Drift adaptation (beyond paper): the clamp rule means that under sustained
distribution drift every new tuple lands in an edge bucket, page summaries
converge toward that one bucket, and partition pruning degrades toward full
scans. ``DriftTracker`` watches an insert stream against a fixed boundary set
(per-bucket hit counters, edge-bucket overflow ratio, reservoir sample of the
inserts themselves) so a maintenance layer can decide *when* the bucket space
has drifted too far; ``rebuild`` then produces a fresh equi-depth boundary set
from the old histogram's own boundary summary blended with the reservoir —
no table re-read. ``runtime.writer.MaintenanceWriter`` drives the lifecycle.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Histogram:
    """Equi-depth complete histogram: H buckets, boundaries (H+1,) float32."""

    bounds: jnp.ndarray

    @property
    def resolution(self) -> int:  # H, the paper's histogram resolution
        return self.bounds.shape[0] - 1

    def tree_flatten(self):
        return (self.bounds,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def build(sample: jnp.ndarray, resolution: int) -> Histogram:
    """Build an equi-depth histogram with ``resolution`` buckets from a sample.

    Boundaries are the (i/H)-quantiles of the sample. Duplicate boundaries are
    nudged apart so every bucket is non-degenerate (ties happen on low-
    cardinality integer attributes).
    """
    sample = jnp.asarray(sample, jnp.float32).ravel()
    qs = jnp.linspace(0.0, 1.0, resolution + 1)
    bounds = jnp.quantile(sample, qs)
    # Enforce strict monotonicity: cumulative-max then epsilon-separate ties.
    bounds = jax.lax.cummax(bounds)
    span = jnp.maximum(bounds[-1] - bounds[0], 1.0)
    eps = span * 1e-6
    steps = jnp.arange(resolution + 1, dtype=jnp.float32) * eps
    return Histogram(bounds=(bounds + steps).astype(jnp.float32))


def build_uniform(lo: float, hi: float, resolution: int) -> Histogram:
    """Histogram for a known-uniform attribute (TPC-H partkey is uniform)."""
    return Histogram(bounds=jnp.linspace(lo, hi, resolution + 1, dtype=jnp.float32))


@partial(jax.jit, static_argnames=())
def bucketize(hist: Histogram, values: jnp.ndarray) -> jnp.ndarray:
    """Map values to bucket ids in [0, H-1] (binary search, §4.2).

    ``jnp.searchsorted`` on the boundary array is the vectorized form of the
    paper's per-tuple binary search. The Pallas kernel
    ``repro.kernels.bucketize`` provides the tiled TPU version; this is the
    canonical jnp path (also its oracle).
    """
    h = hist.resolution
    ids = jnp.searchsorted(hist.bounds, values.astype(jnp.float32), side="right") - 1
    return jnp.clip(ids, 0, h - 1).astype(jnp.int32)


def hit_bucket_range(hist: Histogram, lo, hi) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bucket-id interval [b_lo, b_hi] hit by a range predicate [lo, hi].

    A bucket is *hit* if the predicate fully contains, overlaps, or is fully
    contained by the bucket (§3.1). For an interval predicate against sorted
    boundaries this is exactly the buckets of the two endpoints.
    """
    b_lo = bucketize(hist, jnp.asarray(lo, jnp.float32)[None])[0]
    b_hi = bucketize(hist, jnp.asarray(hi, jnp.float32)[None])[0]
    return b_lo, b_hi


def host_bounds(hist: Histogram) -> np.ndarray:
    return np.asarray(hist.bounds)


# ---------------------------------------------------------------------------
# Drift telemetry + incremental boundary rebuild (beyond paper; Lan et al.
# 2023 / FITing-Tree motivate the monitored re-summarization lifecycle)
# ---------------------------------------------------------------------------

class DriftTracker:
    """Insert-stream drift telemetry against a fixed boundary set.

    Host-side and O(log H) per observed value: each insert is bucketized
    against the armed bounds (per-bucket hit counters), counted as
    out-of-range if it falls outside [bounds[0], bounds[-1]), and offered to
    a fixed-size reservoir (algorithm R) so ``rebuild`` later sees an
    unbiased sample of the whole stream since the last ``rearm``.

    ``edge_overflow_ratio`` is the drift signal: the fraction of observed
    inserts that clamped into the two edge buckets. Under an in-distribution
    stream the expectation is ~2/H; a drifting stream pushes it toward 1.0.
    """

    def __init__(self, hist: Histogram, reservoir_size: int = 4096,
                 seed: int = 0):
        self._reservoir_size = reservoir_size
        self._seed = seed
        self.rearm(hist)

    def rearm(self, hist: Histogram) -> None:
        """Reset every counter and the reservoir against new bounds (called
        after a re-summarization completes: drift is measured relative to
        the bounds actually serving)."""
        self._bounds = host_bounds(hist)
        self.resolution = self._bounds.shape[0] - 1
        self.hits = np.zeros((self.resolution,), np.int64)
        self.observed = 0
        self.out_of_range = 0
        self.reservoir = np.empty((self._reservoir_size,), np.float32)
        self._res_fill = 0
        self._rng = np.random.default_rng(self._seed)

    def observe(self, values) -> None:
        """Fold a batch (or scalar) of inserted values into the telemetry."""
        vals = np.asarray(values, np.float32).ravel()
        if vals.size == 0:
            return
        ids = np.clip(np.searchsorted(self._bounds, vals, side="right") - 1,
                      0, self.resolution - 1)
        np.add.at(self.hits, ids, 1)
        self.out_of_range += int(((vals < self._bounds[0])
                                  | (vals >= self._bounds[-1])).sum())
        for v in vals:
            self.observed += 1
            if self._res_fill < self.reservoir.size:
                self.reservoir[self._res_fill] = v
                self._res_fill += 1
            else:
                j = int(self._rng.integers(0, self.observed))
                if j < self.reservoir.size:
                    self.reservoir[j] = v

    @property
    def armed_histogram(self) -> Histogram:
        """The boundary set drift is currently measured against."""
        return Histogram(jnp.asarray(self._bounds))

    @property
    def edge_overflow_ratio(self) -> float:
        """Fraction of observed inserts that landed in an edge bucket (the
        clamp targets); 0.0 before anything is observed."""
        if not self.observed:
            return 0.0
        return float(self.hits[0] + self.hits[-1]) / self.observed

    def sample(self) -> np.ndarray:
        """Copy of the reservoir's filled prefix (<= reservoir_size values)."""
        return self.reservoir[: self._res_fill].copy()


def rebuild(hist: Histogram, sample: np.ndarray, resolution: int | None = None,
            *, old_count: int | None = None, new_count: int | None = None
            ) -> Histogram:
    """New equi-depth boundary set after drift, without re-reading the table.

    The old bounds are themselves an equi-depth summary of the pre-drift
    distribution — each of the H+1 boundary points stands for
    ``old_count / (H+1)`` tuples' worth of mass — so a weighted quantile over
    {old boundary points, reservoir sample points} approximates the
    equi-depth histogram of (old table + recent inserts). ``old_count`` /
    ``new_count`` weight the two point sets (defaults: equal mass). The
    result gets the same strict-monotonicity treatment as ``build``.
    """
    sample = np.sort(np.asarray(sample, np.float32).ravel())
    if sample.size == 0:
        raise ValueError("rebuild needs a non-empty sample of recent inserts")
    if resolution is None:
        resolution = hist.resolution
    old_pts = host_bounds(hist).astype(np.float64)
    old_count = sample.size if old_count is None else max(int(old_count), 0)
    new_count = sample.size if new_count is None else max(int(new_count), 0)
    if old_count + new_count == 0:
        old_count = new_count = 1
    pts = np.concatenate([old_pts, sample.astype(np.float64)])
    wts = np.concatenate([
        np.full(old_pts.size, old_count / old_pts.size),
        np.full(sample.size, new_count / sample.size)])
    order = np.argsort(pts, kind="stable")
    pts, wts = pts[order], wts[order]
    cum = np.cumsum(wts)
    cum /= cum[-1]
    qs = np.linspace(0.0, 1.0, resolution + 1)
    bounds = np.interp(qs, cum, pts)
    bounds[0] = pts[0]          # edges cover the full blended range
    bounds[-1] = pts[-1]
    bounds = np.maximum.accumulate(bounds)
    span = max(float(bounds[-1] - bounds[0]), 1.0)
    bounds = bounds + np.arange(resolution + 1, dtype=np.float64) * (span * 1e-6)
    # Strictness must survive the float32 cast: for large-magnitude, narrow-
    # span keys the epsilon above collapses below the float32 ulp, and a
    # remap drain would refuse tied bounds forever. Separate residual ties
    # by whole float32 ulps (H is a few hundred: the host loop is free).
    b32 = bounds.astype(np.float32)
    for i in range(1, b32.size):
        if b32[i] <= b32[i - 1]:
            b32[i] = np.nextafter(b32[i - 1], np.float32(np.inf))
    return Histogram(bounds=jnp.asarray(b32))
