"""Complete height-balanced (equi-depth) histogram (§4.1).

PostgreSQL maintains an equi-depth histogram per attribute; Hippo retrieves it
and keeps it on disk (§7.1). Here we build it explicitly from a sample of the
indexed attribute and keep the bucket *boundaries* as a device array.

Bucket convention: ``H`` buckets with boundaries ``bounds`` of shape (H+1,).
Bucket ``i`` covers the half-open interval [bounds[i], bounds[i+1]) except the
last bucket, which is closed on the right. ``bucketize`` maps values to bucket
ids in [0, H-1]; out-of-range values clamp to the edge buckets (a new tuple
beyond the observed range still hits the edge bucket, matching the paper's
assumption that the complete histogram is never rebuilt on local updates, §4.1).

Drift adaptation (beyond paper): the clamp rule means that under sustained
distribution drift every new tuple lands in an edge bucket, page summaries
converge toward that one bucket, and partition pruning degrades toward full
scans. ``DriftTracker`` watches an insert stream against a fixed boundary set
(per-bucket hit counters, edge-bucket overflow ratio, reservoir sample of the
inserts themselves) so a maintenance layer can decide *when* the bucket space
has drifted too far; ``rebuild`` then produces a fresh equi-depth boundary set
from the old histogram's own boundary summary blended with the reservoir —
no table re-read. ``runtime.writer.MaintenanceWriter`` drives the lifecycle.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Histogram:
    """Equi-depth complete histogram: H buckets, boundaries (H+1,) float32."""

    bounds: jnp.ndarray

    @property
    def resolution(self) -> int:  # H, the paper's histogram resolution
        return self.bounds.shape[0] - 1

    def tree_flatten(self):
        return (self.bounds,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def build(sample: jnp.ndarray, resolution: int) -> Histogram:
    """Build an equi-depth histogram with ``resolution`` buckets from a sample.

    Boundaries are the (i/H)-quantiles of the sample, finalized by
    ``strict_float32_bounds`` so ties are nudged apart and every bucket is
    non-degenerate even where the epsilon ladder collapses below the float32
    ulp (large-magnitude keys, low-cardinality integer attributes) — the
    same finalizer every other boundary producer (``rebuild``, the learned
    materialization) runs through.
    """
    sample = jnp.asarray(sample, jnp.float32).ravel()
    qs = jnp.linspace(0.0, 1.0, resolution + 1)
    bounds = np.asarray(jnp.quantile(sample, qs), np.float64)
    return Histogram(bounds=jnp.asarray(strict_float32_bounds(bounds)))


def build_uniform(lo: float, hi: float, resolution: int) -> Histogram:
    """Histogram for a known-uniform attribute (TPC-H partkey is uniform)."""
    return Histogram(bounds=jnp.linspace(lo, hi, resolution + 1, dtype=jnp.float32))


@partial(jax.jit, static_argnames=())
def bucketize(hist: Histogram, values: jnp.ndarray) -> jnp.ndarray:
    """Map values to bucket ids in [0, H-1] (binary search, §4.2).

    ``jnp.searchsorted`` on the boundary array is the vectorized form of the
    paper's per-tuple binary search. The Pallas kernel
    ``repro.kernels.bucketize`` provides the tiled TPU version; this is the
    canonical jnp path (also its oracle).
    """
    h = hist.resolution
    ids = jnp.searchsorted(hist.bounds, values.astype(jnp.float32), side="right") - 1
    return jnp.clip(ids, 0, h - 1).astype(jnp.int32)


def hit_bucket_range(hist: Histogram, lo, hi) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bucket-id interval [b_lo, b_hi] hit by a range predicate [lo, hi].

    A bucket is *hit* if the predicate fully contains, overlaps, or is fully
    contained by the bucket (§3.1). For an interval predicate against sorted
    boundaries this is exactly the buckets of the two endpoints.

    A predicate entirely outside the summary domain (``hi < bounds[0]`` or
    ``lo > bounds[-1]``), or an empty one (``lo > hi``), returns the empty
    bucket range ``(1, 0)`` (b_lo > b_hi) instead of clamping both endpoints
    into an edge bucket: clamping would spuriously select every page
    summarized under that edge bucket for a query that provably matches no
    in-domain tuple. (The hot-path conversion ``predicate.interval_bitmaps``
    deliberately keeps the clamp — drifted tuples clamp into edge buckets at
    insert time, §4.1, so out-of-domain *selection* must still reach them;
    this helper reports the histogram-domain hit range only.)
    """
    lo_f = jnp.asarray(lo, jnp.float32)
    hi_f = jnp.asarray(hi, jnp.float32)
    b_lo = bucketize(hist, lo_f[None])[0]
    b_hi = bucketize(hist, hi_f[None])[0]
    outside = (hi_f < hist.bounds[0]) | (lo_f > hist.bounds[-1]) | (lo_f > hi_f)
    return (jnp.where(outside, jnp.int32(1), b_lo),
            jnp.where(outside, jnp.int32(0), b_hi))


def host_bounds(hist: Histogram) -> np.ndarray:
    return np.asarray(hist.bounds)


# ---------------------------------------------------------------------------
# Drift telemetry + incremental boundary rebuild (beyond paper; Lan et al.
# 2023 / FITing-Tree motivate the monitored re-summarization lifecycle)
# ---------------------------------------------------------------------------

class DriftTracker:
    """Insert-stream drift telemetry against a fixed boundary set.

    Host-side and O(log H) per observed value: each insert is bucketized
    against the armed bounds (per-bucket hit counters), counted as
    out-of-range if it falls outside [bounds[0], bounds[-1]), and offered to
    a fixed-size reservoir (algorithm R) so ``rebuild`` later sees an
    unbiased sample of the whole stream since the last ``rearm``.

    ``edge_overflow_ratio`` is the drift signal: the fraction of observed
    inserts that clamped into the two edge buckets. Under an in-distribution
    stream the expectation is ~2/H; a drifting stream pushes it toward 1.0.
    """

    def __init__(self, hist: Histogram, reservoir_size: int = 4096,
                 seed: int = 0):
        self._reservoir_size = reservoir_size
        self._seed = seed
        self.rearm(hist)

    def rearm(self, hist: Histogram) -> None:
        """Reset every counter and the reservoir against new bounds (called
        after a re-summarization completes: drift is measured relative to
        the bounds actually serving)."""
        self._bounds = host_bounds(hist)
        self.resolution = self._bounds.shape[0] - 1
        self.hits = np.zeros((self.resolution,), np.int64)
        self.observed = 0
        self.out_of_range = 0
        self.reservoir = np.empty((self._reservoir_size,), np.float32)
        self._res_fill = 0
        self._rng = np.random.default_rng(self._seed)

    def observe(self, values) -> None:
        """Fold a batch (or scalar) of inserted values into the telemetry.

        Fully vectorized — one ``searchsorted`` for the counters and one
        batched algorithm-R admission for the reservoir — so a per-row
        insert stream can coalesce its observations into array calls
        instead of paying a Python loop iteration per value. The batched
        admission draws each value's slot against its own running count
        (identical per-value admission probability to the scalar loop) and
        applies them with a single fancy assignment, whose last-wins
        overwrite order matches sequential application.
        """
        vals = np.asarray(values, np.float32).ravel()
        if vals.size == 0:
            return
        ids = np.clip(np.searchsorted(self._bounds, vals, side="right") - 1,
                      0, self.resolution - 1)
        np.add.at(self.hits, ids, 1)
        self.out_of_range += int(((vals < self._bounds[0])
                                  | (vals >= self._bounds[-1])).sum())
        start = self.observed
        self.observed += vals.size
        # fill the reservoir's empty prefix directly ...
        take = min(self.reservoir.size - self._res_fill, vals.size)
        if take > 0:
            self.reservoir[self._res_fill: self._res_fill + take] = vals[:take]
            self._res_fill += take
        rest = vals[take:]
        if rest.size == 0:
            return
        # ... then admit the overflow: value k (1-based running count c_k)
        # replaces a uniform slot j ~ [0, c_k) when j lands in the reservoir
        counts = start + take + 1 + np.arange(rest.size, dtype=np.int64)
        j = self._rng.integers(0, counts)
        admit = j < self.reservoir.size
        self.reservoir[j[admit]] = rest[admit]

    @property
    def armed_histogram(self) -> Histogram:
        """The boundary set drift is currently measured against."""
        return Histogram(jnp.asarray(self._bounds))

    @property
    def edge_overflow_ratio(self) -> float:
        """Fraction of observed inserts that landed in an edge bucket (the
        clamp targets); 0.0 before anything is observed."""
        if not self.observed:
            return 0.0
        return float(self.hits[0] + self.hits[-1]) / self.observed

    def sample(self) -> np.ndarray:
        """Copy of the reservoir's filled prefix (<= reservoir_size values)."""
        return self.reservoir[: self._res_fill].copy()


def strict_float32_bounds(bounds: np.ndarray) -> np.ndarray:
    """Finalize a nondecreasing boundary array into strictly increasing
    float32 bounds (the invariant every summary swap validates).

    Cumulative-max first (tolerating small non-monotone wobbles from
    interpolation), then an epsilon ladder proportional to the span, then —
    because for large-magnitude, narrow-span keys that epsilon collapses
    below the float32 ulp and a remap drain would refuse tied bounds
    forever — residual ties are separated by whole float32 ulps (H is a few
    hundred: the host loop is free). Shared by ``rebuild`` and the learned
    boundary materialization (``core.learned.boundaries``) so every policy
    that can feed ``writer._drain_resummarize`` produces bounds that pass
    its strictness check.
    """
    b = np.maximum.accumulate(np.asarray(bounds, np.float64).ravel())
    span = max(float(b[-1] - b[0]), 1.0)
    b = b + np.arange(b.size, dtype=np.float64) * (span * 1e-6)
    b32 = b.astype(np.float32)
    for i in range(1, b32.size):
        if b32[i] <= b32[i - 1]:
            b32[i] = np.nextafter(b32[i - 1], np.float32(np.inf))
    return b32


def rebuild(hist: Histogram, sample: np.ndarray, resolution: int | None = None,
            *, old_count: int | None = None, new_count: int | None = None
            ) -> Histogram:
    """New equi-depth boundary set after drift, without re-reading the table.

    The old bounds are themselves an equi-depth summary of the pre-drift
    distribution — each of the H+1 boundary points stands for
    ``old_count / (H+1)`` tuples' worth of mass — so a weighted quantile over
    {old boundary points, reservoir sample points} approximates the
    equi-depth histogram of (old table + recent inserts). ``old_count`` /
    ``new_count`` weight the two point sets (defaults: equal mass). The
    result gets the same strict-monotonicity treatment as ``build``.
    """
    sample = np.sort(np.asarray(sample, np.float32).ravel())
    if sample.size == 0:
        raise ValueError("rebuild needs a non-empty sample of recent inserts")
    if resolution is None:
        resolution = hist.resolution
    old_pts = host_bounds(hist).astype(np.float64)
    old_count = sample.size if old_count is None else max(int(old_count), 0)
    new_count = sample.size if new_count is None else max(int(new_count), 0)
    if old_count + new_count == 0:
        old_count = new_count = 1
    pts = np.concatenate([old_pts, sample.astype(np.float64)])
    wts = np.concatenate([
        np.full(old_pts.size, old_count / old_pts.size),
        np.full(sample.size, new_count / sample.size)])
    order = np.argsort(pts, kind="stable")
    pts, wts = pts[order], wts[order]
    cum = np.cumsum(wts)
    cum /= cum[-1]
    qs = np.linspace(0.0, 1.0, resolution + 1)
    bounds = np.interp(qs, cum, pts)
    bounds[0] = pts[0]          # edges cover the full blended range
    bounds[-1] = pts[-1]
    return Histogram(bounds=jnp.asarray(strict_float32_bounds(bounds)))
