"""Complete height-balanced (equi-depth) histogram (§4.1).

PostgreSQL maintains an equi-depth histogram per attribute; Hippo retrieves it
and keeps it on disk (§7.1). Here we build it explicitly from a sample of the
indexed attribute and keep the bucket *boundaries* as a device array.

Bucket convention: ``H`` buckets with boundaries ``bounds`` of shape (H+1,).
Bucket ``i`` covers the half-open interval [bounds[i], bounds[i+1]) except the
last bucket, which is closed on the right. ``bucketize`` maps values to bucket
ids in [0, H-1]; out-of-range values clamp to the edge buckets (a new tuple
beyond the observed range still hits the edge bucket, matching the paper's
assumption that the complete histogram is never rebuilt on local updates, §4.1).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Histogram:
    """Equi-depth complete histogram: H buckets, boundaries (H+1,) float32."""

    bounds: jnp.ndarray

    @property
    def resolution(self) -> int:  # H, the paper's histogram resolution
        return self.bounds.shape[0] - 1

    def tree_flatten(self):
        return (self.bounds,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def build(sample: jnp.ndarray, resolution: int) -> Histogram:
    """Build an equi-depth histogram with ``resolution`` buckets from a sample.

    Boundaries are the (i/H)-quantiles of the sample. Duplicate boundaries are
    nudged apart so every bucket is non-degenerate (ties happen on low-
    cardinality integer attributes).
    """
    sample = jnp.asarray(sample, jnp.float32).ravel()
    qs = jnp.linspace(0.0, 1.0, resolution + 1)
    bounds = jnp.quantile(sample, qs)
    # Enforce strict monotonicity: cumulative-max then epsilon-separate ties.
    bounds = jax.lax.cummax(bounds)
    span = jnp.maximum(bounds[-1] - bounds[0], 1.0)
    eps = span * 1e-6
    steps = jnp.arange(resolution + 1, dtype=jnp.float32) * eps
    return Histogram(bounds=(bounds + steps).astype(jnp.float32))


def build_uniform(lo: float, hi: float, resolution: int) -> Histogram:
    """Histogram for a known-uniform attribute (TPC-H partkey is uniform)."""
    return Histogram(bounds=jnp.linspace(lo, hi, resolution + 1, dtype=jnp.float32))


@partial(jax.jit, static_argnames=())
def bucketize(hist: Histogram, values: jnp.ndarray) -> jnp.ndarray:
    """Map values to bucket ids in [0, H-1] (binary search, §4.2).

    ``jnp.searchsorted`` on the boundary array is the vectorized form of the
    paper's per-tuple binary search. The Pallas kernel
    ``repro.kernels.bucketize`` provides the tiled TPU version; this is the
    canonical jnp path (also its oracle).
    """
    h = hist.resolution
    ids = jnp.searchsorted(hist.bounds, values.astype(jnp.float32), side="right") - 1
    return jnp.clip(ids, 0, h - 1).astype(jnp.int32)


def hit_bucket_range(hist: Histogram, lo, hi) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bucket-id interval [b_lo, b_hi] hit by a range predicate [lo, hi].

    A bucket is *hit* if the predicate fully contains, overlaps, or is fully
    contained by the bucket (§3.1). For an interval predicate against sorted
    boundaries this is exactly the buckets of the two endpoints.
    """
    b_lo = bucketize(hist, jnp.asarray(lo, jnp.float32)[None])[0]
    b_hi = bucketize(hist, jnp.asarray(hi, jnp.float32)[None])[0]
    return b_lo, b_hi


def host_bounds(hist: Histogram) -> np.ndarray:
    return np.asarray(hist.bounds)
