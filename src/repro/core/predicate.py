"""Query predicates and their conversion to bucket bitmaps (§3.1).

Unit predicates are equality (``attr = v``) and range (``lo <= attr <= hi``);
conjunctions AND their bucket bitmaps — only buckets hit by *all* units are
kept (Fig. 2). Every predicate reduces to a closed interval [lo, hi] over the
attribute, so the converted bitmap is a contiguous run of set bits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core.histogram import Histogram, bucketize

_INF = float("inf")


@dataclass(frozen=True)
class Predicate:
    """Closed-interval predicate over the indexed attribute.

    equality(v)    -> lo = hi = v
    greater(v)     -> lo = nextafter(v), hi = +inf   (strict >)
    conjunctions   -> intersection of intervals
    """

    lo: float = -_INF
    hi: float = _INF

    @staticmethod
    def equality(v: float) -> "Predicate":
        return Predicate(lo=float(v), hi=float(v))

    @staticmethod
    def between(lo: float, hi: float) -> "Predicate":
        return Predicate(lo=float(lo), hi=float(hi))

    @staticmethod
    def greater(v: float) -> "Predicate":
        return Predicate(lo=float(np.nextafter(np.float32(v), np.float32(_INF))), hi=_INF)

    @staticmethod
    def less(v: float) -> "Predicate":
        return Predicate(lo=-_INF, hi=float(np.nextafter(np.float32(v), np.float32(-_INF))))

    def and_(self, other: "Predicate") -> "Predicate":
        return Predicate(lo=max(self.lo, other.lo), hi=min(self.hi, other.hi))

    @property
    def empty(self) -> bool:
        return self.lo > self.hi

    def selectivity_interval(self) -> tuple[float, float]:
        return (self.lo, self.hi)


_F32_MAX = 3.4e38   # finite clamp for ±inf predicate endpoints


def _finite_bounds(preds: Sequence[Predicate]) -> tuple[np.ndarray, np.ndarray]:
    """Predicate intervals as finite float32 host arrays (one clamp rule for
    every conversion and inspection path)."""
    los = np.asarray([max(p.lo, -_F32_MAX) for p in preds], np.float32)
    his = np.asarray([min(p.hi, _F32_MAX) for p in preds], np.float32)
    return los, his


def to_bucket_bitmap(pred: Predicate, hist: Histogram) -> jnp.ndarray:
    """Convert a predicate to the packed bitmap of hit buckets (§3.1, Fig. 2).

    Returns a (W,) uint32 packed bitmap; at least one bucket is always hit for
    a non-empty predicate (SF*H >= 1 in the paper's cost model, §6.1).
    """
    return to_bucket_bitmaps([pred], hist)[0]


def intervals(preds: Sequence[Predicate]) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(los, his) float32 device arrays for a batch of predicates.

    Infinities are clamped to the float32 range so the inspection compares
    stay finite; an empty predicate keeps lo > hi and matches nothing.
    """
    los, his = _finite_bounds(preds)
    return jnp.asarray(los), jnp.asarray(his)


@jax.jit
def interval_bitmaps(bounds: jnp.ndarray, los: jnp.ndarray, his: jnp.ndarray,
                     nonempty: jnp.ndarray) -> jnp.ndarray:
    """Fused device half of the §3.1 conversion: intervals -> (Q, W) bitmaps.

    bounds: (H+1,) histogram boundaries (H is static from the shape); los/
    his: (Q,) finite interval endpoints; nonempty: (Q,) bool (False rows
    produce all-zero bitmaps). One jit dispatch replaces the dozen eager ops
    the conversion used to cost per batch — on the serving path this was
    ~40% of a compact batch's wall time on CPU. The endpoint bucketing is
    ``histogram.bucketize``'s searchsorted inlined so the whole conversion
    fuses.
    """
    h = bounds.shape[-1] - 1
    b_lo = jnp.clip(jnp.searchsorted(bounds, los, side="right") - 1, 0, h - 1)
    b_hi = jnp.clip(jnp.searchsorted(bounds, his, side="right") - 1, 0, h - 1)
    idx = jnp.arange(bm.num_words(h) * bm.WORD_BITS, dtype=jnp.int32)
    bits = ((idx[None, :] >= b_lo[:, None]) & (idx[None, :] <= b_hi[:, None])
            & (idx[None, :] < h) & nonempty[:, None])
    return bm.from_bool(bits)


@jax.jit
def interval_bitmaps_sharded(bounds: jnp.ndarray, los: jnp.ndarray,
                             his: jnp.ndarray, nonempty: jnp.ndarray
                             ) -> jnp.ndarray:
    """``interval_bitmaps`` per shard: (S, H+1) stacked bounds -> (S, Q, W).

    Row s converts the batch under shard s's boundary set, so the fused
    sharded search paths stay exact while shards serve different bounds
    epochs mid-drift-resummarization (``core.partition``) — and the steady
    state pays the same single dispatch, not one per shard.
    """
    return jax.vmap(interval_bitmaps, in_axes=(0, None, None, None))(
        bounds, los, his, nonempty)


def _nonempty(preds: Sequence[Predicate]) -> np.ndarray:
    return np.asarray([not p.empty for p in preds])


def to_bucket_bitmaps(preds: Sequence[Predicate], hist: Histogram) -> jnp.ndarray:
    """Batched §3.1 conversion: Q predicates -> (Q, W) packed query bitmaps.

    One fused dispatch (``interval_bitmaps``) converts all Q predicates;
    empty predicates produce all-zero rows. The scalar ``to_bucket_bitmap``
    is this with Q=1, so the paths agree by construction.
    """
    h = hist.resolution
    if not preds:
        return bm.zeros(h, 0)
    los, his = _finite_bounds(preds)
    return interval_bitmaps(hist.bounds, jnp.asarray(los), jnp.asarray(his),
                            jnp.asarray(_nonempty(preds)))


def matches(pred: Predicate, values: jnp.ndarray) -> jnp.ndarray:
    """Exact tuple-level predicate evaluation (used by page inspection)."""
    v = values.astype(jnp.float32)
    return (v >= pred.lo) & (v <= pred.hi)
