"""Query predicates and their conversion to bucket bitmaps (§3.1).

Unit predicates are equality (``attr = v``) and range (``lo <= attr <= hi``);
conjunctions AND their bucket bitmaps — only buckets hit by *all* units are
kept (Fig. 2). Every predicate reduces to a closed interval [lo, hi] over the
attribute, so the converted bitmap is a contiguous run of set bits.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core.histogram import Histogram, hit_bucket_range

_INF = float("inf")


@dataclass(frozen=True)
class Predicate:
    """Closed-interval predicate over the indexed attribute.

    equality(v)    -> lo = hi = v
    greater(v)     -> lo = nextafter(v), hi = +inf   (strict >)
    conjunctions   -> intersection of intervals
    """

    lo: float = -_INF
    hi: float = _INF

    @staticmethod
    def equality(v: float) -> "Predicate":
        return Predicate(lo=float(v), hi=float(v))

    @staticmethod
    def between(lo: float, hi: float) -> "Predicate":
        return Predicate(lo=float(lo), hi=float(hi))

    @staticmethod
    def greater(v: float) -> "Predicate":
        return Predicate(lo=float(np.nextafter(np.float32(v), np.float32(_INF))), hi=_INF)

    @staticmethod
    def less(v: float) -> "Predicate":
        return Predicate(lo=-_INF, hi=float(np.nextafter(np.float32(v), np.float32(-_INF))))

    def and_(self, other: "Predicate") -> "Predicate":
        return Predicate(lo=max(self.lo, other.lo), hi=min(self.hi, other.hi))

    @property
    def empty(self) -> bool:
        return self.lo > self.hi

    def selectivity_interval(self) -> tuple[float, float]:
        return (self.lo, self.hi)


def to_bucket_bitmap(pred: Predicate, hist: Histogram) -> jnp.ndarray:
    """Convert a predicate to the packed bitmap of hit buckets (§3.1, Fig. 2).

    Returns a (W,) uint32 packed bitmap; at least one bucket is always hit for
    a non-empty predicate (SF*H >= 1 in the paper's cost model, §6.1).
    """
    h = hist.resolution
    if pred.empty:
        return bm.zeros(h)
    span = hist.bounds[-1] - hist.bounds[0]
    lo = jnp.clip(jnp.float32(max(pred.lo, -3.4e38)), hist.bounds[0] - span, hist.bounds[-1] + span)
    hi = jnp.clip(jnp.float32(min(pred.hi, 3.4e38)), hist.bounds[0] - span, hist.bounds[-1] + span)
    b_lo, b_hi = hit_bucket_range(hist, lo, hi)
    return bm.range_mask(h, b_lo, b_hi)


def matches(pred: Predicate, values: jnp.ndarray) -> jnp.ndarray:
    """Exact tuple-level predicate evaluation (used by page inspection)."""
    v = values.astype(jnp.float32)
    return (v >= pred.lo) & (v <= pred.hi)
