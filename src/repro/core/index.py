"""Hippo index — structure, build, search, and maintenance (§2–§5).

State layout (fixed-shape device arrays, functional updates):

  bitmaps   (S, W) uint32  partial histograms in packed bitmap form (physical slots)
  starts    (S,)   int32   first page summarized by each slot
  ends      (S,)   int32   last page summarized by each slot (inclusive)
  sorted_order (S,) int32  logical (page-ascending) position -> physical slot;
                           this is the paper's *index entries sorted list* (§5.3)
  slot_live (S,)   bool    false for slots abandoned by out-of-place updates
  num_entries      int32   logical entry count
  num_slots        int32   physical slots in use (>= num_entries with relocation)
  summarized_until int32   last page id covered by the index (-1 if empty)

Static config (``HippoConfig``) carries H (resolution), D (density threshold),
page_card, and capacity; it is hashable and passed as a static argument.

Out-of-place updates: the paper relocates an updated entry to the end of the
index when its compressed bitmap no longer fits (§5.1). Fixed-width device
slots always fit, so relocation is **optional** here (``relocate_on_update``);
enabling it exercises the sorted-list indirection exactly as in Fig. 4.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import grouping
from repro.core.histogram import Histogram, bucketize
from repro.core.predicate import Predicate, to_bucket_bitmap

_INT32_MAX = np.iinfo(np.int32).max


@dataclass(frozen=True)
class HippoConfig:
    resolution: int = 400          # H — complete histogram resolution (default, §7)
    density: float = 0.2           # D — partial histogram density threshold (default, §7)
    page_card: int = 50            # tuples per page (paper's running example)
    max_slots: int = 1 << 14       # physical entry capacity
    relocate_on_update: bool = True  # model §5.1 out-of-place updates

    @property
    def words(self) -> int:
        return bm.num_words(self.resolution)


class HippoState(NamedTuple):
    bounds: jnp.ndarray        # (H+1,) f32 — complete histogram boundaries
    bitmaps: jnp.ndarray       # (S, W) u32
    starts: jnp.ndarray        # (S,) i32
    ends: jnp.ndarray          # (S,) i32
    sorted_order: jnp.ndarray  # (S,) i32
    slot_live: jnp.ndarray     # (S,) bool
    num_entries: jnp.ndarray   # i32 scalar
    num_slots: jnp.ndarray     # i32 scalar
    summarized_until: jnp.ndarray  # i32 scalar

    @property
    def histogram(self) -> Histogram:
        return Histogram(self.bounds)


class SearchResult(NamedTuple):
    count: jnp.ndarray            # qualified tuple count
    qualified: jnp.ndarray        # (num_pages, page_card) bool — exact matches
    page_mask: jnp.ndarray        # (num_pages,) bool — possible qualified pages
    pages_inspected: jnp.ndarray  # scalar i32 (the paper's I/O metric)
    entries_matched: jnp.ndarray  # scalar i32


class BatchSearchResult(NamedTuple):
    """Per-query results of ``search_many`` (query axis Q leads).

    ``qualified`` is intentionally omitted: a (Q, P, C) tuple mask is the one
    output whose memory scales with Q×table size; counts and page masks carry
    the paper's metrics and the engine's result payload.
    """
    counts: jnp.ndarray           # (Q,) i32
    page_mask: jnp.ndarray        # (Q, num_pages) bool
    pages_inspected: jnp.ndarray  # (Q,) i32
    entries_matched: jnp.ndarray  # (Q,) i32


class CompactBatchResult(NamedTuple):
    """Per-query results of the batched gather path (``search_compact_many``).

    Work after the bitmap filter is proportional to ``max_selected`` gathered
    pages, not to the table — the paper's "read only possible qualified
    pages" cost model on an accelerator. ``truncated`` is exact per query: it
    fires iff one of *that query's* selected pages fell outside the gathered
    slab, in which case ``counts[q]``/``row_ids[q]`` are lower bounds and the
    caller must fall back to a wider slab or the dense path.
    ``pages_inspected``/``entries_matched`` are computed before the gather,
    so they are exact even for truncated rows.
    """
    counts: jnp.ndarray           # (Q,) i32
    pages_inspected: jnp.ndarray  # (Q,) i32 — possible qualified pages (exact)
    entries_matched: jnp.ndarray  # (Q,) i32
    truncated: jnp.ndarray        # (Q,) bool — slab missed >=1 of q's pages
    bucket_needed: jnp.ndarray    # i32 scalar — slab size that avoids any
    #                               truncation (max per-shard union of the
    #                               batch's page masks); drives adaptive
    #                               max_selected bucketing upstream
    pages_selected: jnp.ndarray   # i32 scalar — distinct pages selected by
    #                               the whole batch (summed over shards)
    pages_gathered: jnp.ndarray   # i32 scalar — selected pages that fit the
    #                               slab, min(union, max_selected) per shard
    #                               summed (gather-occupancy numerator)
    row_ids: jnp.ndarray          # (Q, top_k) i32 global row ids in ascending
    #                               order, -1 padded; (Q, 0) when top_k == 0


# ---------------------------------------------------------------------------
# Build (§4, Algorithm 2)
# ---------------------------------------------------------------------------

def build(cfg: HippoConfig, hist: Histogram, keys: jnp.ndarray,
          valid: jnp.ndarray) -> HippoState:
    """Initialize Hippo over a paged key column.

    Device work: bucketize + grouping scan (jit). Entry extraction is a cheap
    host finalize. Returns a fixed-capacity ``HippoState``.
    """
    num_pages = keys.shape[0]
    if num_pages == 0:
        # Empty table: zero-entry index; Algorithm 3 grows it on first insert.
        starts = ends = np.zeros((0,), np.int32)
        packed = np.zeros((0, cfg.words), np.uint32)
    else:
        page_bits = grouping.page_bucket_bits(hist, keys, valid, cfg.resolution)
        flags, merged = grouping.group_pages(page_bits, cfg.resolution, cfg.density)
        starts, ends, packed = grouping.finalize_entries(np.asarray(flags), np.asarray(merged))
    e = starts.shape[0]
    if e > cfg.max_slots:
        raise ValueError(f"built {e} entries > max_slots {cfg.max_slots}; raise capacity")
    s, w = cfg.max_slots, cfg.words

    bitmaps = np.zeros((s, w), np.uint32)
    bitmaps[:e] = packed
    st = np.full((s,), _INT32_MAX, np.int32)
    st[:e] = starts
    en = np.full((s,), _INT32_MAX, np.int32)
    en[:e] = ends
    order = np.arange(s, dtype=np.int32)   # build order is page order (§5.3 init)
    live = np.zeros((s,), bool)
    live[:e] = True
    return HippoState(
        bounds=hist.bounds,
        bitmaps=jnp.asarray(bitmaps),
        starts=jnp.asarray(st),
        ends=jnp.asarray(en),
        sorted_order=jnp.asarray(order),
        slot_live=jnp.asarray(live),
        num_entries=jnp.int32(e),
        num_slots=jnp.int32(e),
        summarized_until=jnp.int32(num_pages - 1 if e else -1),
    )


# ---------------------------------------------------------------------------
# Search (§3, Algorithm 1)
# ---------------------------------------------------------------------------

def _logical_starts(state: HippoState) -> jnp.ndarray:
    """starts in logical (sorted-list) order, padded with INT32_MAX."""
    s = state.sorted_order.shape[0]
    pos = jnp.arange(s, dtype=jnp.int32)
    starts = state.starts[state.sorted_order]
    return jnp.where(pos < state.num_entries, starts, _INT32_MAX)


def locate_slot(state: HippoState, page_id) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Binary search the sorted list for the entry owning ``page_id`` (§5.3).

    Returns (physical_slot, logical_pos). Caller guarantees the page is
    summarized (page_id <= summarized_until).
    """
    ls = _logical_starts(state)
    pos = jnp.searchsorted(ls, page_id, side="right").astype(jnp.int32) - 1
    pos = jnp.clip(pos, 0, None)
    return state.sorted_order[pos], pos


def _expand_page_mask(state: HippoState, match: jnp.ndarray,
                      num_pages: int) -> jnp.ndarray:
    """Expand matched entry page-ranges to a page bitmap (Bitmap b, Alg. 1).

    Live entries partition the summarized page space contiguously in logical
    (sorted-list) order — the §5.3 invariant ``locate_slot``'s binary search
    already relies on — so each page belongs to at most one entry, and the
    mask is a *gather* of the owning entry's match bit: binary-search every
    page's logical position once, then look its match up per query. (The
    previous boundary-delta scatter + prefix sum computed the same mask but
    XLA:CPU scatters made it the most expensive fixed cost of a batch.)
    Pages past the last entry's ``end`` — and everything in an empty index —
    resolve to no entry and stay False. ``match`` is (S,) or (Q, S); the
    result matches with shape (num_pages,) or (Q, num_pages).
    """
    squeeze = match.ndim == 1
    m = match[None] if squeeze else match
    ls = _logical_starts(state)                        # (S,), INT32_MAX pads
    pages = jnp.arange(num_pages, dtype=jnp.int32)
    pos = jnp.searchsorted(ls, pages, side="right").astype(jnp.int32) - 1
    slot = state.sorted_order[jnp.clip(pos, 0, None)]  # owning physical slot
    in_range = (pos >= 0) & (pages <= state.ends[slot])
    page_mask = m[:, slot] & in_range[None, :]
    return page_mask[0] if squeeze else page_mask


@partial(jax.jit, static_argnames=())
def search(state: HippoState, query_bitmap: jnp.ndarray, keys: jnp.ndarray,
           valid: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> SearchResult:
    """Algorithm 1: filter false positives by bitmap AND, inspect the rest.

    keys/valid: (num_pages, page_card) device views of the table.
    lo/hi: the predicate interval for exact inspection (step 3).
    """
    num_pages = keys.shape[0]
    s = state.bitmaps.shape[0]
    live = state.slot_live & (jnp.arange(s) < state.num_slots)
    # Step 2 — bit-level parallel joint-bucket test (Fig. 3).
    match = bm.any_joint(state.bitmaps, query_bitmap[None, :]) & live
    page_mask = _expand_page_mask(state, match, num_pages)
    # Step 3 — inspect possible qualified pages tuple-by-tuple (vectorized).
    v = keys.astype(jnp.float32)
    qualified = page_mask[:, None] & valid & (v >= lo) & (v <= hi)
    return SearchResult(
        count=qualified.sum(dtype=jnp.int32),
        qualified=qualified,
        page_mask=page_mask,
        pages_inspected=page_mask.sum(dtype=jnp.int32),
        entries_matched=match.sum(dtype=jnp.int32),
    )


@partial(jax.jit, static_argnames=())
def search_many(state: HippoState, query_bitmaps: jnp.ndarray, keys: jnp.ndarray,
                valid: jnp.ndarray, los: jnp.ndarray, his: jnp.ndarray,
                ) -> BatchSearchResult:
    """Algorithm 1 over a batch of Q predicates in one device program.

    query_bitmaps: (Q, W) packed query bitmaps; los/his: (Q,) predicate
    intervals. The entry-match and range-expand steps of ``search`` gain a
    leading query axis — one (Q, S) joint-bucket AND, one batched scatter into
    (Q, P+1) boundary deltas, one row-wise prefix sum — so Q queries cost one
    dispatch instead of Q. Row q of every output is bit-identical to the
    scalars ``search`` returns for predicate q.
    """
    num_pages = keys.shape[0]
    s = state.bitmaps.shape[0]
    live = state.slot_live & (jnp.arange(s) < state.num_slots)
    # Step 2, batched: joint-bucket test of every query against every entry.
    match = bm.any_joint(query_bitmaps[:, None, :], state.bitmaps[None, :, :])
    match = match & live[None, :]                                   # (Q, S)
    page_mask = _expand_page_mask(state, match, num_pages)          # (Q, P)
    # Step 3, batched: inspect possible qualified pages for every query.
    v = keys.astype(jnp.float32)[None]
    qualified = (page_mask[:, :, None] & valid[None]
                 & (v >= los[:, None, None]) & (v <= his[:, None, None]))
    return BatchSearchResult(
        counts=qualified.sum(axis=(1, 2), dtype=jnp.int32),
        page_mask=page_mask,
        pages_inspected=page_mask.sum(axis=1, dtype=jnp.int32),
        entries_matched=match.sum(axis=1, dtype=jnp.int32),
    )


# Per-shard vmap axes for a stacked ``HippoState``: every array gains a
# leading shard axis, *including* ``bounds`` — each shard carries its own
# complete-histogram boundary set so a drift re-summarization can remap one
# shard at a time while the others keep serving under their old bounds.
# Query bitmaps are converted per shard epoch (``core.partition``) and fed
# with a matching leading shard axis.
SHARD_AXES = HippoState(
    bounds=0, bitmaps=0, starts=0, ends=0, sorted_order=0, slot_live=0,
    num_entries=0, num_slots=0, summarized_until=0)


@partial(jax.jit, static_argnames=())
def search_many_sharded(shards: HippoState, query_bitmaps: jnp.ndarray,
                        keys: jnp.ndarray, valid: jnp.ndarray,
                        los: jnp.ndarray, his: jnp.ndarray) -> BatchSearchResult:
    """``search_many`` over S shards in one device program, count-reduced.

    ``shards`` is a stacked ``HippoState`` (leading shard axis per
    ``SHARD_AXES``); keys/valid are (S, PPS, page_card) slabs where shard s
    owns global pages [s*PPS, (s+1)*PPS) and its entry page ids are local to
    the slab. ``query_bitmaps`` is (S, Q, W): row s holds the Q predicates
    converted under shard s's histogram bounds — identical rows while every
    shard shares one bounds epoch, distinct rows mid-drift-resummarization
    (the exactness contract is per shard: a shard's page bitmaps and its
    query bitmaps always share one bucket space). Each shard runs the full
    Algorithm 1 pipeline over its slab; counts/match-stats reduce by
    summation over the shard axis — the ``jax.lax.psum`` of a ``shard_map``
    placement, expressed as an array-axis sum so it is identical under vmap
    on one device and lowers to an AllReduce when the shard axis is sharded
    over a mesh ``data`` axis (``launch.shardings.sharded_hippo_shardings``).

    Shards partition the page space, so per-shard exact counts sum to exactly
    the unsharded count: row q's ``counts`` is bit-identical to
    ``search_many`` on the unsharded index. ``page_mask`` is returned in
    global page order, (Q, S*PPS).
    """
    per = jax.vmap(search_many,
                   in_axes=(SHARD_AXES, 0, 0, 0, None, None))(
        shards, query_bitmaps, keys, valid, los, his)
    s, q = per.counts.shape
    pps = keys.shape[1]
    page_mask = jnp.moveaxis(per.page_mask, 0, 1).reshape(q, s * pps)
    return BatchSearchResult(
        counts=per.counts.sum(axis=0),                 # psum over shards
        page_mask=page_mask,
        pages_inspected=per.pages_inspected.sum(axis=0),
        entries_matched=per.entries_matched.sum(axis=0),
    )


@partial(jax.jit, static_argnames=())
def staged_overlay_counts(staged_vals: jnp.ndarray, staged_live: jnp.ndarray,
                          los: jnp.ndarray, his: jnp.ndarray) -> jnp.ndarray:
    """Exact counts of staged-but-undrained rows per query.

    staged_vals: (S, B) f32 pending insert values per shard, padded to a
    bucketed width B; staged_live: (S, B) bool (False for pads and for staged
    rows killed by a later delete); los/his: (Q,) f32 predicate intervals.
    Returns (Q,) i32. Staged rows live in no page yet, so this is a plain
    interval test — the device half of the writer's staging-buffer overlay
    (``runtime.writer.MaintenanceWriter``).
    """
    v = staged_vals[None]                                       # (1, S, B)
    hit = (staged_live[None] & (v >= los[:, None, None])
           & (v <= his[:, None, None]))
    return hit.sum(axis=(1, 2), dtype=jnp.int32)


def search_many_sharded_staged(shards: HippoState, query_bitmaps: jnp.ndarray,
                               keys: jnp.ndarray, valid: jnp.ndarray,
                               los: jnp.ndarray, his: jnp.ndarray,
                               staged_vals: jnp.ndarray,
                               staged_live: jnp.ndarray) -> BatchSearchResult:
    """``search_many_sharded`` plus the staging-buffer overlay.

    ``counts`` gains the staged rows matching each predicate, so results
    never go stale while inserts wait in the writer's per-shard queues:
    row q equals what ``search_many_sharded`` would return *after* every
    staged row drained. ``page_mask``/``pages_inspected``/``entries_matched``
    are the index-only values — staged rows occupy no page until their drain.
    """
    res = search_many_sharded(shards, query_bitmaps, keys, valid, los, his)
    return res._replace(
        counts=res.counts + staged_overlay_counts(staged_vals, staged_live,
                                                  los, his))


@partial(jax.jit, static_argnames=("max_selected",))
def search_compact(state: HippoState, query_bitmap: jnp.ndarray, keys: jnp.ndarray,
                   valid: jnp.ndarray, lo, hi, max_selected: int):
    """Gather-then-inspect variant: touches only selected pages (TPU I/O model).

    Work after filtering is proportional to ``max_selected`` pages — the
    accelerator analogue of "only read possible qualified pages from disk".
    Returns (count, pages_inspected, truncated); if ``truncated`` is true the
    selection overflowed ``max_selected`` and the caller must fall back to the
    dense path (the count would otherwise be incomplete).

    Fill-value contract: the selection pads with ``fill_value=num_pages`` and
    the gathers run with ``mode="fill"``, so pad rows contribute nothing; a
    ``max_selected`` of zero would make every row a pad and silently count 0,
    so it is rejected here (static arg => plain raise at trace time).
    """
    if max_selected < 1:
        raise ValueError(f"max_selected must be >= 1, got {max_selected}")
    num_pages = keys.shape[0]
    s = state.bitmaps.shape[0]
    live = state.slot_live & (jnp.arange(s) < state.num_slots)
    match = bm.any_joint(state.bitmaps, query_bitmap[None, :]) & live
    page_mask = _expand_page_mask(state, match, num_pages)
    n_sel = page_mask.sum(dtype=jnp.int32)
    sel = jnp.nonzero(page_mask, size=max_selected, fill_value=num_pages)[0]
    in_range = sel < num_pages
    pk = jnp.where(in_range[:, None], keys.at[sel].get(mode="fill", fill_value=0.0), 0.0)
    pv = valid.at[sel].get(mode="fill", fill_value=False) & in_range[:, None]
    qual = pv & (pk.astype(jnp.float32) >= lo) & (pk.astype(jnp.float32) <= hi)
    return qual.sum(dtype=jnp.int32), n_sel, n_sel > max_selected


@partial(jax.jit, static_argnames=("max_selected", "top_k"))
def search_compact_many(state: HippoState, query_bitmaps: jnp.ndarray,
                        keys: jnp.ndarray, valid: jnp.ndarray,
                        los: jnp.ndarray, his: jnp.ndarray, *,
                        max_selected: int, top_k: int = 0
                        ) -> CompactBatchResult:
    """Batched gather-then-inspect: Q predicates over one shared page slab.

    The per-query page masks of Algorithm 1 step 2 are unioned, the union's
    pages are gathered **once** into a ``(max_selected, C)`` slab, and every
    query's interval test runs against that shared slab — so inspect cost is
    O(Q x max_selected x C) instead of ``search_many``'s O(Q x P x C),
    i.e. proportional to the batch's selectivity, not the table.

    Row q's ``counts`` is bit-identical to ``search_many`` whenever
    ``truncated[q]`` is False (pages are gathered in ascending page order and
    inspection is exact). With ``top_k > 0``, ``row_ids[q]`` carries the
    first ``top_k`` qualifying global row ids (``page_id * C + slot``) in
    ascending order, -1 padded; when ``counts[q] > top_k`` the id list is a
    prefix (callers see the shortfall from the count itself).

    The fill-value contract of ``search_compact`` applies: selection pads
    with ``num_pages`` and gathers with ``mode="fill"``, so pad rows can
    never qualify; ``max_selected`` must be >= 1.
    """
    if max_selected < 1:
        raise ValueError(f"max_selected must be >= 1, got {max_selected}")
    if top_k < 0:
        raise ValueError(f"top_k must be >= 0, got {top_k}")
    num_pages, card = keys.shape
    s = state.bitmaps.shape[0]
    live = state.slot_live & (jnp.arange(s) < state.num_slots)
    # Step 2, batched: joint-bucket test + page-range expansion per query.
    match = bm.any_joint(query_bitmaps[:, None, :], state.bitmaps[None, :, :])
    match = match & live[None, :]                                   # (Q, S)
    page_mask = _expand_page_mask(state, match, num_pages)          # (Q, P)
    # Union across the batch: one gather serves every query's inspection.
    union = jnp.any(page_mask, axis=0)                              # (P,)
    n_union = union.sum(dtype=jnp.int32)
    sel = jnp.nonzero(union, size=max_selected, fill_value=num_pages)[0]
    in_range = sel < num_pages                                      # (M,)
    slab_keys = jnp.where(in_range[:, None],
                          keys.at[sel].get(mode="fill", fill_value=0.0), 0.0)
    slab_valid = valid.at[sel].get(mode="fill", fill_value=False) & in_range[:, None]
    # Each query's mask restricted to the gathered slab (filter-match half of
    # the fused inspect; kernels/compact_inspect is the Pallas twin).
    sel_mask = (page_mask.at[:, sel].get(mode="fill", fill_value=False)
                & in_range[None, :])                                # (Q, M)
    v = slab_keys.astype(jnp.float32)[None]
    qual = (sel_mask[:, :, None] & slab_valid[None]
            & (v >= los[:, None, None]) & (v <= his[:, None, None]))
    pages_inspected = page_mask.sum(axis=1, dtype=jnp.int32)
    covered = sel_mask.sum(axis=1, dtype=jnp.int32)
    if top_k:
        # First top_k qualifying rows per query, in slab order == ascending
        # global row id order (sel is ascending, slots are row-ordered).
        flat = qual.reshape(qual.shape[0], -1)                      # (Q, M*C)
        gids = (sel[:, None] * card
                + jnp.arange(card, dtype=jnp.int32)[None, :]).reshape(-1)
        npos = flat.shape[1]
        pos = jnp.where(flat, jnp.arange(npos, dtype=jnp.int32)[None, :], npos)
        k_eff = min(top_k, npos)   # a slab of M*C rows can yield at most M*C ids
        # smallest k_eff positions per row in ascending order: top_k of the
        # negated positions selects them at O(n log k) instead of a full sort
        first = -jax.lax.top_k(-pos, k_eff)[0]                      # (Q, K)
        row_ids = jnp.where(first < npos,
                            gids.at[first].get(mode="fill", fill_value=-1), -1)
        if k_eff < top_k:
            row_ids = jnp.pad(row_ids, ((0, 0), (0, top_k - k_eff)),
                              constant_values=-1)
    else:
        row_ids = jnp.zeros((qual.shape[0], 0), jnp.int32)
    return CompactBatchResult(
        counts=qual.sum(axis=(1, 2), dtype=jnp.int32),
        pages_inspected=pages_inspected,
        entries_matched=match.sum(axis=1, dtype=jnp.int32),
        truncated=covered < pages_inspected,
        bucket_needed=n_union,
        pages_selected=n_union,
        pages_gathered=jnp.minimum(n_union, max_selected),
        row_ids=row_ids,
    )


_I32_PAD = jnp.int32(_INT32_MAX)


@partial(jax.jit, static_argnames=("max_selected", "top_k"))
def search_compact_many_sharded(shards: HippoState, query_bitmaps: jnp.ndarray,
                                keys: jnp.ndarray, valid: jnp.ndarray,
                                los: jnp.ndarray, his: jnp.ndarray, *,
                                max_selected: int, top_k: int = 0
                                ) -> CompactBatchResult:
    """``search_compact_many`` over S shards, count-reduced like
    ``search_many_sharded``.

    ``query_bitmaps`` is (S, Q, W), one conversion per shard bounds epoch
    (see ``search_many_sharded``). ``max_selected`` is the *per-shard* slab
    size (each shard gathers its own union). Counts/pages_inspected/
    entries_matched sum over the shard axis — bit-identical to the unsharded
    gather over the same pages wherever no shard truncated; ``truncated``
    ORs over shards per query, and ``bucket_needed`` is the max per-shard
    union (the slab size that would clear every flag). Shard-local row ids
    globalize by the slab offset (shard s's local row r is global
    ``s * PPS * C + r``) and merge by an ascending sort, so ``row_ids``
    equals the unsharded result's.
    """
    fn = partial(search_compact_many, max_selected=max_selected, top_k=top_k)
    per = jax.vmap(fn, in_axes=(SHARD_AXES, 0, 0, 0, None, None))(
        shards, query_bitmaps, keys, valid, los, his)
    if top_k:
        s, _, card = keys.shape
        offs = (jnp.arange(s, dtype=jnp.int32) * keys.shape[1] * card)
        gids = jnp.where(per.row_ids >= 0,
                         per.row_ids + offs[:, None, None], _I32_PAD)
        q = gids.shape[1]
        merged = jnp.moveaxis(gids, 0, 1).reshape(q, -1)      # (Q, S*K)
        merged = jax.lax.sort(merged, dimension=1)[:, :top_k]
        row_ids = jnp.where(merged < _I32_PAD, merged, -1)
    else:
        row_ids = per.row_ids[0]
    return CompactBatchResult(
        counts=per.counts.sum(axis=0),                 # psum over shards
        pages_inspected=per.pages_inspected.sum(axis=0),
        entries_matched=per.entries_matched.sum(axis=0),
        truncated=jnp.any(per.truncated, axis=0),
        bucket_needed=per.bucket_needed.max(),
        pages_selected=per.pages_selected.sum(),
        pages_gathered=per.pages_gathered.sum(),
        row_ids=row_ids,
    )


def search_compact_many_sharded_staged(shards: HippoState,
                                       query_bitmaps: jnp.ndarray,
                                       keys: jnp.ndarray, valid: jnp.ndarray,
                                       los: jnp.ndarray, his: jnp.ndarray,
                                       staged_vals: jnp.ndarray,
                                       staged_live: jnp.ndarray, *,
                                       max_selected: int, top_k: int = 0
                                       ) -> CompactBatchResult:
    """``search_compact_many_sharded`` plus the staging-buffer overlay.

    The compact twin of ``search_many_sharded_staged``: counts gain the
    staged rows matching each predicate, so the gather path never goes stale
    while inserts wait in the writer's queues. Staged rows occupy no page
    until their drain, so they appear in ``counts`` only — never in
    ``row_ids``/``pages_inspected`` (exactly as the dense path keeps them out
    of ``page_mask``) — and they cannot cause truncation.
    """
    res = search_compact_many_sharded(shards, query_bitmaps, keys, valid,
                                      los, his, max_selected=max_selected,
                                      top_k=top_k)
    return res._replace(
        counts=res.counts + staged_overlay_counts(staged_vals, staged_live,
                                                  los, his))


# ---------------------------------------------------------------------------
# Maintenance — eager insert (§5.1, Algorithm 3)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def insert_tuple(cfg: HippoConfig, state: HippoState, value: jnp.ndarray,
                 page_id: jnp.ndarray) -> HippoState:
    """Algorithm 3: eager single-tuple index update.

    Steps: (1) bucketize the new value; (2) locate the owning entry via the
    sorted list; (3) set the bucket bit / extend the last entry / open a new
    entry, per the density rule.
    """
    hist = Histogram(state.bounds)
    b = bucketize(hist, value[None])[0]
    word = b // 32
    bit = jnp.uint32(1) << jnp.uint32(b % 32)
    is_new_page = page_id > state.summarized_until

    def existing_page(st: HippoState) -> HippoState:
        slot, pos = locate_slot(st, page_id)
        old_word = st.bitmaps[slot, word]
        new_word = old_word | bit
        changed = new_word != old_word

        def in_place(st: HippoState) -> HippoState:
            return st._replace(bitmaps=st.bitmaps.at[slot, word].set(new_word))

        def relocate(st: HippoState) -> HippoState:
            # §5.1: updated entry may not fit its old slot -> append a new
            # physical entry at the end, fix the sorted list pointer (Fig. 4).
            new_slot = st.num_slots
            bitmaps = st.bitmaps.at[new_slot].set(st.bitmaps[slot]).at[new_slot, word].set(new_word)
            return st._replace(
                bitmaps=bitmaps,
                starts=st.starts.at[new_slot].set(st.starts[slot]),
                ends=st.ends.at[new_slot].set(st.ends[slot]),
                slot_live=st.slot_live.at[slot].set(False).at[new_slot].set(True),
                sorted_order=st.sorted_order.at[pos].set(new_slot),
                num_slots=st.num_slots + 1,
            )

        if cfg.relocate_on_update:
            return jax.lax.cond(changed, relocate, lambda s: s, st)
        return jax.lax.cond(changed, in_place, lambda s: s, st)

    def new_page(st: HippoState) -> HippoState:
        last_slot = st.sorted_order[jnp.maximum(st.num_entries - 1, 0)]
        last_density = jnp.where(
            st.num_entries > 0,
            bm.density(st.bitmaps[last_slot], cfg.resolution),
            jnp.float32(2.0),  # empty index -> always create
        )

        def extend(st: HippoState) -> HippoState:
            return st._replace(
                bitmaps=st.bitmaps.at[last_slot, word].set(st.bitmaps[last_slot, word] | bit),
                ends=st.ends.at[last_slot].set(page_id),
                summarized_until=page_id,
            )

        def create(st: HippoState) -> HippoState:
            slot = st.num_slots
            zero = jnp.zeros((cfg.words,), jnp.uint32).at[word].set(bit)
            return st._replace(
                bitmaps=st.bitmaps.at[slot].set(zero),
                starts=st.starts.at[slot].set(page_id),
                ends=st.ends.at[slot].set(page_id),
                slot_live=st.slot_live.at[slot].set(True),
                sorted_order=st.sorted_order.at[st.num_entries].set(slot),
                num_entries=st.num_entries + 1,
                num_slots=st.num_slots + 1,
                summarized_until=page_id,
            )

        return jax.lax.cond(last_density < cfg.density, extend, create, st)

    return jax.lax.cond(is_new_page, new_page, existing_page, state)


@partial(jax.jit, static_argnames=("cfg",))
def insert_batch_existing(cfg: HippoConfig, state: HippoState, values: jnp.ndarray,
                          page_ids: jnp.ndarray, mask: jnp.ndarray) -> HippoState:
    """Vectorized eager update for tuples landing on already-summarized pages.

    Beyond-paper fast path: bucketize all values, locate all owning slots with
    one vectorized sorted-list binary search, and OR the new bits in via a
    segment reduction. Semantically identical to repeated ``insert_tuple``
    (modulo physical relocation, which fixed-width slots make unnecessary).

    ``mask`` selects the tuples to apply (shape-stable: callers pass the full
    batch each time; masked-out tuples route to a dropped segment).
    """
    hist = Histogram(state.bounds)
    ids = bucketize(hist, values)                      # (N,)
    slots, _ = jax.vmap(lambda p: locate_slot(state, p))(page_ids)
    slots = jnp.where(mask, slots, cfg.max_slots)      # dropped by segment_max
    onehot = jax.nn.one_hot(ids, cfg.resolution, dtype=jnp.int32)  # (N, H)
    agg = jax.ops.segment_max(onehot, slots,
                              num_segments=cfg.max_slots + 1) > 0
    packed = bm.from_bool(agg[: cfg.max_slots])
    return state._replace(bitmaps=state.bitmaps | packed)


# ---------------------------------------------------------------------------
# Maintenance — lazy delete / vacuum (§5.2)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def resummarize_slots(cfg: HippoConfig, state: HippoState, keys: jnp.ndarray,
                      valid: jnp.ndarray, affected: jnp.ndarray) -> HippoState:
    """Re-summarize the page ranges of ``affected`` slots (vacuum, §5.2).

    The refreshed bitmap can only lose bits, so the update is in place and the
    sorted list is untouched (paper's observation). ``affected``: (S,) bool.
    """
    num_pages = keys.shape[0]
    hist = Histogram(state.bounds)
    page_bits = grouping.page_bucket_bits(hist, keys, valid, cfg.resolution)  # (P, H)
    # entry-of-page for affected slots via boundary deltas over live slots.
    s = state.bitmaps.shape[0]
    live = state.slot_live & (jnp.arange(s) < state.num_slots) & affected
    # Map each page to its owning affected slot (or S = "none").
    seg = jnp.full((num_pages,), s, jnp.int32)
    # scatter slot id at starts, then forward-fill within [start, end].
    slot_ids = jnp.arange(s, dtype=jnp.int32)
    start_marks = jnp.full((num_pages,), -1, jnp.int32)
    start_marks = start_marks.at[jnp.clip(state.starts, 0, num_pages - 1)].max(
        jnp.where(live, slot_ids, -1), mode="drop")
    filled = jax.lax.associative_scan(jnp.maximum, start_marks)
    ends_of = jnp.where(filled >= 0, state.ends[jnp.clip(filled, 0, s - 1)], -1)
    in_range = (filled >= 0) & (jnp.arange(num_pages) <= ends_of)
    seg = jnp.where(in_range, filled, s)
    agg = jax.ops.segment_max(page_bits.astype(jnp.int32), seg,
                              num_segments=s + 1) > 0          # (S+1, H)
    fresh = bm.from_bool(agg[:s])
    new_bitmaps = jnp.where(affected[:, None], fresh, state.bitmaps)
    return state._replace(bitmaps=new_bitmaps)


@partial(jax.jit, static_argnames=("cfg",))
def resummarize_shard(cfg: HippoConfig, state: HippoState, keys: jnp.ndarray,
                      valid: jnp.ndarray, new_bounds: jnp.ndarray) -> HippoState:
    """Remap a shard's partial histograms onto new complete-histogram bounds.

    The drift-adaptation unit of work (``runtime.writer``): every live
    entry's packed bitmap is rebuilt from its pages' tuples bucketized under
    ``new_bounds``, and the state's ``bounds`` swap to the new boundary set
    in the same functional update. Entry page ranges, the sorted list, and
    every count are untouched — the remap changes which buckets a page's
    tuples land in, never which pages an entry covers — so counts stay
    bit-identical as long as query bitmaps convert under the same bounds the
    shard serves (the per-shard epoch contract in ``core.partition``).

    Built on ``resummarize_slots`` with every live slot affected: one jit
    trace per slab shape serves every shard and every remap, and the whole
    remap is plain jnp (kernel-free — no Pallas path to revalidate on TPU).
    """
    s = state.bitmaps.shape[0]
    live = state.slot_live & (jnp.arange(s) < state.num_slots)
    st = state._replace(bounds=new_bounds)
    return resummarize_slots(cfg, st, keys, valid, live)


# ---------------------------------------------------------------------------
# Storage accounting (paper's index-size metric)
# ---------------------------------------------------------------------------

def index_nbytes(cfg: HippoConfig, state: HippoState, compressed: bool = False) -> int:
    """Bytes of live index storage: entries (bitmap + 2 page ids) + sorted list.

    ``compressed=True`` reports the serialized RLE form (paper's on-disk
    compressed bitmaps); the device-resident form is fixed-width words.
    """
    e = int(state.num_entries)
    live = np.asarray(state.slot_live)
    words = np.asarray(state.bitmaps)[live]
    if compressed:
        bitmap_bytes = sum(bm.compressed_nbytes(wrow) for wrow in words)
    else:
        bitmap_bytes = words.nbytes
    page_range_bytes = e * 8          # two int32 page ids per entry
    sorted_list_bytes = e * 4         # one pointer per entry (§5.3)
    histogram_bytes = state.bounds.shape[0] * 4
    return bitmap_bytes + page_range_bytes + sorted_list_bytes + histogram_bytes
