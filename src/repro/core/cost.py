"""Hippo cost estimation models (§6).

These closed-form estimates drive query planning, storage planning, and the
cost-model validation benchmark (`benchmarks/bench_cost_model.py`), which
checks them against measured behaviour of the real index.

Notation (Table 2): H resolution, D density threshold, P pages/entry,
T tuples/entry, Card cardinality, pageCard tuples/page, SF selectivity factor.
"""
from __future__ import annotations

import math


def prob_inspect(sf: float, resolution: int, density: float) -> float:
    """Probability a partial histogram has joint buckets with the predicate.

    Formula 1 piecewise: Prob = (SF*H)*D clipped to 1, with SF*H >= 1 because a
    non-empty predicate hits at least one bucket (§6.1).
    """
    hit_buckets = max(1.0, math.ceil(sf * resolution))
    return min(1.0, hit_buckets * density)


def query_time_tuples(sf: float, resolution: int, density: float, card: int) -> float:
    """Formula 2: expected inspected tuples (the disk-I/O proxy)."""
    return prob_inspect(sf, resolution, density) * card


def tuples_per_entry(resolution: int, density: float) -> float:
    """Formula 3: coupon-collector expectation T(H, D).

    T = H * (1/H + 1/(H-1) + ... + 1/(H - D*H + 1)) — tuples drawn until D*H
    distinct buckets are collected.
    """
    h = resolution
    k = max(1, int(round(density * h)))
    return h * sum(1.0 / (h - j) for j in range(k))


def pages_per_entry(resolution: int, density: float, page_card: int) -> float:
    """Formula 4: P = T / pageCard (valid when D*H >= pageCard)."""
    return tuples_per_entry(resolution, density) / page_card


def num_entries(card: int, resolution: int, density: float) -> float:
    """Formula 5/6: expected index entry count Card / T."""
    return card / tuples_per_entry(resolution, density)


def entry_nbytes(resolution: int) -> int:
    """Bytes per entry: packed bitmap words + 2 page ids + sorted-list ptr."""
    words = (resolution + 31) // 32
    return words * 4 + 8 + 4


def index_nbytes(card: int, resolution: int, density: float) -> float:
    """Index size estimate = entries * entry size (§6.2)."""
    return num_entries(card, resolution, density) * entry_nbytes(resolution)


def init_time_ios(card: int, resolution: int, density: float) -> float:
    """Formula 7: Card tuple reads + one write per entry."""
    return card + num_entries(card, resolution, density)


def insert_time_ios(card: int, resolution: int, density: float) -> float:
    """Formula 8: log(entries) sorted-list binary search + 4 constant I/Os."""
    e = max(2.0, num_entries(card, resolution, density))
    return math.log2(e) + 4.0


def btree_insert_time_ios(card: int) -> float:
    """B+-Tree comparison point used in §7.3.2: ~log(Card) per insert."""
    return math.log2(max(2, card))
