"""The paper's primary contribution: the Hippo sparse index, in JAX."""
from repro.core import bitmap, cost, grouping, histogram, predicate  # noqa: F401
from repro.core.hippo import HippoIndex  # noqa: F401
from repro.core.partition import ShardedHippoIndex, ShardSpec  # noqa: F401
from repro.core.index import HippoConfig, HippoState, SearchResult  # noqa: F401
from repro.core.predicate import Predicate  # noqa: F401
