"""HippoKV — Hippo-style page summaries over a KV cache (beyond-paper).

The paper's structure (page ranges + bucket-bitmap summaries + AND-filter)
applied to long-context decode: the "table" is the key cache, a "page" is a
block of ``page_size`` consecutive cache positions, and the indexed
"attribute" is the key's projection onto a set of quantized directions.

Summaries: for each page and each feature channel c (a learned/PCA projection
of keys; here the top-``num_channels`` key dims by variance), an equi-depth
histogram over the channel's values is built and the page's bitmap marks the
buckets present. At decode time the query selects, per channel, the buckets
whose values could produce a large |q_c * k_c| contribution (the outermost
buckets in the direction of sign(q_c)); pages whose bitmaps miss all selected
buckets in every channel are pruned — Quest-style upper-bound pruning, with
the paper's bitmap machinery instead of min/max.

Unlike the paper's exact-predicate use, KV pruning is APPROXIMATE (dropping a
page drops its softmax mass). ``hippo_kv_attention`` therefore exposes the
kept-mass diagnostics and the repo keeps exact attention as the default
(DESIGN.md §3); tests bound the output error against full attention.

Applicability: attention-bearing archs only — rwkv6 has no KV cache and
recurrentgemma's local window is already O(window) (DESIGN.md §5).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm


@dataclass(frozen=True)
class KVIndexConfig:
    page_size: int = 64          # cache positions per summarized page
    num_channels: int = 8        # key channels summarized per head
    resolution: int = 16         # histogram buckets per channel
    keep_buckets: int = 4        # query-side: outermost buckets selected


class KVIndex:
    """Per-(batch, head) page summaries of a key cache."""

    def __init__(self, cfg: KVIndexConfig, channels: jnp.ndarray,
                 bounds: jnp.ndarray, bitmaps: jnp.ndarray):
        self.cfg = cfg
        self.channels = channels   # (C,) int32 — key dims summarized
        self.bounds = bounds       # (C, R+1) f32 — per-channel bucket bounds
        self.bitmaps = bitmaps     # (B, H, P, C, W) uint32 — page summaries

    @property
    def num_pages(self) -> int:
        return self.bitmaps.shape[2]

    def nbytes(self) -> int:
        return int(self.bitmaps.size) * 4 + int(self.bounds.size) * 4


def build_kv_index(cfg: KVIndexConfig, keys: jnp.ndarray) -> KVIndex:
    """keys: (B, S, H, hd) with S % page_size == 0."""
    b, s, h, hd = keys.shape
    p = s // cfg.page_size
    kf = keys.astype(jnp.float32)
    # pick the highest-variance key dims as summary channels (host-static)
    var = kf.reshape(-1, hd).var(axis=0)
    channels = jnp.argsort(-var)[: cfg.num_channels].astype(jnp.int32)
    sel = kf[..., channels]                              # (B, S, H, C)
    # equi-depth bounds per channel (global across the cache)
    qs = jnp.linspace(0.0, 1.0, cfg.resolution + 1)
    bounds = jnp.quantile(sel.reshape(-1, cfg.num_channels), qs, axis=0).T
    eps = (bounds[:, -1:] - bounds[:, :1] + 1.0) * 1e-6
    bounds = bounds + jnp.arange(cfg.resolution + 1) * eps  # strict monotone
    # bucketize + per-page bitmaps
    ids = jax.vmap(lambda v, bd: jnp.clip(
        jnp.searchsorted(bd, v, side="right") - 1, 0, cfg.resolution - 1),
        in_axes=(-1, 0), out_axes=-1)(sel, bounds)       # (B, S, H, C)
    ids = ids.reshape(b, p, cfg.page_size, h, cfg.num_channels)
    onehot = jax.nn.one_hot(ids, cfg.resolution, dtype=bool)  # (B,P,ps,H,C,R)
    page_bits = onehot.any(axis=2)                       # (B, P, H, C, R)
    bitmaps = bm.from_bool(page_bits).transpose(0, 2, 1, 3, 4)  # (B,H,P,C,W)
    return KVIndex(cfg, channels, bounds, bitmaps)


def query_page_mask(index: KVIndex, q: jnp.ndarray,
                    min_channels: int = 1) -> jnp.ndarray:
    """q: (B, H, hd) single decode query -> (B, H, P) bool pages to keep.

    Per channel, select the ``keep_buckets`` outermost buckets in the
    direction of sign(q_c) (largest |q_c*k_c| upper bound); a page survives
    if at least ``min_channels`` channels have a joint bucket — Algorithm 1's
    AND-filter per channel, vote-combined across channels (min_channels=1 is
    the permissive OR; higher values prune harder).
    """
    cfg = index.cfg
    qc = q.astype(jnp.float32)[..., index.channels]      # (B, H, C)
    r = cfg.resolution
    idx = jnp.arange(r)
    hi_mask = idx >= (r - cfg.keep_buckets)              # top buckets
    lo_mask = idx < cfg.keep_buckets                     # bottom buckets
    want_bits = jnp.where(qc[..., None] >= 0, hi_mask, lo_mask)  # (B,H,C,R)
    want = bm.from_bool(want_bits)                       # (B, H, C, W)
    joint = bm.any_joint(index.bitmaps, want[:, :, None])  # (B, H, P, C)
    return joint.sum(axis=-1) >= min_channels            # (B, H, P)


@partial(jax.jit, static_argnames=("page_size",))
def hippo_kv_attention(q: jnp.ndarray, keys: jnp.ndarray, values: jnp.ndarray,
                       page_mask: jnp.ndarray, page_size: int):
    """Decode attention over kept pages only (others masked out).

    q: (B, H, hd); keys/values: (B, S, H, hd); page_mask: (B, H, P).
    Returns (out (B, H, hd), kept_mass (B, H)) where kept_mass is the softmax
    mass retained vs full attention (diagnostic for the approximation).
    """
    b, s, h, hd = keys.shape
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        keys.astype(jnp.float32)) * scale
    full = jax.nn.softmax(scores, axis=-1)
    pos_mask = jnp.repeat(page_mask, page_size, axis=-1)[..., :s]  # (B,H,S)
    masked = jnp.where(pos_mask, scores, -1e30)
    probs = jax.nn.softmax(masked, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, values.astype(jnp.float32))
    kept_mass = (full * pos_mask).sum(axis=-1)
    return out.astype(q.dtype), kept_mass
