"""Learned summaries: error-bounded piecewise-linear CDF models that place
Hippo's bucket boundaries where the keys actually are.

The complete histogram is Hippo's only notion of the key distribution —
every pruning decision (entry bitmaps, shard summaries, the compact gather
union) happens in its bucket space — so boundary *placement* is pruning
quality. Equal-mass quantiles (``histogram.build`` / ``rebuild``) are the
classical answer, but they waste resolution on two regimes this repo's
workloads live in:

- **duplicate-heavy skew** (zipf-ish discrete keys): many quantiles tie on
  each heavy value and get epsilon-laddered apart into stripes no tuple can
  land in (``bucketize`` is a point lookup — all duplicates of a value fall
  in one bucket), silently shrinking the effective H;
- **drift refits**: ``rebuild`` blends the old boundary summary equal-mass
  with the drift reservoir, bounding the old data's resolution loss at 2x —
  a defensible default when nothing is known about the workload, but under
  sustained drift the queries chase the reservoir window and the old
  region's boundary budget is mostly dead weight.

Following FITing-Tree's shrinking-cone segmentation, ``fit_cdf`` fits a
monotone piecewise-linear model to the weighted empirical CDF of a sample
under a maximum-error bound in *mass* units, binary-searching the error to
fit a **fixed segment budget** — so every model has the same (small) shape
regardless of the data. The fit target is the *boundary-allocation* CDF:
each distinct key's mass is water-filled down to at most one bucket's
worth (``1/H``) before fitting, because a heavy hitter can never occupy
more than one bucket and its excess mass only drags quantile boundaries
into stripes no tuple can land in. ``boundaries`` then materializes the
model back into an ordinary ``(H+1,)`` strictly-increasing boundary array
by inverse CDF at the equi-mass grid, spending the freed budget on the
regions where extra boundaries actually separate tuples.

The materialization is the load-bearing design point: a learned model
*produces a Histogram*, so ``bucketize``, ``hit_bucket_range``, the
bucketize Pallas kernel, predicate conversion, and the entire downstream
bitmap/gather stack run unchanged — same shapes, same traces, just
better-placed bounds. Swapping a model in per shard reuses the writer's
``resummarize`` drain unit verbatim (``runtime.writer``), and the
equal-mass path stays available as the fallback/oracle
(``summary="equal_mass"`` everywhere, plus an automatic fallback here when
a sample is too degenerate to fit).

Everything in this module is host-side numpy over at most a few thousand
points (the build sample cap or the drift reservoir) — fitting costs
microseconds and sits on the maintenance path, never the query path.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.core import histogram as hg

DEFAULT_SEGMENTS = 64    # fixed segment budget: every model has this shape
# Learned refit policy: fraction of the total mass the *old* boundary
# summary keeps. Deliberately below rebuild's equal-mass 0.5 — the reservoir
# is where the workload is writing and (under drift) querying, so it gets
# the dominant share of the boundary budget; the old region keeps enough to
# stay first-class for mixed workloads.
OLD_MASS_FRACTION = 0.25


class DegenerateSample(ValueError):
    """The sample cannot support a CDF fit (fewer than two distinct keys)."""


@dataclass(frozen=True)
class PiecewiseLinearModel:
    """A monotone piecewise-linear CDF model with a fixed segment budget.

    ``knots_x``/``knots_y`` are padded to ``segments + 1`` by repeating the
    last knot (``n_knots`` marks the filled prefix), so every model carries
    the same array shapes however many segments the fit actually needed.
    ``max_error`` is the achieved max |empirical CDF - model| over the fit
    points, in mass units (fraction of total weight).
    """
    knots_x: np.ndarray      # (segments + 1,) float64, nondecreasing
    knots_y: np.ndarray      # (segments + 1,) float64 CDF values in [0, 1]
    n_knots: int             # filled prefix length (>= 2)
    segments: int            # the fixed budget the fit was run under
    max_error: float         # achieved sup-norm error, mass units

    @property
    def used_segments(self) -> int:
        return self.n_knots - 1

    def cdf(self, xs) -> np.ndarray:
        """Model CDF at ``xs`` (clamped to [0, 1] outside the knot span)."""
        return np.interp(np.asarray(xs, np.float64),
                         self.knots_x[: self.n_knots],
                         self.knots_y[: self.n_knots])


def _weighted_cdf_points(sample, weights, mass_clamp: float | None = None
                         ) -> tuple[np.ndarray, np.ndarray]:
    """(x, y): distinct sorted keys and the empirical CDF *at* each key
    (inclusive), weights normalized to total mass 1. Ties collapse into one
    point carrying their summed mass.

    ``mass_clamp`` (typically ``1/H``) caps any single distinct key's mass
    at one bucket's worth — the boundary-allocation correction for
    duplicate-heavy keys. ``bucketize`` is a point lookup, so every
    duplicate of one key lands in one bucket no matter how many boundaries
    equal-mass quantiles tie onto it; mass beyond one bucket's worth is
    dead weight for summary placement, and the clamp water-fills it back
    into the keys that can still absorb boundaries, so the materialized
    grid spends the freed budget where it can actually prune."""
    x = np.asarray(sample, np.float64).ravel()
    if weights is None:
        w = np.full(x.size, 1.0 / max(x.size, 1))
    else:
        w = np.asarray(weights, np.float64).ravel()
        if w.shape != x.shape:
            raise ValueError(f"weights shape {w.shape} != sample {x.shape}")
        total = w.sum()
        if not np.isfinite(total) or total <= 0:
            raise ValueError("weights must be finite with positive total")
        w = w / total
    order = np.argsort(x, kind="stable")
    x, w = x[order], w[order]
    cum = np.cumsum(w)
    # inclusive CDF at each *distinct* x: keep the last position of each run
    last = np.ones(x.size, bool)
    last[:-1] = x[1:] != x[:-1]
    xd, cumd = x[last], cum[last]
    if mass_clamp is not None and xd.size > 1:
        mass = np.diff(cumd, prepend=0.0)
        mass = _clamp_masses(mass, float(mass_clamp))
        cumd = np.cumsum(mass)
        cumd /= cumd[-1]
    return xd, cumd


def _clamp_masses(mass: np.ndarray, clamp: float) -> np.ndarray:
    """Water-fill per-key masses so none exceeds ``clamp`` and the total
    stays 1: scale the unsaturated keys up uniformly, saturating keys at
    the cap as the scale pushes them over, until the scaled remainder fits.
    Exact fixed point (each round saturates at least one key, and at most
    ``1/clamp`` keys can ever saturate, so the loop is short); when every
    key caps out — fewer distinct keys than buckets — mass goes uniform,
    which is the best a point-lookup summary can do."""
    if not 0.0 < clamp < 1.0 or mass.max() <= clamp:
        return mass
    sat = np.zeros(mass.size, bool)
    for _ in range(mass.size):
        free = 1.0 - clamp * sat.sum()
        unsat_mass = mass[~sat].sum()
        if free <= 0.0 or unsat_mass <= 0.0:
            break
        scale = free / unsat_mass
        newly = ~sat & (mass * scale > clamp)
        if not newly.any():
            out = np.where(sat, clamp, mass * scale)
            return out / out.sum()
        sat |= newly
    return np.full(mass.size, 1.0 / mass.size)


def _greedy_knots(x: np.ndarray, y: np.ndarray, eps: float) -> list[int]:
    """FITing-Tree's shrinking cone: indices of a maximal greedy knot set
    such that some line from each knot stays within ``eps`` of every point
    up to the next knot. O(n) — each point narrows one cone once."""
    n = x.size
    knots = [0]
    s = 0
    while s < n - 1:
        lo, hi = -np.inf, np.inf
        j = s + 1
        while j < n:
            dx = x[j] - x[s]
            lo = max(lo, (y[j] - eps - y[s]) / dx)
            hi = min(hi, (y[j] + eps - y[s]) / dx)
            if lo > hi:        # cone emptied: previous point ends the segment
                break
            j += 1
        end = j - 1 if j < n else n - 1
        knots.append(end)
        s = end
    return knots


def fit_cdf(sample, weights=None, *, segments: int = DEFAULT_SEGMENTS,
            mass_clamp: float | None = None) -> PiecewiseLinearModel:
    """Fit a monotone piecewise-linear CDF with at most ``segments``
    segments, minimizing the error bound by binary search.

    The greedy cone pass is monotone in eps (larger eps => fewer segments),
    so ~40 bisection steps over [0, 1] find the smallest error bound whose
    greedy cover fits the budget; the knots are the empirical CDF points at
    the final cover's cut positions (monotone by construction, so the
    inverse CDF in ``boundaries`` is well defined).

    With ``mass_clamp`` the fit target is the *boundary-allocation* CDF —
    per-key mass capped at one bucket's worth (see ``_weighted_cdf_points``)
    — rather than the raw data CDF; ``max_error`` is measured against that
    target. Raises ``DegenerateSample`` when the sample has fewer than two
    distinct keys — there is no CDF to fit; callers fall back to the
    equal-mass path.
    """
    if segments < 1:
        raise ValueError(f"segments must be >= 1, got {segments}")
    x, y = _weighted_cdf_points(sample, weights, mass_clamp)
    if x.size < 2:
        raise DegenerateSample(
            f"need >= 2 distinct keys to fit a CDF, got {x.size}")
    lo, hi = 0.0, 1.0
    knots = None
    if len(_greedy_knots(x, y, 0.0)) - 1 <= segments:
        knots, hi = _greedy_knots(x, y, 0.0), 0.0       # exactly representable
    else:
        for _ in range(40):
            mid = 0.5 * (lo + hi)
            k = _greedy_knots(x, y, mid)
            if len(k) - 1 <= segments:
                hi, knots = mid, k
            else:
                lo = mid
    idx = np.asarray(knots, np.int64)
    kx = np.full(segments + 1, x[idx[-1]], np.float64)
    ky = np.full(segments + 1, y[idx[-1]], np.float64)
    kx[: idx.size] = x[idx]
    ky[: idx.size] = y[idx]
    achieved = float(np.abs(
        np.interp(x, kx[: idx.size], ky[: idx.size]) - y).max())
    return PiecewiseLinearModel(knots_x=kx, knots_y=ky, n_knots=int(idx.size),
                                segments=segments, max_error=achieved)


def boundaries(model: PiecewiseLinearModel, resolution: int) -> hg.Histogram:
    """Materialize H adaptive bucket boundaries from the model: inverse CDF
    at the equi-mass grid, finalized to strictly increasing float32 (the
    invariant ``writer._drain_resummarize`` validates). The result is an
    ordinary ``Histogram`` — every consumer of bounds runs unchanged."""
    kx = model.knots_x[: model.n_knots]
    ky = model.knots_y[: model.n_knots]
    qs = np.linspace(0.0, 1.0, resolution + 1)
    b = np.interp(qs, ky, kx)
    b[0], b[-1] = kx[0], kx[-1]          # edges cover the modeled span
    return hg.Histogram(bounds=jnp.asarray(hg.strict_float32_bounds(b)))


def build_histogram(sample, resolution: int,
                    *, segments: int = DEFAULT_SEGMENTS
                    ) -> tuple[hg.Histogram, PiecewiseLinearModel | None]:
    """CREATE INDEX path: fit the build sample and materialize bounds.

    Returns ``(hist, model)``; on a degenerate sample the equal-mass
    builder is the fallback/oracle and ``model`` is None.
    """
    sample = np.asarray(sample, np.float32).ravel()
    try:
        model = fit_cdf(sample, segments=segments,
                        mass_clamp=1.0 / resolution)
    except DegenerateSample:
        return hg.build(jnp.asarray(sample), resolution), None
    return boundaries(model, resolution), model


def learned_rebuild(hist: hg.Histogram, sample: np.ndarray,
                    resolution: int | None = None,
                    *, segments: int = DEFAULT_SEGMENTS,
                    old_mass: float = OLD_MASS_FRACTION
                    ) -> tuple[hg.Histogram, PiecewiseLinearModel | None]:
    """Drift-refit path: fit {old boundary summary, reservoir sample} with
    the reservoir carrying ``1 - old_mass`` of the total mass.

    The learned twin of ``histogram.rebuild`` (same inputs, same no-table-
    re-read contract): the old bounds' H+1 points summarize the pre-drift
    distribution and keep ``old_mass`` of the boundary budget; the reservoir
    — where the workload is writing, and under drift querying — gets the
    rest, plus the PLR smoothing that stops duplicate-heavy reservoirs from
    collapsing quantiles into epsilon ladders. Returns ``(hist, model)``;
    degenerate inputs fall back to equal-mass ``rebuild`` with model None.
    """
    sample = np.asarray(sample, np.float32).ravel()
    if sample.size == 0:
        raise ValueError("learned_rebuild needs a non-empty sample of "
                         "recent inserts")
    if not 0.0 <= old_mass < 1.0:
        raise ValueError(f"old_mass must be in [0, 1), got {old_mass}")
    if resolution is None:
        resolution = hist.resolution
    old_pts = hg.host_bounds(hist).astype(np.float64)
    pts = np.concatenate([old_pts, sample.astype(np.float64)])
    wts = np.concatenate([
        np.full(old_pts.size, old_mass / old_pts.size),
        np.full(sample.size, (1.0 - old_mass) / sample.size)])
    try:
        model = fit_cdf(pts, wts, segments=segments,
                        mass_clamp=1.0 / resolution)
    except DegenerateSample:
        return hg.rebuild(hist, sample, resolution), None
    return boundaries(model, resolution), model
