"""Density-driven page grouping (§4.3, Algorithm 2).

The build scans pages in storage order, OR-ing each page's bucket bitmap into
a *working partial histogram*; when the working histogram's density exceeds
the user threshold D, the current entry is cut (the triggering page is the
entry's last page) and a fresh working histogram starts at the next page.

``group_pages`` is the jit-compiled device scan; it emits one boolean cut-flag
per page. ``page_bucket_bits`` produces per-page bucket membership (the unpacked
partial histogram of a single page). Entry extraction from flags is a cheap
host step (``finalize_entries``) since it only runs at build time.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core.histogram import Histogram, bucketize


@partial(jax.jit, static_argnames=("resolution",))
def page_bucket_bits(hist: Histogram, keys: jnp.ndarray, valid: jnp.ndarray,
                     resolution: int) -> jnp.ndarray:
    """Per-page bucket membership: (num_pages, H) bool.

    keys/valid: (num_pages, page_card). Invalid tuples hit no bucket.
    A single scatter covers all tuples — the vectorized form of the paper's
    per-tuple binary search + bucket-set accumulation (§4.2).
    """
    num_pages, page_card = keys.shape
    ids = bucketize(hist, keys.reshape(-1))                     # (N,)
    ids = jnp.where(valid.reshape(-1), ids, -1)                 # dropped by mode=drop
    page_idx = jnp.repeat(jnp.arange(num_pages, dtype=jnp.int32), page_card)
    bits = jnp.zeros((num_pages, resolution), dtype=bool)
    return bits.at[page_idx, ids].set(True, mode="drop")


@partial(jax.jit, static_argnames=("resolution", "density_threshold"))
def group_pages(page_bits: jnp.ndarray, resolution: int,
                density_threshold: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Algorithm 2 grouping scan.

    page_bits: (num_pages, H) bool per-page bucket membership.
    Returns (cut_flags (num_pages,) bool, merged_bits (num_pages, H) bool) where
    merged_bits[p] is the working histogram *after* absorbing page p — the
    entry bitmap whenever cut_flags[p] is set.
    """
    h = resolution

    def step(acc, pb):
        merged = acc | pb
        dens = merged.sum() / h
        cut = dens > density_threshold
        nxt = jnp.where(cut, jnp.zeros_like(merged), merged)
        return nxt, (cut, merged)

    init = jnp.zeros((h,), dtype=bool)
    _, (flags, merged) = jax.lax.scan(step, init, page_bits)
    # Trailing partial entry: the last page always closes an entry (§4,
    # "store the partial histogram ... as an index entry" for the remainder).
    flags = flags.at[-1].set(True)
    return flags, merged


def finalize_entries(flags: np.ndarray, merged_bits: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Extract (starts, ends, entry_bitmaps_packed) from the grouping scan.

    Host-side (build-time only). ``ends`` are the cut pages; ``starts`` follow
    the previous cut. Bitmaps are packed to uint32 words.
    """
    flags = np.asarray(flags)
    merged_bits = np.asarray(merged_bits)
    ends = np.flatnonzero(flags).astype(np.int32)
    starts = np.concatenate([[0], ends[:-1] + 1]).astype(np.int32)
    entry_bits = merged_bits[ends]                               # (E, H) bool
    packed = np.asarray(bm.from_bool(jnp.asarray(entry_bits)))   # (E, W) uint32
    return starts, ends, packed
