"""High-level Hippo index API — the paper's CREATE INDEX / SELECT / INSERT /
DELETE / VACUUM surface (§7.1), wrapping the functional core.

    table = PagedTable.from_values(values, page_card=50)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    res = idx.search(Predicate.between(1000, 2000))
    idx.insert(1234.0)                  # eager (Algorithm 3)
    table.delete_where(500, 600)        # marks pages dirty
    idx.vacuum()                        # lazy re-summarize (§5.2)

The wrapper owns the host-side table handle plus the device ``HippoState`` and
keeps simple maintenance counters (entries touched, bytes written) used by the
maintenance benchmarks as the I/O-cost metric.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.core import histogram as hg
from repro.core import index as hix
from repro.core.predicate import (Predicate, intervals, to_bucket_bitmap,
                                  to_bucket_bitmaps)
from repro.storage.table import PagedTable


def sample_keys(table: PagedTable, sample_size: int = 65536) -> np.ndarray:
    """The CREATE INDEX build sample: live tuples, capped at ``sample_size``
    by a fixed-seed uniform draw. One definition of the sampling policy so
    every summary policy (equal-mass quantiles, learned CDF) fits the same
    sample."""
    if table.num_pages == 0:
        raise ValueError(
            "empty table: pass an explicit hist (the complete histogram "
            "is DBMS-maintained and cannot be sampled from zero tuples)")
    live = table.keys[: table.num_pages][table.valid[: table.num_pages]]
    if live.size > sample_size:
        rng = np.random.default_rng(0)
        live = rng.choice(live, size=sample_size, replace=False)
    return live


def sample_histogram(table: PagedTable, resolution: int,
                     sample_size: int = 65536) -> hg.Histogram:
    """The DBMS-maintained complete histogram, sampled from the table (§4.1).

    Shared by the unsharded and sharded CREATE INDEX paths so the sampling
    policy (live-tuple mask, fixed seed, cap) has one definition.
    """
    return hg.build(jnp.asarray(sample_keys(table, sample_size)), resolution)


@dataclass
class MaintenanceCounters:
    inserts: int = 0
    entries_touched: int = 0
    entries_created: int = 0
    vacuums: int = 0
    entries_resummarized: int = 0


@dataclass
class HippoIndex:
    cfg: hix.HippoConfig
    state: hix.HippoState
    table: PagedTable
    counters: MaintenanceCounters = field(default_factory=MaintenanceCounters)

    # -- creation ------------------------------------------------------------

    @staticmethod
    def create(table: PagedTable, resolution: int = 400, density: float = 0.2,
               max_slots: int | None = None, sample_size: int = 65536,
               relocate_on_update: bool = True, hist: hg.Histogram | None = None,
               ) -> "HippoIndex":
        """CREATE INDEX ... USING hippo(attr). Builds the complete histogram
        from a table sample (the DBMS-maintained histogram, §4.1), then runs
        Algorithm 2."""
        if max_slots is None:
            # worst case one entry per page, plus an update budget
            max_slots = int(table.num_pages * 1.25) + 1024
        cfg = hix.HippoConfig(resolution=resolution, density=density,
                              page_card=table.page_card, max_slots=max_slots,
                              relocate_on_update=relocate_on_update)
        if hist is None:
            hist = sample_histogram(table, resolution, sample_size)
        state = hix.build(cfg, hist, table.device_keys(), table.device_valid())
        return HippoIndex(cfg=cfg, state=state, table=table)

    # -- query (Algorithm 1) ---------------------------------------------------

    def search(self, pred: Predicate) -> hix.SearchResult:
        qbm = to_bucket_bitmap(pred, self.state.histogram)
        los, his = intervals([pred])
        return hix.search(self.state, qbm, self.table.device_keys(),
                          self.table.device_valid(), los[0], his[0])

    def search_batch(self, preds: list[Predicate]) -> hix.BatchSearchResult:
        """Batched Algorithm 1: Q predicates in one device program.

        Row q of the result equals the corresponding ``search(preds[q])``
        scalars; see ``runtime.engine.QueryEngine`` for the queued/slotted
        serving front over this path.
        """
        qbms = to_bucket_bitmaps(preds, self.state.histogram)
        los, his = intervals(preds)
        return hix.search_many(self.state, qbms, self.table.device_keys(),
                               self.table.device_valid(), los, his)

    def search_compact(self, pred: Predicate, max_selected: int | None = None):
        """Gather-path search. Returns (count, pages_inspected, truncated)."""
        qbm = to_bucket_bitmap(pred, self.state.histogram)
        if max_selected is None:
            max_selected = self.table.num_pages
        los, his = intervals([pred])
        return hix.search_compact(self.state, qbm, self.table.device_keys(),
                                  self.table.device_valid(), los[0], his[0],
                                  max_selected=max_selected)

    def search_compact_batch(self, preds: list[Predicate], *,
                             max_selected: int, top_k: int = 0
                             ) -> hix.CompactBatchResult:
        """Batched gather path: union the batch's page masks, gather once,
        inspect every predicate against the shared slab
        (``core.index.search_compact_many``). Counts are bit-identical to
        ``search_batch`` for rows whose ``truncated`` flag is clear; with
        ``top_k`` set, rows carry qualifying global row ids
        (``page_id * page_card + slot``, decode via
        ``PagedTable.row_values``)."""
        qbms = to_bucket_bitmaps(preds, self.state.histogram)
        los, his = intervals(preds)
        return hix.search_compact_many(
            self.state, qbms, self.table.device_keys(),
            self.table.device_valid(), los, his,
            max_selected=max_selected, top_k=top_k)

    @property
    def gather_cap(self) -> int:
        """Slab width at which the gather path can never truncate (the
        compact engine mode's dense-fallback ``max_selected``)."""
        return max(self.table.num_pages, 1)

    # -- maintenance -----------------------------------------------------------

    def _require_slot_capacity(self, needed: int = 1) -> None:
        """Refuse maintenance that would overflow the physical slot array.

        The jit'd update paths cannot raise; an out-of-capacity scatter would
        silently drop writes and corrupt the sorted list. Checked here, before
        any table or index state changes.
        """
        if int(self.state.num_slots) + needed > self.cfg.max_slots:
            raise RuntimeError(
                f"index at slot capacity ({int(self.state.num_slots)}/"
                f"{self.cfg.max_slots}); rebuild with a larger max_slots")

    def insert(self, value: float) -> None:
        """Eager single-tuple insert: table append + Algorithm 3 update."""
        _, opens_page = self.table.next_page_id()
        if opens_page or self.cfg.relocate_on_update:
            # Only the new-entry and relocation paths consume a slot;
            # in-place bit updates never do.
            self._require_slot_capacity()
        page_id, _ = self.table.insert(value)
        before = int(self.state.num_entries)
        self.state = hix.insert_tuple(self.cfg, self.state, jnp.float32(value),
                                      jnp.int32(page_id))
        self.counters.inserts += 1
        self.counters.entries_touched += 1
        self.counters.entries_created += int(self.state.num_entries) - before

    def insert_batch(self, values: np.ndarray) -> None:
        """Vectorized insert (beyond-paper fast path). Atomic: either the
        whole batch lands or, on slot-capacity exhaustion, table and index
        are rolled back to their pre-batch snapshot before the raise.

        Tuples landing on already-summarized pages take one fused scatter;
        tuples opening new pages replay the eager path (they are few: at most
        one page per page_card tuples).
        """
        values = np.asarray(values, np.float32).ravel()
        if values.size == 0:
            return
        snap_state = self.state
        snap_pages, snap_fill = self.table.num_pages, self.table.fill
        try:
            self._insert_batch_apply(values)
        except RuntimeError:
            self.state = snap_state
            self.table.truncate_to(snap_pages, snap_fill)
            raise
        self.counters.inserts += len(values)

    def _insert_batch_apply(self, values: np.ndarray) -> None:
        pages = []
        for v in values:
            pid, _ = self.table.insert(float(v))
            pages.append(pid)
        pages = np.asarray(pages, np.int32)
        old_mask = pages <= int(self.state.summarized_until)
        if old_mask.any():
            # full batch passed with a mask => one stable jit shape per N;
            # the fused scatter never relocates, so it consumes no slots
            self.state = hix.insert_batch_existing(
                self.cfg, self.state, jnp.asarray(values),
                jnp.asarray(pages), jnp.asarray(old_mask))
        for v, p in zip(values[~old_mask], pages[~old_mask]):
            # only page-opening creates and (with relocation) eager updates
            # can consume a slot — check per tuple, at actual need
            if self.cfg.relocate_on_update or p > int(self.state.summarized_until):
                self._require_slot_capacity()
            self.state = hix.insert_tuple(self.cfg, self.state, jnp.float32(v),
                                          jnp.int32(p))

    def vacuum(self) -> int:
        """Lazy maintenance after deletes (§5.2): re-summarize entries whose
        ranges contain dirty pages. Returns entries re-summarized."""
        dirty_pages = np.flatnonzero(self.table.dirty[: self.table.num_pages])
        if dirty_pages.size == 0:
            return 0
        s = self.cfg.max_slots
        affected = np.zeros((s,), bool)
        for p in dirty_pages:
            slot, _ = hix.locate_slot(self.state, jnp.int32(int(p)))
            affected[int(slot)] = True
        self.state = hix.resummarize_slots(
            self.cfg, self.state, self.table.device_keys(),
            self.table.device_valid(), jnp.asarray(affected))
        self.table.clear_dirty(dirty_pages)
        n = int(affected.sum())
        self.counters.vacuums += 1
        self.counters.entries_resummarized += n
        return n

    # -- introspection ----------------------------------------------------------

    @property
    def num_entries(self) -> int:
        return int(self.state.num_entries)

    def nbytes(self, compressed: bool = False) -> int:
        return hix.index_nbytes(self.cfg, self.state, compressed=compressed)

    def entries_host(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(starts, ends, bitmaps) of live entries in logical order."""
        order = np.asarray(self.state.sorted_order)[: self.num_entries]
        return (np.asarray(self.state.starts)[order],
                np.asarray(self.state.ends)[order],
                np.asarray(self.state.bitmaps)[order])
