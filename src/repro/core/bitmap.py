"""Packed bitmap primitives for Hippo partial histograms.

The paper stores each partial histogram as a compressed bitmap over the H
buckets of the complete histogram (§2, §4.2). On TPU we keep bitmaps as
fixed-width packed ``uint32`` word arrays — lane-parallel AND/OR on the VPU is
the hardware-native form of the paper's "bit-level parallelism" (§3.2).
RLE compression is applied only at the serialization boundary (see
``rle_compress``/``rle_decompress``), mirroring WAH-style on-disk compression.

All functions are pure jnp and jit-safe; shapes are static.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

WORD_BITS = 32


def num_words(num_bits: int) -> int:
    """Words needed to hold ``num_bits`` bits."""
    return (num_bits + WORD_BITS - 1) // WORD_BITS


def zeros(num_bits: int, *leading) -> jnp.ndarray:
    """An all-zero packed bitmap with optional leading batch dims."""
    return jnp.zeros((*leading, num_words(num_bits)), dtype=jnp.uint32)


def set_bit(bm: jnp.ndarray, idx) -> jnp.ndarray:
    """Set bit ``idx`` (scalar) in the trailing word axis of ``bm``."""
    word = idx // WORD_BITS
    bit = jnp.uint32(idx % WORD_BITS)
    return bm.at[..., word].set(bm[..., word] | (jnp.uint32(1) << bit))


def get_bit(bm: jnp.ndarray, idx) -> jnp.ndarray:
    word = idx // WORD_BITS
    bit = jnp.uint32(idx % WORD_BITS)
    return (bm[..., word] >> bit) & jnp.uint32(1)


def from_bool(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a (..., H) boolean array into (..., ceil(H/32)) uint32 words.

    Bit ``b`` of word ``w`` corresponds to bucket ``w*32 + b``.
    """
    h = bits.shape[-1]
    w = num_words(h)
    pad = w * WORD_BITS - h
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*bits.shape[:-1], pad), dtype=bits.dtype)], axis=-1
        )
    bits = bits.reshape(*bits.shape[:-1], w, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def to_bool(bm: jnp.ndarray, num_bits: int) -> jnp.ndarray:
    """Unpack (..., W) words to a (..., num_bits) boolean array."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (bm[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*bm.shape[:-1], bm.shape[-1] * WORD_BITS)
    return bits[..., :num_bits].astype(bool)


def popcount(bm: jnp.ndarray) -> jnp.ndarray:
    """Per-bitmap population count over the trailing word axis (int32)."""
    x = bm
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x * jnp.uint32(0x01010101)) >> 24
    return x.astype(jnp.int32).sum(axis=-1)


def density(bm: jnp.ndarray, num_bits: int) -> jnp.ndarray:
    """Partial histogram density (§4.3): kept buckets / total buckets."""
    return popcount(bm).astype(jnp.float32) / jnp.float32(num_bits)


def any_joint(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """True where bitmaps share at least one set bit (joint buckets, §3.2).

    Broadcasts over leading dims; reduces the trailing word axis.
    """
    return jnp.any((a & b) != 0, axis=-1)


def union(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a | b


def range_mask(num_bits: int, lo, hi) -> jnp.ndarray:
    """Packed bitmap with bits [lo, hi] (inclusive) set. lo/hi may be traced."""
    idx = jnp.arange(num_words(num_bits) * WORD_BITS, dtype=jnp.int32)
    bits = (idx >= lo) & (idx <= hi) & (idx < num_bits)
    return from_bool(bits)


# ---------------------------------------------------------------------------
# Serialization-boundary compression (host-side numpy; mirrors the paper's
# compressed on-disk bitmap format).
# ---------------------------------------------------------------------------

def rle_compress(words: np.ndarray) -> np.ndarray:
    """Simple word-level RLE: runs of identical words -> (count, word) pairs.

    Operates on a 1-D uint32 word array (one bitmap, or a flattened batch).
    Returns a 1-D uint32 array of interleaved (count, word) pairs.
    """
    words = np.asarray(words, dtype=np.uint32).ravel()
    if words.size == 0:
        return np.zeros((0,), dtype=np.uint32)
    change = np.flatnonzero(np.diff(words)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [words.size]])
    counts = (ends - starts).astype(np.uint32)
    vals = words[starts]
    return np.stack([counts, vals], axis=1).ravel()


def rle_decompress(pairs: np.ndarray) -> np.ndarray:
    pairs = np.asarray(pairs, dtype=np.uint32).reshape(-1, 2)
    return np.repeat(pairs[:, 1], pairs[:, 0])


def compressed_nbytes(words: np.ndarray) -> int:
    """Size in bytes of the RLE-compressed form (paper's storage metric)."""
    return int(rle_compress(words).nbytes)
