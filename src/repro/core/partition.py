"""Sharded partition layer — device-parallel Hippo over contiguous page slabs.

The paper scales Hippo by keeping the index tiny while the *table* grows
(§6's storage model, §7's TPC-H experiments); this layer scales it across
*devices*. The page space is split into S contiguous slabs ("shards") of
``pages_per_shard`` pages each, and every shard carries a full, independent
Hippo structure over its slab:

  shard           a contiguous page extent [s*PPS, (s+1)*PPS) with its own
                  entry table — the paper's index over one table fragment,
                  so every per-shard quantity (§6 index size, §6.1 query
                  cost SF*H, §7 maintenance I/O) applies per shard unchanged
  routing map     ``ShardSpec``: pure page-id arithmetic mapping any page to
                  its owning shard (the thin analogue of a partition catalog)
  summary bitmap  the union of a shard's live partial-histogram bitmaps —
                  one (W,) packed bitmap per shard. A query whose bucket
                  bitmap shares no joint bucket with a shard's summary
                  (§3.2's test, lifted from entries to shards) cannot match
                  any entry there, so the shard is skipped outright:
                  partition pruning with the same no-false-negative guarantee
                  as the entry-level filter

Search runs Algorithm 1 per shard and reduces counts/match-stats across the
shard axis (``core.index.search_many_sharded``); because shards partition the
page space and page inspection is exact, per-shard counts sum bit-identically
to the unsharded count. Maintenance (Algorithm 3 inserts, §5.2 vacuum) routes
through ``ShardSpec`` and touches exactly one shard's arrays per page — the
locality that lets shards live on different devices (``launch.shardings``)
and lets the async writer (``runtime.writer.MaintenanceWriter``) rebuild and
swap shard s's slice between query batches while every other shard keeps
serving. The writer attaches as ``staging`` (its pending rows overlay into
``search_batch`` counts) and raises ``swap_in_flight`` while a slice is
mid-swap, which every query/maintenance surface checks.

Entry page ids inside each shard are *local* to its slab; global page order
is recovered by construction since slabs are contiguous and append-ordered.

Bounds epochs (drift adaptation): every shard carries its *own* complete-
histogram boundary set (``SHARD_AXES.bounds = 0``), initially identical
across shards. A drift re-summarization (``runtime.writer``) remaps shards
onto new bounds one at a time, bumping that shard's entry in
``bounds_epochs``; predicates are converted once per distinct epoch and fed
to the fused search paths as (S, Q, W) per-shard query bitmaps, so every
shard's query bitmaps and page bitmaps always share one bucket space —
counts stay exact before, during, and after a partial re-summarization.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import histogram as hg
from repro.core import index as hix
from repro.core import learned as ln
from repro.core.hippo import MaintenanceCounters, sample_histogram, sample_keys
from repro.core.predicate import (Predicate, intervals,
                                  interval_bitmaps_sharded, to_bucket_bitmaps)
from repro.storage.table import PagedTable

# Summary-policy ladder: how a boundary set is produced, at build time and at
# every drift refit. "equal_mass" is the paper's equi-depth quantile summary
# (``histogram.build``/``rebuild``) and the fallback/oracle; "learned" fits an
# error-bounded piecewise-linear CDF (``core.learned``) and materializes its
# boundaries — same Histogram type, same downstream stack, better placement on
# skewed/drifting keys. The policy is a property of the *index* (it governs
# every shard's bounds), consumed by ``runtime.writer.schedule_resummarize``.
SUMMARY_POLICIES = ("equal_mass", "learned")


@dataclass(frozen=True)
class ShardSpec:
    """The routing map: shard s owns global pages [s*PPS, (s+1)*PPS)."""
    num_shards: int
    pages_per_shard: int

    @property
    def total_pages(self) -> int:
        return self.num_shards * self.pages_per_shard

    def owner(self, page_id: int) -> int:
        """Owning shard of a global page id (may be >= num_shards: overflow)."""
        return page_id // self.pages_per_shard

    def page_lo(self, s: int) -> int:
        return s * self.pages_per_shard

    def to_local(self, page_id: int) -> int:
        return page_id - self.page_lo(self.owner(page_id))


class ShardedHippoState(NamedTuple):
    shards: hix.HippoState     # stacked per hix.SHARD_AXES (incl. per-shard bounds)
    summaries: jnp.ndarray     # (S, W) u32 — OR of live entry bitmaps per shard


# ---------------------------------------------------------------------------
# Stacked-state plumbing
# ---------------------------------------------------------------------------

def shard_state(shards: hix.HippoState, s: int) -> hix.HippoState:
    """Slice one shard's ``HippoState`` out of the stacked arrays."""
    return hix.HippoState(*(
        leaf if ax is None else leaf[s]
        for leaf, ax in zip(shards, hix.SHARD_AXES)))


@jax.jit
def set_shard(shards: hix.HippoState, s, st: hix.HippoState) -> hix.HippoState:
    """Write one shard's ``HippoState`` back into the stacked arrays.

    Jitted with ``s`` traced, so every shard (and every writer swap) reuses
    one compiled scatter program instead of nine eager dispatches.
    """
    return hix.HippoState(*(
        stacked if ax is None else stacked.at[s].set(new)
        for stacked, new, ax in zip(shards, st, hix.SHARD_AXES)))


@jax.jit
def summary_of(st: hix.HippoState) -> jnp.ndarray:
    """(W,) packed union of a shard's live entry bitmaps (pruning filter).

    After deletes+vacuum the union can only lose bits, so a cached summary is
    always a superset of the true union — stale summaries may fail to prune a
    shard but can never skip a matching one.
    """
    s = st.bitmaps.shape[0]
    live = st.slot_live & (jnp.arange(s) < st.num_slots)
    masked = jnp.where(live[:, None], st.bitmaps, jnp.uint32(0))
    return jax.lax.reduce(masked, jnp.uint32(0), jax.lax.bitwise_or, (0,))


def build_sharded(cfg: hix.HippoConfig, spec: ShardSpec, hist: hg.Histogram,
                  table: PagedTable) -> ShardedHippoState:
    """Algorithm 2 per shard: the grouping scan restarts at every slab
    boundary, so no entry ever spans two shards (maintenance stays local)."""
    states = []
    for s in range(spec.num_shards):
        lo = spec.page_lo(s)
        hi = min(lo + spec.pages_per_shard, table.num_pages)
        n = max(hi - lo, 0)
        keys = jnp.asarray(table.keys[lo:hi]) if n else jnp.zeros(
            (0, table.page_card), jnp.float32)
        valid = jnp.asarray(table.valid[lo:hi]) if n else jnp.zeros(
            (0, table.page_card), bool)
        states.append(hix.build(cfg, hist, keys, valid))
    shards = hix.HippoState(*(
        states[0][i] if ax is None else jnp.stack([st[i] for st in states])
        for i, ax in enumerate(hix.SHARD_AXES)))
    summaries = jnp.stack([summary_of(st) for st in states])
    return ShardedHippoState(shards=shards, summaries=summaries)


# ---------------------------------------------------------------------------
# High-level sharded index (CREATE INDEX ... PARTITION BY page range)
# ---------------------------------------------------------------------------

@dataclass
class ShardedHippoIndex:
    """Shard-parallel counterpart of ``core.hippo.HippoIndex``.

    ``cfg.max_slots`` is *per shard*. ``search_batch`` matches
    ``HippoIndex.search_batch`` in signature and in counts (bit-identical),
    so ``runtime.engine.QueryEngine`` serves either transparently; its
    sharded mode additionally uses ``plan_batch``/
    ``search_batch_shard_arrays`` for summary-pruned per-shard dispatch.
    """
    cfg: hix.HippoConfig
    spec: ShardSpec
    state: ShardedHippoState
    table: PagedTable
    counters: MaintenanceCounters = field(default_factory=MaintenanceCounters)
    # Attached ``runtime.writer.MaintenanceWriter`` (None when maintenance is
    # synchronous). When present, ``search_batch`` folds its staging-buffer
    # overlay into counts so queries never go stale while inserts wait in the
    # per-shard queues.
    staging: object | None = field(default=None, repr=False, compare=False)
    # Shard id currently being rebuilt by a writer drain (None otherwise).
    # Queries and maintenance refuse while set: mid-swap the stacked state
    # and the table disagree about that shard, and serving from it would
    # return silently wrong counts.
    swap_in_flight: int | None = field(default=None, repr=False, compare=False)
    # Per-shard bounds epoch: bumped when a drift re-summarization remaps a
    # shard onto new histogram bounds. Shards sharing an epoch share one
    # predicate conversion (``_query_bitmaps``); epochs diverge only while a
    # re-summarization is partially drained.
    bounds_epochs: np.ndarray = field(default=None, repr=False, compare=False)
    # Summary policy (see SUMMARY_POLICIES): consulted by the writer at every
    # ``schedule_resummarize`` to pick the boundary builder for the refit.
    summary: str = "equal_mass"
    # Per-shard learned model (``learned.PiecewiseLinearModel``) whose
    # boundaries shard s currently serves; None under equal-mass bounds or
    # after a degenerate-sample fallback. Recorded by the writer drain at the
    # same moment it bumps ``bounds_epochs[s]``.
    summary_models: list = field(default=None, repr=False, compare=False)

    def __post_init__(self):
        if self.bounds_epochs is None:
            self.bounds_epochs = np.zeros((self.spec.num_shards,), np.int64)
        if self.summary not in SUMMARY_POLICIES:
            raise ValueError(f"summary must be one of {SUMMARY_POLICIES}, "
                             f"got {self.summary!r}")
        if self.summary_models is None:
            self.summary_models = [None] * self.spec.num_shards

    # -- creation ------------------------------------------------------------

    @staticmethod
    def create(table: PagedTable, num_shards: int = 4, resolution: int = 400,
               density: float = 0.2, pages_per_shard: int | None = None,
               max_slots: int | None = None, sample_size: int = 65536,
               relocate_on_update: bool = True,
               hist: hg.Histogram | None = None,
               summary: str = "equal_mass") -> "ShardedHippoIndex":
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        if summary not in SUMMARY_POLICIES:
            raise ValueError(f"summary must be one of {SUMMARY_POLICIES}, "
                             f"got {summary!r}")
        if pages_per_shard is None:
            # slab headroom mirrors HippoIndex.create's slot headroom: 25%
            # growth room plus a fixed floor so tiny tables can still insert
            target = int(table.num_pages * 1.25) + 64
            pages_per_shard = -(-target // num_shards)
        spec = ShardSpec(num_shards=num_shards, pages_per_shard=pages_per_shard)
        if spec.total_pages < table.num_pages:
            raise ValueError(
                f"shard layout {num_shards}x{pages_per_shard} covers "
                f"{spec.total_pages} pages < table's {table.num_pages}")
        if max_slots is None:
            # per-shard mirror of HippoIndex.create's default: worst case one
            # entry per slab page, plus the same fixed update budget
            max_slots = int(pages_per_shard * 1.25) + 1024
        cfg = hix.HippoConfig(resolution=resolution, density=density,
                              page_card=table.page_card, max_slots=max_slots,
                              relocate_on_update=relocate_on_update)
        model = None
        if hist is None:
            if summary == "learned":
                # same build sample as the equal-mass path, fit instead of
                # quantiled; a degenerate sample falls back inside
                hist, model = ln.build_histogram(
                    sample_keys(table, sample_size), resolution)
            else:
                hist = sample_histogram(table, resolution, sample_size)
        state = build_sharded(cfg, spec, hist, table)
        return ShardedHippoIndex(cfg=cfg, spec=spec, state=state, table=table,
                                 summary=summary,
                                 summary_models=[model] * num_shards)

    # -- device views --------------------------------------------------------

    def _slabs(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        return (self.table.device_keys_sharded(self.spec.num_shards,
                                               self.spec.pages_per_shard),
                self.table.device_valid_sharded(self.spec.num_shards,
                                                self.spec.pages_per_shard))

    # -- mid-swap refusal ----------------------------------------------------

    def _check_swap_guard(self) -> None:
        """Refuse queries/maintenance while a writer drain is swapping a shard.

        Between a drain's table appends and its state swap, shard
        ``swap_in_flight``'s slice of ``ShardedHippoState`` describes a table
        that no longer exists; any result computed from it would be silently
        wrong. Single-threaded callers only hit this via re-entrancy (e.g. a
        query issued from inside a drain hook), but the refusal must be loud
        either way.
        """
        if self.swap_in_flight is not None:
            raise RuntimeError(
                f"shard {self.swap_in_flight} swap in flight: queries and "
                f"maintenance are refused until the writer drain completes "
                f"(state and table disagree about that shard mid-swap)")

    def _check_no_staged(self) -> None:
        """Refuse direct inserts while a writer holds staged rows: staged
        page routing was predicted from the table tail, and a direct append
        would shift it under the queues."""
        if self.staging is not None and self.staging.queue_depth:
            raise RuntimeError(
                f"writer has {self.staging.queue_depth} staged rows pending: "
                f"route writes through the writer (or flush() it first) — a "
                f"direct insert would shift the table tail and break the "
                f"staged rows' page routing")

    # -- query ---------------------------------------------------------------

    def _query_bitmaps(self, preds: list[Predicate]) -> jnp.ndarray:
        """(S, Q, W) packed query bitmaps, row s converted under shard s's
        histogram bounds. One fused dispatch over the stacked (S, H+1)
        bounds (``predicate.interval_bitmaps_sharded``) serves every epoch
        mix: identical rows while all shards share one bounds epoch,
        distinct rows while a drift re-summarization is partially drained —
        same trace either way."""
        if not preds:
            return bm.zeros(self.cfg.resolution, self.spec.num_shards, 0)
        los, his = intervals(preds)
        return interval_bitmaps_sharded(
            self.state.shards.bounds, los, his,
            jnp.asarray([not p.empty for p in preds]))

    def search_batch(self, preds: list[Predicate]) -> hix.BatchSearchResult:
        """Fused (Q, S) path: one device program over every shard, counts
        reduced across the shard axis. Bit-identical counts to the unsharded
        ``HippoIndex.search_batch``; with a writer attached, counts also
        include its staged-but-undrained rows (never-stale contract)."""
        self._check_swap_guard()
        qbms = self._query_bitmaps(preds)
        los, his = intervals(preds)
        keys, valid = self._slabs()
        if self.staging is not None and self.staging.staged_rows:
            vals, live = self.staging.device_buffers()
            res = hix.search_many_sharded_staged(self.state.shards, qbms, keys,
                                                 valid, los, his, vals, live)
        else:
            res = hix.search_many_sharded(self.state.shards, qbms, keys, valid,
                                          los, his)
        return res._replace(page_mask=res.page_mask[:, : self.table.num_pages])

    def search_compact_batch(self, preds: list[Predicate], *,
                             max_selected: int, top_k: int = 0
                             ) -> hix.CompactBatchResult:
        """Batched gather path over every shard in one device program
        (``core.index.search_compact_many_sharded``): each shard gathers its
        own (``max_selected``, C) slab of the batch union and inspects every
        predicate against it, counts reduced across the shard axis. With a
        writer attached, the staging-buffer overlay folds into counts exactly
        as on the dense path (never-stale contract); staged rows occupy no
        page yet, so they appear in counts only, never in row ids, and cannot
        truncate. Row ids are global (``page_id * page_card + slot``) and
        bit-identical to the unsharded gather."""
        self._check_swap_guard()
        qbms = self._query_bitmaps(preds)
        los, his = intervals(preds)
        keys, valid = self._slabs()
        if self.staging is not None and self.staging.staged_rows:
            vals, live = self.staging.device_buffers()
            return hix.search_compact_many_sharded_staged(
                self.state.shards, qbms, keys, valid, los, his, vals, live,
                max_selected=max_selected, top_k=top_k)
        return hix.search_compact_many_sharded(
            self.state.shards, qbms, keys, valid, los, his,
            max_selected=max_selected, top_k=top_k)

    @property
    def gather_cap(self) -> int:
        """Per-shard slab width at which the gather path can never truncate
        (a shard's union is at most its ``pages_per_shard`` slab pages)."""
        return self.spec.pages_per_shard

    def search_batch_shard(self, s: int, preds: list[Predicate]
                           ) -> hix.BatchSearchResult:
        """Algorithm 1 over one shard's slab only (list-of-predicates form).

        Shapes are identical for every shard, so one compiled trace per batch
        size serves all S shards. Predicates convert under *this shard's*
        bounds (shards may serve different epochs mid-resummarization)."""
        qbms = to_bucket_bitmaps(preds, self.shard_histogram(s))
        los, his = intervals(preds)
        return self.search_batch_shard_arrays(s, qbms, los, his)

    def search_batch_shard_arrays(self, s: int, qbms, los, his
                                  ) -> hix.BatchSearchResult:
        """Array form of ``search_batch_shard`` for callers that already
        converted predicates once (``plan_batch``): qbms (Q, W) uint32,
        los/his (Q,) float32. Counts are index-only — the engine's routed
        dispatch adds the writer's staging overlay itself (staged rows belong
        to no entry yet, so summary pruning cannot route them)."""
        self._check_swap_guard()
        keys, valid = self._slabs()
        return hix.search_many(shard_state(self.state.shards, s),
                               jnp.asarray(qbms), keys[s], valid[s],
                               jnp.asarray(los), jnp.asarray(his))

    def plan_batch(self, preds: list[Predicate]
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """One predicate conversion (per bounds epoch) for a routed batch.

        Returns host arrays (qbms (S, Q, W), los (Q,), his (Q,),
        match (Q, S)) where ``qbms[s]`` holds the predicates converted under
        shard s's bounds epoch and ``match[q, s]`` is the joint-bucket test
        of query q (converted for shard s) against shard s's summary. False
        entries are provably count-zero for that (query, shard) pair, so a
        dispatcher may skip them; rows of ``qbms[s]`` slice/pad directly
        into ``search_batch_shard_arrays`` calls without reconverting the
        predicates per shard.
        """
        self._check_swap_guard()
        qbms = self._query_bitmaps(preds)                       # (S, Q, W)
        los, his = intervals(preds)
        match = np.asarray(bm.any_joint(qbms,
                                        self.state.summaries[:, None, :])).T
        return np.asarray(qbms), np.asarray(los), np.asarray(his), match

    def shard_match_matrix(self, preds: list[Predicate]) -> np.ndarray:
        """(Q, S) bool pruning matrix (see ``plan_batch``)."""
        return self.plan_batch(preds)[3]

    def search(self, pred: Predicate) -> hix.BatchSearchResult:
        """Single-predicate convenience: row 0 of a Q=1 fused batch."""
        return self.search_batch([pred])

    def count(self, pred: Predicate) -> int:
        return int(self.search_batch([pred]).counts[0])

    # -- maintenance ---------------------------------------------------------

    def _require_capacity(self, s: int, page_id: int, opens_page: bool) -> None:
        """Refuse, before any mutation, inserts the shard layout cannot hold:
        a page past the last slab, or slot exhaustion inside shard s."""
        if s >= self.spec.num_shards:
            raise RuntimeError(
                f"shard layout full: page {page_id} falls past shard "
                f"{self.spec.num_shards - 1}'s slab "
                f"(pages_per_shard={self.spec.pages_per_shard}); rebuild with "
                f"more shards or larger slabs")
        if opens_page or self.cfg.relocate_on_update:
            if int(self.state.shards.num_slots[s]) + 1 > self.cfg.max_slots:
                raise RuntimeError(
                    f"shard {s} at slot capacity "
                    f"({int(self.state.shards.num_slots[s])}/"
                    f"{self.cfg.max_slots}); rebuild with a larger max_slots")

    def _apply_shard(self, s: int, st: hix.HippoState) -> None:
        self.state = ShardedHippoState(
            shards=set_shard(self.state.shards, s, st),
            summaries=self.state.summaries.at[s].set(summary_of(st)))

    def insert(self, value: float) -> None:
        """Eager insert routed to the owning shard (Algorithm 3, shard-local)."""
        self._check_swap_guard()
        self._check_no_staged()
        page_id, opens_page = self.table.next_page_id()
        s = self.spec.owner(page_id)
        self._require_capacity(s, page_id, opens_page)
        self.table.insert(value)
        st = shard_state(self.state.shards, s)
        before = int(st.num_entries)
        st = hix.insert_tuple(self.cfg, st, jnp.float32(value),
                              jnp.int32(self.spec.to_local(page_id)))
        self._apply_shard(s, st)
        self.counters.inserts += 1
        self.counters.entries_touched += 1
        self.counters.entries_created += int(st.num_entries) - before

    def insert_batch(self, values: np.ndarray) -> None:
        """Atomic vectorized insert: tuples landing on already-summarized
        pages take one fused scatter per touched shard (same batch shape for
        every shard => one compiled trace); page-opening tuples replay the
        eager path. On refusal the table and every shard roll back."""
        self._check_swap_guard()
        self._check_no_staged()
        values = np.asarray(values, np.float32).ravel()
        if values.size == 0:
            return
        snap_state = self.state
        snap_pages, snap_fill = self.table.num_pages, self.table.fill
        try:
            self._insert_batch_apply(values)
        except RuntimeError:
            self.state = snap_state
            self.table.truncate_to(snap_pages, snap_fill)
            raise
        self.counters.inserts += len(values)

    def _insert_batch_apply(self, values: np.ndarray) -> None:
        pages = []
        for v in values:
            pid, _ = self.table.insert(float(v))
            if self.spec.owner(pid) >= self.spec.num_shards:
                raise RuntimeError(
                    f"shard layout full: page {pid} falls past shard "
                    f"{self.spec.num_shards - 1}'s slab; rebuild with more "
                    f"shards or larger slabs")
            pages.append(pid)
        pages = np.asarray(pages, np.int32)
        owners = pages // self.spec.pages_per_shard
        old_mask = pages <= self.summarized_until
        vals_dev = jnp.asarray(values)
        for s in np.unique(owners[old_mask]):
            local = jnp.asarray(np.clip(pages - self.spec.page_lo(int(s)), 0,
                                        self.spec.pages_per_shard - 1))
            mask = jnp.asarray(old_mask & (owners == s))
            st = hix.insert_batch_existing(
                self.cfg, shard_state(self.state.shards, int(s)), vals_dev,
                local, mask)
            self._apply_shard(int(s), st)
        for v, p in zip(values[~old_mask], pages[~old_mask]):
            s = self.spec.owner(int(p))
            opens = int(p) > self.summarized_until
            if opens or self.cfg.relocate_on_update:
                self._require_capacity(s, int(p), opens)
            st = hix.insert_tuple(self.cfg, shard_state(self.state.shards, s),
                                  jnp.float32(v),
                                  jnp.int32(self.spec.to_local(int(p))))
            self._apply_shard(s, st)

    def dirty_shards(self) -> np.ndarray:
        """Shard ids owning at least one dirty page (pending vacuum work)."""
        dirty_pages = np.flatnonzero(self.table.dirty[: self.table.num_pages])
        return np.unique(dirty_pages // self.spec.pages_per_shard)

    def vacuum(self) -> int:
        """§5.2 lazy maintenance, shard-grouped: dirty pages re-summarize
        entries inside their owning shards only (dirty spans touch each shard
        independently). Returns total entries re-summarized."""
        self._check_swap_guard()
        shards = self.dirty_shards()
        if shards.size == 0:
            return 0
        total = 0
        for s in shards:
            total += self._vacuum_shard_locked(int(s))
        return total

    def vacuum_shard(self, s: int) -> int:
        """Vacuum one shard: re-summarize its entries covering dirty pages
        and clear *only that shard's* dirty notes. The per-shard unit of work
        the async writer drains between query batches — other shards' dirty
        pages stay queued, and their state/summaries are untouched. Returns
        entries re-summarized (0 if the shard has no dirty pages)."""
        self._check_swap_guard()
        return self._vacuum_shard_locked(s)

    def _vacuum_shard_locked(self, s: int) -> int:
        """``vacuum_shard`` body without the swap guard — for the writer,
        which holds ``swap_in_flight`` itself while draining a vacuum."""
        dirty_pages = np.flatnonzero(self.table.dirty[: self.table.num_pages])
        dirty_pages = dirty_pages[dirty_pages // self.spec.pages_per_shard == s]
        if dirty_pages.size == 0:
            return 0
        keys, valid = self._slabs()
        st = shard_state(self.state.shards, s)
        affected = np.zeros((self.cfg.max_slots,), bool)
        lo = self.spec.page_lo(s)
        for p in dirty_pages:
            slot, _ = hix.locate_slot(st, jnp.int32(int(p) - lo))
            affected[int(slot)] = True
        st = hix.resummarize_slots(self.cfg, st, keys[s], valid[s],
                                   jnp.asarray(affected))
        self._apply_shard(s, st)
        self.table.clear_dirty(dirty_pages)
        n = int(affected.sum())
        # one counted vacuum per shard that actually did work, on every
        # entry point (vacuum / vacuum_shard / writer drain) alike
        self.counters.vacuums += 1
        self.counters.entries_resummarized += n
        return n

    # -- introspection -------------------------------------------------------

    def shard_histogram(self, s: int) -> hg.Histogram:
        """Shard s's complete histogram (its current bounds epoch)."""
        return hg.Histogram(self.state.shards.bounds[s])

    @property
    def histogram(self) -> hg.Histogram:
        """The histogram shared by every shard — valid only while all shards
        sit on one bounds epoch (always true outside a partially-drained
        re-summarization); prefer ``shard_histogram`` in epoch-aware code."""
        return self.shard_histogram(0)

    @property
    def num_shards(self) -> int:
        return self.spec.num_shards

    @property
    def num_entries(self) -> int:
        return int(np.asarray(self.state.shards.num_entries).sum())

    @property
    def summarized_until(self) -> int:
        """Last globally-summarized page id (-1 if the index is empty)."""
        su = np.asarray(self.state.shards.summarized_until)
        glob = np.where(su >= 0,
                        su + np.arange(self.spec.num_shards) *
                        self.spec.pages_per_shard, -1)
        return int(glob.max())

    def shard_entry_counts(self) -> np.ndarray:
        return np.asarray(self.state.shards.num_entries)

    def nbytes(self, compressed: bool = False) -> int:
        """Live index bytes summed over shards, plus the routing map and the
        per-shard summary bitmaps (the layer's only additions)."""
        total = 0
        for s in range(self.spec.num_shards):
            total += hix.index_nbytes(self.cfg, shard_state(self.state.shards, s),
                                      compressed=compressed)
        total += self.spec.num_shards * 8        # routing map: page range per shard
        total += int(np.asarray(self.state.summaries).nbytes)
        return total

    # -- persistence (checkpointing.snapshot) --------------------------------

    def save(self, root, *, wal_seqno: int = 0, keep: int = 3, **kw):
        """Durably snapshot this index (table, shards, bounds/epochs, models,
        and any attached writer's staged state) under ``<root>/snap_<N>/``.
        Returns the committed snapshot directory. Extra keywords (``epoch``,
        ``compact``) pass through to
        ``repro.checkpointing.snapshot.save_index``."""
        from repro.checkpointing.snapshot import save_index
        return save_index(root, self, wal_seqno=wal_seqno, keep=keep, **kw)

    def save_delta(self, root, *, shards, wal_seqno: int = 0, **kw):
        """Durably commit an incremental delta — the given shards' index
        sections and table slab rows — against the last full snapshot under
        ``root``. See ``repro.checkpointing.snapshot.save_delta``."""
        from repro.checkpointing.snapshot import save_delta
        return save_delta(root, self, shards=shards, wal_seqno=wal_seqno,
                          **kw)

    @staticmethod
    def load(root, *, epoch: int | None = None) -> "ShardedHippoIndex":
        """Reconstruct the latest (or a given) committed snapshot. Counts,
        row ids, bounds, epochs, and learned models round-trip exactly; use
        ``checkpointing.snapshot.recover_index`` (or
        ``runtime.engine.QueryEngine.recover``) to also replay a write-ahead
        journal after a crash."""
        from repro.checkpointing.snapshot import load_index
        return load_index(root, epoch=epoch)[0]
