from repro.core.baselines.btree import BPlusTree  # noqa: F401
from repro.core.baselines.fullscan import FullScan  # noqa: F401
from repro.core.baselines.minmax import MinMaxIndex  # noqa: F401
