"""Sequential scan baseline — zero index storage, Card inspection cost."""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class FullScan:
    @staticmethod
    def search(keys: jnp.ndarray, valid: jnp.ndarray, lo, hi):
        v = keys.astype(jnp.float32)
        qual = valid & (v >= lo) & (v <= hi)
        return qual.sum(dtype=jnp.int32), jnp.int32(keys.shape[0])

    @staticmethod
    def nbytes() -> int:
        return 0
