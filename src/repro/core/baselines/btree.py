"""B+-Tree baseline (§7.3's comparison target; §8 "Tree Index Structures").

Node-based with a configurable fanout so storage and maintenance costs mirror
a disk B+-Tree: every leaf stores (key, tuple-pointer) pairs — the per-tuple
index entries whose volume is exactly what Hippo eliminates. We account:

  * nbytes()          — total node storage (the 5–15% overhead of Table 1a)
  * io.node_reads / node_writes / node_splits — maintenance cost metric
    (the paper's insert-time comparison is I/O-bound tree traversal + splits)

Leaves are numpy arrays for bulk-queries; structure mutations are per-key, as
in the real thing. Keys are float32 attribute values; pointers are
(page_id << 16 | slot) int64 tids.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class _IOCounters:
    node_reads: int = 0
    node_writes: int = 0
    node_splits: int = 0


class _Node:
    __slots__ = ("leaf", "keys", "children", "ptrs", "next")

    def __init__(self, leaf: bool):
        self.leaf = leaf
        self.keys: list[float] = []
        self.children: list[_Node] = []   # internal
        self.ptrs: list[int] = []         # leaf tuple pointers
        self.next: "_Node | None" = None  # leaf chain


@dataclass
class BPlusTree:
    fanout: int = 256
    root: _Node = field(default_factory=lambda: _Node(leaf=True))
    io: _IOCounters = field(default_factory=_IOCounters)
    num_keys: int = 0

    # -- bulk load (index initialization) ------------------------------------

    @staticmethod
    def bulk_load(values: np.ndarray, page_card: int, fanout: int = 256) -> "BPlusTree":
        """Sorted bottom-up bulk load — the fast CREATE INDEX path."""
        values = np.asarray(values, np.float32).ravel()
        order = np.argsort(values, kind="stable")
        tids = (order // page_card).astype(np.int64) << 16 | (order % page_card)
        skeys = values[order]
        t = BPlusTree(fanout=fanout)
        leaf_cap = fanout
        leaves: list[_Node] = []
        for i in range(0, len(skeys), leaf_cap):
            n = _Node(leaf=True)
            n.keys = [float(k) for k in skeys[i : i + leaf_cap]]
            n.ptrs = [int(p) for p in tids[i : i + leaf_cap]]
            if leaves:
                leaves[-1].next = n
            leaves.append(n)
            t.io.node_writes += 1
        level = leaves
        while len(level) > 1:
            parents: list[_Node] = []
            for i in range(0, len(level), fanout):
                n = _Node(leaf=False)
                n.children = level[i : i + fanout]
                n.keys = [c.keys[0] for c in n.children[1:]]
                parents.append(n)
                t.io.node_writes += 1
            level = parents
        t.root = level[0] if level else _Node(leaf=True)
        t.num_keys = len(skeys)
        return t

    # -- search ----------------------------------------------------------------

    def _descend(self, key: float) -> _Node:
        node = self.root
        while not node.leaf:
            self.io.node_reads += 1
            idx = int(np.searchsorted(node.keys, key, side="right"))
            node = node.children[idx]
        self.io.node_reads += 1
        return node

    def range_search(self, lo: float, hi: float) -> list[int]:
        """Return tuple pointers with key in [lo, hi]."""
        out: list[int] = []
        node = self._descend(lo)
        while node is not None:
            ks = np.asarray(node.keys, np.float32)
            sel = np.flatnonzero((ks >= lo) & (ks <= hi))
            out.extend(node.ptrs[i] for i in sel)
            if len(node.keys) and node.keys[-1] > hi:
                break
            node = node.next
            if node is not None:
                self.io.node_reads += 1
        return out

    def count_range(self, lo: float, hi: float) -> int:
        return len(self.range_search(lo, hi))

    # -- maintenance -------------------------------------------------------------

    def insert(self, key: float, tid: int) -> None:
        path: list[tuple[_Node, int]] = []
        node = self.root
        while not node.leaf:
            self.io.node_reads += 1
            idx = int(np.searchsorted(node.keys, key, side="right"))
            path.append((node, idx))
            node = node.children[idx]
        self.io.node_reads += 1
        pos = int(np.searchsorted(node.keys, key, side="right"))
        node.keys.insert(pos, float(key))
        node.ptrs.insert(pos, int(tid))
        self.io.node_writes += 1
        self.num_keys += 1
        # split up the path
        while len(node.keys) > self.fanout:
            self.io.node_splits += 1
            mid = len(node.keys) // 2
            right = _Node(leaf=node.leaf)
            if node.leaf:
                right.keys, node.keys = node.keys[mid:], node.keys[:mid]
                right.ptrs, node.ptrs = node.ptrs[mid:], node.ptrs[:mid]
                right.next, node.next = node.next, right
                sep = right.keys[0]
            else:
                sep = node.keys[mid]
                right.keys, node.keys = node.keys[mid + 1 :], node.keys[:mid]
                right.children, node.children = node.children[mid + 1 :], node.children[: mid + 1]
            self.io.node_writes += 2
            if path:
                parent, idx = path.pop()
                parent.keys.insert(idx, float(sep))
                parent.children.insert(idx + 1, right)
                self.io.node_writes += 1
                node = parent
            else:
                new_root = _Node(leaf=False)
                new_root.keys = [float(sep)]
                new_root.children = [node, right]
                self.root = new_root
                self.io.node_writes += 1
                break

    def delete(self, key: float) -> bool:
        """Eager single-key delete (no rebalancing — conservative I/O count)."""
        node = self._descend(key)
        ks = np.asarray(node.keys, np.float32)
        pos = np.flatnonzero(ks == np.float32(key))
        if pos.size == 0:
            return False
        i = int(pos[0])
        node.keys.pop(i)
        node.ptrs.pop(i)
        self.io.node_writes += 1
        self.num_keys -= 1
        return True

    # -- storage accounting --------------------------------------------------------

    def _count_nodes(self) -> tuple[int, int]:
        leaves = internals = 0
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.leaf:
                leaves += 1
            else:
                internals += 1
                stack.extend(n.children)
        return leaves, internals

    def nbytes(self) -> int:
        """Key + pointer bytes across all nodes (float32 key, int64 tid/child)."""
        leaves, internals = self._count_nodes()
        total = 0
        stack = [self.root]
        while stack:
            n = stack.pop()
            total += len(n.keys) * 4
            total += len(n.ptrs) * 8 if n.leaf else len(n.children) * 8
            total += 16  # header
            if not n.leaf:
                stack.extend(n.children)
        return total
