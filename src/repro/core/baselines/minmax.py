"""Min-max sparse index baseline (BRIN / Zone Map, §8 "Sparse Index
Structures").

Stores per page-range only (min, max) of the key. On unordered attributes the
ranges cover nearly the whole domain, so most predicates overlap most ranges —
the failure mode Hippo's histogram summaries fix (§1, §8). Pure jnp.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MinMaxIndex:
    pages_per_range: int
    mins: jnp.ndarray  # (R,)
    maxs: jnp.ndarray  # (R,)

    @staticmethod
    def build(keys: jnp.ndarray, valid: jnp.ndarray, pages_per_range: int = 1
              ) -> "MinMaxIndex":
        num_pages = keys.shape[0]
        r = (num_pages + pages_per_range - 1) // pages_per_range
        pad = r * pages_per_range - num_pages
        k = jnp.pad(keys.astype(jnp.float32), ((0, pad), (0, 0)))
        v = jnp.pad(valid, ((0, pad), (0, 0)))
        k = k.reshape(r, -1)
        v = v.reshape(r, -1)
        mins = jnp.where(v, k, jnp.inf).min(axis=1)
        maxs = jnp.where(v, k, -jnp.inf).max(axis=1)
        return MinMaxIndex(pages_per_range=pages_per_range, mins=mins, maxs=maxs)

    def search(self, keys: jnp.ndarray, valid: jnp.ndarray, lo, hi):
        """Returns (count, pages_inspected) for predicate [lo, hi]."""
        num_pages = keys.shape[0]
        overlap = (self.mins <= hi) & (self.maxs >= lo)          # (R,)
        page_mask = jnp.repeat(overlap, self.pages_per_range)[:num_pages]
        v = keys.astype(jnp.float32)
        qual = page_mask[:, None] & valid & (v >= lo) & (v <= hi)
        return qual.sum(dtype=jnp.int32), page_mask.sum(dtype=jnp.int32)

    def nbytes(self) -> int:
        return int(self.mins.shape[0]) * 8  # two float32 per range
