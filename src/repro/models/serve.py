"""Serving: KV/recurrent cache structures, prefill, and single-token decode.

Cache layout mirrors the scan-over-units parameter stacking: one stacked cache
pytree per pattern position, so decode scans units exactly like training does.

Decode attention evaluates the query against the full cache with masking; in
fp32 with the softmax reduction over the cache axis. Under the production
sharding the cache's sequence axis is sharded over the ``model`` mesh axis
whenever kv-heads don't divide it (GQA kv=1..8), so XLA's SPMD partitioner
turns the softmax max/sum reductions into small all-reduces — exactly the
flash-decoding partial-softmax combine, expressed at the XLA level.

Local-attention blocks cache only their window (recurrentgemma: 2048), and
recurrent blocks carry O(d) / O(d^2) state — which is what makes the
long_500k decode cell cheap for the ssm/hybrid archs.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers, moe, rglru, rwkv


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg, kind: str, max_seq: int) -> int:
    if kind == "attn_local":
        return min(cfg.window, max_seq)
    return max_seq


def block_cache_init(cfg, kind: str, batch: int, max_seq: int):
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    if kind in ("attn", "attn_local", "moe"):
        s = _attn_cache_len(cfg, kind, max_seq)
        return {
            "k": jnp.zeros((batch, s, cfg.num_kv_heads, hd), dt),
            "v": jnp.zeros((batch, s, cfg.num_kv_heads, hd), dt),
        }
    if kind == "rec":
        return {
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_model), dt),
            "h": jnp.zeros((batch, cfg.d_model), dt),
        }
    # rwkv
    nh = cfg.d_model // hd
    return {
        "shift_t": jnp.zeros((batch, cfg.d_model), dt),
        "shift_c": jnp.zeros((batch, cfg.d_model), dt),
        "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
    }


def init_cache(cfg, batch: int, max_seq: int):
    cache = {"units": {}}
    for j, kind in enumerate(cfg.block_pattern):
        one = block_cache_init(cfg, kind, batch, max_seq)
        cache["units"][f"b{j}_{kind}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.num_units, *x.shape)), one)
    if cfg.leftover_pattern:
        cache["extra"] = [block_cache_init(cfg, kind, batch, max_seq)
                          for kind in cfg.leftover_pattern]
    return cache


# ---------------------------------------------------------------------------
# decode attention (single token against the cache)
# ---------------------------------------------------------------------------

def decode_attention(cfg, p, x, cache, pos, angles, *, window: int = 0):
    """x: (B, 1, d); cache k/v: (B, S_c, Hkv, hd); pos: absolute position.

    Returns (out (B, 1, d), new_cache). For local attention the cache is a
    rolling buffer indexed mod window.
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = layers.qkv_project(cfg, p, x)          # (B,1,H*,hd)
    if angles is not None:
        cos, sin = angles
        q = layers.apply_rope(q, cos, sin, cfg.rope_fraction)
        k = layers.apply_rope(k, cos, sin, cfg.rope_fraction)

    s_c = cache["k"].shape[1]
    slot = pos % s_c if window else jnp.minimum(pos, s_c - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))

    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd)
    # bf16 operands + fp32 accumulation: an explicit fp32 cast of the cache
    # materializes a full fp32 cache copy hoisted across the unit scan
    # (measured 4 x 1.6 GiB on llama4 decode_32k — EXPERIMENTS.md §Perf)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, ck,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    kv_idx = jnp.arange(s_c)
    if window:
        # rolling buffer: valid entries are the last min(pos+1, window) writes
        age = (slot - kv_idx) % s_c                    # 0 = newest
        mask = age < jnp.minimum(pos + 1, s_c)
    else:
        mask = kv_idx <= pos
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)            # reductions over S_c
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(x.dtype), cv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, hq * hd).astype(x.dtype) @ p["wo"]
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# per-block decode
# ---------------------------------------------------------------------------

def block_decode(cfg, kind: str, p, x, cache, pos, angles):
    h = layers.apply_norm(cfg, p["norm1"], x)
    if kind in ("attn", "attn_local", "moe"):
        window = cfg.window if kind == "attn_local" else 0
        out, cache = decode_attention(cfg, p["attn"], h, cache, pos, angles,
                                      window=window)
        x = x + out
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            x = x + moe.moe_apply(cfg, p["moe"], h2)
        else:
            x = x + layers.ffn_apply(p["ffn"], h2)
    elif kind == "rec":
        out, st = rglru.rglru_block_apply(cfg, p["rec"], h, state=cache)
        cache = st
        x = x + out
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.ffn_apply(p["ffn"], h2)
    else:  # rwkv
        out, st_t = rwkv.time_mix_apply(
            cfg, p["tmix"], h,
            state={"shift": cache["shift_t"], "wkv": cache["wkv"]})
        x = x + out
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        out, st_c = rwkv.channel_mix_apply(cfg, p["tmix"], h2,
                                           state={"shift": cache["shift_c"]})
        x = x + out
        cache = {"shift_t": st_t["shift"], "wkv": st_t["wkv"],
                 "shift_c": st_c["shift"]}
    return x, cache


# ---------------------------------------------------------------------------
# decode step (the serve_step lowered by the dry-run)
# ---------------------------------------------------------------------------

def decode_step(cfg, params, cache, tokens, pos):
    """One-token decode. tokens: (B, 1) int32 (or (B, 1, d) embeddings for
    stub frontends); pos: scalar int32 absolute position. Returns
    (logits (B, V), new_cache)."""
    x = transformer_embed(cfg, params, tokens, pos)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    angles = layers.positional_angles(cfg, positions)

    def unit_fn(x, scanned):
        unit_params, unit_cache = scanned
        new_cache = {}
        for j, kind in enumerate(cfg.block_pattern):
            name = f"b{j}_{kind}"
            x, new_cache[name] = block_decode(cfg, kind, unit_params[name], x,
                                              unit_cache[name], pos, angles)
        return x, new_cache

    if cfg.num_units > 0:
        x, new_unit_cache = jax.lax.scan(
            unit_fn, x, (params["units"], cache["units"]))
    else:
        new_unit_cache = cache["units"]
    new_cache = {"units": new_unit_cache}
    if cfg.leftover_pattern:
        extras = []
        for j, kind in enumerate(cfg.leftover_pattern):
            x, c = block_decode(cfg, kind, params["extra"][j], x,
                                cache["extra"][j], pos, angles)
            extras.append(c)
        new_cache["extra"] = extras

    x = layers.apply_norm(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (x[:, 0] @ head), new_cache


def transformer_embed(cfg, params, tokens, pos):
    from repro.models import transformer
    positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)
    return transformer.embed_inputs(cfg, params, tokens, positions)


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that also populates the cache
# ---------------------------------------------------------------------------

def block_prefill(cfg, kind: str, p, x, angles, max_seq: int):
    """Training-path compute + cache capture. Returns (x, cache)."""
    b, s, _ = x.shape
    h = layers.apply_norm(cfg, p["norm1"], x)
    if kind in ("attn", "attn_local", "moe"):
        window = cfg.window if kind == "attn_local" else 0
        q, k, v = layers.qkv_project(cfg, p["attn"], h)
        if angles is not None:
            cos, sin = angles
            q = layers.apply_rope(q, cos, sin, cfg.rope_fraction)
            k = layers.apply_rope(k, cos, sin, cfg.rope_fraction)
        out = layers.attention(q, k, v, causal=True, window=window,
                               q_chunk=cfg.q_chunk)
        x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            x = x + moe.moe_apply(cfg, p["moe"], h2)
        else:
            x = x + layers.ffn_apply(p["ffn"], h2)
        s_c = _attn_cache_len(cfg, kind, max_seq)
        if window and s <= s_c:
            # rolling buffer: last s tokens land at slots (pos % window)
            ck = jnp.zeros((b, s_c, *k.shape[2:]), k.dtype)
            idx = jnp.arange(s) % s_c
            ck = ck.at[:, idx].set(k)
            cv = jnp.zeros((b, s_c, *v.shape[2:]), v.dtype).at[:, idx].set(v)
        else:
            take = min(s, s_c)
            pad = s_c - take
            ck = jnp.pad(k[:, -take:], ((0, 0), (0, pad), (0, 0), (0, 0)))
            cv = jnp.pad(v[:, -take:], ((0, 0), (0, pad), (0, 0), (0, 0)))
            if window:  # rolling alignment for long prefill
                roll = s % s_c
                ck = jnp.roll(ck, roll, axis=1)
                cv = jnp.roll(cv, roll, axis=1)
        cache = {"k": ck, "v": cv}
    elif kind == "rec":
        out, st = rglru.rglru_block_apply(cfg, p["rec"], h, state=None)
        x = x + out
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.ffn_apply(p["ffn"], h2)
        cache = st
    else:  # rwkv
        out, st_t = rwkv.time_mix_apply(cfg, p["tmix"], h, state=None)
        x = x + out
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        out, st_c = rwkv.channel_mix_apply(cfg, p["tmix"], h2, state=None)
        x = x + out
        cache = {"shift_t": st_t["shift"], "shift_c": st_c["shift"],
                 "wkv": st_t["wkv"]}
    return x, cache


def prefill(cfg, params, inputs, positions, max_seq: int):
    """Forward over the prompt; returns (last-token logits (B, V), cache)."""
    from repro.models import transformer
    x = transformer.embed_inputs(cfg, params, inputs, positions)
    angles = layers.positional_angles(cfg, positions)

    def unit_fn(x, unit_params):
        caches = {}
        for j, kind in enumerate(cfg.block_pattern):
            name = f"b{j}_{kind}"
            x, caches[name] = block_prefill(cfg, kind, unit_params[name], x,
                                            angles, max_seq)
        return x, caches

    cache = {"units": {}}
    if cfg.num_units > 0:
        x, cache["units"] = jax.lax.scan(unit_fn, x, params["units"])
    if cfg.leftover_pattern:
        extras = []
        for j, kind in enumerate(cfg.leftover_pattern):
            x, c = block_prefill(cfg, kind, params["extra"][j], x, angles, max_seq)
            extras.append(c)
        cache["extra"] = extras

    x = layers.apply_norm(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return x[:, -1] @ head, cache


# ---------------------------------------------------------------------------
# host-side generation loop (examples / integration tests)
# ---------------------------------------------------------------------------

def generate(cfg, params, prompt_tokens, num_steps: int, max_seq: int,
             temperature: float = 0.0, key=None):
    """Greedy/temperature sampling. prompt_tokens: (B, S) int32."""
    b, s = prompt_tokens.shape[0], prompt_tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    logits, cache = prefill(cfg, params, prompt_tokens, positions, max_seq)
    step_fn = jax.jit(partial(decode_step, cfg))
    out = []
    for t in range(num_steps):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        out.append(nxt)
        logits, cache = step_fn(params, cache, nxt[:, None], jnp.int32(s + t))
    return jnp.stack(out, axis=1)
