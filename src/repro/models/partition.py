"""Activation sharding constraints.

XLA's sharding propagation can drop the batch sharding of activations inside
a deep scanned trunk (observed: per-device trunk buffers carrying the FULL
global microbatch, f32[64,4096,256], on the recurrentgemma train_4k cell —
23.5 GiB of temp instead of ~6). ``constrain_batch`` pins the leading
activation dim to the data axes whenever the model runs under a mesh context;
outside a mesh (CPU unit tests) it is a no-op.
"""
from __future__ import annotations

import warnings

import jax
from jax.sharding import PartitionSpec as P


def _current_mesh():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        try:
            from jax.interpreters import pxla
            m = pxla.thread_resources.env.physical_mesh
        except Exception:  # noqa: BLE001
            return None
    return None if m is None or m.empty else m


# Layout override: dryrun/train set this to e.g. ("pod", "data", "model") for
# pure-FSDP experiments (batch sharded over every axis => no tensor
# parallelism; weights are all-gathered per use). None = default DP axes.
BATCH_AXES_OVERRIDE: tuple | None = None


def constrain_batch(x, batch_dim: int = 0):
    """Pin x's batch dim to the widest dividing prefix of the batch axes
    (override or ("pod","data"))."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    import numpy as np
    want = tuple(a for a in (BATCH_AXES_OVERRIDE or ("pod", "data"))
                 if a in mesh.axis_names)
    axes = ()
    for k in range(len(want), 0, -1):   # longest dividing prefix wins
        size = int(np.prod([mesh.shape[a] for a in want[:k]]))
        if size and x.shape[batch_dim] % size == 0:
            axes = want[:k]
            break
    if not axes:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(x, P(*spec))
