"""Griffin/RecurrentGemma recurrent block: temporal conv + RG-LRU.

RG-LRU (arXiv:2402.19427 §2.4):
    r_t = sigmoid(x_t W_r)                     recurrence gate
    i_t = sigmoid(x_t W_i)                     input gate
    a_t = exp(-c * softplus(Lambda) * r_t)     data-dependent decay in (0,1)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The affine recurrence (h -> a*h + b) is associative, so within a TIME CHUNK it
runs as ``jax.lax.associative_scan`` (O(log L) depth on TPU); chunks are
scanned with a carried (conv window, h) state and jax.checkpoint on the chunk
body. Unchunked, the associative scan's backward keeps per-level (a, b)
intermediates over the whole sequence (measured 61 GiB/device on the
recurrentgemma train_4k dry-run — EXPERIMENTS.md §Perf); chunked, the
footprint is bounded by the chunk length.

Block layout (Griffin): y = W_out( GeLU(x W_gate) * RG-LRU(conv1d(x W_x)) ).
The same chunk path serves decode (S=1, carried state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

TIME_CHUNK = 256


def rglru_params_init(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    return {
        "w_x": layers.dense_init(keys[0], (d, d), dt),
        "w_gate": layers.dense_init(keys[1], (d, d), dt),
        "w_out": layers.dense_init(keys[2], (d, d), dt),
        # depthwise causal temporal conv, width cfg.conv_width; tap 0 applies
        # to the newest timestep
        "conv": layers.dense_init(keys[3], (cfg.conv_width, d), dt, scale=0.5),
        "w_r": layers.dense_init(keys[4], (d, d), dt),
        "w_i": layers.dense_init(keys[5], (d, d), dt),
        # Lambda init so softplus(Lambda) spans decay half-lives ~ [3, 700]
        "lam": jnp.linspace(-2.0, 2.0, d).astype(jnp.float32),
    }


def _conv_with_tail(u: jnp.ndarray, tail: jnp.ndarray, w: jnp.ndarray):
    """Causal depthwise conv over a chunk given the previous K-1 inputs.

    u: (B, L, d); tail: (B, K-1, d); w: (K, d) with w[0] on the newest step.
    Returns (uc (B, L, d), new_tail (B, K-1, d))."""
    k = w.shape[0]
    ext = jnp.concatenate([tail, u], axis=1)          # (B, L+K-1, d)
    out = jnp.zeros_like(u)
    for j in range(k):                                 # K=4 — stays fused
        out = out + ext[:, k - 1 - j : ext.shape[1] - j, :] * w[j][None, None, :]
    return out, ext[:, -(k - 1):, :]


def _chunk_core(cfg, p, xc, tail, h0):
    """One time chunk of the recurrent branch. xc: (B, L, d) block input
    (post-norm); tail: (B, K-1, d) conv carry; h0: (B, d) hidden carry.
    Returns (h (B, L, d), new_tail, h_last)."""
    u = xc @ p["w_x"]
    uc, new_tail = _conv_with_tail(u, tail, p["conv"])
    r = jax.nn.sigmoid((uc @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((uc @ p["w_i"]).astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"])[None, None, :] * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * uc.astype(jnp.float32))
    # fold the carry as a virtual step 0: a_0 = 0, b_0 = h0
    a_ext = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
    b_ext = jnp.concatenate([h0.astype(jnp.float32)[:, None], gated], axis=1)

    def combine(pq, qr):
        a1, b1 = pq
        a2, b2 = qr
        return a1 * a2, b2 + a2 * b1

    _, h = jax.lax.associative_scan(combine, (a_ext, b_ext), axis=1)
    h = h[:, 1:].astype(xc.dtype)
    return h, new_tail, h[:, -1]


def rglru_block_apply(cfg, p, x, state=None):
    """Full Griffin recurrent block. x: (B, S, d).

    state: None (training/prefill from zero state) or
    {"conv": (B, K-1, d), "h": (B, d)} (decode / continued prefill).
    Returns (y, new_state).
    """
    b, s, d = x.shape
    kw = cfg.conv_width - 1
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    tail0 = (jnp.zeros((b, kw, d), x.dtype) if state is None
             else state["conv"].astype(x.dtype))
    h0 = (jnp.zeros((b, d), x.dtype) if state is None
          else state["h"].astype(x.dtype))

    lc = min(TIME_CHUNK, s)
    while s % lc:
        lc -= 1
    if lc == s:
        h, tail, h_last = _chunk_core(cfg, p, x, tail0, h0)
    else:
        nc = s // lc
        xc = x.reshape(b, nc, lc, d).transpose(1, 0, 2, 3)

        def chunk_fn(carry, xch):
            tail, h0 = carry
            h, tail, h_last = _chunk_core(cfg, p, xch, tail, h0)
            return (tail, h_last), h

        (tail, h_last), hs = jax.lax.scan(jax.checkpoint(chunk_fn),
                                          (tail0, h0), xc)
        h = hs.transpose(1, 0, 2, 3).reshape(b, s, d)

    y = (gate * h) @ p["w_out"]
    return y, {"conv": tail, "h": h_last}
