"""Shared transformer layers: norms, positional encodings, blocked GQA
attention, SwiGLU. Pure-functional: params are dict pytrees, inits take rng
keys, applies are jit/pjit-safe.

Attention is *blocked* (flash-style): queries are processed in chunks with a
lax.scan; per chunk the full K/V is visited with causal/window masking and the
softmax runs in fp32. This keeps peak memory at O(q_chunk * S) per head rather
than O(S^2), which is what makes prefill_32k lowerable, and it is
remat-friendly for training.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_init(cfg, key):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# ---------------------------------------------------------------------------
# positional encodings
# ---------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> tuple:
    """positions: (..., S) -> cos/sin (..., S, dim/2) in fp32."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B, S, half_rot). Rotates the leading
    ``fraction`` of head dims (stablelm rotates 25%)."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    half = rot // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., :half], xr[..., half:]
    c = cos[..., None, :half].astype(x.dtype)
    s = sin[..., None, :half].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


def mrope_angles(positions: jnp.ndarray, sections: tuple, theta: float):
    """Multimodal RoPE (qwen2-vl): positions (B, 3, S) for (t, h, w); each
    head-dim section uses its own position stream. Returns cos/sin
    (B, S, sum(sections))."""
    cs, ss = [], []
    for i, sec in enumerate(sections):
        freqs = theta ** (-jnp.arange(0, sec, dtype=jnp.float32) / sum(sections))
        ang = positions[:, i, :].astype(jnp.float32)[..., None] * freqs
        cs.append(jnp.cos(ang))
        ss.append(jnp.sin(ang))
    return jnp.concatenate(cs, axis=-1), jnp.concatenate(ss, axis=-1)


def sinusoidal_embedding(positions: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Absolute sinusoidal position embedding (musicgen): (..., S) -> (..., S, dim)."""
    half = dim // 2
    freqs = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def positional_angles(cfg, positions):
    """cos/sin streams for the configured scheme; None for sinusoidal."""
    hd = cfg.resolved_head_dim
    if cfg.pos_emb == "rope":
        if positions.ndim == 3:  # (B, 3, S) stub passes mrope-style positions
            positions = positions[:, 0, :]
        return rope_angles(positions, int(hd * cfg.rope_fraction), cfg.rope_theta)
    if cfg.pos_emb == "mrope":
        if positions.ndim == 2:  # text-only: all three streams identical
            positions = jnp.broadcast_to(positions[:, None, :],
                                         (positions.shape[0], 3, positions.shape[1]))
        return mrope_angles(positions, cfg.mrope_sections, cfg.rope_theta)
    return None


# ---------------------------------------------------------------------------
# blocked (flash-style) attention
# ---------------------------------------------------------------------------

def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int = 0, q_chunk: int = 512,
              q_offset: int = 0) -> jnp.ndarray:
    """GQA attention. q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd).

    Queries are scanned in chunks; keys/values are visited in full per chunk
    with fp32 softmax. ``window`` > 0 restricts to a local causal window.
    ``q_offset`` is the absolute position of q[0] relative to k[0] (used by
    decode where Sq=1 sits at the end of the cache).
    """
    b, sq, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    qc = min(q_chunk, sq)
    while sq % qc:
        qc -= 1
    n_chunks = sq // qc

    kt = k.transpose(0, 2, 3, 1)           # (B, Hkv, hd, Skv)
    vt = v.transpose(0, 2, 1, 3)           # (B, Hkv, Skv, hd)
    kv_idx = jnp.arange(skv)

    def chunk_fn(carry, ci):
        qs = q.reshape(b, n_chunks, qc, hq, hd)[:, ci]          # (B, qc, Hq, hd)
        qg = qs.reshape(b, qc, hkv, g, hd).transpose(0, 2, 3, 1, 4)  # (B,Hkv,g,qc,hd)
        # bf16 operands, fp32 MXU accumulation: fp32 lives only in the scores
        scores = jnp.einsum("bhgqd,bhdk->bhgqk", qg, kt,
                            preferred_element_type=jnp.float32) * scale
        q_idx = q_offset + ci * qc + jnp.arange(qc)
        mask = jnp.ones((qc, skv), bool)
        if causal:
            mask &= kv_idx[None, :] <= q_idx[:, None]
        if window:
            mask &= kv_idx[None, :] > q_idx[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)     # PV in model dtype
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vt,
                         preferred_element_type=jnp.float32)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qc, hq, hd)
        return carry, out.astype(q.dtype)

    # Remat each chunk: backward recomputes the fp32 scores/probs instead of
    # saving them per chunk — the flash-attention memory profile (O(qc*Skv)
    # transient instead of O(Sq*Skv) resident during the layer's backward).
    _, chunks = jax.lax.scan(jax.checkpoint(chunk_fn), None, jnp.arange(n_chunks))
    # chunks: (n_chunks, B, qc, Hq, hd) -> (B, Sq, Hq, hd)
    return chunks.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, hd)


# ---------------------------------------------------------------------------
# attention block (pre-norm attn + SwiGLU ffn) — kinds: attn / attn_local / moe
# ---------------------------------------------------------------------------

def attn_params_init(cfg, key):
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 5)
    p = {
        "wq": dense_init(keys[0], (cfg.d_model, cfg.num_heads * hd), dt),
        "wk": dense_init(keys[1], (cfg.d_model, cfg.num_kv_heads * hd), dt),
        "wv": dense_init(keys[2], (cfg.d_model, cfg.num_kv_heads * hd), dt),
        "wo": dense_init(keys[3], (cfg.num_heads * hd, cfg.d_model), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dt)
    return p


def qkv_project(cfg, p, x):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def attn_apply(cfg, p, x, angles, *, window: int = 0):
    """Self-attention over the full sequence (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = qkv_project(cfg, p, x)
    if angles is not None:
        cos, sin = angles
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        k = apply_rope(k, cos, sin, cfg.rope_fraction)
    out = attention(q, k, v, causal=True, window=window, q_chunk=cfg.q_chunk)
    return out.reshape(b, s, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------

def ffn_params_init(cfg, key, d_ff: int | None = None):
    dt = jnp.dtype(cfg.dtype)
    f = d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(keys[0], (cfg.d_model, f), dt),
        "w_up": dense_init(keys[1], (cfg.d_model, f), dt),
        "w_down": dense_init(keys[2], (f, cfg.d_model), dt),
    }


def ffn_apply(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
