"""Model assembly: block dispatch, scan-over-units parameter stacking,
forward pass, and the training loss.

The layer stack is grouped into repeating *units* (``cfg.block_pattern``) and
scanned with ``jax.lax.scan`` over stacked unit parameters — one traced copy
of the unit regardless of depth (compact HLO, fast multi-pod compiles) — with
``jax.checkpoint`` on the unit body for activation rematerialization.
Leftover layers (depth not divisible by the pattern) run unscanned.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers, moe, partition, rglru, rwkv


# ---------------------------------------------------------------------------
# block dispatch
# ---------------------------------------------------------------------------

def block_params_init(cfg, kind: str, key):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": layers.norm_init(cfg, k1)}
    if kind in ("attn", "attn_local", "moe"):
        p["attn"] = layers.attn_params_init(cfg, k2)
        p["norm2"] = layers.norm_init(cfg, k1)
        if kind == "moe":
            p["moe"] = moe.moe_params_init(cfg, k3)
        else:
            p["ffn"] = layers.ffn_params_init(cfg, k3)
    elif kind == "rec":
        p["rec"] = rglru.rglru_params_init(cfg, k2)
        p["norm2"] = layers.norm_init(cfg, k1)
        p["ffn"] = layers.ffn_params_init(cfg, k3)
    elif kind == "rwkv":
        p["tmix"] = rwkv.rwkv_params_init(cfg, k2)
        p["norm2"] = layers.norm_init(cfg, k1)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def block_apply(cfg, kind: str, p, x, angles):
    """Pre-norm residual block (training / prefill path, no carried state)."""
    h = layers.apply_norm(cfg, p["norm1"], x)
    if kind in ("attn", "attn_local", "moe"):
        window = cfg.window if kind == "attn_local" else 0
        x = x + layers.attn_apply(cfg, p["attn"], h, angles, window=window)
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            x = x + moe.moe_apply(cfg, p["moe"], h2)
        else:
            x = x + layers.ffn_apply(p["ffn"], h2)
    elif kind == "rec":
        out, _ = rglru.rglru_block_apply(cfg, p["rec"], h)
        x = x + out
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.ffn_apply(p["ffn"], h2)
    else:  # rwkv
        out, _ = rwkv.time_mix_apply(cfg, p["tmix"], h)
        x = x + out
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        out, _ = rwkv.channel_mix_apply(cfg, p["tmix"], h2)
        x = x + out
    return x


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4 + cfg.unit_len)
    params = {}
    if cfg.frontend == "tokens":
        params["embed"] = layers.dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                                            dt, scale=1.0)
    else:
        # modality frontend is a stub: inputs arrive as embeddings; a single
        # projection stands in for the (excluded) encoder output interface.
        params["frontend_proj"] = layers.dense_init(
            keys[0], (cfg.d_model, cfg.d_model), dt)

    units = {}
    for j, kind in enumerate(cfg.block_pattern):
        unit_keys = jax.random.split(keys[1 + j], max(cfg.num_units, 1))
        units[f"b{j}_{kind}"] = jax.vmap(
            lambda k: block_params_init(cfg, kind, k))(unit_keys)
    params["units"] = units

    extra = []
    for j, kind in enumerate(cfg.leftover_pattern):
        extra.append(block_params_init(cfg, kind, keys[2 + cfg.unit_len]))
    if extra:
        params["extra"] = extra

    params["final_norm"] = layers.norm_init(cfg, keys[-2])
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(keys[-1], (cfg.d_model, cfg.vocab_size), dt)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def embed_inputs(cfg, params, inputs, positions):
    """tokens (B, S) int32 -> embeddings, or pass float embeddings through the
    frontend stub projection. Adds sinusoidal absolute PE when configured.

    Decode (S=1) embeds via one-hot matmul: a gather from the vocab-sharded
    table makes XLA all-gather the whole table (~2 GiB transient for the 400B
    vocab), while the one-hot contraction keeps it sharded and reduces a few
    KiB of partials instead."""
    if cfg.frontend == "tokens":
        if inputs.shape[1] == 1:
            onehot = jax.nn.one_hot(inputs, cfg.vocab_size,
                                    dtype=params["embed"].dtype)
            x = onehot @ params["embed"]
        else:
            x = params["embed"][inputs]
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype)) @ params["frontend_proj"]
    if cfg.pos_emb == "sinusoidal":
        pos = positions if positions.ndim == 2 else positions[:, 0]
        x = x + layers.sinusoidal_embedding(pos, cfg.d_model).astype(x.dtype)
    return x


def trunk(cfg, params, inputs, positions, *, remat: bool = True):
    """Embed + all blocks + final norm -> hidden states (B, S, d)."""
    x = partition.constrain_batch(embed_inputs(cfg, params, inputs, positions))
    angles = layers.positional_angles(cfg, positions)

    def unit_fn(x, unit_params):
        for j, kind in enumerate(cfg.block_pattern):
            x = block_apply(cfg, kind, unit_params[f"b{j}_{kind}"], x, angles)
        return partition.constrain_batch(x)

    body = jax.checkpoint(unit_fn) if remat else unit_fn
    if cfg.num_units > 0:
        x, _ = jax.lax.scan(lambda h, p: (body(h, p), None), x, params["units"])
    for j, kind in enumerate(cfg.leftover_pattern):
        blk = lambda h, p, kind=kind: block_apply(cfg, kind, p, h, angles)
        if remat:
            blk = jax.checkpoint(blk)
        x = blk(x, params["extra"][j])

    return layers.apply_norm(cfg, params["final_norm"], x)


def lm_head(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(cfg, params, inputs, positions, *, remat: bool = True):
    """Full-sequence forward -> logits (B, S, V)."""
    return trunk(cfg, params, inputs, positions, remat=remat) @ lm_head(cfg, params)


def loss_fn(cfg, params, batch, *, remat: bool = True, ce_chunk: int = 256):
    """Next-token cross entropy with a *chunked fused* head (big-vocab trick):
    the (B, S, V) logits tensor is never materialized — each sequence chunk
    computes head-matmul + log-softmax + gather and is rematerialized in the
    backward pass. Labels of -1 are masked; softmax in fp32.
    """
    x = trunk(cfg, params, batch["inputs"], batch["positions"], remat=remat)
    head = lm_head(cfg, params)
    labels = batch["labels"]
    b, s, _ = x.shape
    cc = min(ce_chunk, s)
    while s % cc:
        cc -= 1
    n_chunks = s // cc

    def chunk(carry, ci):
        tot, cnt = carry
        xc = jax.lax.dynamic_slice_in_dim(x, ci * cc, cc, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, ci * cc, cc, axis=1)
        logits = (xc @ head).astype(jnp.float32)          # (B, cc, V) transient
        mask = lc >= 0
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None],
                                 axis=-1)[..., 0]
        tot = tot + ((lse - ll) * mask).sum()
        cnt = cnt + mask.sum()
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(chunk),
                                 (jnp.float32(0), jnp.int32(0)),
                                 jnp.arange(n_chunks))
    return tot / jnp.maximum(cnt, 1)
