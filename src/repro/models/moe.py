"""Mixture-of-Experts FFN with capacity-based *slot-indexed* dispatch.

Dispatch is gather/scatter on flat expert slots rather than the Mesh-TF
one-hot einsum: the (tokens, E, C) dispatch tensor of the einsum formulation
is O(N*E*C) and explodes at production token counts (measured 62 TiB/device
for qwen2-moe train_4k — see EXPERIMENTS.md §Perf); slot indexing keeps the
footprint at O(E*C*d) per token group.

Tokens are grouped per batch row (GShard-style groups): capacity is computed
within each group, routing state is (S, K) ints per group, and every einsum
over experts is a batched matmul that shards cleanly — experts over the
``model`` mesh axis when divisible (llama4: 128/16 = 8 experts/shard, EP) and
TP inside the expert FFN otherwise (qwen2-moe: 60 experts, d_ff sharded).
Shared experts are a plain SwiGLU applied to every token.

Router: softmax (qwen) or sigmoid (llama4) over expert logits in fp32; top-k
selection; tokens beyond an expert's capacity are dropped (their output falls
back to the shared/residual path), matching Switch/GShard semantics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


def moe_params_init(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    f = cfg.moe_d_ff or cfg.d_ff
    keys = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(keys[0], (cfg.d_model, cfg.num_experts),
                                    jnp.float32),
        "w_gate": layers.dense_init(keys[1], (cfg.num_experts, cfg.d_model, f), dt),
        "w_up": layers.dense_init(keys[2], (cfg.num_experts, cfg.d_model, f), dt),
        "w_down": layers.dense_init(keys[3], (cfg.num_experts, f, cfg.d_model), dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.ffn_params_init(
            cfg, keys[4], d_ff=cfg.num_shared_experts * f)
    return p


def group_capacity(cfg, group_tokens: int) -> int:
    cap = int(math.ceil(cfg.capacity_factor * group_tokens * cfg.top_k
                        / max(cfg.num_experts, 1)))
    return max(cap, 1)


def _route(cfg, xf, router):
    """xf: (S, d) one group. Returns (slot (S, K), gate (S, K)) with
    slot = expert*C + position_in_expert for kept assignments (OOB slot E*C
    marks capacity-dropped assignments)."""
    s = xf.shape[0]
    e, k = cfg.num_experts, cfg.top_k
    c = group_capacity(cfg, s)
    logits = xf.astype(jnp.float32) @ router
    if cfg.router_act == "sigmoid":
        probs = jax.nn.sigmoid(logits)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                   # (S, K)
    if cfg.router_act == "softmax" and k > 1:
        gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)
    # position of each assignment within its expert (running count over the
    # flattened (token, k) order — deterministic, first-come-first-served)
    onehot = jax.nn.one_hot(expert_idx.reshape(-1), e, dtype=jnp.int32)  # (S*K, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1      # (S*K,)
    pos = pos.reshape(s, k)
    keep = pos < c
    slot = jnp.where(keep, expert_idx * c + pos, e * c)          # OOB -> dropped
    return slot, gate * keep


def moe_apply(cfg, p, x):
    """x: (B, S, d) -> (B, S, d). Routed top-k experts + shared experts."""
    b, s, d = x.shape
    e = cfg.num_experts
    c = group_capacity(cfg, s)

    slot, gate = jax.vmap(lambda xg: _route(cfg, xg, p["router"]))(x)  # (B, S, K)

    # dispatch: scatter tokens into (B, E*C, d) slot buffers (drop OOB)
    def scatter_one(xg, slot_g, gate_g):
        buf = jnp.zeros((e * c, d), x.dtype)
        idx = slot_g.reshape(-1)                                  # (S*K,)
        tok = jnp.repeat(jnp.arange(xg.shape[0]), slot_g.shape[1])
        return buf.at[idx].add(xg[tok], mode="drop")

    exp_in = jax.vmap(scatter_one)(x, slot, gate)                # (B, E*C, d)
    exp_in = exp_in.reshape(b, e, c, d)

    hidden = jax.nn.silu(jnp.einsum("becd,edf->becf", exp_in, p["w_gate"]))
    hidden = hidden * jnp.einsum("becd,edf->becf", exp_in, p["w_up"])
    exp_out = jnp.einsum("becf,efd->becd", hidden, p["w_down"])  # (B, E, C, d)

    # combine: gather each assignment's slot output, weight by the gate
    def gather_one(out_g, slot_g, gate_g):
        flat = out_g.reshape(e * c, d)
        picked = flat.at[slot_g.reshape(-1)].get(mode="fill", fill_value=0.0)
        picked = picked.reshape(*slot_g.shape, d)                # (S, K, d)
        return (picked * gate_g[..., None].astype(picked.dtype)).sum(axis=1)

    out = jax.vmap(gather_one)(exp_out, slot, gate)              # (B, S, d)

    if cfg.num_shared_experts:
        out = out + layers.ffn_apply(p["shared"], x.reshape(b * s, d)).reshape(b, s, d)
    return out


def aux_load_balance_loss(cfg, x, p):
    """Switch-style load-balance auxiliary loss."""
    n = x.shape[0] * x.shape[1]
    logits = x.reshape(n, -1).astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, cfg.num_experts), axis=0)
    frac_probs = probs.mean(axis=0)
    return cfg.num_experts * jnp.sum(frac_tokens * frac_probs)
