"""RWKV-6 ("Finch", arXiv:2404.05892) block: data-dependent-decay linear
attention (time-mix) + channel-mix. Attention-free; per-head state is a
(head_k x head_v) matrix, so decode is O(d^2) per token independent of
context length — which is why rwkv6 runs the long_500k cell.

Time-mix recurrence (per head, per step):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(wx_t)) a data-dependent per-channel decay and u the
"bonus" for the current token. Token-shift interpolation (data-dependent mu
via a low-rank projection) feeds r/k/v/w/g.

The sequence is processed in TIME CHUNKS (default 128 steps) under an outer
lax.scan carrying (wkv state, shift token), with jax.checkpoint on the chunk
body: the backward pass stores state only at chunk boundaries and recomputes
within a chunk. Without chunking, scan backward saves the (B, H, hd, hd) fp32
state at *every* step (measured 86 GiB/device on the train_4k dry-run —
EXPERIMENTS.md §Perf); with it, the footprint is S/chunk boundary states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

LORA_R = 32
TIME_CHUNK = 128


def rwkv_params_init(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    keys = jax.random.split(key, 12)
    return {
        # time-mix projections
        "w_r": layers.dense_init(keys[0], (d, d), dt),
        "w_k": layers.dense_init(keys[1], (d, d), dt),
        "w_v": layers.dense_init(keys[2], (d, d), dt),
        "w_g": layers.dense_init(keys[3], (d, d), dt),
        "w_o": layers.dense_init(keys[4], (d, d), dt),
        # data-dependent decay (low-rank): wx = w_base + tanh(x A) B
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "decay_a": layers.dense_init(keys[5], (d, LORA_R), dt),
        "decay_b": layers.dense_init(keys[6], (LORA_R, d), dt, scale=0.1),
        # token-shift interpolation factors (data-dependent mu, low-rank)
        "mu_base": jnp.full((5, d), 0.5, jnp.float32),
        "mu_a": layers.dense_init(keys[7], (d, LORA_R), dt),
        "mu_b": layers.dense_init(keys[8], (LORA_R, 5 * d), dt, scale=0.1),
        "bonus": jnp.zeros((d,), jnp.float32),
        # channel-mix
        "cm_k": layers.dense_init(keys[9], (d, cfg.d_ff), dt),
        "cm_v": layers.dense_init(keys[10], (cfg.d_ff, d), dt),
        "cm_r": layers.dense_init(keys[11], (d, d), dt),
        "cm_mu": jnp.full((2, d), 0.5, jnp.float32),
    }


def _heads(cfg, x):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    return x.reshape(b, s, d // hd, hd)


def _tmix_chunk(cfg, p, xc, prev, s0):
    """One time chunk. xc: (B, L, d); prev: (B, d) last token of the previous
    chunk; s0: (B, H, hd, hd) fp32 carry-in state.
    Returns (out (B, L, d) fp32-pregate, s_last, last_token)."""
    b, l, d = xc.shape
    hd = cfg.resolved_head_dim
    nh = d // hd
    xs = jnp.concatenate([prev[:, None], xc[:, :-1]], axis=1)     # shifted

    # data-dependent interpolation mu_t for the 5 streams (r, k, v, w, g);
    # mixing stays in the model dtype (fp32 blow-up measured 27 GiB at 32k)
    lora = jnp.tanh(xc @ p["mu_a"]) @ p["mu_b"]                   # (B, L, 5d)
    mu = (p["mu_base"].reshape(1, 1, 5, d)
          + lora.reshape(b, l, 5, d).astype(jnp.float32)).astype(xc.dtype)
    mixed = mu * xc[:, :, None] + (1 - mu) * xs[:, :, None]
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    r = _heads(cfg, xr @ p["w_r"])                                # (B,L,H,hd)
    k = _heads(cfg, xk @ p["w_k"])
    v = _heads(cfg, xv @ p["w_v"])
    g = jax.nn.silu((xg @ p["w_g"]).astype(jnp.float32))
    wx = p["decay_base"] + (jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]
                            ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wx)).reshape(b, l, nh, hd)               # (0,1)
    u = p["bonus"].reshape(nh, hd)

    def step(S, inp):
        rt, kt, vt, wt = inp                                      # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]                  # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs_t = tuple(t.astype(jnp.float32).transpose(1, 0, 2, 3)
                 for t in (r, k, v, w))
    s_last, outs = jax.lax.scan(step, s0, xs_t)
    out = outs.transpose(1, 0, 2, 3).reshape(b, l, d)             # fp32
    out = (out * g).astype(xc.dtype) @ p["w_o"]
    return out, s_last, xc[:, -1]


def time_mix_apply(cfg, p, x, state=None):
    """RWKV6 time-mix. x: (B, S, d). state: {"shift": (B, d),
    "wkv": (B, H, hd, hd)} carry-in (decode/chunked prefill) or None.
    Returns (out, new_state)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    nh = d // hd
    prev0 = (jnp.zeros((b, d), x.dtype) if state is None
             else state["shift"].astype(x.dtype))
    s0 = (jnp.zeros((b, nh, hd, hd), jnp.float32) if state is None
          else state["wkv"].astype(jnp.float32))

    lc = min(TIME_CHUNK, s)
    while s % lc:
        lc -= 1
    if lc == s:  # single chunk (decode and short sequences)
        out, s_last, last = _tmix_chunk(cfg, p, x, prev0, s0)
        return out, {"shift": last, "wkv": s_last}

    nc = s // lc
    xc = x.reshape(b, nc, lc, d).transpose(1, 0, 2, 3)            # (nc,B,L,d)

    def chunk_fn(carry, xch):
        s0, prev = carry
        out, s_last, last = _tmix_chunk(cfg, p, xch, prev, s0)
        return (s_last, last), out

    (s_last, last), outs = jax.lax.scan(jax.checkpoint(chunk_fn),
                                        (s0, prev0), xc)
    out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
    return out, {"shift": last, "wkv": s_last}


def channel_mix_apply(cfg, p, x, state=None):
    """RWKV channel-mix (squared-ReLU FFN with token shift)."""
    if state is None:
        xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xs = state["shift"][:, None, :].astype(x.dtype)
    mu = p["cm_mu"].reshape(1, 1, 2, x.shape[-1]).astype(x.dtype)
    mr = mu[:, :, 0] * x + (1 - mu[:, :, 0]) * xs
    mk = mu[:, :, 1] * x + (1 - mu[:, :, 1]) * xs
    hidden = jnp.square(jax.nn.relu(mk @ p["cm_k"]))
    out = jax.nn.sigmoid((mr @ p["cm_r"]).astype(jnp.float32)).astype(x.dtype) \
        * (hidden @ p["cm_v"])
    return out, {"shift": x[:, -1]}
