from repro.models import layers, moe, rglru, rwkv, transformer  # noqa: F401
