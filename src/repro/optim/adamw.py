"""AdamW with configurable moment dtype and global-norm clipping.

Distributed-optimization notes (used by the dry-run configs):
  * Moments inherit the parameter sharding, so with FSDP-sharded params the
    optimizer state is automatically ZeRO-3 partitioned.
  * ``moment_dtype=bfloat16`` halves optimizer-state HBM; ``"int8"`` stores
    both moments as row-quantized int8 (max-abs scale per trailing-dim row,
    8-bit-Adam style) — 4x smaller than fp32, used for the 400B MoE cell
    where even bf16 moments (6.2 GiB/chip at 256 chips) blow the v5e budget.
    Update math always runs in fp32; quantization error is storage-only.
  * Gradient accumulation lives in the train step (scan over microbatches),
    composing with this update unchanged.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def q8_encode(x32: jnp.ndarray) -> dict:
    """Row-quantize fp32 to {q: int8, s: f32 (..., 1)} (symmetric max-abs)."""
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    s = jnp.maximum(amax / 127.0, 1e-20)
    q = jnp.clip(jnp.round(x32 / s), -127, 127).astype(jnp.int8)
    return {"q": q, "s": s.astype(jnp.float32)}


def q8_decode(d: dict) -> jnp.ndarray:
    return d["q"].astype(jnp.float32) * d["s"]


def _is_q8(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "s"}


def adamw_init(params, moment_dtype: str = "float32") -> AdamWState:
    if moment_dtype == "int8":
        zeros = lambda p: {"q": jnp.zeros(p.shape, jnp.int8),
                           "s": jnp.zeros((*p.shape[:-1], 1), jnp.float32)}
    else:
        dt = jnp.dtype(moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
                 max_grad_norm: float = 1.0, unit_scan: bool = False):
    """One AdamW step. ``lr`` may be a scalar or traced value.

    ``unit_scan=True`` applies the update to the scanned layer stack
    (``params["units"]``) one unit at a time via lax.scan: optimizer
    transients (fp32 moment decode/encode buffers) are bounded by one unit's
    parameters instead of the whole model — required for the 400B cell, where
    whole-model fp32 transients alone exceed HBM.

    Returns (new_params, new_state, metrics).
    """
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        quant = _is_q8(m)
        m32 = q8_decode(m) if quant else m.astype(jnp.float32)
        v32 = q8_decode(v) if quant else v.astype(jnp.float32)
        m32 = b1 * m32 + (1 - b1) * g32
        v32 = b2 * v32 + (1 - b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if quant:
            return new_p, q8_encode(m32), q8_encode(v32)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    def tree_upd(p_t, g_t, m_t, v_t):
        out = jax.tree_util.tree_map(upd, p_t, g_t, m_t, v_t)
        is_t = lambda t: isinstance(t, tuple)
        return (jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_t),
                jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_t),
                jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=is_t))

    if unit_scan and isinstance(params, dict) and "units" in params:
        rest_p = {k: v for k, v in params.items() if k != "units"}
        rest_g = {k: v for k, v in grads.items() if k != "units"}
        rest_m = {k: v for k, v in state.mu.items() if k != "units"}
        rest_v = {k: v for k, v in state.nu.items() if k != "units"}
        new_rest_p, new_rest_m, new_rest_v = tree_upd(rest_p, rest_g,
                                                      rest_m, rest_v)

        def unit_step(_, xs):
            return None, tree_upd(*xs)

        _, (u_p, u_m, u_v) = jax.lax.scan(
            unit_step, None,
            (params["units"], grads["units"], state.mu["units"],
             state.nu["units"]))
        new_params = {**new_rest_p, "units": u_p}
        new_mu = {**new_rest_m, "units": u_m}
        new_nu = {**new_rest_v, "units": u_v}
    else:
        new_params, new_mu, new_nu = tree_upd(params, grads, state.mu, state.nu)
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
