"""PagedTable — the heap-file analogue backing a Hippo index.

PostgreSQL stores tuples in fixed-size heap pages; Hippo summarizes *pages*.
Here a page is a fixed-width row block of ``page_card`` tuples. The key
attribute is a float32 column of shape (num_pages, page_card); additional
payload columns ride along untouched. Mutations (insert/delete) are host-side
numpy — the buffer-manager role — while queries operate on jnp device views.

Deletions mark a validity bit and a per-page ``dirty`` flag, which is exactly
the "note in the page header" PostgreSQL leaves for VACUUM (§5.2 / §7.1);
``HippoIndex.vacuum`` consumes the dirty flags.

Sharded views: ``device_keys_sharded``/``device_valid_sharded`` reshape the
page space into S contiguous slabs of ``pages_per_shard`` pages each — the
storage-layout half of the partition layer (``core.partition``). Each shard
owns the page range [s*PPS, (s+1)*PPS); slab pages past ``num_pages`` are
zero-key/invalid padding, so per-shard programs are shape-stable while the
table grows into its slabs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp


@dataclass
class PagedTable:
    page_card: int
    capacity_pages: int
    keys: np.ndarray = field(default=None)      # (capacity_pages, page_card) f32
    valid: np.ndarray = field(default=None)     # (capacity_pages, page_card) bool
    dirty: np.ndarray = field(default=None)     # (capacity_pages,) bool — VACUUM notes
    num_pages: int = 0                          # pages in use (last may be partial)
    fill: int = 0                               # tuples in the last page
    num_dirty: int = 0                          # pages with a pending VACUUM note
    #                                             (kept incrementally: the engine's
    #                                             on_depth backlog reads it per write)
    payload: dict = field(default_factory=dict)  # name -> (capacity, page_card) array
    _dev: tuple | None = field(default=None, repr=False, compare=False)  # device-view cache
    _dev_shard: tuple | None = field(default=None, repr=False, compare=False)  # slab-view cache
    # Mutations mark the slab cache stale instead of dropping it, so a
    # shard-local writer swap can patch just the touched slabs back in
    # (``refresh_shard_slabs``) instead of re-uploading every shard.
    _dev_shard_stale: bool = field(default=False, repr=False, compare=False)

    def __post_init__(self):
        if self.keys is None:
            self.keys = np.zeros((self.capacity_pages, self.page_card), np.float32)
        if self.valid is None:
            self.valid = np.zeros((self.capacity_pages, self.page_card), bool)
        if self.dirty is None:
            self.dirty = np.zeros((self.capacity_pages,), bool)

    # -- construction -------------------------------------------------------

    @staticmethod
    def from_values(values: np.ndarray, page_card: int, spare_pages: int = 0,
                    payload: dict | None = None) -> "PagedTable":
        values = np.asarray(values, np.float32).ravel()
        n = values.size
        num_pages = (n + page_card - 1) // page_card
        cap = num_pages + spare_pages
        t = PagedTable(page_card=page_card, capacity_pages=cap)
        flat = t.keys.reshape(-1)
        flat[:n] = values
        vflat = t.valid.reshape(-1)
        vflat[:n] = True
        t.num_pages = num_pages
        t.fill = n - (num_pages - 1) * page_card if n else 0
        for name, col in (payload or {}).items():
            buf = np.zeros((cap, page_card), np.asarray(col).dtype)
            buf.reshape(-1)[:n] = np.asarray(col).ravel()
            t.payload[name] = buf
        return t

    # -- properties ----------------------------------------------------------

    @property
    def cardinality(self) -> int:
        return int(self.valid[: self.num_pages].sum())

    def heap_nbytes(self) -> int:
        """Bytes of live table storage (key column only, paper's table size)."""
        return self.num_pages * self.page_card * 4

    # -- row-id decoding (compact-path result payloads) ----------------------

    def row_values(self, row_ids: np.ndarray, payload: str | None = None
                   ) -> np.ndarray:
        """Fetch key (or payload-column) values for global row ids.

        A global row id is ``page_id * page_card + slot`` — the coordinate
        the gather path's ``row_ids`` results use
        (``core.index.search_compact_many``). Negative ids (the -1 pads of a
        ``top_k`` result) are skipped, so a padded id row can be passed
        straight through. Raises on ids past the table's tuple capacity.
        """
        ids = np.asarray(row_ids).ravel()
        ids = ids[ids >= 0]
        if ids.size and int(ids.max()) >= self.num_pages * self.page_card:
            raise IndexError(
                f"row id {int(ids.max())} past the table's "
                f"{self.num_pages * self.page_card} tuple slots")
        col = self.keys if payload is None else self.payload[payload]
        return col.reshape(-1)[ids]

    # -- device views --------------------------------------------------------

    def _device_views(self, n: int) -> tuple:
        """(keys, valid) device arrays for the first ``n`` pages, cached until
        the next host-side mutation — a query-heavy loop (the batched engine)
        pays one H2D transfer per mutation, not per batch."""
        if self._dev is None or self._dev[0] != n:
            self._dev = (n, jnp.asarray(self.keys[:n]), jnp.asarray(self.valid[:n]))
        return self._dev

    def device_keys(self, num_pages: int | None = None) -> jnp.ndarray:
        n = self.num_pages if num_pages is None else num_pages
        return self._device_views(n)[1]

    def device_valid(self, num_pages: int | None = None) -> jnp.ndarray:
        n = self.num_pages if num_pages is None else num_pages
        return self._device_views(n)[2]

    # -- sharded device views (core.partition slab layout) -------------------

    def _shard_views(self, num_shards: int, pages_per_shard: int) -> tuple:
        """(keys, valid) slabs of shape (S, PPS, page_card), cached like
        ``_device_views``. Slab pages beyond ``num_pages`` are invalid padding;
        per-shard entries never cover them, so they cost inspection FLOPs only
        inside their shard's fixed-shape program."""
        key = (num_shards, pages_per_shard, self.num_pages)
        if (self._dev_shard is None or self._dev_shard_stale
                or self._dev_shard[0] != key):
            total = num_shards * pages_per_shard
            if total < self.num_pages:
                raise ValueError(
                    f"slab layout {num_shards}x{pages_per_shard} covers {total} "
                    f"pages < table's {self.num_pages}")
            keys = np.zeros((total, self.page_card), np.float32)
            valid = np.zeros((total, self.page_card), bool)
            keys[: self.num_pages] = self.keys[: self.num_pages]
            valid[: self.num_pages] = self.valid[: self.num_pages]
            shape = (num_shards, pages_per_shard, self.page_card)
            self._dev_shard = (key, jnp.asarray(keys.reshape(shape)),
                               jnp.asarray(valid.reshape(shape)))
            self._dev_shard_stale = False
        return self._dev_shard

    def _host_slab(self, s: int, pages_per_shard: int) -> tuple:
        """(keys, valid) host copy of shard s's slab, zero/invalid padded."""
        lo = s * pages_per_shard
        hi = min(lo + pages_per_shard, self.num_pages)
        keys = np.zeros((pages_per_shard, self.page_card), np.float32)
        valid = np.zeros((pages_per_shard, self.page_card), bool)
        if hi > lo:
            keys[: hi - lo] = self.keys[lo:hi]
            valid[: hi - lo] = self.valid[lo:hi]
        return keys, valid

    def refresh_shard_slabs(self, shard_ids, num_shards: int,
                            pages_per_shard: int) -> bool:
        """Patch a stale slab cache in place after shard-local mutations.

        Contract: every mutation since the cache went stale must be confined
        to the slabs in ``shard_ids`` (the writer's drain/swap guarantees
        this; ``delete_where`` callers can pass the owners of the pages they
        dirtied). Each touched slab is re-uploaded with one (PPS, C) H2D
        instead of rebuilding the whole (S, PPS, C) view. Returns True if the
        cache was patched; False if there was no compatible cache (the next
        ``device_*_sharded`` call rebuilds fully — always correct).
        """
        if self._dev_shard is None:
            return False
        (cs, cpps, _), keys_dev, valid_dev = self._dev_shard
        if (cs, cpps) != (num_shards, pages_per_shard):
            return False
        if num_shards * pages_per_shard < self.num_pages:
            return False                     # table outgrew the layout
        for s in sorted(set(int(s) for s in shard_ids)):
            hk, hv = self._host_slab(s, pages_per_shard)
            keys_dev = keys_dev.at[s].set(jnp.asarray(hk))
            valid_dev = valid_dev.at[s].set(jnp.asarray(hv))
        key = (num_shards, pages_per_shard, self.num_pages)
        self._dev_shard = (key, keys_dev, valid_dev)
        self._dev_shard_stale = False
        return True

    def device_keys_sharded(self, num_shards: int, pages_per_shard: int) -> jnp.ndarray:
        return self._shard_views(num_shards, pages_per_shard)[1]

    def device_valid_sharded(self, num_shards: int, pages_per_shard: int) -> jnp.ndarray:
        return self._shard_views(num_shards, pages_per_shard)[2]

    # -- mutations (host side = buffer manager) ------------------------------

    def next_page_id(self) -> tuple[int, bool]:
        """(page the next append lands on, whether it opens a new page).

        The single statement of the append policy — index layers that must
        route or capacity-check *before* mutating (``HippoIndex.insert``,
        shard routing in ``core.partition``) predict through this instead of
        re-deriving the fill rule."""
        new_page = self.fill == self.page_card or self.num_pages == 0
        return (self.num_pages if new_page else self.num_pages - 1), new_page

    def insert(self, value: float) -> tuple[int, bool]:
        """Append one tuple; returns (page_id, is_new_page).

        Appends to the last partially-filled page, else opens a new page —
        matching heap-file append behaviour assumed by Algorithm 3.
        """
        _, new_page = self.next_page_id()
        if new_page:
            if self.num_pages == self.capacity_pages:
                self._grow()
            self.num_pages += 1
            self.fill = 0
        p = self.num_pages - 1
        self.keys[p, self.fill] = np.float32(value)
        self.valid[p, self.fill] = True
        self.fill += 1
        self._dev = None
        self._dev_shard_stale = True
        return p, new_page

    def insert_batch(self, values: np.ndarray) -> tuple[int, int]:
        """Vectorized append; returns (first_page_touched, last_page)."""
        values = np.asarray(values, np.float32).ravel()
        first = max(self.num_pages - 1, 0)
        for v in values:  # page-boundary bookkeeping is trivial; keys are bulk-set below
            self.insert(float(v))
        return first, self.num_pages - 1

    def delete_where(self, lo: float, hi: float) -> int:
        """Mark tuples with key in [lo, hi] deleted; set page dirty notes."""
        live = self.valid[: self.num_pages]
        hit = live & (self.keys[: self.num_pages] >= lo) & (self.keys[: self.num_pages] <= hi)
        if not hit.any():
            return 0                      # nothing changed: keep device caches
        npages = hit.any(axis=1)
        self.num_dirty += int((npages & ~self.dirty[: self.num_pages]).sum())
        self.valid[: self.num_pages] &= ~hit
        self.dirty[: self.num_pages] |= npages
        self._dev = None
        self._dev_shard_stale = True
        return int(hit.sum())

    def clear_dirty(self, page_ids: np.ndarray) -> None:
        # dedup: repeated ids must not decrement num_dirty twice
        ids = np.unique(np.asarray(page_ids, np.int64))
        self.num_dirty -= int(self.dirty[ids].sum())
        self.dirty[ids] = False

    def truncate_to(self, num_pages: int, fill: int) -> None:
        """Drop tuples appended past a (num_pages, fill) snapshot.

        Rollback primitive for atomic batch inserts: appends only ever write
        forward of the snapshot position, so clearing that region restores
        the pre-batch table exactly.
        """
        self.valid[num_pages:] = False
        self.keys[num_pages:] = 0.0
        self.num_dirty -= int(self.dirty[num_pages:].sum())
        self.dirty[num_pages:] = False
        if num_pages:
            self.valid[num_pages - 1, fill:] = False
            self.keys[num_pages - 1, fill:] = 0.0
        self.num_pages = num_pages
        self.fill = fill
        self._dev = None
        self._dev_shard_stale = True

    def _grow(self) -> None:
        add = max(self.capacity_pages // 2, 64)
        self.keys = np.concatenate([self.keys, np.zeros((add, self.page_card), np.float32)])
        self.valid = np.concatenate([self.valid, np.zeros((add, self.page_card), bool)])
        self.dirty = np.concatenate([self.dirty, np.zeros((add,), bool)])
        for name, buf in self.payload.items():
            self.payload[name] = np.concatenate([buf, np.zeros((add, self.page_card), buf.dtype)])
        self.capacity_pages += add
