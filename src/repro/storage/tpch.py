"""TPC-H-style Lineitem workload (§7): generator + Q6/Q15/Q20 analogues.

The paper builds indexes on Lineitem's ``partkey`` (uniform ints) and
``l_shipdate`` and runs range predicates at chosen selectivity factors. We
generate the columns the three queries touch; dates are days since epoch
(uniform over 7 years, as in TPC-H).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable

DATE_LO, DATE_HI = 0, 7 * 365          # ~1992-01-01 .. 1998-12-31 in days
PARTKEY_MAX = 200_000


@dataclass
class Lineitem:
    partkey: np.ndarray
    shipdate: np.ndarray
    discount: np.ndarray
    quantity: np.ndarray
    extendedprice: np.ndarray
    suppkey: np.ndarray

    @property
    def card(self) -> int:
        return self.partkey.shape[0]


def generate_lineitem(card: int, seed: int = 0) -> Lineitem:
    rng = np.random.default_rng(seed)
    return Lineitem(
        partkey=rng.integers(1, PARTKEY_MAX, card).astype(np.float32),
        shipdate=rng.integers(DATE_LO, DATE_HI, card).astype(np.float32),
        discount=(rng.integers(0, 11, card) / 100.0).astype(np.float32),
        quantity=rng.integers(1, 51, card).astype(np.float32),
        extendedprice=rng.uniform(900.0, 105000.0, card).astype(np.float32),
        suppkey=rng.integers(1, 10_000, card).astype(np.float32),
    )


def build_shipdate_index(li: Lineitem, page_card: int = 50, resolution: int = 400,
                         density: float = 0.2) -> HippoIndex:
    table = PagedTable.from_values(li.shipdate, page_card=page_card,
                                   spare_pages=64)
    return HippoIndex.create(table, resolution=resolution, density=density)


def _page_select(idx: HippoIndex, lo: float, hi: float) -> np.ndarray:
    """Hippo access path: qualifying-tuple mask (flat, aligned to storage)."""
    res = idx.search(Predicate.between(lo, hi))
    return np.asarray(res.qualified).reshape(-1)[: idx.table.cardinality]


def q6(li: Lineitem, idx: HippoIndex, date_lo: float, date_hi: float) -> float:
    """Forecasting revenue change: SUM(extendedprice * discount) over a
    shipdate range AND discount/quantity filters (plan: index scan on
    shipdate -> residual filters -> aggregate)."""
    sel = _page_select(idx, date_lo, date_hi)
    mask = sel & (li.discount >= 0.05) & (li.discount <= 0.07) & (li.quantity < 24)
    return float((li.extendedprice[mask] * li.discount[mask]).sum())


def q15(li: Lineitem, idx: HippoIndex, date_lo: float, date_hi: float):
    """Top supplier: the revenue view groups by suppkey over a shipdate
    range; the view is consumed twice (max + equality join), which is why the
    paper sees the index invoked twice."""
    best = None
    for _ in range(2):  # the view is evaluated twice in the paper's plan
        sel = _page_select(idx, date_lo, date_hi)
        rev = np.zeros(10_000, np.float64)
        np.add.at(rev, li.suppkey[sel].astype(np.int64),
                  (li.extendedprice[sel] * (1.0 - li.discount[sel])).astype(np.float64))
        best = (int(rev.argmax()), float(rev.max()))
    return best


def q20(li: Lineitem, idx: HippoIndex, date_lo: float, date_hi: float):
    """Potential part promotion (subquery form): per (partkey, suppkey) sum
    of quantity over a shipdate range; result feeds the outer join."""
    sel = _page_select(idx, date_lo, date_hi)
    key = (li.partkey[sel].astype(np.int64) * 10_000
           + li.suppkey[sel].astype(np.int64)) % (1 << 20)
    qty = np.zeros(1 << 20, np.float64)
    np.add.at(qty, key, li.quantity[sel].astype(np.float64))
    thresh = qty[key] * 0.5
    return int((li.quantity[sel] > thresh).sum())


def selectivity_window(sf: float) -> tuple[float, float]:
    """A shipdate window with the requested selectivity (uniform dates)."""
    width = (DATE_HI - DATE_LO) * sf
    lo = (DATE_HI - DATE_LO) / 2
    return lo, lo + width
