from repro.storage.table import PagedTable  # noqa: F401
