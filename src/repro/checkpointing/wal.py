"""Write-ahead journal for staged index maintenance.

The async writer (``runtime.writer.MaintenanceWriter``) acknowledges a
write the moment it is staged — long before a drain applies it to the
table and index. The journal makes that acknowledgement durable:
*append before admission*. Every staged insert, every delete, and every
scheduled re-summarization appends one fsynced record here before the
writer mutates any in-memory state, so a crash at any point loses no
acknowledged operation: recovery loads the last committed snapshot and
replays the journal suffix (``checkpointing.snapshot.recover_index``).

Layout under ``<root>/wal/``: one append-only file per shard for inserts
(``shard_<k>.log`` — inserts are the high-rate stream and route to exactly
one shard) plus ``global.log`` for deletes and re-summarizations (both are
inherently cross-shard). A global monotonically increasing sequence number
stamps every record, so replay merges the files back into the exact
admission order.

Record framing (little-endian)::

    [crc32 u32][payload_len u32][seqno u64][kind u8][payload ...]

The CRC covers seqno + kind + payload. A torn tail — a record cut mid-way
by a crash — fails the length or CRC check and terminates that file's
replay at the last good record; records are fsynced one at a time, so the
only record that can ever be torn is the one being appended at the moment
of the crash, which was by definition not yet acknowledged.

Truncation: ``reset()`` empties every journal file. It is called only
*after* a snapshot commits (the snapshot captures the writer's staged
queues, so the journal's history is redundant from that point). Sequence
numbers keep increasing across resets, and the snapshot records the
``last_seqno`` watermark at its commit; replay skips records at or below
the watermark, so a crash *between* snapshot commit and journal reset can
never double-apply an operation.

``truncate_through(seqno)`` is the watermark-aware form the background
persister needs: when a snapshot commits *asynchronously*, the foreground
may have appended records past the snapshot's watermark by the time the
commit callback runs — ``reset()`` would destroy those still-unsnapshotted
acknowledgements. ``truncate_through`` rewrites each file keeping only the
records past the watermark, each file committed by an atomic rename; a
crash mid-truncate leaves some files trimmed and some not, which replay
tolerates because every surviving record at or below the watermark is
filtered by the watermark discipline anyway. Appends and truncations can
race across threads (engine foreground vs. persister commit callback), so
both run under one internal lock.
"""
from __future__ import annotations

import os
import struct
import threading
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from repro.runtime.faultinject import crashpoint

_FRAME = struct.Struct("<IIQB")      # crc32, payload_len, seqno, kind

KIND_INSERT = 1       # payload: <If  shard, value
KIND_DELETE = 2       # payload: <ff  lo, hi
KIND_RESUM = 3        # payload: <B   policy id, then (H+1,) f32 bounds

_INSERT = struct.Struct("<If")
_DELETE = struct.Struct("<ff")

# Policy ids are part of the on-disk format: append-only.
_POLICY_IDS = {"equal_mass": 0, "learned": 1}
_POLICY_NAMES = {v: k for k, v in _POLICY_IDS.items()}

_MAX_PAYLOAD = 1 << 24     # sanity bound: no record carries >16 MiB


@dataclass(frozen=True)
class WalRecord:
    """One replayable operation, decoded."""
    seqno: int
    kind: int
    shard: int | None = None          # KIND_INSERT
    value: float | None = None        # KIND_INSERT
    lo: float | None = None           # KIND_DELETE
    hi: float | None = None           # KIND_DELETE
    policy: str | None = None         # KIND_RESUM
    bounds: np.ndarray | None = None  # KIND_RESUM


def _decode(seqno: int, kind: int, payload: bytes) -> WalRecord | None:
    if kind == KIND_INSERT and len(payload) == _INSERT.size:
        shard, value = _INSERT.unpack(payload)
        return WalRecord(seqno, kind, shard=shard, value=value)
    if kind == KIND_DELETE and len(payload) == _DELETE.size:
        lo, hi = _DELETE.unpack(payload)
        return WalRecord(seqno, kind, lo=lo, hi=hi)
    if kind == KIND_RESUM and len(payload) >= 1 \
            and (len(payload) - 1) % 4 == 0:
        policy = _POLICY_NAMES.get(payload[0])
        bounds = np.frombuffer(payload, np.float32, offset=1).copy()
        if policy is not None and bounds.size:
            return WalRecord(seqno, kind, policy=policy, bounds=bounds)
    return None      # unknown kind / malformed payload: treat as torn


class Journal:
    """Append-only per-shard WAL under ``<root>/wal/``.

    ``sync=False`` skips the per-append fsync (benchmarks measuring
    in-memory paths); durability-bearing callers keep the default.
    """

    def __init__(self, root: str | Path, num_shards: int, *,
                 sync: bool = True):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.dir = Path(root) / "wal"
        self.dir.mkdir(parents=True, exist_ok=True)
        self.num_shards = num_shards
        self.sync = sync
        # appends (engine foreground) and truncations (persister commit
        # callback) may run on different threads; file state is guarded
        self._lock = threading.Lock()
        self._handles: dict[str, object] = {}  # guarded-by: _lock
        # resume seqno allocation after the highest surviving record, so
        # post-recovery appends always order after everything on disk
        records = self.replay()
        self._next_seqno = (records[-1].seqno + 1) if records else 1

    # -- file plumbing -------------------------------------------------------

    def _filenames(self) -> list[str]:
        return [f"shard_{s}.log" for s in range(self.num_shards)] + \
            ["global.log"]

    def _handle(self, name: str):  # requires-lock: _lock
        h = self._handles.get(name)
        if h is None or h.closed:
            h = open(self.dir / name, "ab")
            self._handles[name] = h
        return h

    def _append(self, name: str, kind: int, payload: bytes) -> int:
        crashpoint("wal.pre_append")
        with self._lock:
            seqno = self._next_seqno
            crc = _crc(seqno, kind, payload)
            h = self._handle(name)
            h.write(_FRAME.pack(crc, len(payload), seqno, kind) + payload)
            h.flush()
            if self.sync:
                os.fsync(h.fileno())
            self._next_seqno += 1
            return seqno

    def close(self) -> None:
        with self._lock:
            self._close_locked()

    def _close_locked(self) -> None:  # requires-lock: _lock
        for h in self._handles.values():
            if not h.closed:
                h.close()
        self._handles.clear()

    # -- append (one call per acknowledged operation) ------------------------

    def append_insert(self, shard: int, value: float) -> int:
        if not 0 <= shard < self.num_shards:
            raise ValueError(f"shard {shard} outside [0, {self.num_shards})")
        return self._append(f"shard_{shard}.log", KIND_INSERT,
                            _INSERT.pack(shard, float(value)))

    def append_delete(self, lo: float, hi: float) -> int:
        return self._append("global.log", KIND_DELETE,
                            _DELETE.pack(float(lo), float(hi)))

    def append_resummarize(self, bounds, policy: str = "equal_mass") -> int:
        pid = _POLICY_IDS.get(policy)
        if pid is None:
            raise ValueError(f"unknown summary policy {policy!r}")
        b = np.ascontiguousarray(np.asarray(bounds, np.float32).ravel())
        if b.size == 0:
            raise ValueError("resummarize record needs a non-empty bounds "
                             "array")
        return self._append("global.log", KIND_RESUM,
                            bytes([pid]) + b.tobytes())

    # -- replay --------------------------------------------------------------

    def replay(self, after: int = 0) -> list[WalRecord]:
        """Every surviving record with ``seqno > after``, in admission
        (sequence-number) order. Torn tails are dropped per file; they can
        only ever be the final, unacknowledged append of a crashed process.
        """
        records: list[WalRecord] = []
        for name in self._filenames():
            path = self.dir / name
            if path.exists():
                records.extend(_scan_file(path))
        records.sort(key=lambda r: r.seqno)
        return [r for r in records if r.seqno > after]

    @property
    def last_seqno(self) -> int:
        """Highest sequence number ever handed out (0 before any append).
        Snapshots record this at commit as the replay watermark."""
        return self._next_seqno - 1

    # -- truncation (post-snapshot GC) ---------------------------------------

    def reset(self) -> None:
        """Empty every journal file — call only after a snapshot that
        captures the writer's staged state has durably committed *and* no
        record was appended past that snapshot's watermark (the synchronous
        drain-commit path guarantees this; concurrent writers must use
        ``truncate_through``). Sequence numbers continue from where they
        were (the watermark discipline depends on it)."""
        with self._lock:
            self._close_locked()
            for name in self._filenames():
                path = self.dir / name
                with open(path, "wb") as f:
                    f.flush()
                    os.fsync(f.fileno())
            fsync_dir_fd = os.open(str(self.dir), os.O_RDONLY)
            try:
                os.fsync(fsync_dir_fd)
            finally:
                os.close(fsync_dir_fd)

    def truncate_through(self, seqno: int) -> None:  # thread: worker
        """Drop every record with ``seqno <=`` the given watermark, keeping
        the rest — the commit callback of an asynchronous snapshot, which
        may run after the foreground appended records the snapshot does not
        cover. Each file is rewritten to a temp sibling, fsynced, and
        renamed in atomically; a crash between files leaves a mix of
        trimmed and untrimmed logs, all of whose at-or-below-watermark
        survivors replay filters out by the watermark discipline."""
        with self._lock:
            self._close_locked()
            for name in self._filenames():
                path = self.dir / name
                if not path.exists():
                    continue
                keep = [r for r in _scan_file(path) if r.seqno > seqno]
                tmp = path.with_suffix(path.suffix + ".trunc")
                with open(tmp, "wb") as f:
                    for rec in keep:
                        payload = _encode_payload(rec)
                        f.write(_FRAME.pack(
                            _crc(rec.seqno, rec.kind, payload),
                            len(payload), rec.seqno, rec.kind) + payload)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
            fsync_dir_fd = os.open(str(self.dir), os.O_RDONLY)
            try:
                os.fsync(fsync_dir_fd)
            finally:
                os.close(fsync_dir_fd)


def _encode_payload(rec: WalRecord) -> bytes:
    """Re-frame a decoded record's payload byte-identically (truncation
    rewrites surviving records; the CRC covers exactly these bytes)."""
    if rec.kind == KIND_INSERT:
        return _INSERT.pack(rec.shard, rec.value)
    if rec.kind == KIND_DELETE:
        return _DELETE.pack(rec.lo, rec.hi)
    if rec.kind == KIND_RESUM:
        return bytes([_POLICY_IDS[rec.policy]]) + \
            np.asarray(rec.bounds, np.float32).tobytes()
    raise ValueError(f"unknown record kind {rec.kind}")


def _crc(seqno: int, kind: int, payload: bytes) -> int:
    import zlib
    return zlib.crc32(struct.pack("<QB", seqno, kind) + payload)


def _scan_file(path: Path) -> list[WalRecord]:
    """Parse one journal file, stopping at the first torn/corrupt record."""
    data = path.read_bytes()
    out: list[WalRecord] = []
    off = 0
    while off + _FRAME.size <= len(data):
        crc, plen, seqno, kind = _FRAME.unpack_from(data, off)
        end = off + _FRAME.size + plen
        if plen > _MAX_PAYLOAD or end > len(data):
            break                       # torn tail: length runs off the file
        payload = data[off + _FRAME.size: end]
        if _crc(seqno, kind, payload) != crc:
            break                       # torn/corrupt record
        rec = _decode(seqno, kind, payload)
        if rec is None:
            break                       # unknown kind: stop, don't guess
        out.append(rec)
        off = end
    return out
