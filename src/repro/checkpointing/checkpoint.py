"""Sharded checkpointing with manifest, async writes, and elastic restore.

Layout per step:  <dir>/step_<N>/
    manifest.json     — pytree structure, leaf shapes/dtypes, step, status
    leaf_<i>.npy      — one array per leaf (host-gathered)
    COMMITTED         — sentinel written last; restore ignores uncommitted dirs
                        (a crash mid-write can never corrupt the latest state)

Elastic scaling: leaves are stored *unsharded* (host-gathered), so a restore
can re-shard onto any mesh — ``restore_checkpoint(..., shardings=...)`` places
each leaf with the target sharding; N-chip -> M-chip moves need no format
change (the cluster-scale variant swaps the npy writes for per-shard files +
the same manifest/commit protocol).

Async mode hands the host arrays to a writer thread; training continues while
the previous step serializes (write-behind checkpointing).
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import numpy as np

import jax

from repro.checkpointing.layout import commit_sentinel, fsync_file


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str | Path, step: int, tree, *, async_write: bool = False):
    """Serialize ``tree`` under step_<step>. Returns the writer thread if async."""
    ckpt_dir = Path(ckpt_dir)
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(l) for l in leaves]
    manifest = {
        "step": step,
        "treedef": str(treedef),  # human-readable structure fingerprint
        "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)} for l in host_leaves],
    }

    def write():
        d = ckpt_dir / f"step_{step}"
        if d.exists():
            shutil.rmtree(d)
        d.mkdir(parents=True)
        for i, arr in enumerate(host_leaves):
            np.save(d / f"leaf_{i}.npy", arr)
        (d / "manifest.json").write_text(json.dumps(manifest))
        # Commit point: every payload byte must be durable *before* the
        # sentinel appears, and the sentinel itself lands via an fsynced
        # temp + atomic rename (checkpointing.layout.commit_sentinel).
        # A bare touch() here could surface after a crash with torn leaf
        # files behind it — a committed-but-corrupt checkpoint.
        for i in range(len(host_leaves)):
            fsync_file(d / f"leaf_{i}.npy")
        fsync_file(d / "manifest.json")
        commit_sentinel(d)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in ckpt_dir.iterdir()
             if d.name.startswith("step_") and (d / "COMMITTED").exists()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, step: int | None = None, *,
                       treedef_like=None, shardings=None):
    """Restore (step, tree). ``treedef_like``: a pytree with the target
    structure (callers always have the state template — init before restore).
    ``shardings``: optional pytree of shardings (or a single sharding applied
    to every leaf) for elastic placement onto the current mesh."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    if treedef_like is None:
        raise ValueError("pass treedef_like= to reconstruct the pytree")
    treedef = jax.tree_util.tree_structure(treedef_like)
    if treedef.num_leaves != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves; template has "
            f"{treedef.num_leaves} — structure mismatch")
    leaves = [np.load(d / f"leaf_{i}.npy") for i in range(len(manifest["leaves"]))]
    if shardings is not None:
        shard_leaves, _ = jax.tree_util.tree_flatten(shardings)
        if len(shard_leaves) == 1 and len(leaves) > 1:
            shard_leaves = shard_leaves * len(leaves)
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, shard_leaves)]
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Keeps the last ``keep`` committed checkpoints; write-behind async."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3, async_write: bool = True):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_write = async_write
        self._pending: threading.Thread | None = None

    def save(self, step: int, tree) -> None:
        self.wait()
        self._pending = save_checkpoint(self.dir, step, tree,
                                        async_write=self.async_write)
        if not self.async_write:
            self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
            self._gc()

    def restore_latest(self, treedef_like, shardings=None):
        self.wait()
        return restore_checkpoint(self.dir, treedef_like=treedef_like,
                                  shardings=shardings)

    def _gc(self) -> None:
        steps = sorted(int(d.name.split("_")[1]) for d in self.dir.iterdir()
                       if d.name.startswith("step_") and (d / "COMMITTED").exists()) \
            if self.dir.exists() else []
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
