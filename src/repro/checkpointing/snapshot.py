"""Index snapshots — durable save/load/recover for a sharded Hippo index.

``save_index`` serializes a ``core.partition.ShardedHippoIndex`` — table
slabs, every shard's live entry prefix, per-shard bounds + epochs, learned
summary models, and (when a ``runtime.writer.MaintenanceWriter`` is
attached) its staged queues and pending re-summarization — into one
section file (``checkpointing.layout``) under ``<root>/snap_<epoch>/``,
committed by a fsync-then-rename ``COMMITTED`` sentinel. ``load_index``
reconstructs an equivalent index; ``recover_index`` additionally replays
the write-ahead journal (``checkpointing.wal``) so a crash at *any*
instant — mid-stage, mid-drain, mid-snapshot — recovers to exactly the
acknowledged state.

Incremental deltas: a full rewrite per drain commit is fine at ~1 MB and
wrong at the 10 GB the ROADMAP north-star targets, so ``save_delta``
commits only what a drain changed — the drain knows exactly which shards
swapped (``runtime.writer.MaintenanceWriter.dirty_checkpoint_shards``).
A delta lives in ``<root>/delta_<base>_<k>/`` beside its base full
snapshot ``snap_<base>/``, in the same section container under the same
COMMITTED-sentinel discipline, and carries per changed shard: that
shard's table slab rows (keys/valid/dirty/payload for its page range),
its full index sections, its bounds and model — plus, because they are
tiny, the complete summaries array, counters, bounds epochs, table
fill/num_pages, the writer's staged state, and the WAL watermark. Delta
sequence numbers are dense (1..k); a committed gap means a skipped commit
and loading refuses with ``CorruptSnapshotError`` rather than serve a
state with a hole in its history. Loading applies base + deltas in order:
each shard's final content comes from the last delta that captured it
(any change to a shard — drain swap, vacuum, resummarize, or a delete
flipping its validity bits — puts it in the next delta), so the chain
replays to the bit-identical index the full rewrite would have produced.
Compaction (``runtime.engine`` policy: after K deltas or when the chain
outweighs the base) folds the chain into a fresh full snapshot; old bases
are pruned together with their deltas.

Collect vs. write: ``collect_full_sections``/``collect_delta_sections``
read the index into host arrays (the only part that must see a quiescent
index), ``write_full_snapshot``/``write_delta_snapshot`` do the file I/O
(the part a background persister thread runs). ``save_index``/
``save_delta`` are the synchronous compositions.

What the bytes are (the paper's §6 storage model, measured for real):

  * only each shard's **live slot prefix** is stored — the device arrays
    are padded to ``max_slots`` for shape stability, but the disk format
    pays for actual entries only;
  * each entry's bucket bitmap is stored as the smaller of its raw packed
    words and its word-level RLE form (``core.bitmap.rle_compress``), one
    flag byte per entry — the paper's compressed-bitmap storage without
    ever inflating dense bitmaps;
  * per-shard boundary arrays are deduplicated in full snapshots: shards
    serving shard 0's epoch reference its bounds instead of repeating
    them (they only diverge while a re-summarization is partially
    drained); a delta stores its changed shards' bounds unconditionally;
  * table validity/dirty masks are bit-packed.

``disk_usage`` splits a snapshot's (or delta's) real file size into table
vs. index bytes — ``benchmarks/bench_storage`` builds the bytes-per-tuple
comparison against the B+-tree baseline from exactly these numbers, and
``benchmarks/bench_recovery`` charges incremental commits by them.

Consistency contract: a snapshot or delta captures (index state, table,
staged queues, pending resummarize, WAL watermark) at one instant.
Recovery = latest committed snapshot + its delta chain + journal records
past the *last chain element's* watermark, replayed through a fresh
writer in admission order. The watermark makes the "truncate journal
after commit" step crash-safe: a crash between the commit and the journal
truncation replays nothing twice. Pruning renames a doomed directory to
``*.tombstone`` before deleting it, so a crash mid-prune can never leave
a half-deleted directory that still carries a COMMITTED sentinel —
tombstones are invisible to epoch/chain discovery and swept on the next
save.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import numpy as np

import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import index as hix
from repro.core.hippo import MaintenanceCounters
from repro.core.learned import PiecewiseLinearModel
from repro.core.partition import (ShardedHippoIndex, ShardedHippoState,
                                  ShardSpec)
from repro.checkpointing.layout import (CorruptSnapshotError, commit_sentinel,
                                        fsync_dir, read_section_file,
                                        section_sizes, write_section_file)
from repro.checkpointing.wal import (KIND_DELETE, KIND_INSERT, KIND_RESUM,
                                     Journal)
from repro.runtime.faultinject import crashpoint
from repro.storage.table import PagedTable

_SNAP_PREFIX = "snap_"
_DELTA_PREFIX = "delta_"
_TOMB = ".tombstone"
_META = "__meta__"
_I32_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# Per-entry bitmap encoding: min(raw words, word-level RLE) per entry
# ---------------------------------------------------------------------------

def _encode_bitmaps(rows: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
    """(flags u8 (n,), lens u32 (n,), data u32 (sum lens,)) for (n, W)."""
    flags = np.zeros((rows.shape[0],), np.uint8)
    lens = np.zeros((rows.shape[0],), np.uint32)
    chunks = []
    for i, row in enumerate(rows):
        rle = bm.rle_compress(row)
        if rle.size < row.size:
            flags[i], lens[i] = 1, rle.size
            chunks.append(rle)
        else:
            flags[i], lens[i] = 0, row.size
            chunks.append(row.astype(np.uint32))
    data = np.concatenate(chunks) if chunks else np.zeros((0,), np.uint32)
    return flags, lens, data


def _decode_bitmaps(flags: np.ndarray, lens: np.ndarray, data: np.ndarray,
                    words: int) -> np.ndarray:
    out = np.zeros((flags.shape[0], words), np.uint32)
    off = 0
    for i, (f, ln) in enumerate(zip(flags, lens)):
        chunk = data[off: off + int(ln)]
        if chunk.size != int(ln):
            raise CorruptSnapshotError(
                "bitmap section shorter than its per-entry lengths claim")
        row = bm.rle_decompress(chunk) if f else chunk
        if row.size != words:
            raise CorruptSnapshotError(
                f"entry bitmap decodes to {row.size} words, index resolution "
                f"wants {words}")
        out[i] = row
        off += int(ln)
    return out


def _encode_model(m: PiecewiseLinearModel | None, prefix: str,
                  sections: dict) -> dict | None:
    if m is None:
        return None
    sections[f"{prefix}/knots_x"] = np.asarray(m.knots_x, np.float64)
    sections[f"{prefix}/knots_y"] = np.asarray(m.knots_y, np.float64)
    return {"n_knots": int(m.n_knots), "segments": int(m.segments),
            "max_error": float(m.max_error)}


def _decode_model(meta: dict | None, prefix: str,
                  sections: dict) -> PiecewiseLinearModel | None:
    if meta is None:
        return None
    return PiecewiseLinearModel(
        knots_x=np.asarray(sections[f"{prefix}/knots_x"], np.float64),
        knots_y=np.asarray(sections[f"{prefix}/knots_y"], np.float64),
        n_knots=int(meta["n_knots"]), segments=int(meta["segments"]),
        max_error=float(meta["max_error"]))


# ---------------------------------------------------------------------------
# Collect: index state -> named host-array sections
# ---------------------------------------------------------------------------

def _collect_shard_sections(st, s: int, pre: str, sections: dict) -> dict:
    """One shard's index sections (live prefix, encoded bitmaps) + meta."""
    n = int(np.asarray(st.num_slots[s]))
    flags, lens, data = _encode_bitmaps(
        np.asarray(st.bitmaps[s][:n], np.uint32))
    sections[f"{pre}/bm_flags"] = flags
    sections[f"{pre}/bm_lens"] = lens
    sections[f"{pre}/bm_data"] = data
    sections[f"{pre}/starts"] = np.asarray(st.starts[s][:n], np.int32)
    sections[f"{pre}/ends"] = np.asarray(st.ends[s][:n], np.int32)
    sections[f"{pre}/order"] = np.asarray(st.sorted_order[s][:n], np.int32)
    sections[f"{pre}/live"] = np.packbits(
        np.asarray(st.slot_live[s][:n], bool))
    return {
        "num_entries": int(np.asarray(st.num_entries[s])),
        "num_slots": n,
        "summarized_until": int(np.asarray(st.summarized_until[s])),
    }


def _collect_writer(w, sections: dict) -> dict | None:
    """The attached writer's staged state (queues, pending resummarize)."""
    if w is None:
        return None
    qshards = []
    for s, q in sorted(w._queues.items()):
        if not q.values:
            continue
        sections[f"wal/q{s}/values"] = np.asarray(q.values, np.float32)
        sections[f"wal/q{s}/live"] = np.asarray(q.live, np.uint8)
        qshards.append(int(s))
    meta = {
        "queues": qshards,
        "pending_resummarize": [int(s) for s in w._pending_resummarize],
        "resum_epoch": int(w._resum_epoch),
        "staged": int(w.stats.staged),
        "killed": int(w.stats.killed),
        "pending_model": _encode_model(w._pending_model, "wal/pmodel",
                                       sections),
    }
    if w._pending_bounds is not None:
        sections["wal/pending_bounds"] = np.asarray(w._pending_bounds,
                                                    np.float32)
    return meta


def collect_full_sections(index: ShardedHippoIndex,
                          wal_seqno: int) -> dict[str, np.ndarray]:
    """Everything a full snapshot stores, as named sections + a meta blob."""
    cfg, spec, table = index.cfg, index.spec, index.table
    sections: dict[str, np.ndarray] = {}

    npages = table.num_pages
    ntuples = npages * table.page_card
    sections["table/keys"] = np.asarray(table.keys[:npages], np.float32)
    sections["table/valid"] = np.packbits(
        table.valid[:npages].reshape(-1))
    sections["table/dirty"] = np.packbits(table.dirty[:npages])
    payload_meta = {}
    for name, col in table.payload.items():
        sections[f"table/payload/{name}"] = np.asarray(col[:npages])
        payload_meta[name] = np.asarray(col).dtype.str

    shards_meta = []
    bounds0 = np.asarray(index.state.shards.bounds[0], np.float32)
    st = index.state.shards
    for s in range(spec.num_shards):
        pre = f"s{s}"
        sm = _collect_shard_sections(st, s, pre, sections)
        own_bounds = False
        if s > 0:
            bs = np.asarray(st.bounds[s], np.float32)
            if not np.array_equal(bs, bounds0):
                sections[f"{pre}/bounds"] = bs
                own_bounds = True
        sm["own_bounds"] = own_bounds
        shards_meta.append(sm)
    sections["s0/bounds"] = bounds0
    sections["summaries"] = np.asarray(index.state.summaries, np.uint32)

    models_meta = [
        _encode_model(m, f"s{s}/model", sections)
        for s, m in enumerate(index.summary_models or
                              [None] * spec.num_shards)]

    writer_meta = _collect_writer(index.staging, sections)

    meta = {
        "kind": "sharded_hippo_index",
        "cfg": {"resolution": cfg.resolution, "density": cfg.density,
                "page_card": cfg.page_card, "max_slots": cfg.max_slots,
                "relocate_on_update": cfg.relocate_on_update},
        "spec": {"num_shards": spec.num_shards,
                 "pages_per_shard": spec.pages_per_shard},
        "summary": index.summary,
        "bounds_epochs": [int(e) for e in index.bounds_epochs],
        "counters": {k: int(v) for k, v in vars(index.counters).items()},
        "table": {"num_pages": npages, "fill": table.fill,
                  "num_tuples": ntuples, "payload": payload_meta},
        "shards": shards_meta,
        "models": models_meta,
        "writer": writer_meta,
        "wal_seqno": int(wal_seqno),
    }
    sections[_META] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode("utf-8"), np.uint8).copy()
    return sections


def collect_delta_sections(index: ShardedHippoIndex, wal_seqno: int,
                           shards, base_epoch: int,
                           delta_seq: int) -> dict[str, np.ndarray]:
    """What one drain commit changed: the given shards' index sections and
    table slab rows, plus the (tiny) global scalars a load needs in full —
    summaries, counters, bounds epochs, table fill, writer staged state."""
    spec, table = index.spec, index.table
    sections: dict[str, np.ndarray] = {}
    npages = table.num_pages
    shard_ids = sorted({int(s) for s in shards})
    if any(s < 0 or s >= spec.num_shards for s in shard_ids):
        raise ValueError(f"delta shards {shard_ids} outside "
                         f"[0, {spec.num_shards})")

    payload_meta = {name: np.asarray(col).dtype.str
                    for name, col in table.payload.items()}
    st = index.state.shards
    shards_meta = {}
    for s in shard_ids:
        pre = f"d{s}"
        sm = _collect_shard_sections(st, s, pre, sections)
        sections[f"{pre}/bounds"] = np.asarray(st.bounds[s], np.float32)
        lo = spec.page_lo(s)
        hi = min(lo + spec.pages_per_shard, npages)
        if hi > lo:
            sections[f"{pre}/keys"] = np.asarray(table.keys[lo:hi],
                                                 np.float32)
            sections[f"{pre}/valid"] = np.packbits(
                table.valid[lo:hi].reshape(-1))
            sections[f"{pre}/dirty"] = np.packbits(table.dirty[lo:hi])
            for name, col in table.payload.items():
                sections[f"{pre}/payload/{name}"] = np.asarray(col[lo:hi])
        sm["page_lo"], sm["page_hi"] = lo, hi
        sm["model"] = _encode_model(
            (index.summary_models or [None] * spec.num_shards)[s],
            f"{pre}/model", sections)
        shards_meta[str(s)] = sm
    sections["summaries"] = np.asarray(index.state.summaries, np.uint32)

    writer_meta = _collect_writer(index.staging, sections)

    meta = {
        "kind": "sharded_hippo_delta",
        "base_epoch": int(base_epoch),
        "delta_seq": int(delta_seq),
        "shards": shard_ids,
        "summary": index.summary,
        "bounds_epochs": [int(e) for e in index.bounds_epochs],
        "counters": {k: int(v) for k, v in vars(index.counters).items()},
        "table": {"num_pages": npages, "fill": table.fill,
                  "num_tuples": npages * table.page_card,
                  "payload": payload_meta},
        "shards_meta": shards_meta,
        "writer": writer_meta,
        "wal_seqno": int(wal_seqno),
    }
    sections[_META] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode("utf-8"), np.uint8).copy()
    return sections


# ---------------------------------------------------------------------------
# Directory discovery: epochs, delta chains, tombstone-safe pruning
# ---------------------------------------------------------------------------

def latest_epoch(root: str | Path) -> int | None:
    """Highest committed snapshot epoch under ``root`` (None if none).
    Tombstoned (mid-prune) directories are never candidates."""
    root = Path(root)
    if not root.exists():
        return None
    epochs = []
    for d in root.iterdir():
        if (d.name.startswith(_SNAP_PREFIX) and not d.name.endswith(_TOMB)
                and (d / "COMMITTED").exists()):
            try:
                epochs.append(int(d.name[len(_SNAP_PREFIX):]))
            except ValueError:
                continue
    return max(epochs) if epochs else None


def _delta_dirs(root: Path, base_epoch: int) -> list[tuple[int, Path]]:
    out = []
    pre = f"{_DELTA_PREFIX}{base_epoch}_"
    for d in root.iterdir():
        if not d.name.startswith(pre) or d.name.endswith(_TOMB):
            continue
        try:
            seq = int(d.name[len(pre):])
        except ValueError:
            continue
        if (d / "COMMITTED").exists():
            out.append((seq, d))
    out.sort()
    return out


def latest_delta_seq(root: str | Path, base_epoch: int) -> int:
    """Highest committed delta sequence against ``base_epoch`` (0 if none)."""
    root = Path(root)
    if not root.exists():
        return 0
    dirs = _delta_dirs(root, base_epoch)
    return dirs[-1][0] if dirs else 0


def delta_chain(root: str | Path, base_epoch: int) -> list[tuple[int, Path]]:
    """Committed deltas against ``base_epoch`` in replay order (seq 1..k).

    Sequence numbers must be dense: a committed delta k without every
    committed delta below it means a commit was skipped (which the
    background persister's poisoning discipline exists to prevent), and
    replaying across the hole would silently lose that commit's shards —
    refuse with ``CorruptSnapshotError`` instead.
    """
    dirs = _delta_dirs(Path(root), base_epoch)
    for i, (seq, _) in enumerate(dirs):
        if seq != i + 1:
            raise CorruptSnapshotError(
                f"delta chain for snapshot {base_epoch} is missing seq "
                f"{i + 1} (found {[s for s, _ in dirs]}): a committed gap "
                f"means a skipped commit — refusing to replay across it")
    return dirs


def _prune(root: Path, keep: int) -> None:
    """Drop all but the newest ``keep`` full snapshots, each with its delta
    chain. Atomic against crashes: a doomed directory is renamed to
    ``<name>.tombstone`` first (one rename — afterwards its COMMITTED
    sentinel is invisible to discovery), then deleted; tombstones left by
    a crash mid-prune are swept here on the next save."""
    for p in list(root.iterdir()):
        if p.name.endswith(_TOMB):
            shutil.rmtree(p, ignore_errors=True)
    committed = sorted(
        (int(p.name[len(_SNAP_PREFIX):]) for p in root.iterdir()
         if p.name.startswith(_SNAP_PREFIX) and not p.name.endswith(_TOMB)
         and (p / "COMMITTED").exists()),
        reverse=True)
    doomed = []
    for old in committed[keep:]:
        doomed.append(root / f"{_SNAP_PREFIX}{old}")
        doomed.extend(p for _, p in _delta_dirs(root, old))
    for d in doomed:
        tomb = d.with_name(d.name + _TOMB)
        try:
            # hippolint: disable=crash -- this rename deletes, not commits:
            # the payload is a doomed-but-committed snapshot, so durability
            # is not required — a crash that loses the rename merely
            # resurrects a committed directory the next save re-sweeps
            os.replace(d, tomb)
        except OSError:
            tomb = d     # rename refused: fall back to direct removal
        shutil.rmtree(tomb, ignore_errors=True)


# ---------------------------------------------------------------------------
# Write: sections -> committed directory (the background persister's half)
# ---------------------------------------------------------------------------

def write_full_snapshot(root: str | Path, sections: dict, *, keep: int = 3,
                        epoch: int | None = None,
                        compact: bool = False) -> Path:
    """Write + commit a full snapshot from pre-collected sections.

    ``epoch=None`` allocates the next epoch from disk (synchronous
    callers); a background persister passes the epoch it reserved at
    collect time. ``compact=True`` marks this full snapshot as a
    compaction fold of a delta chain — same bytes, distinct crash-point
    site. Pruning (``keep``) runs after the commit.
    """
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    if epoch is None:
        epoch = (latest_epoch(root) or 0) + 1
    d = root / f"{_SNAP_PREFIX}{epoch}"
    if d.exists():
        shutil.rmtree(d)     # leftover uncommitted attempt
    d.mkdir()
    fsync_dir(root)
    write_section_file(d / "index.bin", sections)
    crashpoint("compact.pre_commit" if compact else "snapshot.pre_commit")
    commit_sentinel(d)
    _prune(root, keep)
    return d


def write_delta_snapshot(root: str | Path, sections: dict, base_epoch: int,
                         delta_seq: int) -> Path:
    """Write + commit one delta against ``snap_<base_epoch>``."""
    root = Path(root)
    base = root / f"{_SNAP_PREFIX}{base_epoch}"
    if not (base / "COMMITTED").exists():
        raise FileNotFoundError(
            f"delta base snapshot {base} is not committed — a delta "
            f"against an uncommitted base could never replay")
    d = root / f"{_DELTA_PREFIX}{base_epoch}_{delta_seq}"
    if d.exists():
        shutil.rmtree(d)     # leftover uncommitted attempt at this seq
    d.mkdir()
    fsync_dir(root)
    write_section_file(d / "index.bin", sections)
    crashpoint("delta.pre_commit")
    commit_sentinel(d)
    return d


def save_index(root: str | Path, index: ShardedHippoIndex, *,
               wal_seqno: int = 0, keep: int = 3, epoch: int | None = None,
               compact: bool = False) -> Path:
    """Durably snapshot ``index`` under ``<root>/snap_<epoch>/``.

    The snapshot is committed by the ``COMMITTED`` sentinel appearing
    (fsync-then-rename); a crash before that leaves an ignorable partial
    directory. ``wal_seqno`` records the journal watermark at this
    snapshot's instant — journal records at or below it are already
    reflected here and must not replay. Keeps the last ``keep`` committed
    snapshots (with their delta chains); older ones are pruned after the
    new commit via tombstone renames.
    """
    return write_full_snapshot(root, collect_full_sections(index, wal_seqno),
                               keep=keep, epoch=epoch, compact=compact)


def save_delta(root: str | Path, index: ShardedHippoIndex, *, shards,
               wal_seqno: int = 0, base_epoch: int | None = None,
               delta_seq: int | None = None) -> Path:
    """Durably commit an incremental delta: the given ``shards``' current
    index sections and table slab rows against the last full snapshot.
    ``shards`` must cover every shard changed since the previous commit
    (the writer's ``dirty_checkpoint_shards`` tracks exactly that)."""
    root = Path(root)
    if base_epoch is None:
        base_epoch = latest_epoch(root)
        if base_epoch is None:
            raise FileNotFoundError(
                f"no committed full snapshot under {root} to delta against")
    if delta_seq is None:
        delta_seq = latest_delta_seq(root, base_epoch) + 1
    sections = collect_delta_sections(index, wal_seqno, shards, base_epoch,
                                      delta_seq)
    return write_delta_snapshot(root, sections, base_epoch, delta_seq)


# ---------------------------------------------------------------------------
# Load
# ---------------------------------------------------------------------------

def _snapshot_dir(root: Path, epoch: int | None) -> Path:
    if epoch is None:
        epoch = latest_epoch(root)
        if epoch is None:
            raise FileNotFoundError(
                f"no committed snapshot under {root} (uncommitted partials, "
                f"if any, are ignored by design)")
    d = root / f"{_SNAP_PREFIX}{epoch}"
    if not (d / "COMMITTED").exists():
        raise FileNotFoundError(
            f"snapshot {d} is not committed — refusing to load a torn "
            f"snapshot")
    return d


def _load_raw(root: str | Path, epoch: int | None
              ) -> tuple[Path, dict, dict[str, np.ndarray]]:
    d = _snapshot_dir(Path(root), epoch)
    sections = read_section_file(d / "index.bin")
    if _META not in sections:
        raise CorruptSnapshotError(f"{d}: snapshot has no metadata section")
    try:
        meta = json.loads(bytes(sections[_META]).decode("utf-8"))
    except ValueError as e:
        raise CorruptSnapshotError(f"{d}: metadata is not valid JSON") from e
    if meta.get("kind") != "sharded_hippo_index":
        raise CorruptSnapshotError(
            f"{d}: snapshot kind {meta.get('kind')!r} is not an index")
    return d, meta, sections


def _read_delta(path: Path, base_epoch: int,
                seq: int) -> tuple[dict, dict[str, np.ndarray]]:
    sections = read_section_file(path / "index.bin")
    if _META not in sections:
        raise CorruptSnapshotError(f"{path}: delta has no metadata section")
    try:
        meta = json.loads(bytes(sections[_META]).decode("utf-8"))
    except ValueError as e:
        raise CorruptSnapshotError(f"{path}: metadata is not valid "
                                   f"JSON") from e
    if meta.get("kind") != "sharded_hippo_delta":
        raise CorruptSnapshotError(
            f"{path}: kind {meta.get('kind')!r} is not an index delta")
    if (int(meta.get("base_epoch", -1)) != base_epoch
            or int(meta.get("delta_seq", -1)) != seq):
        raise CorruptSnapshotError(
            f"{path}: delta claims base {meta.get('base_epoch')} seq "
            f"{meta.get('delta_seq')} but sits at base {base_epoch} seq "
            f"{seq} — directory layout and contents disagree")
    return meta, sections


def _load_chain(root: Path, epoch: int | None
                ) -> tuple[dict, dict, list[tuple[dict, dict]]]:
    """Base snapshot meta/sections plus its committed delta chain, in
    replay order."""
    d, meta, sections = _load_raw(root, epoch)
    base_epoch = int(d.name[len(_SNAP_PREFIX):])
    chain = [_read_delta(p, base_epoch, seq)
             for seq, p in delta_chain(root, base_epoch)]
    return meta, sections, chain


def _decode_shard_leaves(cfg: hix.HippoConfig, pre: str, sm: dict,
                         sections: dict, bounds: np.ndarray) -> dict:
    """One shard's HippoState leaves (numpy, padded to max_slots)."""
    S, W = cfg.max_slots, cfg.words
    n = sm["num_slots"]
    bitmaps = np.zeros((S, W), np.uint32)
    bitmaps[:n] = _decode_bitmaps(
        sections[f"{pre}/bm_flags"], sections[f"{pre}/bm_lens"],
        sections[f"{pre}/bm_data"], W)
    starts = np.full((S,), _I32_MAX, np.int32)
    starts[:n] = sections[f"{pre}/starts"]
    ends = np.full((S,), _I32_MAX, np.int32)
    ends[:n] = sections[f"{pre}/ends"]
    order = np.arange(S, dtype=np.int32)
    order[:n] = sections[f"{pre}/order"]
    live = np.zeros((S,), bool)
    live[:n] = np.unpackbits(sections[f"{pre}/live"],
                             count=n).astype(bool)
    return {
        "bounds": bounds, "bitmaps": bitmaps, "starts": starts, "ends": ends,
        "sorted_order": order, "slot_live": live,
        "num_entries": np.int32(sm["num_entries"]),
        "num_slots": np.int32(n),
        "summarized_until": np.int32(sm["summarized_until"]),
    }


def _rebuild_table(meta: dict, sections: dict,
                   chain: list[tuple[dict, dict]]) -> PagedTable:
    """Base table rows patched by each delta's changed-shard slab rows, at
    the chain's final capacity."""
    page_card = meta["cfg"]["page_card"]
    eff_t = (chain[-1][0] if chain else meta)["table"]
    npages, fill = eff_t["num_pages"], eff_t["fill"]
    base_np = meta["table"]["num_pages"]
    keys = np.zeros((npages, page_card), np.float32)
    valid = np.zeros((npages, page_card), bool)
    dirty = np.zeros((npages,), bool)
    keys[:base_np] = np.array(sections["table/keys"], np.float32).reshape(
        base_np, page_card)
    valid[:base_np] = np.unpackbits(
        sections["table/valid"],
        count=base_np * page_card).astype(bool).reshape(base_np, page_card)
    dirty[:base_np] = np.unpackbits(sections["table/dirty"],
                                    count=base_np).astype(bool)
    payload = {}
    for name, dstr in meta["table"]["payload"].items():
        col = np.zeros((npages, page_card), np.dtype(dstr))
        col[:base_np] = np.array(
            sections[f"table/payload/{name}"]).reshape(base_np, page_card)
        payload[name] = col
    for dmeta, dsec in chain:
        for s in dmeta["shards"]:
            sm = dmeta["shards_meta"][str(s)]
            lo, hi = sm["page_lo"], sm["page_hi"]
            if hi <= lo:
                continue
            if hi > npages:
                raise CorruptSnapshotError(
                    f"delta seq {dmeta['delta_seq']} patches pages up to "
                    f"{hi} but the chain's final table has {npages} pages")
            pre, n = f"d{s}", hi - lo
            keys[lo:hi] = np.array(dsec[f"{pre}/keys"], np.float32).reshape(
                n, page_card)
            valid[lo:hi] = np.unpackbits(
                dsec[f"{pre}/valid"],
                count=n * page_card).astype(bool).reshape(n, page_card)
            dirty[lo:hi] = np.unpackbits(dsec[f"{pre}/dirty"],
                                         count=n).astype(bool)
            for name in payload:
                payload[name][lo:hi] = np.array(
                    dsec[f"{pre}/payload/{name}"]).reshape(n, page_card)
    return PagedTable(
        page_card=page_card, capacity_pages=npages, keys=keys,
        valid=valid, dirty=dirty, num_pages=npages, fill=fill,
        num_dirty=int(dirty.sum()), payload=payload)


def _rebuild_state(cfg: hix.HippoConfig, meta: dict, sections: dict,
                   chain: list[tuple[dict, dict]]) -> ShardedHippoState:
    """Base per-shard leaves, each replaced by the last delta that captured
    its shard; stacked to device arrays once at the end."""
    bounds0 = np.asarray(sections["s0/bounds"], np.float32)
    per_shard = []
    for s, sm in enumerate(meta["shards"]):
        pre = f"s{s}"
        bounds = (np.asarray(sections[f"{pre}/bounds"], np.float32)
                  if s > 0 and sm["own_bounds"] else bounds0)
        per_shard.append(_decode_shard_leaves(cfg, pre, sm, sections, bounds))
    summaries = np.asarray(sections["summaries"], np.uint32)
    for dmeta, dsec in chain:
        for s in dmeta["shards"]:
            sm = dmeta["shards_meta"][str(s)]
            pre = f"d{s}"
            bounds = np.asarray(dsec[f"{pre}/bounds"], np.float32)
            per_shard[int(s)] = _decode_shard_leaves(cfg, pre, sm, dsec,
                                                     bounds)
        summaries = np.asarray(dsec["summaries"], np.uint32)
    shards = hix.HippoState(**{
        f: jnp.asarray(np.stack([ps[f] for ps in per_shard]))
        for f in hix.HippoState._fields})
    return ShardedHippoState(shards=shards, summaries=jnp.asarray(summaries))


def _build_index(meta: dict, sections: dict,
                 chain: list[tuple[dict, dict]]) -> ShardedHippoIndex:
    c = meta["cfg"]
    cfg = hix.HippoConfig(
        resolution=c["resolution"], density=c["density"],
        page_card=c["page_card"], max_slots=c["max_slots"],
        relocate_on_update=c["relocate_on_update"])
    spec = ShardSpec(num_shards=meta["spec"]["num_shards"],
                     pages_per_shard=meta["spec"]["pages_per_shard"])
    eff = chain[-1][0] if chain else meta
    models = [_decode_model(mm, f"s{s}/model", sections)
              for s, mm in enumerate(meta["models"])]
    for dmeta, dsec in chain:
        for s in dmeta["shards"]:
            models[int(s)] = _decode_model(
                dmeta["shards_meta"][str(s)]["model"], f"d{s}/model", dsec)
    return ShardedHippoIndex(
        cfg=cfg, spec=spec,
        state=_rebuild_state(cfg, meta, sections, chain),
        table=_rebuild_table(meta, sections, chain),
        counters=MaintenanceCounters(**eff["counters"]),
        bounds_epochs=np.asarray(eff["bounds_epochs"], np.int64),
        summary=eff["summary"],
        summary_models=models)


def load_index(root: str | Path, *, epoch: int | None = None
               ) -> tuple[ShardedHippoIndex, dict]:
    """Reconstruct the latest (or a specific) committed snapshot's index,
    its delta chain applied.

    Returns ``(index, meta)``; with a delta chain, ``meta`` is the base
    snapshot's metadata with the chain-effective scalars (wal watermark,
    counters, table, writer state, bounds epochs) folded in. The index is
    writer-less; use ``recover_index`` (or ``QueryEngine.recover``) when a
    journal/staged state may exist. Counts, row ids, bounds, epochs, and
    learned models round-trip exactly (``tests/test_persistence.py``).
    """
    meta, sections, chain = _load_chain(Path(root), epoch)
    index = _build_index(meta, sections, chain)
    if chain:
        eff = dict(meta)
        last = chain[-1][0]
        for k in ("wal_seqno", "counters", "bounds_epochs", "summary",
                  "table", "writer"):
            eff[k] = last[k]
        eff["deltas"] = len(chain)
        return index, eff
    return index, meta


# ---------------------------------------------------------------------------
# Recovery: snapshot + delta chain + journal replay
# ---------------------------------------------------------------------------

def _restore_writer(index: ShardedHippoIndex, meta: dict, sections: dict):
    """Reattach a writer carrying the snapshot's staged state."""
    from repro.runtime.writer import MaintenanceWriter, _ShardQueue
    w = MaintenanceWriter(index)
    wm = meta["writer"]
    for s in wm["queues"]:
        q = _ShardQueue()
        q.values = [float(v) for v in sections[f"wal/q{s}/values"]]
        q.live = [bool(b) for b in sections[f"wal/q{s}/live"]]
        q.n_live = sum(q.live)
        w._queues[int(s)] = q
        if q.n_live:
            w.drift.observe(np.asarray(
                [v for v, a in zip(q.values, q.live) if a], np.float32))
    w._staged_total = sum(len(q.values) for q in w._queues.values())
    w._version += 1
    w.stats.staged = int(wm["staged"])
    w.stats.killed = int(wm["killed"])
    w._pending_resummarize = [int(s) for s in wm["pending_resummarize"]]
    w._resum_epoch = int(wm["resum_epoch"])
    if "wal/pending_bounds" in sections:
        w._pending_bounds = np.asarray(sections["wal/pending_bounds"],
                                       np.float32)
    w._pending_model = _decode_model(wm["pending_model"], "wal/pmodel",
                                     sections)
    return w


def recover_index(root: str | Path, *, epoch: int | None = None,
                  wal_sync: bool = True):
    """Crash recovery: latest committed snapshot + delta chain + journal
    suffix replay.

    Returns ``(index, writer, journal)``. The writer holds the staged
    state exactly as acknowledged before the crash (the chain's last
    captured queues plus replayed journal records past the chain's
    watermark); the journal is attached to it, so subsequent writes keep
    journaling. ``writer`` is None only when the snapshot had no writer
    and the journal is empty.
    """
    root = Path(root)
    meta, sections, chain = _load_chain(root, epoch)
    index = _build_index(meta, sections, chain)
    eff_meta, eff_sections = (chain[-1] if chain else (meta, sections))
    journal = Journal(root, index.spec.num_shards, sync=wal_sync)
    records = journal.replay(after=int(eff_meta.get("wal_seqno", 0)))

    writer = None
    if eff_meta["writer"] is not None:
        writer = _restore_writer(index, eff_meta, eff_sections)
    elif records:
        from repro.runtime.writer import MaintenanceWriter
        writer = MaintenanceWriter(index)

    for rec in records:
        if rec.kind == KIND_INSERT:
            s = writer.write(rec.value)
            if s != rec.shard:
                raise CorruptSnapshotError(
                    f"journal replay routed a staged insert to shard {s} "
                    f"but the record was acknowledged on shard {rec.shard} "
                    f"— snapshot and journal disagree")
        elif rec.kind == KIND_DELETE:
            writer.delete(rec.lo, rec.hi)
        elif rec.kind == KIND_RESUM:
            writer.schedule_resummarize(bounds=rec.bounds, policy=rec.policy)
    if writer is not None:
        writer.journal = journal
    return index, writer, journal


# ---------------------------------------------------------------------------
# Storage accounting (the bench's real-bytes source)
# ---------------------------------------------------------------------------

def _is_table_section(name: str) -> bool:
    if name.startswith("table/"):
        return True
    # delta layout: d<shard>/{keys,valid,dirty,payload/*} are slab rows
    if name.startswith("d") and "/" in name:
        tail = name.split("/", 1)[1]
        return tail in ("keys", "valid", "dirty") or \
            tail.startswith("payload/")
    return False


def disk_usage(snapshot: str | Path) -> dict[str, int]:
    """Real byte split of a snapshot or delta: ``total`` file size,
    ``table`` (heap payload sections), and ``index`` (everything else:
    entries, bounds, summaries, models, staged state, metadata, headers).
    The index figure is what ``bench_storage`` charges Hippo per tuple —
    container overhead included, nothing amortized away."""
    snapshot = Path(snapshot)
    f = snapshot / "index.bin" if snapshot.is_dir() else snapshot
    sizes = section_sizes(f)
    total = f.stat().st_size
    table = sum(nb for name, nb in sizes.items() if _is_table_section(name))
    return {"total": total, "table": table, "index": total - table}
