"""Index snapshots — durable save/load/recover for a sharded Hippo index.

``save_index`` serializes a ``core.partition.ShardedHippoIndex`` — table
slabs, every shard's live entry prefix, per-shard bounds + epochs, learned
summary models, and (when a ``runtime.writer.MaintenanceWriter`` is
attached) its staged queues and pending re-summarization — into one
section file (``checkpointing.layout``) under ``<root>/snap_<epoch>/``,
committed by a fsync-then-rename ``COMMITTED`` sentinel. ``load_index``
reconstructs an equivalent index; ``recover_index`` additionally replays
the write-ahead journal (``checkpointing.wal``) so a crash at *any*
instant — mid-stage, mid-drain, mid-snapshot — recovers to exactly the
acknowledged state.

What the bytes are (the paper's §6 storage model, measured for real):

  * only each shard's **live slot prefix** is stored — the device arrays
    are padded to ``max_slots`` for shape stability, but the disk format
    pays for actual entries only;
  * each entry's bucket bitmap is stored as the smaller of its raw packed
    words and its word-level RLE form (``core.bitmap.rle_compress``), one
    flag byte per entry — the paper's compressed-bitmap storage without
    ever inflating dense bitmaps;
  * per-shard boundary arrays are deduplicated: shards serving shard 0's
    epoch reference its bounds instead of repeating them (they only
    diverge while a re-summarization is partially drained);
  * table validity/dirty masks are bit-packed.

``disk_usage`` splits a snapshot's real file size into table vs. index
bytes — ``benchmarks/bench_storage`` builds the bytes-per-tuple comparison
against the B+-tree baseline from exactly these numbers.

Consistency contract: a snapshot captures (index state, table, staged
queues, pending resummarize, WAL watermark) at one instant. Recovery =
latest committed snapshot + journal records past the watermark, replayed
through a fresh writer in admission order. The watermark makes the
"truncate journal after snapshot" step crash-safe: a crash between the
snapshot commit and the journal reset replays nothing twice.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path

import numpy as np

import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import index as hix
from repro.core.hippo import MaintenanceCounters
from repro.core.learned import PiecewiseLinearModel
from repro.core.partition import (ShardedHippoIndex, ShardedHippoState,
                                  ShardSpec)
from repro.checkpointing.layout import (CorruptSnapshotError, commit_sentinel,
                                        fsync_dir, read_section_file,
                                        section_sizes, write_section_file)
from repro.checkpointing.wal import (KIND_DELETE, KIND_INSERT, KIND_RESUM,
                                     Journal)
from repro.storage.table import PagedTable

_SNAP_PREFIX = "snap_"
_META = "__meta__"
_I32_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# Per-entry bitmap encoding: min(raw words, word-level RLE) per entry
# ---------------------------------------------------------------------------

def _encode_bitmaps(rows: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
    """(flags u8 (n,), lens u32 (n,), data u32 (sum lens,)) for (n, W)."""
    flags = np.zeros((rows.shape[0],), np.uint8)
    lens = np.zeros((rows.shape[0],), np.uint32)
    chunks = []
    for i, row in enumerate(rows):
        rle = bm.rle_compress(row)
        if rle.size < row.size:
            flags[i], lens[i] = 1, rle.size
            chunks.append(rle)
        else:
            flags[i], lens[i] = 0, row.size
            chunks.append(row.astype(np.uint32))
    data = np.concatenate(chunks) if chunks else np.zeros((0,), np.uint32)
    return flags, lens, data


def _decode_bitmaps(flags: np.ndarray, lens: np.ndarray, data: np.ndarray,
                    words: int) -> np.ndarray:
    out = np.zeros((flags.shape[0], words), np.uint32)
    off = 0
    for i, (f, ln) in enumerate(zip(flags, lens)):
        chunk = data[off: off + int(ln)]
        if chunk.size != int(ln):
            raise CorruptSnapshotError(
                "bitmap section shorter than its per-entry lengths claim")
        row = bm.rle_decompress(chunk) if f else chunk
        if row.size != words:
            raise CorruptSnapshotError(
                f"entry bitmap decodes to {row.size} words, index resolution "
                f"wants {words}")
        out[i] = row
        off += int(ln)
    return out


def _encode_model(m: PiecewiseLinearModel | None, prefix: str,
                  sections: dict) -> dict | None:
    if m is None:
        return None
    sections[f"{prefix}/knots_x"] = np.asarray(m.knots_x, np.float64)
    sections[f"{prefix}/knots_y"] = np.asarray(m.knots_y, np.float64)
    return {"n_knots": int(m.n_knots), "segments": int(m.segments),
            "max_error": float(m.max_error)}


def _decode_model(meta: dict | None, prefix: str,
                  sections: dict) -> PiecewiseLinearModel | None:
    if meta is None:
        return None
    return PiecewiseLinearModel(
        knots_x=np.asarray(sections[f"{prefix}/knots_x"], np.float64),
        knots_y=np.asarray(sections[f"{prefix}/knots_y"], np.float64),
        n_knots=int(meta["n_knots"]), segments=int(meta["segments"]),
        max_error=float(meta["max_error"]))


# ---------------------------------------------------------------------------
# Save
# ---------------------------------------------------------------------------

def _collect_sections(index: ShardedHippoIndex,
                      wal_seqno: int) -> dict[str, np.ndarray]:
    """Everything the snapshot stores, as named sections + a meta blob."""
    cfg, spec, table = index.cfg, index.spec, index.table
    sections: dict[str, np.ndarray] = {}

    npages = table.num_pages
    ntuples = npages * table.page_card
    sections["table/keys"] = np.asarray(table.keys[:npages], np.float32)
    sections["table/valid"] = np.packbits(
        table.valid[:npages].reshape(-1))
    sections["table/dirty"] = np.packbits(table.dirty[:npages])
    payload_meta = {}
    for name, col in table.payload.items():
        sections[f"table/payload/{name}"] = np.asarray(col[:npages])
        payload_meta[name] = np.asarray(col).dtype.str

    shards_meta = []
    bounds0 = np.asarray(index.state.shards.bounds[0], np.float32)
    for s in range(spec.num_shards):
        st = index.state.shards
        n = int(np.asarray(st.num_slots[s]))
        pre = f"s{s}"
        flags, lens, data = _encode_bitmaps(
            np.asarray(st.bitmaps[s][:n], np.uint32))
        sections[f"{pre}/bm_flags"] = flags
        sections[f"{pre}/bm_lens"] = lens
        sections[f"{pre}/bm_data"] = data
        sections[f"{pre}/starts"] = np.asarray(st.starts[s][:n], np.int32)
        sections[f"{pre}/ends"] = np.asarray(st.ends[s][:n], np.int32)
        sections[f"{pre}/order"] = np.asarray(st.sorted_order[s][:n], np.int32)
        sections[f"{pre}/live"] = np.packbits(
            np.asarray(st.slot_live[s][:n], bool))
        own_bounds = False
        if s > 0:
            bs = np.asarray(st.bounds[s], np.float32)
            if not np.array_equal(bs, bounds0):
                sections[f"{pre}/bounds"] = bs
                own_bounds = True
        shards_meta.append({
            "num_entries": int(np.asarray(st.num_entries[s])),
            "num_slots": n,
            "summarized_until": int(np.asarray(st.summarized_until[s])),
            "own_bounds": own_bounds,
        })
    sections["s0/bounds"] = bounds0
    sections["summaries"] = np.asarray(index.state.summaries, np.uint32)

    models_meta = [
        _encode_model(m, f"s{s}/model", sections)
        for s, m in enumerate(index.summary_models or
                              [None] * spec.num_shards)]

    writer_meta = None
    w = index.staging
    if w is not None:
        qshards = []
        for s, q in sorted(w._queues.items()):
            if not q.values:
                continue
            sections[f"wal/q{s}/values"] = np.asarray(q.values, np.float32)
            sections[f"wal/q{s}/live"] = np.asarray(q.live, np.uint8)
            qshards.append(int(s))
        writer_meta = {
            "queues": qshards,
            "pending_resummarize": [int(s) for s in
                                    w._pending_resummarize],
            "resum_epoch": int(w._resum_epoch),
            "staged": int(w.stats.staged),
            "killed": int(w.stats.killed),
            "pending_model": _encode_model(w._pending_model, "wal/pmodel",
                                           sections),
        }
        if w._pending_bounds is not None:
            sections["wal/pending_bounds"] = np.asarray(w._pending_bounds,
                                                        np.float32)

    meta = {
        "kind": "sharded_hippo_index",
        "cfg": {"resolution": cfg.resolution, "density": cfg.density,
                "page_card": cfg.page_card, "max_slots": cfg.max_slots,
                "relocate_on_update": cfg.relocate_on_update},
        "spec": {"num_shards": spec.num_shards,
                 "pages_per_shard": spec.pages_per_shard},
        "summary": index.summary,
        "bounds_epochs": [int(e) for e in index.bounds_epochs],
        "counters": {k: int(v) for k, v in vars(index.counters).items()},
        "table": {"num_pages": npages, "fill": table.fill,
                  "num_tuples": ntuples, "payload": payload_meta},
        "shards": shards_meta,
        "models": models_meta,
        "writer": writer_meta,
        "wal_seqno": int(wal_seqno),
    }
    sections[_META] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode("utf-8"), np.uint8).copy()
    return sections


def latest_epoch(root: str | Path) -> int | None:
    """Highest committed snapshot epoch under ``root`` (None if none)."""
    root = Path(root)
    if not root.exists():
        return None
    epochs = []
    for d in root.iterdir():
        if d.name.startswith(_SNAP_PREFIX) and (d / "COMMITTED").exists():
            try:
                epochs.append(int(d.name[len(_SNAP_PREFIX):]))
            except ValueError:
                continue
    return max(epochs) if epochs else None


def save_index(root: str | Path, index: ShardedHippoIndex, *,
               wal_seqno: int = 0, keep: int = 3) -> Path:
    """Durably snapshot ``index`` under ``<root>/snap_<epoch>/``.

    The snapshot is committed by the ``COMMITTED`` sentinel appearing
    (fsync-then-rename); a crash before that leaves an ignorable partial
    directory. ``wal_seqno`` records the journal watermark at this
    snapshot's instant — journal records at or below it are already
    reflected here and must not replay. Keeps the last ``keep`` committed
    snapshots; older ones are pruned after the new commit.
    """
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    epoch = (latest_epoch(root) or 0) + 1
    d = root / f"{_SNAP_PREFIX}{epoch}"
    if d.exists():
        shutil.rmtree(d)     # leftover uncommitted attempt
    d.mkdir()
    fsync_dir(root)
    write_section_file(d / "index.bin", _collect_sections(index, wal_seqno))
    commit_sentinel(d)
    committed = sorted(
        (int(p.name[len(_SNAP_PREFIX):]) for p in root.iterdir()
         if p.name.startswith(_SNAP_PREFIX) and (p / "COMMITTED").exists()),
        reverse=True)
    for old in committed[keep:]:
        shutil.rmtree(root / f"{_SNAP_PREFIX}{old}", ignore_errors=True)
    return d


# ---------------------------------------------------------------------------
# Load
# ---------------------------------------------------------------------------

def _snapshot_dir(root: Path, epoch: int | None) -> Path:
    if epoch is None:
        epoch = latest_epoch(root)
        if epoch is None:
            raise FileNotFoundError(
                f"no committed snapshot under {root} (uncommitted partials, "
                f"if any, are ignored by design)")
    d = root / f"{_SNAP_PREFIX}{epoch}"
    if not (d / "COMMITTED").exists():
        raise FileNotFoundError(
            f"snapshot {d} is not committed — refusing to load a torn "
            f"snapshot")
    return d


def _load_raw(root: str | Path, epoch: int | None
              ) -> tuple[Path, dict, dict[str, np.ndarray]]:
    d = _snapshot_dir(Path(root), epoch)
    sections = read_section_file(d / "index.bin")
    if _META not in sections:
        raise CorruptSnapshotError(f"{d}: snapshot has no metadata section")
    try:
        meta = json.loads(bytes(sections[_META]).decode("utf-8"))
    except ValueError as e:
        raise CorruptSnapshotError(f"{d}: metadata is not valid JSON") from e
    if meta.get("kind") != "sharded_hippo_index":
        raise CorruptSnapshotError(
            f"{d}: snapshot kind {meta.get('kind')!r} is not an index")
    return d, meta, sections


def _rebuild_table(meta: dict, sections: dict) -> PagedTable:
    t = meta["table"]
    npages, page_card = t["num_pages"], meta["cfg"]["page_card"]
    keys = np.array(sections["table/keys"], np.float32).reshape(
        npages, page_card)
    valid = np.unpackbits(
        sections["table/valid"], count=npages * page_card).astype(bool)
    dirty = np.unpackbits(sections["table/dirty"], count=npages).astype(bool)
    payload = {}
    for name in t["payload"]:
        payload[name] = np.array(
            sections[f"table/payload/{name}"]).reshape(npages, page_card)
    return PagedTable(
        page_card=page_card, capacity_pages=npages, keys=keys,
        valid=valid.reshape(npages, page_card), dirty=dirty,
        num_pages=npages, fill=t["fill"],
        num_dirty=int(dirty.sum()), payload=payload)


def _rebuild_state(cfg: hix.HippoConfig, meta: dict,
                   sections: dict) -> ShardedHippoState:
    S, W = cfg.max_slots, cfg.words
    bounds0 = np.asarray(sections["s0/bounds"], np.float32)
    leaves = {f: [] for f in hix.HippoState._fields}
    for s, sm in enumerate(meta["shards"]):
        pre, n = f"s{s}", sm["num_slots"]
        bitmaps = np.zeros((S, W), np.uint32)
        bitmaps[:n] = _decode_bitmaps(
            sections[f"{pre}/bm_flags"], sections[f"{pre}/bm_lens"],
            sections[f"{pre}/bm_data"], W)
        starts = np.full((S,), _I32_MAX, np.int32)
        starts[:n] = sections[f"{pre}/starts"]
        ends = np.full((S,), _I32_MAX, np.int32)
        ends[:n] = sections[f"{pre}/ends"]
        order = np.arange(S, dtype=np.int32)
        order[:n] = sections[f"{pre}/order"]
        live = np.zeros((S,), bool)
        live[:n] = np.unpackbits(sections[f"{pre}/live"],
                                 count=n).astype(bool)
        bounds = (np.asarray(sections[f"{pre}/bounds"], np.float32)
                  if sm["own_bounds"] else bounds0)
        leaves["bounds"].append(bounds)
        leaves["bitmaps"].append(bitmaps)
        leaves["starts"].append(starts)
        leaves["ends"].append(ends)
        leaves["sorted_order"].append(order)
        leaves["slot_live"].append(live)
        leaves["num_entries"].append(np.int32(sm["num_entries"]))
        leaves["num_slots"].append(np.int32(n))
        leaves["summarized_until"].append(np.int32(sm["summarized_until"]))
    shards = hix.HippoState(**{
        f: jnp.asarray(np.stack(leaves[f])) for f in hix.HippoState._fields})
    return ShardedHippoState(
        shards=shards,
        summaries=jnp.asarray(np.asarray(sections["summaries"], np.uint32)))


def load_index(root: str | Path, *, epoch: int | None = None
               ) -> tuple[ShardedHippoIndex, dict]:
    """Reconstruct the latest (or a specific) committed snapshot's index.

    Returns ``(index, meta)``. The index is writer-less; use
    ``recover_index`` (or ``QueryEngine.recover``) when a journal/staged
    state may exist. Counts, row ids, bounds, epochs, and learned models
    round-trip exactly (``tests/test_persistence.py``).
    """
    _, meta, sections = _load_raw(root, epoch)
    c = meta["cfg"]
    cfg = hix.HippoConfig(
        resolution=c["resolution"], density=c["density"],
        page_card=c["page_card"], max_slots=c["max_slots"],
        relocate_on_update=c["relocate_on_update"])
    spec = ShardSpec(num_shards=meta["spec"]["num_shards"],
                     pages_per_shard=meta["spec"]["pages_per_shard"])
    index = ShardedHippoIndex(
        cfg=cfg, spec=spec,
        state=_rebuild_state(cfg, meta, sections),
        table=_rebuild_table(meta, sections),
        counters=MaintenanceCounters(**meta["counters"]),
        bounds_epochs=np.asarray(meta["bounds_epochs"], np.int64),
        summary=meta["summary"],
        summary_models=[_decode_model(mm, f"s{s}/model", sections)
                        for s, mm in enumerate(meta["models"])])
    return index, meta


# ---------------------------------------------------------------------------
# Recovery: snapshot + journal replay
# ---------------------------------------------------------------------------

def _restore_writer(index: ShardedHippoIndex, meta: dict, sections: dict):
    """Reattach a writer carrying the snapshot's staged state."""
    from repro.runtime.writer import MaintenanceWriter, _ShardQueue
    w = MaintenanceWriter(index)
    wm = meta["writer"]
    for s in wm["queues"]:
        q = _ShardQueue()
        q.values = [float(v) for v in sections[f"wal/q{s}/values"]]
        q.live = [bool(b) for b in sections[f"wal/q{s}/live"]]
        q.n_live = sum(q.live)
        w._queues[int(s)] = q
        if q.n_live:
            w.drift.observe(np.asarray(
                [v for v, a in zip(q.values, q.live) if a], np.float32))
    w._staged_total = sum(len(q.values) for q in w._queues.values())
    w._version += 1
    w.stats.staged = int(wm["staged"])
    w.stats.killed = int(wm["killed"])
    w._pending_resummarize = [int(s) for s in wm["pending_resummarize"]]
    w._resum_epoch = int(wm["resum_epoch"])
    if "wal/pending_bounds" in sections:
        w._pending_bounds = np.asarray(sections["wal/pending_bounds"],
                                       np.float32)
    w._pending_model = _decode_model(wm["pending_model"], "wal/pmodel",
                                     sections)
    return w


def recover_index(root: str | Path, *, epoch: int | None = None,
                  wal_sync: bool = True):
    """Crash recovery: latest committed snapshot + journal suffix replay.

    Returns ``(index, writer, journal)``. The writer holds the staged
    state exactly as acknowledged before the crash (snapshot queues plus
    replayed journal records past the snapshot's watermark); the journal
    is attached to it, so subsequent writes keep journaling. ``writer`` is
    None only when the snapshot had no writer and the journal is empty.
    """
    root = Path(root)
    _, meta, sections = _load_raw(root, epoch)
    index, _ = load_index(root, epoch=epoch)
    journal = Journal(root, index.spec.num_shards, sync=wal_sync)
    records = journal.replay(after=int(meta.get("wal_seqno", 0)))

    writer = None
    if meta["writer"] is not None:
        writer = _restore_writer(index, meta, sections)
    elif records:
        from repro.runtime.writer import MaintenanceWriter
        writer = MaintenanceWriter(index)

    for rec in records:
        if rec.kind == KIND_INSERT:
            s = writer.write(rec.value)
            if s != rec.shard:
                raise CorruptSnapshotError(
                    f"journal replay routed a staged insert to shard {s} "
                    f"but the record was acknowledged on shard {rec.shard} "
                    f"— snapshot and journal disagree")
        elif rec.kind == KIND_DELETE:
            writer.delete(rec.lo, rec.hi)
        elif rec.kind == KIND_RESUM:
            writer.schedule_resummarize(bounds=rec.bounds, policy=rec.policy)
    if writer is not None:
        writer.journal = journal
    return index, writer, journal


# ---------------------------------------------------------------------------
# Storage accounting (the bench's real-bytes source)
# ---------------------------------------------------------------------------

def disk_usage(snapshot: str | Path) -> dict[str, int]:
    """Real byte split of a snapshot: ``total`` file size, ``table`` (heap
    payload sections), and ``index`` (everything else: entries, bounds,
    summaries, models, staged state, metadata, headers). The index figure
    is what ``bench_storage`` charges Hippo per tuple — container overhead
    included, nothing amortized away."""
    snapshot = Path(snapshot)
    f = snapshot / "index.bin" if snapshot.is_dir() else snapshot
    sizes = section_sizes(f)
    total = f.stat().st_size
    table = sum(nb for name, nb in sizes.items()
                if name.startswith("table/"))
    return {"total": total, "table": table, "index": total - table}
