"""Versioned binary section container — the on-disk layout primitive.

Every durable artifact in this repo (index snapshots, the serialized
baseline indexes in ``benchmarks/bench_storage``) is one *section file*: a
fixed-offset header, a fixed-width section table, and raw C-contiguous
array payloads. The layout is deliberately dumb — no compression or
framing cleverness at this layer (bitmap-level encoding happens above, in
``checkpointing.snapshot``) — so a reader can validate the whole file
before trusting a single byte of it:

    offset 0    header (64 bytes)
                  magic ``b"HIPPOIX1"``, format version, section count,
                  table offset, total file size, CRC32 of everything
                  after the header
    offset 64   section table (152 bytes per section)
                  name (48B utf-8), dtype str (16B), ndim, shape (8×u64),
                  absolute payload offset, payload nbytes
    then        payloads, 64-byte aligned

Readers re-derive every extent from the header and refuse anything that
does not add up: short files, bad magic, unknown versions, sections
pointing outside the file, dtype/shape/nbytes disagreement, CRC mismatch.
All refusals raise ``CorruptSnapshotError`` — a torn or truncated file is
an error, never garbage counts.

Durability helpers (``write_file_durable``, ``commit_sentinel``) implement
the fsync-then-rename discipline: payload bytes are fsynced *before* the
commit marker becomes visible, and the marker itself appears via an atomic
``os.replace`` of an fsynced temp file, so a crash at any instant leaves
either the old committed state or the new one — never a committed-but-torn
snapshot.
"""
from __future__ import annotations

import os
import struct
import zlib
from pathlib import Path

import numpy as np

MAGIC = b"HIPPOIX1"
FORMAT_VERSION = 1

_HEADER = struct.Struct("<8sIIQQI28x")       # magic, ver, nsec, table_off,
_SECTION = struct.Struct("<48s16sII8QQQ")    # file_size, crc  / name, dtype,
_ALIGN = 64                                  # ndim, pad, shape[8], off, nbytes
_MAX_NAME = 48
_MAX_DTYPE = 16
_MAX_NDIM = 8


class CorruptSnapshotError(Exception):
    """The file is not a valid snapshot: truncated, torn, version-bumped,
    or internally inconsistent. Loading must fail loudly, never return
    garbage counts."""


# ---------------------------------------------------------------------------
# Durability primitives (fsync-then-rename)
# ---------------------------------------------------------------------------

def fsync_file(path: str | Path) -> None:
    """Force a file's bytes to stable storage."""
    fd = os.open(str(path), os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: str | Path) -> None:
    """Force a directory entry (rename/create) to stable storage."""
    fd = os.open(str(path), os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_file_durable(path: str | Path, data: bytes) -> None:
    """Write ``data`` to ``path`` atomically and durably: temp file in the
    same directory, flush + fsync, ``os.replace`` onto the final name, then
    fsync the directory so the rename itself survives a crash."""
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(path.parent)


def commit_sentinel(directory: str | Path, name: str = "COMMITTED") -> Path:
    """Publish a commit marker in ``directory`` via fsync-then-rename.

    Callers must have fsynced the directory's payload files first — the
    sentinel's appearance is the commit point, so everything it vouches for
    has to be durable before it exists.
    """
    directory = Path(directory)
    sentinel = directory / name
    write_file_durable(sentinel, b"")
    return sentinel


# ---------------------------------------------------------------------------
# Section codec
# ---------------------------------------------------------------------------

def _pad_to(n: int, align: int = _ALIGN) -> int:
    return -(-n // align) * align


def pack_sections(sections: dict[str, np.ndarray]) -> bytes:
    """Serialize named arrays into one section-file byte string."""
    entries = []
    payloads = []
    offset = _pad_to(_HEADER.size + _SECTION.size * len(sections))
    for name, arr in sections.items():
        arr = np.asarray(arr)
        if arr.ndim and not arr.flags.c_contiguous:
            # ascontiguousarray only when needed: it promotes 0-d to 1-d,
            # which would silently rewrite a scalar section's shape
            arr = np.ascontiguousarray(arr)
        nb = name.encode("utf-8")
        db = arr.dtype.str.encode("ascii")
        if len(nb) > _MAX_NAME:
            raise ValueError(f"section name too long ({len(nb)} > {_MAX_NAME} "
                             f"bytes): {name!r}")
        if len(db) > _MAX_DTYPE:
            raise ValueError(f"dtype string too long: {arr.dtype.str!r}")
        if arr.ndim > _MAX_NDIM:
            raise ValueError(f"section {name!r} has {arr.ndim} dims "
                             f"(max {_MAX_NDIM})")
        shape = list(arr.shape) + [0] * (_MAX_NDIM - arr.ndim)
        entries.append(_SECTION.pack(nb, db, arr.ndim, 0, *shape,
                                     offset, arr.nbytes))
        payloads.append((offset, arr.tobytes()))
        offset = _pad_to(offset + arr.nbytes)
    body = bytearray(offset - _HEADER.size)
    table = b"".join(entries)
    body[: len(table)] = table
    for off, raw in payloads:
        body[off - _HEADER.size: off - _HEADER.size + len(raw)] = raw
    file_size = _HEADER.size + len(body)
    crc = zlib.crc32(bytes(body))
    header = _HEADER.pack(MAGIC, FORMAT_VERSION, len(sections),
                          _HEADER.size, file_size, crc)
    return header + bytes(body)


def unpack_sections(data: bytes, *, origin: str = "<bytes>"
                    ) -> dict[str, np.ndarray]:
    """Parse and fully validate a section file; inverse of ``pack_sections``.

    Raises ``CorruptSnapshotError`` on any inconsistency — the returned
    arrays are only constructed after every check has passed.
    """
    if len(data) < _HEADER.size:
        raise CorruptSnapshotError(
            f"{origin}: {len(data)} bytes is shorter than the "
            f"{_HEADER.size}-byte header (truncated file)")
    magic, version, nsec, table_off, file_size, crc = _HEADER.unpack_from(data)
    if magic != MAGIC:
        raise CorruptSnapshotError(
            f"{origin}: bad magic {magic!r} (not a snapshot file)")
    if version != FORMAT_VERSION:
        raise CorruptSnapshotError(
            f"{origin}: format version {version} != supported "
            f"{FORMAT_VERSION} — refusing to guess at an unknown layout")
    if file_size != len(data):
        raise CorruptSnapshotError(
            f"{origin}: header claims {file_size} bytes, file has "
            f"{len(data)} (truncated or padded file)")
    if table_off != _HEADER.size or \
            table_off + nsec * _SECTION.size > file_size:
        raise CorruptSnapshotError(
            f"{origin}: section table ({nsec} sections at offset "
            f"{table_off}) runs outside the file")
    if zlib.crc32(data[_HEADER.size:]) != crc:
        raise CorruptSnapshotError(
            f"{origin}: CRC mismatch — payload bytes are torn or corrupted")
    out: dict[str, np.ndarray] = {}
    for i in range(nsec):
        (nb, db, ndim, _pad, *rest) = _SECTION.unpack_from(
            data, table_off + i * _SECTION.size)
        shape, off, nbytes = tuple(rest[:_MAX_NDIM]), rest[_MAX_NDIM], rest[-1]
        name = nb.rstrip(b"\0").decode("utf-8", errors="replace")
        if ndim > _MAX_NDIM:
            raise CorruptSnapshotError(
                f"{origin}: section {name!r} claims {ndim} dims")
        try:
            dtype = np.dtype(db.rstrip(b"\0").decode("ascii"))
        except (TypeError, UnicodeDecodeError) as e:
            raise CorruptSnapshotError(
                f"{origin}: section {name!r} has unparseable dtype") from e
        shape = shape[:ndim]
        expect = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        if expect != nbytes:
            raise CorruptSnapshotError(
                f"{origin}: section {name!r} shape {shape} x {dtype} wants "
                f"{expect} bytes, table records {nbytes}")
        if off + nbytes > file_size:
            raise CorruptSnapshotError(
                f"{origin}: section {name!r} payload [{off}, {off + nbytes}) "
                f"runs past the {file_size}-byte file")
        if name in out:
            raise CorruptSnapshotError(
                f"{origin}: duplicate section name {name!r}")
        out[name] = np.frombuffer(
            data, dtype=dtype, count=int(np.prod(shape, dtype=np.int64)),
            offset=off).reshape(shape).copy()
    return out


def write_section_file(path: str | Path,
                       sections: dict[str, np.ndarray]) -> int:
    """Durably write a section file (temp + fsync + rename); returns its
    size in bytes."""
    data = pack_sections(sections)
    write_file_durable(path, data)
    return len(data)


def read_section_file(path: str | Path) -> dict[str, np.ndarray]:
    path = Path(path)
    try:
        data = path.read_bytes()
    except OSError as e:
        raise CorruptSnapshotError(f"cannot read {path}: {e}") from e
    return unpack_sections(data, origin=str(path))


def section_sizes(path: str | Path) -> dict[str, int]:
    """Per-section payload bytes of a section file (validates it fully)."""
    return {name: arr.nbytes
            for name, arr in read_section_file(path).items()}
