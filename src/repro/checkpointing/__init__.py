from repro.checkpointing.checkpoint import (  # noqa: F401
    CheckpointManager, restore_checkpoint, save_checkpoint,
)
from repro.checkpointing.layout import (  # noqa: F401
    CorruptSnapshotError, commit_sentinel, pack_sections, read_section_file,
    section_sizes, unpack_sections, write_file_durable, write_section_file,
)
from repro.checkpointing.snapshot import (  # noqa: F401
    delta_chain, disk_usage, latest_delta_seq, latest_epoch, load_index,
    recover_index, save_delta, save_index,
)
from repro.checkpointing.wal import Journal, WalRecord  # noqa: F401
