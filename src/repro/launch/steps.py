"""jit-able train / prefill / decode step factories + abstract input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct and shardable, with zero device allocation — which is what
the dry-run lowers against.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import serve, transformer
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.optim.adamw import AdamWState


# ---------------------------------------------------------------------------
# abstract shapes (no allocation)
# ---------------------------------------------------------------------------

def params_shape(cfg):
    return jax.eval_shape(lambda k: transformer.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def opt_state_shape(cfg, p_shape, moment_dtype: str = "float32"):
    return jax.eval_shape(lambda p: adamw_init(p, moment_dtype), p_shape)


def cache_shape(cfg, batch: int, max_seq: int):
    return jax.eval_shape(lambda: serve.init_cache(cfg, batch, max_seq))


def input_specs(cfg, shape, kind: str):
    """ShapeDtypeStructs for one (arch x shape) cell.

    train:   {inputs, labels, positions}
    prefill: {inputs, positions}
    decode:  {tokens, pos}  (cache comes from ``cache_shape``)
    """
    b, s = shape.global_batch, shape.seq_len
    tok = (jax.ShapeDtypeStruct((b, s), jnp.int32) if cfg.frontend == "tokens"
           else jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16))
    pos = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if kind == "train":
        return {"inputs": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "positions": pos}
    if kind == "prefill":
        return {"inputs": tok, "positions": pos}
    # decode: one new token against a seq_len cache
    tok1 = (jax.ShapeDtypeStruct((b, 1), jnp.int32) if cfg.frontend == "tokens"
            else jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16))
    return {"tokens": tok1, "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg, *, peak_lr: float = 3e-4, warmup: int = 2000,
                    total: int = 100_000, weight_decay: float = 0.1,
                    remat: bool = True, accum: int = 1,
                    accum_dtype: str = "float32", opt_unit_scan: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum`` > 1 scans over microbatches (gradient accumulation): activation
    memory scales with batch/accum while arithmetic and gradient math are
    unchanged. Accumulation buffers shard like the params; ``accum_dtype``
    trades accumulator precision for HBM (bf16 used for the 400B cell, where
    an fp32 buffer alone is 6.2 GiB/device).
    """
    adt = jnp.dtype(accum_dtype)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: transformer.loss_fn(cfg, p, batch, remat=remat))(params)

    def train_step(params, opt_state, batch):
        lr = warmup_cosine(opt_state.step, peak_lr=peak_lr, warmup_steps=warmup,
                           total_steps=total)
        if accum == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda t: t.reshape(accum, t.shape[0] // accum, *t.shape[1:]),
                batch)

            def acc_fn(carry, mb):
                tot, g_acc = carry
                loss, g = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32)).astype(adt), g_acc, g)
                return (tot + loss, g_acc), None

            g0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, adt), params)
            (loss_sum, grads), _ = jax.lax.scan(acc_fn, (jnp.float32(0), g0), micro)
            loss = loss_sum / accum
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
        params, opt_state, metrics = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay,
            unit_scan=opt_unit_scan)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, max_seq: int):
    def prefill_step(params, batch):
        return serve.prefill(cfg, params, batch["inputs"], batch["positions"],
                             max_seq)
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, cache, tokens, pos):
        return serve.decode_step(cfg, params, cache, tokens, pos)
    return decode_step
