"""Serving driver: batched request decoding against a prefillable model.

Implements a minimal continuous-batching front: requests arrive with prompts,
get prefilled into a shared KV cache batch, and decode in lock-step; finished
requests free their slot for the next queued request. On CPU this drives
reduced configs (examples/serve_decode.py); the step functions are the same
ones the dry-run lowers for the production mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --requests 6 --batch 4 --prompt-len 16 --gen 24
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import serve, transformer


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    generated: list = field(default_factory=list)
    done: bool = False


class BatchServer:
    """Lock-step batched decoder with slot recycling."""

    def __init__(self, cfg, params, batch: int, max_seq: int):
        self.cfg, self.params = cfg, params
        self.batch, self.max_seq = batch, max_seq
        self.cache = serve.init_cache(cfg, batch, max_seq)
        self.pos = np.zeros(batch, np.int64)
        self.slots: list[Request | None] = [None] * batch
        self._decode = jax.jit(
            lambda p, c, t, pos: serve.decode_step(cfg, p, c, t, pos))

    def admit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                # prefill the slot (single-request prefill, then merge cache)
                prompt = jnp.asarray(req.prompt[None, :])
                positions = jnp.arange(prompt.shape[1])[None, :]
                logits, cache1 = serve.prefill(self.cfg, self.params, prompt,
                                               positions, self.max_seq)
                self.cache = jax.tree_util.tree_map(
                    lambda full, one: full.at[:, i].set(one[:, 0])
                    if full.ndim >= 2 and full.shape[1] == self.batch else full,
                    self.cache, cache1)
                req.generated.append(int(jnp.argmax(logits[0])))
                self.slots[i] = req
                self.pos[i] = prompt.shape[1]
                return True
        return False

    def step(self) -> None:
        """One lock-step decode for all active slots."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        tokens = np.zeros((self.batch, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].generated[-1]
        # lock-step uses the max position; per-slot masks come from cache state
        pos = int(max(self.pos[i] for i in active))
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens), jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            self.slots[i].generated.append(int(nxt[i]))
            self.pos[i] += 1

    def retire(self, max_gen: int) -> list[Request]:
        out = []
        for i, s in enumerate(self.slots):
            if s is not None and len(s.generated) >= max_gen:
                s.done = True
                out.append(s)
                self.slots[i] = None
        return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    queue = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                args.prompt_len).astype(np.int32))
             for i in range(args.requests)]
    server = BatchServer(cfg, params, args.batch,
                         max_seq=args.prompt_len + args.gen + 1)

    finished: list[Request] = []
    t0 = time.time()
    steps = 0
    while len(finished) < args.requests:
        while queue and server.admit(queue[0]):
            print(f"admitted request {queue[0].rid}")
            queue.pop(0)
        server.step()
        steps += 1
        finished.extend(server.retire(args.gen))
    dt = time.time() - t0
    tok = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)} requests / {tok} tokens in {dt:.2f}s "
          f"({steps} decode steps, {tok/dt:.1f} tok/s)")
    for r in finished[:3]:
        print(f"  req {r.rid}: {r.generated[:8]}...")
    return finished


if __name__ == "__main__":
    main()
