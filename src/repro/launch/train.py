"""End-to-end training driver: Hippo-indexed data pipeline -> sharded train
steps -> checkpoint/restart, with the fault-tolerant loop.

On this CPU container it trains reduced configs end-to-end (examples/ uses it
for the ~100M-token-scale run); on a real cluster the same driver runs the
full configs — the only difference is the mesh and the config name.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 50 \
      --reduced --batch 8 --seq 64 --ckpt-dir /tmp/ckpt [--resume]
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpointing import CheckpointManager
from repro.configs import get_config
from repro.core.predicate import Predicate
from repro.data import HippoDataPipeline, synthesize_corpus
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import make_param_shardings, replicated
from repro.models import transformer
from repro.optim import adamw_init
from repro.optim.adamw import AdamWState
from repro.runtime import StepWatchdog, resilient_loop


def build_state(cfg, key):
    params = transformer.init_params(cfg, key)
    opt = adamw_init(params)
    return {"params": params, "opt": opt}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale config (CPU-friendly)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--stop-after", type=int, default=None,
                    help="simulate preemption: exit after this step (schedule "
                         "still spans --steps, so a resumed run is "
                         "bit-identical to an uninterrupted one)")
    ap.add_argument("--quality-min", type=float, default=0.0,
                    help="Hippo-index data selection predicate lower bound")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.frontend != "tokens":
        raise SystemExit("training driver expects a token-frontend arch")

    # --- data: Hippo-indexed selection ------------------------------------
    corpus = synthesize_corpus(num_seqs=4096, seq_len=args.seq + 1,
                               vocab_size=cfg.vocab_size, seed=args.seed)
    pipe = HippoDataPipeline.create(
        corpus, Predicate.between(args.quality_min, 1.0), seed=args.seed)
    print(f"data: {pipe.selected_ids.size}/{corpus.num_seqs} sequences selected "
          f"(inspected {pipe.pages_inspected}/{corpus.table.num_pages} pages "
          f"via Hippo index)")

    # --- state + sharding ---------------------------------------------------
    mesh = make_host_mesh(data=1, model=max(1, len(jax.devices())))
    state = build_state(cfg, jax.random.PRNGKey(args.seed))
    train_step = steps_lib.make_train_step(
        cfg, peak_lr=args.lr, warmup=max(2, args.steps // 10),
        total=args.steps, accum=args.accum)
    jitted = jax.jit(train_step)

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume:
        try:
            start, state = mgr.restore_latest(state)
            print(f"resumed from step {start}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")

    wd = StepWatchdog()
    losses = []

    def step_fn(step, state):
        batch = jax.tree_util.tree_map(jnp.asarray,
                                       pipe.get_batch(step, args.batch))
        params, opt, metrics = jitted(state["params"], state["opt"], batch)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        return {"params": params, "opt": opt}

    def save_fn(step, state):
        mgr.save(step, state)

    def restore_fn():
        return mgr.restore_latest(state)

    t0 = time.time()
    stop_at = min(args.steps, args.stop_after) if args.stop_after else args.steps
    state, stats = resilient_loop(
        num_steps=stop_at, step_fn=step_fn, state=state, save_fn=save_fn,
        restore_fn=restore_fn, checkpoint_every=args.ckpt_every, watchdog=wd,
        start_step=start)
    dt = time.time() - t0
    print(f"done: {stats.steps_run} steps in {dt:.1f}s "
          f"({stats.failures} failures, {stats.restores} restores, "
          f"{stats.stragglers} straggler steps)")
    print(f"loss: first {losses[0]:.4f} -> last {losses[-1]:.4f}")
    mgr.wait()
    return losses


if __name__ == "__main__":
    main()
