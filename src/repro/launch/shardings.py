"""PartitionSpecs for parameters, optimizer state, inputs, and caches.

Layout policy (DESIGN.md §6):
  * 2-D param sharding: "width" dims (d_model) over ``data`` (FSDP/ZeRO-3),
    "parallel" dims (heads*hd, d_ff, vocab, experts) over ``model`` (TP/EP).
    Params are replicated over ``pod`` (DP between pods).
  * MoE experts shard over ``model`` when divisible (llama4 128/16) else TP
    inside the expert FFN (qwen2-moe 60 experts).
  * Batch dims shard over ("pod","data") when divisible, falling back to
    "data" or replication (long_500k has batch=1).
  * Decode KV caches shard sequence over ``model`` (flash-decode combine) and
    batch over data axes.

Every rule validates divisibility against the actual mesh before applying;
non-divisible dims degrade to replication (never a wrong-answer shard).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import batch_axes


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh, spec: P, shape: tuple) -> P:
    """Drop spec axes that don't divide the corresponding dim."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is not None and dim % _axis_size(mesh, axes) == 0:
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_COL = {  # (..., d_in, parallel_out): d_in over data, out over model
    "wq", "wk", "wv", "w_gate", "w_up", "w_x", "w_r", "w_i",
    "w_k", "w_v", "w_g", "cm_k", "cm_r", "decay_a", "mu_a", "lm_head",
}
_ROW = {  # (..., parallel_in, d_out): in over model, d_out over data
    "wo", "w_down", "w_out", "w_o", "cm_v", "decay_b", "mu_b",
}
_REPL = {"norm1", "norm2", "scale", "bias", "lam", "decay_base", "mu_base",
         "bonus", "conv", "router", "bq", "bk", "bv"}


def param_spec(cfg, path: tuple, leaf) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    stacked = "units" in names            # leading num_units dim from vmap
    lead = (None,) if stacked else ()
    expert = any("moe" in n for n in names) and name in (
        "w_gate", "w_up", "w_down") and not any(n == "shared" for n in names)

    if name == "embed":
        # vocab over model, d over data. The token gather does force an
        # all-gather of the table (XLA "involuntary full rematerialization"
        # warning), but that transient is CHEAPER than d-sharding the table:
        # measured 21.9 vs 25.8 GiB/device on recurrentgemma train_4k
        # (refuted hypothesis logged in EXPERIMENTS.md §Perf).
        return P("model", "data")
    if name == "frontend_proj":
        return P("data", "model")
    if name == "lm_head":
        return P("data", "model")
    if expert:
        # (E, d, f) or (E, f, d)
        if cfg.num_experts % 16 == 0:     # EP over model
            return P(*lead, "model", "data", None)
        if name in ("w_gate", "w_up"):    # TP inside expert
            return P(*lead, None, "data", "model")
        return P(*lead, None, "model", "data")
    if name in _COL:
        return P(*lead, "data", "model")
    if name in _ROW:
        return P(*lead, "model", "data")
    return P()  # norms, scalars, biases, router — replicate


def make_param_shardings(cfg, mesh, params_shape):
    """Pytree of NamedShardings matching an eval_shape'd params pytree."""
    def one(path, leaf):
        spec = _fit(mesh, param_spec(cfg, path, leaf), leaf.shape)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def make_opt_shardings(cfg, mesh, opt_shape):
    """Shardings for an AdamWState pytree (any moment dtype).

    Moments mirror their param's sharding; int8-quantized moments add {q, s}
    leaves — q shards like its param, s (a (..., 1) row scale) drops the
    trailing-axis spec via divisibility fitting.
    """
    def one(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        if names and names[-1] in ("q", "s"):
            path = path[:-1]
        spec = _fit(mesh, param_spec(cfg, path, leaf), leaf.shape)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, opt_shape)


# ---------------------------------------------------------------------------
# input / batch specs
# ---------------------------------------------------------------------------

def _batch_spec_axes(mesh, batch: int):
    from repro.models import partition
    if partition.BATCH_AXES_OVERRIDE:
        want = tuple(a for a in partition.BATCH_AXES_OVERRIDE
                     if a in mesh.axis_names)
        for k in range(len(want), 0, -1):  # longest dividing prefix
            if batch % _axis_size(mesh, want[:k]) == 0:
                return want[:k]
    ba = batch_axes(mesh)
    if ba and batch % _axis_size(mesh, ba) == 0:
        return ba
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0:
        return "data"
    return None


def train_batch_shardings(cfg, mesh, batch: int):
    ba = _batch_spec_axes(mesh, batch)
    tok = NamedSharding(mesh, P(ba, None))
    if cfg.frontend != "tokens":
        tok = NamedSharding(mesh, P(ba, None, None))
    return {
        "inputs": tok,
        "labels": NamedSharding(mesh, P(ba, None)),
        "positions": NamedSharding(mesh, P(ba, None)),
    }


def tree_cache_shardings(cfg, mesh, cache_shape, batch: int):
    """Shardings matching serve.init_cache: KV caches shard sequence over
    ``model`` (flash-decode partial-softmax combine) and batch over data axes;
    recurrent states shard their width dims over ``model``."""
    ba = _batch_spec_axes(mesh, batch)

    def one(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        stacked = "units" in names       # leading num_units dim
        lead = (None,) if stacked else ()
        nd = leaf.ndim - len(lead)
        if names[-1] in ("k", "v") and nd == 4:     # (B, S_c, KV, hd)
            spec = P(*lead, ba, "model", None, None)
        elif nd == 4:                               # rwkv wkv (B, H, hdk, hdv)
            spec = P(*lead, ba, None, "model", None)
        elif nd == 3:                               # rec conv (B, K-1, d)
            spec = P(*lead, ba, None, "model")
        elif nd == 2:                               # shift/h states (B, d)
            spec = P(*lead, ba, "model")
        else:
            spec = P()
        return NamedSharding(mesh, _fit(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Hippo shard placement (core.partition): shard axis over ``data``
# ---------------------------------------------------------------------------

def sharded_hippo_shardings(mesh, state):
    """NamedShardings for a ``core.partition.ShardedHippoState``.

    Every stacked leaf's leading shard axis goes over the mesh ``data`` axis
    (divisibility-fitted, degrading to replication like every other rule
    here) — including the per-shard histogram ``bounds``, which gained a
    shard axis with the drift-resummarization layer. Under this placement
    the shard-axis sums in ``core.index.search_many_sharded`` lower to the
    cross-device AllReduce — the ``jax.lax.psum`` of the count-reduce engine.
    """
    from repro.core import index as hix
    from repro.core.partition import ShardedHippoState

    def one(leaf, lead_sharded):
        spec = P("data") if lead_sharded else P()
        return NamedSharding(mesh, _fit(mesh, spec, leaf.shape))

    shards = hix.HippoState(*(
        one(leaf, ax == 0)
        for leaf, ax in zip(state.shards, hix.SHARD_AXES)))
    return ShardedHippoState(shards=shards,
                             summaries=one(state.summaries, True))


def shard_slab_shardings(mesh, slab):
    """Sharding for (S, PPS, page_card) table slabs: shard axis over ``data``."""
    return NamedSharding(mesh, _fit(mesh, P("data"), slab.shape))


def place_sharded(mesh, state, keys, valid):
    """device_put a ``ShardedHippoState`` + its table slabs onto the mesh.

    Returns (state, keys, valid) with every shard-axis array placed over the
    ``data`` axis; pass them straight to ``search_many_sharded``.
    """
    st = jax.device_put(state, sharded_hippo_shardings(mesh, state))
    k = jax.device_put(keys, shard_slab_shardings(mesh, keys))
    v = jax.device_put(valid, shard_slab_shardings(mesh, valid))
    return st, k, v
