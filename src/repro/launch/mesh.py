"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips — the ``pod`` axis carries
pure data parallelism (params replicated across pods, gradients all-reduced
over the slow inter-pod links; FSDP + TP stay inside a pod where ICI is fast).

A FUNCTION, not a module constant: importing this module must never touch JAX
device state (the dry-run sets XLA_FLAGS before any jax import; tests build
small meshes of their own).
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across jax versions.

    Newer jax wants explicit ``axis_types`` (Auto for the sharding pass);
    jax 0.4.x has neither ``jax.sharding.AxisType`` nor the kwarg — there Auto
    is the only behaviour, so plain ``make_mesh`` is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests, examples)."""
    return make_mesh_compat((data, model), ("data", "model"))


def make_shard_mesh(num_shards: int):
    """1-D ``data`` mesh for Hippo shard placement (``core.partition``).

    Uses the largest divisor of ``num_shards`` that fits the local device
    count, so the shard axis of a ``ShardedHippoState`` always divides the
    mesh (each device serves a contiguous block of shards; one device =
    everything replicated, which is the CPU test case).
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    n = jax.device_count()
    d = max(k for k in range(1, min(num_shards, n) + 1) if num_shards % k == 0)
    return make_mesh_compat((d,), ("data",))


def batch_axes(mesh) -> tuple:
    """Mesh axes a batch dimension shards over (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
