import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_XLA_EXTRA"):  # debugging hooks (e.g. --xla_dump_to)
    os.environ["XLA_FLAGS"] += " " + os.environ["REPRO_XLA_EXTRA"]

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the appropriate step (train_step for train_4k; prefill_step for
     prefill_32k; decode serve_step for decode_32k / long_500k) against
     ShapeDtypeStruct inputs with explicit in_shardings,
  3. compiles, records memory_analysis / cost_analysis, and parses the
     collective ops (kind, shape, bytes, group size) out of the HLO,
  4. writes one JSON record per cell under artifacts/dryrun/.

Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs; the run exits non-zero if any cell fails.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out artifacts/dryrun]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_config, list_archs, shape_cells  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.shardings import (  # noqa: E402
    make_opt_shardings, make_param_shardings, replicated,
    train_batch_shardings, tree_cache_shardings,
)
from repro.optim.adamw import AdamWState  # noqa: E402

_COLLECTIVE_RE = re.compile(
    r"(\w+\[[^\]]*\][^=]*)= ([\w-]*(?:all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)[\w-]*)\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collectives(hlo_text: str) -> list[dict]:
    """Extract collective ops with output bytes and replica-group size."""
    out = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"= ((?:\w+\[[^\]]+\])(?:[^ ]*)) ([\w-]*(?:all-gather|"
                      r"all-reduce|reduce-scatter|all-to-all|collective-permute)"
                      r"[\w-]*)\(", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        op = re.sub(r"-start$|-done$", "", op)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shape_str):
            if dt in _DTYPE_BYTES:
                elems = 1
                for d in dims.split(","):
                    if d:
                        elems *= int(d)
                nbytes += elems * _DTYPE_BYTES[dt]
        g = _GROUPS_RE.search(line)
        group = 1
        if g:
            first = g.group(1).split("}")[0].lstrip("{")
            group = len([t for t in first.split(",") if t.strip() != ""])
        out.append({"op": op, "bytes": nbytes, "group": group})
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Lower + compile one cell; returns the JSON record."""
    cfg = get_config(arch)
    shape = next(s for s in shape_cells(cfg) if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    big = "400b" in arch
    moment_dtype = "bfloat16" if big else "float32"  # bf16 moments for 400B (int8 measured worse: §Perf)
    accum_dtype = "bfloat16" if big else "float32"
    # Layout (EXPERIMENTS.md SPerf iteration 2): non-MoE TRAIN cells use
    # FSDP-only -- batch shards over (pod,data,model) jointly, weights
    # all-gather per use (overlappable) instead of blocking TP all-reduces.
    # Measured on yi-6b train_4k: 12.56 -> 5.36 GiB/chip and the collective
    # term drops below the compute term. MoE archs keep TP/EP (a gathered
    # 16B-param MoE unit would not fit); decode/prefill keep TP (batch is
    # too small to shard 256/512-way).
    from repro.models import partition
    # hybrid (Griffin) refutes FSDP-only: 21.0 GiB vs 9.2 with TP — the
    # d^2-heavy recurrent units make gathered-weight working sets dominate.
    fsdp_only = (shape.kind == "train" and cfg.num_experts == 0
                 and cfg.family != "hybrid")
    partition.BATCH_AXES_OVERRIDE = (("pod", "data", "model") if fsdp_only
                                     else None)
    # gradient accumulation: keep activation working set ~4 seq/device
    # (1 for the 400B cell; 1 seq/chip already under FSDP-only)
    n_dev_batch = 32 if multi_pod else 16
    if multi_pod and arch == "qwen2-moe-a2.7b":
        n_dev_batch = 16   # accum 4: fits 16 GiB (16.24 at accum 2)
    if fsdp_only:
        # batch shards over the widest dividing prefix of (pod,data,model):
        # 256 ways single-pod (1 seq/chip), 32 ways multi-pod (pod,data)
        n_dev_batch = 32 if multi_pod else 256
    per_dev_seqs = 1 if big else 4
    accum = (max(1, shape.global_batch // (n_dev_batch * per_dev_seqs))
             if shape.kind == "train" else 1)

    t0 = time.perf_counter()
    with mesh:
        p_shape = steps_lib.params_shape(cfg)
        p_sh = make_param_shardings(cfg, mesh, p_shape)
        specs = steps_lib.input_specs(cfg, shape, shape.kind)

        if shape.kind == "train":
            o_shape = steps_lib.opt_state_shape(cfg, p_shape, moment_dtype)
            o_sh = make_opt_shardings(cfg, mesh, o_shape)
            b_sh = train_batch_shardings(cfg, mesh, shape.global_batch)
            # optimizer-state experiments for the 400B cell (EXPERIMENTS.md
            # SPerf): bf16+plain 18.1 GiB < int8+unit_scan 20.4 < int8+plain
            # 23.2 < bf16+unit_scan 31.6 => bf16 moments, plain update.
            step = steps_lib.make_train_step(cfg, accum=accum,
                                             accum_dtype=accum_dtype)
            # donate params+opt state: updated state aliases the old buffers
            # (without donation every train cell pays a full extra copy)
            lowered = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            ).lower(p_shape, o_shape, specs)
        elif shape.kind == "prefill":
            b_sh = train_batch_shardings(cfg, mesh, shape.global_batch)
            b_sh = {k: b_sh[k] for k in ("inputs", "positions")}
            # the returned cache must leave sharded (seq over model); without
            # out_shardings XLA materializes it replicated (measured +13 GiB
            # on llama4 prefill_32k)
            c_shape = steps_lib.cache_shape(cfg, shape.global_batch, shape.seq_len)
            c_sh = tree_cache_shardings(cfg, mesh, c_shape, shape.global_batch)
            # smaller q-chunk at 32k: halves the transient fp32 score tiles
            from dataclasses import replace as _replace
            pcfg = _replace(cfg, q_chunk=128)
            step = steps_lib.make_prefill_step(pcfg, max_seq=shape.seq_len)
            lowered = jax.jit(
                step, in_shardings=(p_sh, b_sh),
                out_shardings=(None, c_sh),
            ).lower(p_shape, specs)
        else:  # decode
            c_shape = steps_lib.cache_shape(cfg, shape.global_batch, shape.seq_len)
            c_sh = tree_cache_shardings(cfg, mesh, c_shape, shape.global_batch)
            tok_sh = train_batch_shardings(cfg, mesh, shape.global_batch)["inputs"]
            step = steps_lib.make_decode_step(cfg)
            # donate the cache: in-place KV append instead of double-buffering
            lowered = jax.jit(
                step, in_shardings=(p_sh, c_sh, tok_sh, replicated(mesh)),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            ).lower(p_shape, c_shape, specs["tokens"], specs["pos"])

        compiled = lowered.compile()

    t1 = time.perf_counter()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    collectives = parse_collectives(compiled.as_text())
    n_dev = 512 if multi_pod else 256

    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "grad_accum": accum,
        "layout": "fsdp_only" if fsdp_only else "tp",
        "compile_s": round(t1 - t0, 2),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            # XLA:CPU does not implement buffer donation, so the donated
            # outputs (new params/opt-state/cache) show up as extra temp; on
            # the TPU target they alias their inputs. The honest per-chip
            # estimate removes one copy of the aliasable outputs:
            "tpu_total_bytes_est": max(
                mem.argument_size_in_bytes,
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                - mem.output_size_in_bytes),
            "total_bytes_per_device": (mem.argument_size_in_bytes
                                       + mem.temp_size_in_bytes),
        },
        "cost_analysis": {
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": {
            "count": len(collectives),
            "ops": sorted({c["op"] for c in collectives}),
            "bytes_by_op": {
                op: sum(c["bytes"] for c in collectives if c["op"] == op)
                for op in {c["op"] for c in collectives}},
        },
    }
    # dry-run proof: memory_analysis must fit a v5e (16 GiB HBM/chip)
    record["fits_hbm_16gib"] = bool(
        record["memory"]["tpu_total_bytes_est"] < 16 * 1024 ** 3)
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list_archs()
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shape_cells(cfg):
            if args.shape and shape.name != args.shape:
                continue
            for multi in meshes:
                tag = f"{arch}_{shape.name}_{'multi' if multi else 'single'}"
                path = out_dir / f"{tag}.json"
                try:
                    rec = lower_cell(arch, shape.name, multi)
                    path.write_text(json.dumps(rec, indent=1))
                    print(f"OK   {tag}  compile={rec['compile_s']}s  "
                          f"tpu_est/dev={rec['memory']['tpu_total_bytes_est']/2**30:.2f}GiB  "
                          f"colls={rec['collectives']['count']}  "
                          f"fits={rec['fits_hbm_16gib']}")
                except Exception as e:  # noqa: BLE001
                    failures.append(tag)
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILED: {failures}")
        raise SystemExit(1)
    print("\nall cells compiled")


if __name__ == "__main__":
    main()
