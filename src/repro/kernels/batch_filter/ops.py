"""batch_filter public wrappers — the fused §3.2 match phase of the engine.

Shapes/dtypes: ``batch_filter(queries (Q, W) uint32, entries (E, W) uint32)
-> (Q, E) int32 0/1`` — joint-bucket test of every query bitmap against
every entry bitmap; ``batch_filter_sharded`` adds a leading shard axis,
``entries (S, E, W) -> (S, Q, E)``, one grid over the whole shard axis.
W = ceil(resolution / 32) packed words (``core.bitmap``).

Wrappers pad Q/E to kernel block multiples and W to the 128-lane width
(zero pads AND to zero, so padding never creates a match), then slice the
result back. On CPU backends the Pallas kernel runs in interpret mode for
validation; ``ref.py`` holds the jnp reference twin that is the CPU
execution path.

Equivalence contract: the sharded form is the unsharded form vmapped over
the shard axis — ``batch_filter_sharded(q, e)[s] == batch_filter(q, e[s])``
bit-exactly, which is what lets ``core.index.search_many_sharded`` reduce
per-shard results into the unsharded answer.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.batch_filter.kernel import (BLOCK_E, BLOCK_Q,
                                               batch_filter_kernel,
                                               batch_filter_sharded_kernel)
from repro.kernels.batch_filter.ref import (batch_filter_ref,
                                            batch_filter_sharded_ref)


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("interpret",))
def batch_filter(queries: jnp.ndarray, entries: jnp.ndarray,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Joint-bucket test of every query bitmap against every entry bitmap.

    queries: (Q, W) uint32, entries: (E, W) uint32 -> (Q, E) int32 0/1.
    On CPU backends runs the Pallas kernel in interpret mode.
    """
    q, w = queries.shape
    e, _ = entries.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    qp = _pad_to(queries, 0, BLOCK_Q)
    qp = _pad_to(qp, 1, 128)
    ep = _pad_to(entries, 0, BLOCK_E)
    ep = _pad_to(ep, 1, 128)
    out = batch_filter_kernel(qp, ep, interpret=interpret)
    return out[:q, :e]


@partial(jax.jit, static_argnames=("interpret",))
def batch_filter_sharded(queries: jnp.ndarray, entries: jnp.ndarray,
                         interpret: bool | None = None) -> jnp.ndarray:
    """Joint-bucket test of every query against every shard's entry table.

    queries: (Q, W) uint32, entries: (S, E, W) uint32 -> (S, Q, E) int32 0/1
    — the fused match phase over the whole shard axis. On CPU backends runs
    the Pallas kernel in interpret mode.
    """
    q, w = queries.shape
    s, e, _ = entries.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    qp = _pad_to(queries, 0, BLOCK_Q)
    qp = _pad_to(qp, 1, 128)
    ep = _pad_to(entries, 1, BLOCK_E)
    ep = _pad_to(ep, 2, 128)
    out = batch_filter_sharded_kernel(qp, ep, interpret=interpret)
    return out[:, :q, :e]


__all__ = ["batch_filter", "batch_filter_ref",
           "batch_filter_sharded", "batch_filter_sharded_ref"]
