"""Pallas kernel: fused batched joint-bucket filter (§3.2 with a query axis).

For query q and entry e: out[q, e] = any_w(queries[q, w] & entries[e, w]) —
the batched-engine form of the bitmap_and kernel. One grid step loads a
(BLOCK_Q, W) query tile and a (BLOCK_E, W) entry tile into VMEM and produces
the full (BLOCK_Q, BLOCK_E) match tile in one pass, so Q queries share each
entry tile's HBM->VMEM transfer instead of re-streaming the index per query.

VMEM budget per grid step: BLOCK_E * PADDED_W * 4 B (entries) + BLOCK_Q *
PADDED_W * 4 B (queries) + BLOCK_Q * BLOCK_E * 4 B (out) plus the broadcast
joint intermediate BLOCK_Q * BLOCK_E * PADDED_W bits. With BLOCK_Q=8,
BLOCK_E=128, PADDED_W=128 the tiles are ~132 KiB and the intermediate stays
well under a MiB — comfortable inside a v5e core's ~16 MiB VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 8    # queries per grid step (sublane-aligned)
BLOCK_E = 128  # entries per grid step (lane-aligned)


def _kernel(queries_ref, entries_ref, out_ref):
    q = queries_ref[...]                        # (BLOCK_Q, W) uint32
    e = entries_ref[...]                        # (BLOCK_E, W) uint32
    joint = (q[:, None, :] & e[None, :, :]) != 0  # (BLOCK_Q, BLOCK_E, W)
    out_ref[...] = jnp.any(joint, axis=-1).astype(jnp.int32)


def batch_filter_kernel(queries: jnp.ndarray, entries: jnp.ndarray,
                        *, interpret: bool = False) -> jnp.ndarray:
    """queries: (Q, W) uint32 (Q % BLOCK_Q == 0); entries: (E, W) uint32
    (E % BLOCK_E == 0, W % 128 == 0). Returns (Q, E) int32 0/1."""
    q, w = queries.shape
    e, _ = entries.shape
    grid = (q // BLOCK_Q, e // BLOCK_E)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_Q, w), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_E, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_Q, BLOCK_E), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, e), jnp.int32),
        interpret=interpret,
    )(queries, entries)


def _kernel_sharded(queries_ref, entries_ref, out_ref):
    q = queries_ref[...]                        # (BLOCK_Q, W) uint32
    e = entries_ref[0]                          # (BLOCK_E, W) uint32
    joint = (q[:, None, :] & e[None, :, :]) != 0  # (BLOCK_Q, BLOCK_E, W)
    out_ref[0] = jnp.any(joint, axis=-1).astype(jnp.int32)


def batch_filter_sharded_kernel(queries: jnp.ndarray, entries: jnp.ndarray,
                                *, interpret: bool = False) -> jnp.ndarray:
    """Shard-axis extension of ``batch_filter_kernel``: the grid gains a
    leading shard dimension so one fused launch covers every (query, shard,
    entry) tile — the match phase of ``core.index.search_many_sharded``.

    queries: (Q, W) uint32 (Q % BLOCK_Q == 0), shared across shards;
    entries: (S, E, W) uint32 (E % BLOCK_E == 0, W % 128 == 0), one entry
    table per shard. Returns (S, Q, E) int32 0/1. The query tile is reused
    across the shard axis, so S shards re-stream only their own entry tiles;
    VMEM per grid step is the unsharded budget plus one (1, BLOCK_E, W) slab.
    """
    q, w = queries.shape
    s, e, _ = entries.shape
    grid = (s, q // BLOCK_Q, e // BLOCK_E)
    return pl.pallas_call(
        _kernel_sharded,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_Q, w), lambda k, i, j: (i, 0)),
            pl.BlockSpec((1, BLOCK_E, w), lambda k, i, j: (k, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, BLOCK_E), lambda k, i, j: (k, i, j)),
        out_shape=jax.ShapeDtypeStruct((s, q, e), jnp.int32),
        interpret=interpret,
    )(queries, entries)
