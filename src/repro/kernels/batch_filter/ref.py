"""Pure-jnp oracle for the batch_filter kernel."""
import jax.numpy as jnp


def batch_filter_ref(queries: jnp.ndarray, entries: jnp.ndarray) -> jnp.ndarray:
    """queries: (Q, W) uint32; entries: (E, W) uint32 -> (Q, E) int32 0/1."""
    return jnp.any((queries[:, None, :] & entries[None, :, :]) != 0,
                   axis=-1).astype(jnp.int32)


def batch_filter_sharded_ref(queries: jnp.ndarray,
                             entries: jnp.ndarray) -> jnp.ndarray:
    """queries: (Q, W) uint32; entries: (S, E, W) uint32 -> (S, Q, E) i32 0/1."""
    return jnp.any((queries[None, :, None, :] & entries[:, None, :, :]) != 0,
                   axis=-1).astype(jnp.int32)
