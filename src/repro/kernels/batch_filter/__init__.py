from repro.kernels.batch_filter.ops import batch_filter  # noqa: F401
