from repro.kernels.batch_filter.ops import (batch_filter,  # noqa: F401
                                            batch_filter_sharded)
