"""Pallas TPU kernels for Hippo's compute hot-spots.

Each kernel directory contains:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, interpret-mode fallback on CPU)
  ref.py    — pure-jnp oracle used by tests and as the CPU execution path

Kernels:
  bitmap_and      — §3.2 joint-bucket filter: AND query bitmap against all
                    entry bitmaps, OR-reduce per entry (bit-level parallelism
                    on VPU lanes)
  batch_filter    — batched-engine form of bitmap_and: Q query bitmaps AND'd
                    against E entry bitmaps in one VMEM pass, (BLOCK_Q,
                    BLOCK_E) match tile per grid step so queries share entry
                    transfers; the sharded variant extends the grid over the
                    shard axis so one launch covers every (query, shard,
                    entry) tile
  bucketize       — §4.2 histogram probe: branchless compare-count of values
                    against resident bucket boundaries (replaces binary search)
  page_inspect    — §3.3 inspection: masked predicate evaluation + per-page
                    counts over the whole table
  compact_inspect — gather-path inspection: fused filter-match × interval
                    test over the batch's gathered possible-qualified-page
                    slab, (BLOCK_Q, BLOCK_M) count tile per grid step — the
                    inspect phase of core.index.search_compact_many, with
                    cost proportional to pages selected instead of table size
"""
