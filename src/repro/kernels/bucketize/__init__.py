from repro.kernels.bucketize.ops import bucketize_values  # noqa: F401
