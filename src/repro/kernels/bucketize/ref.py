"""Pure-jnp oracle for the bucketize kernel: the searchsorted formulation."""
import jax.numpy as jnp


def bucketize_ref(values: jnp.ndarray, bounds: jnp.ndarray, resolution: int) -> jnp.ndarray:
    """values: (N,) f32; bounds: (H+1,) f32 strictly increasing -> (N,) int32."""
    ids = jnp.searchsorted(bounds, values.astype(jnp.float32), side="right") - 1
    return jnp.clip(ids, 0, resolution - 1).astype(jnp.int32)
