"""Pallas kernel: histogram bucket probe (§4.2).

Maps each value to its equi-depth bucket id: id = (#boundaries <= v) - 1.
The paper binary-searches the histogram per tuple; a serial branchy search is
hostile to the VPU, so we adapt it (DESIGN.md §2): boundaries are small enough
to sit resident in VMEM (H+1 <= a few thousand floats), and the probe becomes
a branchless compare-and-count over boundary chunks — O(N*H) lane-parallel
compares rather than O(N log H) serial branches. For H=400 that is ~4 vreg
sweeps per 8x128 value tile.

VMEM per step: BLOCK_N*4 (values) + PADDED_H*4 (bounds) + BLOCK_N*4 (out):
with BLOCK_N = 8*128 = 1024 that is ~12 KiB.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8          # sublanes per value tile
LANES = 128
BLOCK_N = BLOCK_ROWS * LANES


def _kernel(values_ref, bounds_ref, out_ref, *, padded_h: int, resolution: int):
    v = values_ref[...]                       # (BLOCK_ROWS, LANES) f32
    count = jnp.zeros(v.shape, jnp.int32)

    def body(j, count):
        b = bounds_ref[0, pl.dslice(j * LANES, LANES)]             # (LANES,)
        # compare every value against this boundary chunk
        cmp = v[:, :, None] >= b[None, None, :]                    # (R, L, L)
        return count + cmp.sum(axis=2).astype(jnp.int32)

    count = jax.lax.fori_loop(0, padded_h // LANES, body, count)
    ids = jnp.clip(count - 1, 0, resolution - 1)
    out_ref[...] = ids


def bucketize_kernel(values: jnp.ndarray, bounds: jnp.ndarray, resolution: int,
                     *, interpret: bool = False) -> jnp.ndarray:
    """values: (N,) f32 with N % BLOCK_N == 0; bounds: (1, PH) f32 with
    PH % 128 == 0, padded with +inf. Returns (N,) int32 bucket ids."""
    n = values.shape[0]
    padded_h = bounds.shape[1]
    v2 = values.reshape(n // LANES, LANES)
    grid = (n // BLOCK_N,)
    out = pl.pallas_call(
        lambda vr, br, orf: _kernel(vr, br, orf, padded_h=padded_h,
                                    resolution=resolution),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, padded_h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // LANES, LANES), jnp.int32),
        interpret=interpret,
    )(v2, bounds)
    return out.reshape(n)
