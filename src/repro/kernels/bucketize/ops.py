"""Public wrapper for the bucketize kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bucketize.kernel import BLOCK_N, bucketize_kernel
from repro.kernels.bucketize.ref import bucketize_ref


@partial(jax.jit, static_argnames=("resolution", "interpret"))
def bucketize_values(values: jnp.ndarray, bounds: jnp.ndarray, resolution: int,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Bucket ids for ``values`` against ``bounds`` ((H+1,) ascending).

    Pads N to the kernel tile and H+1 to lane width (+inf so padding never
    counts). Matches ``bucketize_ref`` bit-exactly for strictly-increasing
    boundaries.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = values.shape[0]
    pad_n = (-n) % BLOCK_N
    v = jnp.pad(values.astype(jnp.float32), (0, pad_n))
    h1 = bounds.shape[0]
    pad_h = (-h1) % 128
    b = jnp.pad(bounds.astype(jnp.float32), (0, pad_h),
                constant_values=jnp.inf)[None, :]
    out = bucketize_kernel(v, b, resolution, interpret=interpret)
    return out[:n]


__all__ = ["bucketize_values", "bucketize_ref"]
