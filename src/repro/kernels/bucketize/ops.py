"""bucketize public wrapper — the §4.1 complete-histogram probe.

Shapes/dtypes: ``bucketize_values(values (N,) f32, bounds (H+1,) f32,
resolution: int) -> (N,) int32`` bucket ids in [0, H), clamped at the
domain edges. ``bounds`` are the strictly-increasing equi-depth boundaries
(``core.histogram``); the kernel binary-searches them per value.

The wrapper pads N to the kernel block and H+1 to the 128-lane width with
+inf so padding never wins a comparison, then slices back. On CPU backends
the Pallas kernel runs in interpret mode for validation; ``ref.py`` is the
jnp reference twin and the CPU execution path — both match bit-exactly for
strictly-increasing boundaries. Build (Algorithm 2), search (predicate
conversion), and maintenance (Algorithm 3) all bucketize through this one
surface, which is what keeps the unsharded and sharded indexes agreeing:
shards share the global ``bounds``, so a value buckets identically no
matter which shard owns its page.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bucketize.kernel import BLOCK_N, bucketize_kernel
from repro.kernels.bucketize.ref import bucketize_ref


@partial(jax.jit, static_argnames=("resolution", "interpret"))
def bucketize_values(values: jnp.ndarray, bounds: jnp.ndarray, resolution: int,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Bucket ids for ``values`` against ``bounds`` ((H+1,) ascending).

    Pads N to the kernel tile and H+1 to lane width (+inf so padding never
    counts). Matches ``bucketize_ref`` bit-exactly for strictly-increasing
    boundaries.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = values.shape[0]
    pad_n = (-n) % BLOCK_N
    v = jnp.pad(values.astype(jnp.float32), (0, pad_n))
    h1 = bounds.shape[0]
    pad_h = (-h1) % 128
    b = jnp.pad(bounds.astype(jnp.float32), (0, pad_h),
                constant_values=jnp.inf)[None, :]
    out = bucketize_kernel(v, b, resolution, interpret=interpret)
    return out[:n]


__all__ = ["bucketize_values", "bucketize_ref"]
