"""Pure-jnp oracle for the page_inspect kernel."""
import jax.numpy as jnp


def page_inspect_ref(keys: jnp.ndarray, valid: jnp.ndarray, mask: jnp.ndarray,
                     lo, hi):
    """keys: (P, C) f32; valid: (P, C) bool; mask: (P,) bool.
    Returns (qual (P, C) bool, counts (P,) int32)."""
    k = keys.astype(jnp.float32)
    qual = mask[:, None] & valid & (k >= lo) & (k <= hi)
    return qual, qual.sum(axis=1, dtype=jnp.int32)
