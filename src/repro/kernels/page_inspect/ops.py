"""page_inspect public wrapper — §3.3 exact inspection of candidate pages.

Shapes/dtypes: ``page_inspect(keys (P, C) f32, valid (P, C) bool, mask (P,)
bool, lo, hi) -> (qual (P, C) bool, counts (P,) int32)`` — the exact
tuple-level predicate test over the pages Algorithm 1's bitmap filter could
not rule out (``mask``), with per-page qualifying counts. C is the page
cardinality (``page_card``), lo/hi the closed predicate interval (±inf
already clamped to finite f32 by ``core.predicate``).

The wrapper pads P to the kernel block (padded pages carry mask=False and
count 0) and slices back. On CPU backends the Pallas kernel runs in
interpret mode for validation; ``ref.py`` is the jnp reference twin and the
CPU execution path. Inspection is exact, which is the root of the layer
equivalence contract: per-shard inspections over a partition of the page
space sum bit-identically to the unsharded inspection, so every search
path (scalar, batched, sharded, staged-overlay) returns the same counts.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.page_inspect.kernel import BLOCK_P, page_inspect_kernel
from repro.kernels.page_inspect.ref import page_inspect_ref


@partial(jax.jit, static_argnames=("interpret",))
def page_inspect(keys: jnp.ndarray, valid: jnp.ndarray, mask: jnp.ndarray,
                 lo, hi, interpret: bool | None = None):
    """Inspect possible-qualified pages: exact qualifying mask + page counts.

    keys: (P, C) f32, valid: (P, C) bool, mask: (P,) bool.
    Returns (qual (P, C) bool, counts (P,) int32).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    p, c = keys.shape
    pad_p = (-p) % BLOCK_P
    pad_c = (-c) % 128
    kp = jnp.pad(keys.astype(jnp.float32), ((0, pad_p), (0, pad_c)),
                 constant_values=jnp.inf)
    vp = jnp.pad(valid.astype(jnp.uint8), ((0, pad_p), (0, pad_c)))
    mp = jnp.pad(mask.astype(jnp.uint8), (0, pad_p))[:, None]
    interval = jnp.stack([jnp.float32(lo), jnp.float32(hi)])[None, :]
    qual, counts = page_inspect_kernel(kp, vp, mp, interval, interpret=interpret)
    return qual[:p, :c].astype(bool), counts[:p, 0]


__all__ = ["page_inspect", "page_inspect_ref"]
