from repro.kernels.page_inspect.ops import page_inspect  # noqa: F401
