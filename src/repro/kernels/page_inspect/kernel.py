"""Pallas kernel: possible-qualified-page inspection (§3.3).

Fuses the three per-tuple predicates of Algorithm 1 step 3 — page selected,
tuple live, key within [lo, hi] — and reduces to a per-page qualifying count,
emitting the exact qualifying-tuple mask. One (BLOCK_P, C) tile of the key
column is streamed through VMEM per grid step; selected/validity masks ride
along as uint8 tiles (bool refs are not TPU-tileable).

The interval endpoints arrive as a (1, 2) float32 operand broadcast to every
grid step — scalar parameters as a resident VMEM block.

VMEM per step: BLOCK_P*C*(4+1+ ) + outs ~ BLOCK_P*(C*5 + C + 4) bytes; with
BLOCK_P=64, C=128: ~48 KiB.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 64   # pages per grid step


def _kernel(keys_ref, valid_ref, mask_ref, interval_ref, qual_ref, count_ref):
    k = keys_ref[...]                              # (BLOCK_P, C) f32
    live = valid_ref[...] != 0                     # (BLOCK_P, C)
    sel = (mask_ref[...] != 0)                     # (BLOCK_P, 1) page mask
    lo = interval_ref[0, 0]
    hi = interval_ref[0, 1]
    qual = sel & live & (k >= lo) & (k <= hi)
    qual_ref[...] = qual.astype(jnp.uint8)
    count_ref[...] = qual.sum(axis=1, keepdims=True).astype(jnp.int32)


def page_inspect_kernel(keys: jnp.ndarray, valid: jnp.ndarray, mask: jnp.ndarray,
                        interval: jnp.ndarray, *, interpret: bool = False):
    """keys: (P, C) f32; valid: (P, C) uint8; mask: (P, 1) uint8;
    interval: (1, 2) f32 [lo, hi]. P % BLOCK_P == 0, C % 128 == 0.
    Returns (qual (P, C) uint8, counts (P, 1) int32)."""
    p, c = keys.shape
    grid = (p // BLOCK_P,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_P, c), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_P, c), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_P, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_P, c), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_P, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, c), jnp.uint8),
            jax.ShapeDtypeStruct((p, 1), jnp.int32),
        ],
        interpret=interpret,
    )(keys, valid, mask, interval)
