"""Pallas kernel: fused filter-match × gathered-slab inspection.

The compact search pipeline (``core.index.search_compact_many``) gathers the
batch's union of possible-qualified pages into one (M, C) slab and inspects
every query against it. This kernel fuses the two per-(query, page) factors
of that inspection in one grid: the filter-match bit (query q selected slab
page m — the gathered restriction of Algorithm 1 step 2) and the exact
interval test of the page's tuples (step 3), reducing to a per-(query, page)
qualifying count. One (BLOCK_Q, 2) interval tile and one (BLOCK_M, C) slab
tile are resident per grid step; every query block reuses the slab tile's
HBM->VMEM transfer, the compact analogue of batch_filter's shared entry
tiles.

VMEM per step: BLOCK_M*C*(4+1) slab + BLOCK_Q*(BLOCK_M + 2*4) masks/intervals
+ BLOCK_Q*BLOCK_M*4 out + the (BLOCK_Q, BLOCK_M, C) boolean intermediate;
with BLOCK_Q=8, BLOCK_M=64, C=128 that is ~105 KiB — comfortable in v5e VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 8    # queries per grid step (sublane-aligned)
BLOCK_M = 64   # gathered slab pages per grid step


def _kernel(keys_ref, valid_ref, selmask_ref, interval_ref, count_ref):
    k = keys_ref[...]                               # (BLOCK_M, C) f32
    live = valid_ref[...] != 0                      # (BLOCK_M, C)
    sel = selmask_ref[...] != 0                     # (BLOCK_Q, BLOCK_M)
    lo = interval_ref[...][:, 0][:, None, None]     # (BLOCK_Q, 1, 1)
    hi = interval_ref[...][:, 1][:, None, None]
    k3 = k[None, :, :]                              # (1, BLOCK_M, C)
    qual = sel[:, :, None] & live[None] & (k3 >= lo) & (k3 <= hi)
    count_ref[...] = qual.sum(axis=2).astype(jnp.int32)


def compact_inspect_kernel(keys: jnp.ndarray, valid: jnp.ndarray,
                           sel_mask: jnp.ndarray, intervals: jnp.ndarray,
                           *, interpret: bool = False) -> jnp.ndarray:
    """keys: (M, C) f32 gathered slab; valid: (M, C) uint8; sel_mask: (Q, M)
    uint8 per-query selected-page mask; intervals: (Q, 2) f32 [lo, hi] rows.
    Q % BLOCK_Q == 0, M % BLOCK_M == 0, C % 128 == 0.
    Returns counts (Q, M) int32 — qualifying tuples per (query, slab page)."""
    m, c = keys.shape
    q, _ = sel_mask.shape
    grid = (q // BLOCK_Q, m // BLOCK_M)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_M, c), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_M, c), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_Q, BLOCK_M), lambda i, j: (i, j)),
            pl.BlockSpec((BLOCK_Q, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_Q, BLOCK_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, m), jnp.int32),
        interpret=interpret,
    )(keys, valid, sel_mask, intervals)
