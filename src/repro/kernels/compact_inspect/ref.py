"""Pure-jnp oracle for the compact_inspect kernel."""
import jax.numpy as jnp


def compact_inspect_ref(keys: jnp.ndarray, valid: jnp.ndarray,
                        sel_mask: jnp.ndarray, los, his) -> jnp.ndarray:
    """keys: (M, C) f32 gathered slab; valid: (M, C) bool; sel_mask: (Q, M)
    bool; los/his: (Q,) f32. Returns counts (Q, M) int32."""
    k = keys.astype(jnp.float32)[None]                  # (1, M, C)
    los = jnp.asarray(los, jnp.float32)
    his = jnp.asarray(his, jnp.float32)
    qual = (sel_mask[:, :, None] & valid[None]
            & (k >= los[:, None, None]) & (k <= his[:, None, None]))
    return qual.sum(axis=2, dtype=jnp.int32)
