from repro.kernels.compact_inspect.ops import compact_inspect  # noqa: F401
