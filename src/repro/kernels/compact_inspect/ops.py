"""compact_inspect public wrapper — the fused inspect phase of the gather
(compact) search pipeline.

Shapes/dtypes: ``compact_inspect(keys (M, C) f32, valid (M, C) bool,
sel_mask (Q, M) bool, los (Q,), his (Q,)) -> counts (Q, M) int32`` — M is
the gathered slab width (``max_selected`` pages of the batch's union,
``core.index.search_compact_many``), C the page cardinality, and
``sel_mask[q, m]`` the filter-match bit restricting query q to the slab
pages its bitmap filter could not rule out. ``counts[q].sum()`` is query
q's qualifying-tuple count over the slab — bit-identical to the compact
search's count for untruncated queries, which is the kernel-level statement
of the compact/dense equivalence contract.

The wrapper pads M to the kernel block (padded slab pages carry valid=False
and sel_mask=False), Q to the query block (padded queries carry the empty
interval lo > hi), and C to the 128-lane width (padded slots carry +inf
keys and valid=False), then slices back. On CPU backends the Pallas kernel
runs in interpret mode for validation; ``ref.py`` is the jnp reference twin
and the CPU execution path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.compact_inspect.kernel import (BLOCK_M, BLOCK_Q,
                                                  compact_inspect_kernel)
from repro.kernels.compact_inspect.ref import compact_inspect_ref


@partial(jax.jit, static_argnames=("interpret",))
def compact_inspect(keys: jnp.ndarray, valid: jnp.ndarray,
                    sel_mask: jnp.ndarray, los, his,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Fused selected-mask × interval inspection of a gathered page slab.

    keys: (M, C) f32, valid: (M, C) bool, sel_mask: (Q, M) bool,
    los/his: (Q,) f32. Returns counts (Q, M) int32.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, c = keys.shape
    q = sel_mask.shape[0]
    pad_m = (-m) % BLOCK_M
    pad_q = (-q) % BLOCK_Q
    pad_c = (-c) % 128
    kp = jnp.pad(keys.astype(jnp.float32), ((0, pad_m), (0, pad_c)),
                 constant_values=jnp.inf)
    vp = jnp.pad(valid.astype(jnp.uint8), ((0, pad_m), (0, pad_c)))
    sp = jnp.pad(sel_mask.astype(jnp.uint8), ((0, pad_q), (0, pad_m)))
    iv = jnp.stack([jnp.asarray(los, jnp.float32),
                    jnp.asarray(his, jnp.float32)], axis=1)       # (Q, 2)
    if pad_q:
        # padded query rows must match nothing: empty interval (lo=1 > hi=0)
        pad_iv = jnp.tile(jnp.asarray([1.0, 0.0], jnp.float32), (pad_q, 1))
        iv = jnp.concatenate([iv, pad_iv], axis=0)
    counts = compact_inspect_kernel(kp, vp, sp, iv, interpret=interpret)
    return counts[:q, :m]


__all__ = ["compact_inspect", "compact_inspect_ref"]
