"""bitmap_and public wrapper — the §3.2 single-query entry filter.

Shapes/dtypes: ``bitmap_and_any(entries (E, W) uint32, query (W,) uint32)
-> (E,) int32 0/1`` — 1 iff entry e shares at least one set bucket bit with
the query bitmap (the paper's joint-bucket test, Fig. 3). W =
ceil(resolution / 32) packed words (``core.bitmap``).

The wrapper pads E to the kernel block and W to the 128-lane width (zero
pads AND to zero, so padding never creates a match) and slices back. On CPU
backends the Pallas kernel runs in interpret mode for validation;
``ref.py`` is the jnp reference twin and the CPU execution path. The
batched engine uses ``kernels.batch_filter`` (a leading query axis, plus a
sharded grid); per shard and per query all three agree bit-exactly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bitmap_and.kernel import BLOCK_E, bitmap_and_any_kernel
from repro.kernels.bitmap_and.ref import bitmap_and_any_ref


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("interpret",))
def bitmap_and_any(entries: jnp.ndarray, query: jnp.ndarray,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Joint-bucket test of every entry bitmap against the query bitmap.

    entries: (E, W) uint32, query: (W,) uint32 -> (E,) int32 0/1.
    On CPU backends runs the Pallas kernel in interpret mode.
    """
    e, w = entries.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    ep = _pad_to(entries, 0, BLOCK_E)
    ep = _pad_to(ep, 1, 128)
    qp = _pad_to(query[None, :], 1, 128)
    out = bitmap_and_any_kernel(ep, qp, interpret=interpret)
    return out[:e]


__all__ = ["bitmap_and_any", "bitmap_and_any_ref"]
