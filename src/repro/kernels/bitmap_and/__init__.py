from repro.kernels.bitmap_and.ops import bitmap_and_any  # noqa: F401
