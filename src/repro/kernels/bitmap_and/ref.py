"""Pure-jnp oracle for the bitmap_and kernel."""
import jax.numpy as jnp


def bitmap_and_any_ref(entries: jnp.ndarray, query: jnp.ndarray) -> jnp.ndarray:
    """entries: (E, W) uint32; query: (W,) uint32 -> (E,) int32 0/1."""
    return jnp.any((entries & query[None, :]) != 0, axis=1).astype(jnp.int32)
