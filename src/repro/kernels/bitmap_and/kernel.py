"""Pallas kernel: joint-bucket filter (§3.2, Fig. 3).

For each index entry e: out[e] = any_w(entries[e, w] & query[w]) — one AND +
OR-reduction per entry over the packed bitmap words. The paper's "bitwise
'AND'ing the bytes from both sides, aka bit-level parallelism" maps onto the
8x128 VPU: a (BLOCK_E, 128) tile processes 128 words of 8 entries per vreg op.

VMEM budget per grid step: BLOCK_E * PADDED_W * 4 B (entries) + PADDED_W * 4 B
(query, broadcast) + BLOCK_E * 4 B (out). With BLOCK_E=512, PADDED_W=128 this
is ~256 KiB — far under the ~16 MiB VMEM of a v5e core, leaving room for
double buffering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_E = 512  # entries per grid step (8-sublane aligned)


def _kernel(entries_ref, query_ref, out_ref):
    e = entries_ref[...]                # (BLOCK_E, W) uint32
    q = query_ref[...]                  # (1, W) uint32
    joint = (e & q) != 0                # VPU lane-parallel AND
    out_ref[...] = jnp.any(joint, axis=1).astype(jnp.int32)


def bitmap_and_any_kernel(entries: jnp.ndarray, query: jnp.ndarray,
                          *, interpret: bool = False) -> jnp.ndarray:
    """entries: (E, W) uint32 (E % BLOCK_E == 0, W % 128 == 0);
    query: (1, W) uint32. Returns (E,) int32 0/1."""
    e, w = entries.shape
    grid = (e // BLOCK_E,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_E, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(entries, query)
