"""The paper's own configuration (§7 experimental setup): Hippo index defaults
and the TPC-H-style workload parameters, exposed like any other config."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HippoPaperConfig:
    resolution: int = 400          # default histogram resolution (§7)
    density: float = 0.2           # default partial histogram density (§7)
    page_card: int = 50            # tuples per page (§6.2 running example)
    # TPC-H-style workload scales: tuples in the Lineitem-like table.
    # (The paper uses 2/20/200 GB; we scale by tuple count on this host.)
    scales: tuple = (60_000, 600_000, 6_000_000)
    selectivities: tuple = (0.00001, 0.0001, 0.001, 0.01)  # 0.001%..1%
    densities_sweep: tuple = (0.2, 0.4, 0.8)               # Fig. 8 / Table 3
    resolutions_sweep: tuple = (400, 800, 1600)            # Fig. 9 / Table 3
    refresh_fraction: float = 0.001                        # TPC-H refresh: 0.1%


DEFAULT = HippoPaperConfig()
