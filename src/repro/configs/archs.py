"""Assigned architecture configs (exact shapes from the assignment brief).

Sources are public literature; tags: [hf] = HuggingFace config,
[arXiv] = paper, [unverified] = assignment-provided.
"""
from repro.configs.base import ModelConfig, register


@register("llama4-maverick-400b-a17b")
def llama4_maverick():
    # [hf:meta-llama/Llama-4; unverified] MoE interleaved every other layer,
    # 128 routed experts top-1 + shared expert, sigmoid router.
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=8192, vocab_size=202048,
        block_pattern=("attn", "moe"),
        num_experts=128, num_shared_experts=1, top_k=1, moe_d_ff=8192,
        router_act="sigmoid", rope_theta=500000.0,
    )


@register("qwen2-moe-a2.7b")
def qwen2_moe():
    # [hf:Qwen/Qwen1.5-MoE-A2.7B] every layer MoE: 60 routed top-4 + 4 shared.
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=151936,
        block_pattern=("moe",),
        num_experts=60, num_shared_experts=4, top_k=4, moe_d_ff=1408,
    )


@register("qwen2-vl-7b")
def qwen2_vl():
    # [arXiv:2409.12191; hf] M-RoPE, dynamic resolution. Vision frontend is a
    # STUB: input_specs() provides precomputed patch embeddings.
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        d_ff=18944, vocab_size=152064,
        pos_emb="mrope", mrope_sections=(16, 24, 24), qkv_bias=True,
        frontend="patches", rope_theta=1000000.0,
    )


@register("musicgen-large")
def musicgen():
    # [arXiv:2306.05284; hf] decoder-only over EnCodec tokens; frontend STUB
    # provides frame embeddings; sinusoidal absolute positions.
    return ModelConfig(
        name="musicgen-large", family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048,
        pos_emb="sinusoidal", norm="layernorm", frontend="frames",
    )


@register("recurrentgemma-9b")
def recurrentgemma():
    # [arXiv:2402.19427; unverified] Griffin: RG-LRU + local attention, 2:1.
    # 38 layers = 12 x (rec, rec, attn_local) + (rec, rec).
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        d_ff=12288, vocab_size=256000, head_dim=256,
        block_pattern=("rec", "rec", "attn_local"), window=2048,
    )


@register("yi-6b")
def yi():
    # [arXiv:2403.04652; hf] llama-arch GQA kv=4.
    return ModelConfig(
        name="yi-6b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
        d_ff=11008, vocab_size=64000, rope_theta=5000000.0,
    )


@register("stablelm-3b")
def stablelm():
    # [hf:stabilityai/stablelm; unverified] MHA, LayerNorm, partial rotary 25%.
    return ModelConfig(
        name="stablelm-3b", family="dense",
        num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=6912, vocab_size=50304,
        norm="layernorm", rope_fraction=0.25,
    )


@register("qwen2.5-3b")
def qwen25():
    # [hf:Qwen/Qwen2.5; hf] GQA kv=2, QKV bias.
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
        d_ff=11008, vocab_size=151936, qkv_bias=True, rope_theta=1000000.0,
    )


@register("smollm-360m")
def smollm():
    # [hf:HuggingFaceTB/SmolLM; hf] small llama-arch, GQA kv=5, head_dim 64.
    return ModelConfig(
        name="smollm-360m", family="dense",
        num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
        d_ff=2560, vocab_size=49152, head_dim=64,
    )


@register("rwkv6-3b")
def rwkv6():
    # [arXiv:2404.05892; hf] Finch — attention-free, data-dependent decay.
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        num_layers=32, d_model=2560, num_heads=0, num_kv_heads=0,
        d_ff=8960, vocab_size=65536, head_dim=64,
        block_pattern=("rwkv",), pos_emb="sinusoidal",
    )
