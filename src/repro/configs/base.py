"""Model / shape configuration dataclasses and the arch registry."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # Block pattern: the repeating unit scanned over; leftover layers follow
    # the pattern prefix. Kinds: attn | attn_local | moe | rec | rwkv
    block_pattern: tuple = ("attn",)
    window: int = 0                 # local-attention window (attn_local)

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 1
    moe_d_ff: int = 0               # expert hidden size (0 -> d_ff)
    capacity_factor: float = 1.25
    router_act: str = "softmax"     # softmax | sigmoid (llama4)

    # Positional encoding
    pos_emb: str = "rope"           # rope | mrope | sinusoidal
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0      # stablelm: 0.25
    mrope_sections: tuple = (16, 24, 24)  # qwen2-vl (t, h, w) half-dims

    # Norm / misc
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    qkv_bias: bool = False
    tie_embeddings: bool = False

    # Recurrent families
    conv_width: int = 4             # griffin temporal conv
    rglru_c: float = 8.0

    # Frontend: tokens (LM) | frames (audio stub) | patches (vision stub)
    frontend: str = "tokens"

    dtype: str = "bfloat16"
    q_chunk: int = 256              # blocked-attention query chunk

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def unit_len(self) -> int:
        return len(self.block_pattern)

    @property
    def num_units(self) -> int:
        return self.num_layers // self.unit_len

    @property
    def leftover_pattern(self) -> tuple:
        return self.block_pattern[: self.num_layers % self.unit_len]

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("rwkv", "rec") for k in self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: no *global* attention blocks."""
        return all(k in ("rwkv", "rec", "attn_local") for k in self.block_pattern)

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized config of the same family (see tests)."""
        scale = dict(
            num_layers=max(2 * self.unit_len, self.unit_len),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            num_experts=min(self.num_experts, 4),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=64 if self.moe_d_ff else 0,
            window=min(self.window, 16) if self.window else 0,
            mrope_sections=(4, 2, 2),
            dtype="float32",
            q_chunk=16,
        )
        scale.update(overrides)
        return replace(self, **scale)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401 — populate registry
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def shape_cells(cfg: ModelConfig) -> list[ShapeConfig]:
    """The shape cells this arch runs (long_500k only for sub-quadratic)."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        cells.append(SHAPES["long_500k"])
    return cells
