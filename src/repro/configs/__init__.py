"""Arch registry — importing this package registers every assigned config."""
from repro.configs.base import (  # noqa: F401
    SHAPES, ModelConfig, ShapeConfig, get_config, list_archs, register,
    shape_cells,
)
from repro.configs import archs  # noqa: F401  (registers all architectures)
from repro.configs import hippo_default  # noqa: F401
