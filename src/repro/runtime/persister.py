"""Background persister — durable commits off the drain path.

PR 8's durability wired ``QueryEngine.save()`` synchronously into the
drain: every drain commit paid section collection *and* the fsync train
before the next query batch could run. Collection must stay foreground
(it reads the index, which the next drain mutates), but the file I/O need
not: the engine collects sections from the immutable post-swap state,
then hands the write to this module's single worker thread and keeps
serving.

Ordering discipline. Jobs commit strictly in submission order (one worker,
FIFO queue) — a delta's sequence number is reserved at collect time, and
``checkpointing.snapshot.delta_chain`` refuses gaps, so out-of-order
commits would be unloadable anyway. The WAL truncation belongs to the
*commit callback* (the job body), not the submitter: truncating at submit
time would destroy acknowledged records whose covering snapshot is still
in the queue — a crash in that window would lose them. The engine's
commit callback truncates only through the job's recorded watermark
(``Journal.truncate_through``), so records appended while the job was in
flight always survive to the next commit.

Poisoning. A failed commit must not be skipped over: if delta k fails and
delta k+1 were allowed to commit, the chain would either gap (refused at
load) or, worse, a later WAL truncation would discard records only delta
k covered. So the first failure *poisons* the persister — every queued
and future job fails fast with ``PersisterPoisoned`` without touching
disk — until the engine performs a synchronous full snapshot
(``QueryEngine.save()``), which supersedes the whole broken chain and
clears the poison. Acknowledged operations stay safe throughout: the WAL
is only ever truncated by a *successful* commit's callback.

Backpressure: the queue is bounded; ``submit`` blocks when the persister
falls ``max_queue`` commits behind (time spent blocked is surfaced via
``PersistStats.blocked_s`` and the engine's ``persist_lag`` stat), so an
unboundedly slow disk degrades the drain rate instead of growing an
unbounded pile of un-durable acknowledged state. ``flush()`` is the
barrier tests and ``QueryEngine.flush_durable()`` use.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, replace

from repro.runtime.faultinject import crashpoint

_STOP = object()


class PersisterPoisoned(RuntimeError):
    """A background commit failed; later commits are refused until a
    synchronous full snapshot supersedes the broken chain."""


@dataclass
class PersistStats:
    submitted: int = 0    # jobs accepted into the queue
    committed: int = 0    # jobs durably committed by the worker
    failed: int = 0       # jobs that raised (first one poisons)
    blocked_s: float = 0.0  # total submit-side backpressure wait


class BackgroundPersister:
    """One worker thread draining a bounded FIFO of commit jobs.

    ``commit_fn(job)`` does the durable work (write sections, commit
    sentinel, truncate WAL through the job's watermark); it runs on the
    worker thread only, one job at a time, in submission order.
    """

    def __init__(self, commit_fn, *, max_queue: int = 4,
                 name: str = "hippo-persister"):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self._commit = commit_fn
        self._q: queue.Queue = queue.Queue(maxsize=max_queue)
        # guards the worker/caller shared state below; held only around
        # state flips and counter bumps, never across commit I/O, so a
        # slow disk cannot block a stats read
        self._lock = threading.Lock()
        self._poison: BaseException | None = None  # guarded-by: _lock
        self._closed = False
        self._inflight = False                     # guarded-by: _lock
        self.stats = PersistStats()                # guarded-by: _lock
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    # -- worker --------------------------------------------------------------

    def _run(self) -> None:
        while True:
            job = self._q.get()
            if job is _STOP:
                self._q.task_done()
                return
            with self._lock:
                poison = self._poison
                self._inflight = True
            try:
                if poison is not None:
                    # fail queued jobs *without* committing: committing past
                    # a failed commit is exactly the gap/loss poisoning
                    # exists to prevent
                    raise PersisterPoisoned(
                        "persister poisoned by an earlier failed commit"
                    ) from poison
                crashpoint("persist.in_flight")
                self._commit(job)
                with self._lock:
                    self.stats.committed += 1
            except BaseException as e:       # noqa: BLE001 — poison on any
                with self._lock:
                    self.stats.failed += 1
                    if self._poison is None:
                        self._poison = e
            finally:
                with self._lock:
                    self._inflight = False
                self._q.task_done()

    # -- submitter side ------------------------------------------------------

    def submit(self, job) -> None:
        """Enqueue one commit job; blocks (backpressure) when the queue is
        full. Raises ``PersisterPoisoned`` immediately if a prior commit
        failed — the caller must fall back to a synchronous full save."""
        if self._closed:
            raise RuntimeError("persister is closed")
        with self._lock:
            poison = self._poison
        if poison is not None:
            raise PersisterPoisoned(
                "persister poisoned by an earlier failed commit"
            ) from poison
        t0 = time.perf_counter()
        self._q.put(job)
        with self._lock:
            self.stats.blocked_s += time.perf_counter() - t0
            self.stats.submitted += 1

    def stats_snapshot(self) -> PersistStats:
        """A consistent copy of the counters, taken under the lock — the
        caller-thread way to read stats while the worker is bumping them."""
        with self._lock:
            return replace(self.stats)

    @property
    def pending(self) -> int:
        """Jobs not yet durably committed (queued + in flight)."""
        with self._lock:
            inflight = self._inflight
        return self._q.qsize() + (1 if inflight else 0)

    @property
    def poisoned(self) -> bool:
        with self._lock:
            return self._poison is not None

    def flush(self, *, raise_on_poison: bool = True) -> None:
        """Barrier: return once every submitted job has been processed.
        Surfaces the first failure (the poison) unless told not to."""
        self._q.join()
        with self._lock:
            poison = self._poison
        if raise_on_poison and poison is not None:
            raise PersisterPoisoned(
                "a background commit failed; acknowledged state past the "
                "last successful commit is covered by the WAL only"
            ) from poison

    def clear_poison(self) -> None:
        """Called after a synchronous full snapshot supersedes the broken
        chain — background commits may resume."""
        with self._lock:
            self._poison = None

    def close(self, timeout: float = 10.0) -> None:
        """Drain the queue, stop the worker, and join it."""
        if self._closed:
            return
        self._closed = True
        self._q.put(_STOP)
        self._thread.join(timeout=timeout)
