"""Batched multi-predicate query engine — the serving front for Hippo search.

Mirrors ``launch/serve.py``'s lock-step batch server: queries arrive as
``Predicate``s, get admitted into a fixed number of slots, execute together in
one device program (``core.index.search_many``), and finished queries free
their slot for the next queued request. The fixed slot count keeps every
``run_batch`` at one stable jit shape — (batch, W) bitmaps, (batch,) interval
bounds — so the trace is compiled once and recycled for the life of the engine.

    engine = QueryEngine(idx, batch=64)
    tickets = [engine.submit(p) for p in preds]
    engine.drain()
    counts = [t.count for t in tickets]

Free slots in a partially-filled batch are padded with the empty predicate
(lo > hi), which converts to an all-zero query bitmap and matches nothing —
the query analogue of a recycled decode slot idling on a pad token. Pads are
tracked separately (``EngineStats.pad_slots``) and never counted as served
work; ``EngineStats.occupancy`` is real queries over dispatched slots.

Sharded mode (``core.partition.ShardedHippoIndex``): the admitted batch is
routed through the per-shard summary bitmaps — a (batch, S) joint-bucket
test — and each shard receives one dispatch carrying only the queries whose
summaries match it, padded to a small bucket width so every shard reuses the
same compiled traces. Shards no admitted query can match are skipped
entirely (partition pruning), and per-query counts are reduced across the
dispatched shards on the way out. Per-shard occupancy lands in
``EngineStats.shard_queries`` / ``shard_slots``.

Shapes/dtypes on the dispatch boundary: predicates convert once per batch to
(Q, W) uint32 packed bucket bitmaps plus (Q,) float32 interval bounds; dense
mode runs one (Q=batch)-wide program, sharded mode runs per-shard programs at
bucketed widths. Equivalence contract: for the same predicate stream, dense
mode on ``HippoIndex``, dense mode on ``ShardedHippoIndex`` (fused (Q, S)
count-reduce), and the summary-routed sharded dispatch all return
bit-identical counts.

Writes (``runtime.writer.MaintenanceWriter``): ``write()``/``delete()``
stage maintenance instead of running Algorithm 3 on the query path; staged
rows are overlaid into counts so results never go stale, and the engine
drains shard queues between batches under one of three interleave policies:

  sync             no writer — write() runs Algorithm 3 immediately and
                   delete() vacuums immediately (the baseline the async
                   benchmark contrasts)
  between_batches  after each ``run_batch``, drain up to ``drain_units``
                   shard queues/vacuums (default for sharded indexes)
  on_depth         drain everything once ``queue_depth`` >= ``drain_depth``
  manual           drain only on explicit ``flush()``

Queue depth, staged rows, and drain latency land in ``EngineStats``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.predicate import Predicate
from repro.runtime.writer import MaintenanceWriter

_EMPTY = Predicate(lo=1.0, hi=0.0)   # lo > hi: matches nothing

_SHARD_BUCKET_MIN = 8   # smallest per-shard dispatch width (trace bucketing)


@dataclass
class QueryTicket:
    """One submitted predicate and, once its batch ran, its results."""
    qid: int
    pred: Predicate
    count: int | None = None
    pages_inspected: int | None = None
    entries_matched: int | None = None
    done: bool = False


@dataclass
class EngineStats:
    submitted: int = 0
    served: int = 0
    batches: int = 0
    slots_filled: int = 0    # real query-slots dispatched (never _EMPTY pads)
    pad_slots: int = 0       # _EMPTY pads dispatched alongside them
    shard_dispatches: int = 0          # per-shard programs run (sharded mode)
    shards_pruned: int = 0             # shard dispatches skipped via summaries
    shard_queries: dict = field(default_factory=dict)  # shard -> real queries
    shard_slots: dict = field(default_factory=dict)    # shard -> slots incl. pads
    # -- async maintenance (runtime.writer) ----------------------------------
    writes: int = 0          # tuples written through the engine
    deletes: int = 0         # tuples deleted through the engine (incl. staged kills)
    drains: int = 0          # drain units applied (shard insert queues + vacuums)
    drained_rows: int = 0    # staged rows applied to the index by drains
    drain_us: float = 0.0    # cumulative wall time spent inside writer drains
    queue_depth: int = 0     # staged tuples pending after the last engine op
    peak_queue_depth: int = 0
    staged_rows: int = 0     # live staged rows currently overlaid into counts

    @property
    def occupancy(self) -> float:
        """Fraction of *dispatched* slots that carried a real query.

        Dense mode dispatches the full batch width, so pads are the free
        batch slots; sharded mode dispatches per-shard bucketed widths, so
        pads are the bucket roundings (a query dispatched to several shards
        fills one slot in each)."""
        total = self.slots_filled + self.pad_slots
        return self.slots_filled / total if total else 0.0

    def shard_occupancy(self) -> dict[int, float]:
        """Per-shard occupancy of the sharded dispatch path."""
        return {s: self.shard_queries[s] / self.shard_slots[s]
                for s in sorted(self.shard_slots) if self.shard_slots[s]}


_DRAIN_POLICIES = ("sync", "between_batches", "on_depth", "manual")


class QueryEngine:
    """Lock-step batched query executor with slot recycling.

    ``sharded`` selects the per-shard dispatch path; by default it turns on
    whenever the index exposes the partition-layer routing surface
    (``plan_batch`` / ``search_batch_shard_arrays``).

    ``drain_policy`` selects the maintenance interleave (see module
    docstring); the default is ``between_batches`` when the index supports a
    writer and ``sync`` otherwise. ``drain_units`` bounds the shard
    queues/vacuums applied per batch under ``between_batches``;
    ``drain_depth`` is the ``on_depth`` trigger.
    """

    def __init__(self, index, batch: int = 64, sharded: bool | None = None,
                 drain_policy: str | None = None, drain_units: int = 1,
                 drain_depth: int = 256,
                 writer: MaintenanceWriter | None = None):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.index = index
        self.batch = batch
        if sharded is None:
            sharded = hasattr(index, "plan_batch")
        if sharded and not hasattr(index, "plan_batch"):
            raise ValueError("sharded=True needs a ShardedHippoIndex-style "
                             "index (plan_batch/search_batch_shard_arrays)")
        self.sharded = sharded
        supports_writer = hasattr(index, "plan_batch")
        if drain_policy is None:
            drain_policy = "between_batches" if supports_writer else "sync"
        if drain_policy not in _DRAIN_POLICIES:
            raise ValueError(f"drain_policy must be one of {_DRAIN_POLICIES}, "
                             f"got {drain_policy!r}")
        if drain_policy != "sync" and not supports_writer:
            raise ValueError(
                "async drain policies need a ShardedHippoIndex-style index "
                "(per-shard queues route by ShardSpec); use "
                "drain_policy='sync' for an unsharded index")
        self.drain_policy = drain_policy
        self.drain_units = drain_units
        self.drain_depth = drain_depth
        if writer is not None and writer.index is not index:
            raise ValueError("writer is bound to a different index than the "
                             "engine's — its staged rows and drains would "
                             "target the wrong index")
        if writer is None and drain_policy != "sync":
            writer = MaintenanceWriter(index)
        self.writer = writer
        self.slots: list[QueryTicket | None] = [None] * batch
        self.queue: list[QueryTicket] = []
        self.stats = EngineStats()
        self._next_qid = 0
        self._auto_drain_suspended = False

    # -- admission (mirrors BatchServer.admit) -------------------------------

    def submit(self, pred: Predicate) -> QueryTicket:
        """Enqueue a predicate; returns its ticket (filled in by run_batch)."""
        t = QueryTicket(qid=self._next_qid, pred=pred)
        self._next_qid += 1
        self.stats.submitted += 1
        self.queue.append(t)
        return t

    def _admit(self) -> None:
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.pop(0)

    # -- writes (async maintenance surface) ----------------------------------

    def write(self, value: float) -> None:
        """Insert one tuple. Sync policy runs Algorithm 3 immediately; async
        policies stage the row into its shard's queue (a host list append)
        and let the interleave policy drain it off the query path. Counts
        include the staged row either way."""
        self.stats.writes += 1
        if self.writer is None:
            self.index.insert(float(value))
            return
        self.writer.write(float(value))
        if (self.drain_policy == "on_depth"
                and self.writer.queue_depth >= self.drain_depth):
            self._drain(None)
        self._sync_writer_stats()

    def delete(self, lo: float, hi: float) -> int:
        """Delete tuples with key in [lo, hi]. The validity-mask update is
        immediate on every policy (queries stay exact, §5.2 lazy deletes);
        sync policy then vacuums on the spot, async policies queue the dirty
        shards for drained ``vacuum_shard`` calls. Returns tuples deleted."""
        if self.writer is None:
            n = self.index.table.delete_where(lo, hi)
            self.index.vacuum()
            self.stats.deletes += n
            return n
        n = self.writer.delete(lo, hi)
        self.stats.deletes += n
        self._sync_writer_stats()
        return n

    def flush(self) -> int:
        """Drain every pending shard queue and vacuum now (explicit policy).
        Returns staged rows applied to the index."""
        if self.writer is None:
            return 0
        rows = self._drain(None)
        return rows

    def _drain(self, max_units: int | None) -> int:
        rows = self.writer.drain(max_units)
        self._auto_drain_suspended = False      # a successful drain re-arms
        self._sync_writer_stats()
        return rows

    def _sync_writer_stats(self) -> None:
        w = self.writer
        st = self.stats
        st.drains = w.stats.drains
        st.drained_rows = w.stats.drained_rows
        st.drain_us = w.stats.total_drain_us
        st.queue_depth = w.queue_depth
        st.staged_rows = w.staged_rows
        st.peak_queue_depth = max(st.peak_queue_depth, w.queue_depth)

    # -- execution ------------------------------------------------------------

    def run_batch(self) -> list[QueryTicket]:
        """Admit queued queries into free slots and execute one device program
        (or, in sharded mode, one summary-routed dispatch per matched shard).

        Returns the tickets retired by this batch (empty if nothing pending).
        """
        # Drain *before* executing: the drain sits between the previous
        # batch and this one either way, and a drain refusal (slot capacity)
        # then raises before any query work instead of discarding a fully
        # computed batch on the way out.
        self._maybe_drain_between_batches()
        self._admit()
        active = [i for i, t in enumerate(self.slots) if t is not None]
        if not active:
            return []
        if self.sharded:
            counts, inspected, matched = self._execute_sharded(active)
        else:
            counts, inspected, matched = self._execute_dense(active)
        finished = []
        for k, i in enumerate(active):
            t = self.slots[i]
            t.count = int(counts[k])
            t.pages_inspected = int(inspected[k])
            t.entries_matched = int(matched[k])
            t.done = True
            finished.append(t)
            self.slots[i] = None          # recycle the slot
        self.stats.batches += 1
        if not self.sharded:
            # dense mode dispatches the full batch width; sharded dispatch
            # accounting happens per shard inside _execute_sharded
            self.stats.slots_filled += len(active)
            self.stats.pad_slots += self.batch - len(active)
        self.stats.served += len(finished)
        return finished

    def _maybe_drain_between_batches(self) -> None:
        """Between-batches drain. A drain refusal (e.g. shard slot capacity)
        raises once, loudly, then suspends auto-draining so queries keep
        serving exactly through the staging overlay; an explicit ``flush()``
        (after fixing capacity) or ``writer.discard()`` re-arms it."""
        if (self.writer is None or self.drain_policy != "between_batches"
                or self._auto_drain_suspended
                or not self.writer.pending_units):
            return
        try:
            self._drain(self.drain_units)
        except RuntimeError:
            self._auto_drain_suspended = True
            raise

    def _execute_dense(self, active: list[int]) -> tuple:
        """One full-width device program; pads fill the free slots."""
        preds = [t.pred if t is not None else _EMPTY for t in self.slots]
        res = self.index.search_batch(preds)
        counts = np.asarray(res.counts)[active]
        inspected = np.asarray(res.pages_inspected)[active]
        matched = np.asarray(res.entries_matched)[active]
        return counts, inspected, matched

    def _execute_sharded(self, active: list[int]) -> tuple:
        """Per-shard dispatch with summary pruning and count-reduce.

        Each shard runs a program over only the active queries whose bucket
        bitmaps share a joint bucket with its summary — padded up to a bucket
        width so all shards share compiled traces — and per-query results sum
        across shards (shards partition the page space, so the reduction is
        exact; a pruned (query, shard) pair is provably count-zero). The
        predicates are converted to bucket bitmaps once per batch
        (``plan_batch``); per-shard dispatches slice and pad the converted
        rows, with zero bitmaps + (lo=1, hi=0) intervals as the pads.
        """
        preds = [self.slots[i].pred for i in active]
        qbms, los, his, match = self.index.plan_batch(preds)
        a = len(active)
        counts = np.zeros((a,), np.int64)
        inspected = np.zeros((a,), np.int64)
        matched = np.zeros((a,), np.int64)
        for s in range(self.index.num_shards):
            hit = np.flatnonzero(match[:, s])
            if hit.size == 0:
                self.stats.shards_pruned += 1
                continue
            width = _SHARD_BUCKET_MIN
            while width < hit.size:
                width *= 2
            qb = np.zeros((width, qbms.shape[1]), qbms.dtype)
            qb[: hit.size] = qbms[hit]
            lo = np.full((width,), _EMPTY.lo, np.float32)
            hi = np.full((width,), _EMPTY.hi, np.float32)
            lo[: hit.size] = los[hit]
            hi[: hit.size] = his[hit]
            res = self.index.search_batch_shard_arrays(s, qb, lo, hi)
            counts[hit] += np.asarray(res.counts)[: hit.size]
            inspected[hit] += np.asarray(res.pages_inspected)[: hit.size]
            matched[hit] += np.asarray(res.entries_matched)[: hit.size]
            self.stats.shard_dispatches += 1
            self.stats.slots_filled += int(hit.size)
            self.stats.pad_slots += width - int(hit.size)
            self.stats.shard_queries[s] = (
                self.stats.shard_queries.get(s, 0) + int(hit.size))
            self.stats.shard_slots[s] = (
                self.stats.shard_slots.get(s, 0) + width)
        # Staging overlay: rows waiting in a writer's queues belong to no
        # index entry yet, so summary routing can't see them — their counts
        # add on top, independent of which shards were dispatched or pruned.
        # Read the overlay from the index's *attached* writer (the single
        # source of truth), not this engine's handle: a sync-policy engine,
        # or one whose writer was superseded, must still see staged rows.
        staging = getattr(self.index, "staging", None)
        if staging is not None and staging.staged_rows:
            counts += staging.staged_counts(los, his).sum(axis=1)
        return counts, inspected, matched

    def drain(self) -> list[QueryTicket]:
        """Run batches until the queue and all slots are empty."""
        finished = []
        while self.queue or any(t is not None for t in self.slots):
            finished.extend(self.run_batch())
        return finished

    def run_all(self, preds: list[Predicate]) -> np.ndarray:
        """Submit + drain convenience; counts in submission order."""
        tickets = [self.submit(p) for p in preds]
        self.drain()
        return np.asarray([t.count for t in tickets], np.int64)
