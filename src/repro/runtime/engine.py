"""Batched multi-predicate query engine — the serving front for Hippo search.

Mirrors ``launch/serve.py``'s lock-step batch server: queries arrive as
``Predicate``s, get admitted into a fixed number of slots, execute together in
one device program (``core.index.search_many``), and finished queries free
their slot for the next queued request. The fixed slot count keeps every
``run_batch`` at one stable jit shape — (batch, W) bitmaps, (batch,) interval
bounds — so the trace is compiled once and recycled for the life of the engine.

    engine = QueryEngine(idx, batch=64)
    tickets = [engine.submit(p) for p in preds]
    engine.drain()
    counts = [t.count for t in tickets]

Free slots in a partially-filled batch are padded with the empty predicate
(lo > hi), which converts to an all-zero query bitmap and matches nothing —
the query analogue of a recycled decode slot idling on a pad token.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.predicate import Predicate

_EMPTY = Predicate(lo=1.0, hi=0.0)   # lo > hi: matches nothing


@dataclass
class QueryTicket:
    """One submitted predicate and, once its batch ran, its results."""
    qid: int
    pred: Predicate
    count: int | None = None
    pages_inspected: int | None = None
    entries_matched: int | None = None
    done: bool = False


@dataclass
class EngineStats:
    submitted: int = 0
    served: int = 0
    batches: int = 0
    slots_filled: int = 0    # occupancy numerator; batches * batch is the denominator


class QueryEngine:
    """Lock-step batched query executor with slot recycling."""

    def __init__(self, index, batch: int = 64):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.index = index
        self.batch = batch
        self.slots: list[QueryTicket | None] = [None] * batch
        self.queue: list[QueryTicket] = []
        self.stats = EngineStats()
        self._next_qid = 0

    # -- admission (mirrors BatchServer.admit) -------------------------------

    def submit(self, pred: Predicate) -> QueryTicket:
        """Enqueue a predicate; returns its ticket (filled in by run_batch)."""
        t = QueryTicket(qid=self._next_qid, pred=pred)
        self._next_qid += 1
        self.stats.submitted += 1
        self.queue.append(t)
        return t

    def _admit(self) -> None:
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.pop(0)

    # -- execution ------------------------------------------------------------

    def run_batch(self) -> list[QueryTicket]:
        """Admit queued queries into free slots and execute one device program.

        Returns the tickets retired by this batch (empty if nothing pending).
        """
        self._admit()
        active = [i for i, t in enumerate(self.slots) if t is not None]
        if not active:
            return []
        preds = [t.pred if t is not None else _EMPTY for t in self.slots]
        res = self.index.search_batch(preds)
        counts = np.asarray(res.counts)
        inspected = np.asarray(res.pages_inspected)
        matched = np.asarray(res.entries_matched)
        finished = []
        for i in active:
            t = self.slots[i]
            t.count = int(counts[i])
            t.pages_inspected = int(inspected[i])
            t.entries_matched = int(matched[i])
            t.done = True
            finished.append(t)
            self.slots[i] = None          # recycle the slot
        self.stats.batches += 1
        self.stats.slots_filled += len(active)
        self.stats.served += len(finished)
        return finished

    def drain(self) -> list[QueryTicket]:
        """Run batches until the queue and all slots are empty."""
        finished = []
        while self.queue or any(t is not None for t in self.slots):
            finished.extend(self.run_batch())
        return finished

    def run_all(self, preds: list[Predicate]) -> np.ndarray:
        """Submit + drain convenience; counts in submission order."""
        tickets = [self.submit(p) for p in preds]
        self.drain()
        return np.asarray([t.count for t in tickets], np.int64)
