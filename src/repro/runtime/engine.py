"""Batched multi-predicate query engine — the serving front for Hippo search.

Mirrors ``launch/serve.py``'s lock-step batch server: queries arrive as
``Predicate``s, get admitted into a fixed number of slots, execute together in
one device program (``core.index.search_many``), and finished queries free
their slot for the next queued request. The fixed slot count keeps every
``run_batch`` at one stable jit shape — (batch, W) bitmaps, (batch,) interval
bounds — so the trace is compiled once and recycled for the life of the engine.

    engine = QueryEngine(idx, batch=64)
    tickets = [engine.submit(p) for p in preds]
    engine.drain()
    counts = [t.count for t in tickets]

Free slots in a partially-filled batch are padded with the empty predicate
(lo > hi), which converts to an all-zero query bitmap and matches nothing —
the query analogue of a recycled decode slot idling on a pad token. Pads are
tracked separately (``EngineStats.pad_slots``) and never counted as served
work; ``EngineStats.occupancy`` is real queries over dispatched slots.

Sharded mode (``core.partition.ShardedHippoIndex``): the admitted batch is
routed through the per-shard summary bitmaps — a (batch, S) joint-bucket
test — and each shard receives one dispatch carrying only the queries whose
summaries match it, padded to a small bucket width so every shard reuses the
same compiled traces. Shards no admitted query can match are skipped
entirely (partition pruning), and per-query counts are reduced across the
dispatched shards on the way out. Per-shard occupancy lands in
``EngineStats.shard_queries`` / ``shard_slots``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.predicate import Predicate

_EMPTY = Predicate(lo=1.0, hi=0.0)   # lo > hi: matches nothing

_SHARD_BUCKET_MIN = 8   # smallest per-shard dispatch width (trace bucketing)


@dataclass
class QueryTicket:
    """One submitted predicate and, once its batch ran, its results."""
    qid: int
    pred: Predicate
    count: int | None = None
    pages_inspected: int | None = None
    entries_matched: int | None = None
    done: bool = False


@dataclass
class EngineStats:
    submitted: int = 0
    served: int = 0
    batches: int = 0
    slots_filled: int = 0    # real query-slots dispatched (never _EMPTY pads)
    pad_slots: int = 0       # _EMPTY pads dispatched alongside them
    shard_dispatches: int = 0          # per-shard programs run (sharded mode)
    shards_pruned: int = 0             # shard dispatches skipped via summaries
    shard_queries: dict = field(default_factory=dict)  # shard -> real queries
    shard_slots: dict = field(default_factory=dict)    # shard -> slots incl. pads

    @property
    def occupancy(self) -> float:
        """Fraction of *dispatched* slots that carried a real query.

        Dense mode dispatches the full batch width, so pads are the free
        batch slots; sharded mode dispatches per-shard bucketed widths, so
        pads are the bucket roundings (a query dispatched to several shards
        fills one slot in each)."""
        total = self.slots_filled + self.pad_slots
        return self.slots_filled / total if total else 0.0

    def shard_occupancy(self) -> dict[int, float]:
        """Per-shard occupancy of the sharded dispatch path."""
        return {s: self.shard_queries[s] / self.shard_slots[s]
                for s in sorted(self.shard_slots) if self.shard_slots[s]}


class QueryEngine:
    """Lock-step batched query executor with slot recycling.

    ``sharded`` selects the per-shard dispatch path; by default it turns on
    whenever the index exposes the partition-layer routing surface
    (``plan_batch`` / ``search_batch_shard_arrays``).
    """

    def __init__(self, index, batch: int = 64, sharded: bool | None = None):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.index = index
        self.batch = batch
        if sharded is None:
            sharded = hasattr(index, "plan_batch")
        if sharded and not hasattr(index, "plan_batch"):
            raise ValueError("sharded=True needs a ShardedHippoIndex-style "
                             "index (plan_batch/search_batch_shard_arrays)")
        self.sharded = sharded
        self.slots: list[QueryTicket | None] = [None] * batch
        self.queue: list[QueryTicket] = []
        self.stats = EngineStats()
        self._next_qid = 0

    # -- admission (mirrors BatchServer.admit) -------------------------------

    def submit(self, pred: Predicate) -> QueryTicket:
        """Enqueue a predicate; returns its ticket (filled in by run_batch)."""
        t = QueryTicket(qid=self._next_qid, pred=pred)
        self._next_qid += 1
        self.stats.submitted += 1
        self.queue.append(t)
        return t

    def _admit(self) -> None:
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.pop(0)

    # -- execution ------------------------------------------------------------

    def run_batch(self) -> list[QueryTicket]:
        """Admit queued queries into free slots and execute one device program
        (or, in sharded mode, one summary-routed dispatch per matched shard).

        Returns the tickets retired by this batch (empty if nothing pending).
        """
        self._admit()
        active = [i for i, t in enumerate(self.slots) if t is not None]
        if not active:
            return []
        if self.sharded:
            counts, inspected, matched = self._execute_sharded(active)
        else:
            counts, inspected, matched = self._execute_dense(active)
        finished = []
        for k, i in enumerate(active):
            t = self.slots[i]
            t.count = int(counts[k])
            t.pages_inspected = int(inspected[k])
            t.entries_matched = int(matched[k])
            t.done = True
            finished.append(t)
            self.slots[i] = None          # recycle the slot
        self.stats.batches += 1
        if not self.sharded:
            # dense mode dispatches the full batch width; sharded dispatch
            # accounting happens per shard inside _execute_sharded
            self.stats.slots_filled += len(active)
            self.stats.pad_slots += self.batch - len(active)
        self.stats.served += len(finished)
        return finished

    def _execute_dense(self, active: list[int]) -> tuple:
        """One full-width device program; pads fill the free slots."""
        preds = [t.pred if t is not None else _EMPTY for t in self.slots]
        res = self.index.search_batch(preds)
        counts = np.asarray(res.counts)[active]
        inspected = np.asarray(res.pages_inspected)[active]
        matched = np.asarray(res.entries_matched)[active]
        return counts, inspected, matched

    def _execute_sharded(self, active: list[int]) -> tuple:
        """Per-shard dispatch with summary pruning and count-reduce.

        Each shard runs a program over only the active queries whose bucket
        bitmaps share a joint bucket with its summary — padded up to a bucket
        width so all shards share compiled traces — and per-query results sum
        across shards (shards partition the page space, so the reduction is
        exact; a pruned (query, shard) pair is provably count-zero). The
        predicates are converted to bucket bitmaps once per batch
        (``plan_batch``); per-shard dispatches slice and pad the converted
        rows, with zero bitmaps + (lo=1, hi=0) intervals as the pads.
        """
        preds = [self.slots[i].pred for i in active]
        qbms, los, his, match = self.index.plan_batch(preds)
        a = len(active)
        counts = np.zeros((a,), np.int64)
        inspected = np.zeros((a,), np.int64)
        matched = np.zeros((a,), np.int64)
        for s in range(self.index.num_shards):
            hit = np.flatnonzero(match[:, s])
            if hit.size == 0:
                self.stats.shards_pruned += 1
                continue
            width = _SHARD_BUCKET_MIN
            while width < hit.size:
                width *= 2
            qb = np.zeros((width, qbms.shape[1]), qbms.dtype)
            qb[: hit.size] = qbms[hit]
            lo = np.full((width,), _EMPTY.lo, np.float32)
            hi = np.full((width,), _EMPTY.hi, np.float32)
            lo[: hit.size] = los[hit]
            hi[: hit.size] = his[hit]
            res = self.index.search_batch_shard_arrays(s, qb, lo, hi)
            counts[hit] += np.asarray(res.counts)[: hit.size]
            inspected[hit] += np.asarray(res.pages_inspected)[: hit.size]
            matched[hit] += np.asarray(res.entries_matched)[: hit.size]
            self.stats.shard_dispatches += 1
            self.stats.slots_filled += int(hit.size)
            self.stats.pad_slots += width - int(hit.size)
            self.stats.shard_queries[s] = (
                self.stats.shard_queries.get(s, 0) + int(hit.size))
            self.stats.shard_slots[s] = (
                self.stats.shard_slots.get(s, 0) + width)
        return counts, inspected, matched

    def drain(self) -> list[QueryTicket]:
        """Run batches until the queue and all slots are empty."""
        finished = []
        while self.queue or any(t is not None for t in self.slots):
            finished.extend(self.run_batch())
        return finished

    def run_all(self, preds: list[Predicate]) -> np.ndarray:
        """Submit + drain convenience; counts in submission order."""
        tickets = [self.submit(p) for p in preds]
        self.drain()
        return np.asarray([t.count for t in tickets], np.int64)
