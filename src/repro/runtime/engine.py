"""Batched multi-predicate query engine — the serving front for Hippo search.

Mirrors ``launch/serve.py``'s lock-step batch server: queries arrive as
``Predicate``s, get admitted into a fixed number of slots, execute together in
one device program (``core.index.search_many``), and finished queries free
their slot for the next queued request. The fixed slot count keeps every
``run_batch`` at one stable jit shape — (batch, W) bitmaps, (batch,) interval
bounds — so the trace is compiled once and recycled for the life of the engine.

    engine = QueryEngine(idx, batch=64)
    tickets = [engine.submit(p) for p in preds]
    engine.drain()
    counts = [t.count for t in tickets]

Free slots in a partially-filled batch are padded with the empty predicate
(lo > hi), which converts to an all-zero query bitmap and matches nothing —
the query analogue of a recycled decode slot idling on a pad token. Pads are
tracked separately (``EngineStats.pad_slots``) and never counted as served
work; ``EngineStats.occupancy`` is real queries over dispatched slots.

Execution modes (``mode``): the default ``compact`` mode runs the batch
through the gather path (``search_compact_batch``): the per-query page masks
are unioned, the union's pages gathered once into a shared slab of
``max_selected`` pages, and every query inspected against that slab — so
inspect cost tracks the batch's selectivity, not the table size. The mode
ladder keeps it exact and trace-stable:

  compact    run at the current slab bucket (a power of two, adapted from
             the batches seen so far, so traces are reused)
  widen      a batch whose union overflows the bucket raises the bucket to
             the next power of two (capped at the width that can never
             truncate) for subsequent batches
  fallback   queries whose own pages overflowed *this* batch's slab
             (per-query ``truncated`` flag) re-run at the never-truncating
             cap — dense-cost, still row-id-capable — so results are always
             bit-identical to dense mode, never silently short

Compact serving stats land in ``EngineStats``: ``compact_hits`` /
``compact_fallbacks``, ``gather_occupancy`` (union pages over slab capacity
dispatched), and ``selected_page_ratio`` (union pages over table pages —
the fraction of the table the batch actually touched). With ``top_k`` set,
tickets additionally carry the first ``top_k`` qualifying global row ids
(``row_ids``; decode via ``PagedTable.row_values``).

``mode="dense"`` is the previous full-table behavior: one (Q, P, C) program
(or, with ``sharded=True``, the summary-routed per-shard dispatch below).

Sharded routed dispatch (``mode="dense"`` + ``sharded=True`` on a
``core.partition.ShardedHippoIndex``): the admitted batch is
routed through the per-shard summary bitmaps — a (batch, S) joint-bucket
test — and each shard receives one dispatch carrying only the queries whose
summaries match it, padded to a small bucket width so every shard reuses the
same compiled traces. Shards no admitted query can match are skipped
entirely (partition pruning), and per-query counts are reduced across the
dispatched shards on the way out. Per-shard occupancy lands in
``EngineStats.shard_queries`` / ``shard_slots``. In compact mode a sharded
index instead runs the fused sharded gather (every shard gathers its own
slab of the batch union; counts reduce across the shard axis), and the
writer's staging overlay folds into counts on either path.

Shapes/dtypes on the dispatch boundary: predicates convert once per batch to
(Q, W) uint32 packed bucket bitmaps plus (Q,) float32 interval bounds; dense
mode runs one (Q=batch)-wide program, sharded mode runs per-shard programs at
bucketed widths, compact mode one (Q=batch, max_selected)-slab program.
Equivalence contract: for the same predicate stream, dense mode on
``HippoIndex``, dense mode on ``ShardedHippoIndex`` (fused (Q, S)
count-reduce), the summary-routed sharded dispatch, and compact mode on
either index all return bit-identical counts.

Writes (``runtime.writer.MaintenanceWriter``): ``write()``/``delete()``
stage maintenance instead of running Algorithm 3 on the query path; staged
rows are overlaid into counts so results never go stale, and the engine
drains shard queues between batches under one of three interleave policies:

  sync             no writer — write() runs Algorithm 3 immediately and
                   delete() vacuums immediately (the baseline the async
                   benchmark contrasts)
  between_batches  after each ``run_batch``, drain up to ``drain_units``
                   shard queues/vacuums (default for sharded indexes)
  on_depth         drain everything once the maintenance backlog — staged
                   tuples plus table pages dirtied by deletes and awaiting
                   vacuum — reaches ``drain_depth`` (checked by ``write()``
                   *and* ``delete()``: a delete-heavy stream adds no queue
                   depth but still accumulates vacuum work)
  manual           drain only on explicit ``flush()``

Queue depth, staged rows, and drain latency land in ``EngineStats``.

Drift re-summarization (``drift_threshold`` / ``auto_resummarize``): the
writer's drift telemetry (``core.histogram.DriftTracker``) watches the
staged insert stream; when the edge-bucket overflow ratio crosses
``drift_threshold`` (after ``drift_min_observed`` inserts), the engine
schedules a re-summarization — one remap drain unit per shard onto a
boundary set rebuilt from the drift reservoir — and the normal drain policy
applies it off the query path. ``auto_resummarize=False`` leaves scheduling
to explicit ``resummarize()`` calls. ``EngineStats`` reports
``resummarizes``, the live ``edge_overflow_ratio``, and the pruning-quality
window around the last re-summarization (``pruning_before_resummarize`` vs.
``pruning_after_resummarize`` — selected-page ratios of the compact batches
before and since).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.partition import SUMMARY_POLICIES
from repro.core.predicate import Predicate
from repro.runtime.writer import MaintenanceWriter

_EMPTY = Predicate(lo=1.0, hi=0.0)   # lo > hi: matches nothing


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p

_SHARD_BUCKET_MIN = 8     # smallest per-shard dispatch width (trace bucketing)
_COMPACT_BUCKET_MIN = 64  # smallest gather-slab width (trace bucketing)
_FALLBACK_Q_MIN = 8       # smallest dense-fallback query width


@dataclass
class QueryTicket:
    """One submitted predicate and, once its batch ran, its results.

    ``row_ids`` is filled only by the compact mode with ``top_k`` set: the
    first ``top_k`` qualifying global row ids in ascending order (pads
    stripped; ``count`` tells the caller whether the list is a prefix).
    """
    qid: int
    pred: Predicate
    count: int | None = None
    pages_inspected: int | None = None
    entries_matched: int | None = None
    row_ids: np.ndarray | None = None
    done: bool = False


@dataclass
class EngineStats:
    submitted: int = 0
    served: int = 0
    batches: int = 0
    slots_filled: int = 0    # real query-slots dispatched (never _EMPTY pads)
    pad_slots: int = 0       # _EMPTY pads dispatched alongside them
    shard_dispatches: int = 0          # per-shard programs run (sharded mode)
    shards_pruned: int = 0             # shard dispatches skipped via summaries
    shard_queries: dict = field(default_factory=dict)  # shard -> real queries
    shard_slots: dict = field(default_factory=dict)    # shard -> slots incl. pads
    # -- compact mode (gather path) ------------------------------------------
    compact_batches: int = 0     # batches executed through the gather path
    compact_hits: int = 0        # queries served off the gathered slab
    compact_fallbacks: int = 0   # truncated queries re-run at the dense cap
    gather_union_pages: int = 0  # batch-union pages gathered into slabs, cum.
    gather_slab_pages: int = 0   # slab capacity dispatched, cumulative
    selected_pages: int = 0      # batch-union pages selected (unclamped), cum.
    table_pages_seen: int = 0    # table pages visible per compact batch, cum.
    # -- async maintenance (runtime.writer) ----------------------------------
    writes: int = 0          # tuples written through the engine
    deletes: int = 0         # tuples deleted through the engine (incl. staged kills)
    drains: int = 0          # drain units applied (inserts + vacuums + resummarizes)
    drained_rows: int = 0    # staged rows applied to the index by drains
    drain_us: float = 0.0    # cumulative wall time spent inside writer drains
    queue_depth: int = 0     # staged tuples pending after the last engine op
    peak_queue_depth: int = 0
    staged_rows: int = 0     # live staged rows currently overlaid into counts
    # -- durable persistence (checkpointing + runtime.persister) -------------
    persists: int = 0          # durable commits (full snapshots + deltas)
    persist_pending: int = 0   # background commits queued or in flight
    persist_lag: int = 0       # journal records not yet covered by a commit
    # -- drift re-summarization ----------------------------------------------
    resummarizes: int = 0            # shard remap units drained
    edge_overflow_ratio: float = 0.0  # writer drift telemetry, live value
    learned_refits: int = 0          # resummarize schedules served by a learned fit
    learned_fallbacks: int = 0       # learned schedules that fell back to equal-mass
    # selected-page ratio of the compact batches before the last resummarize
    # was scheduled; the matching "after" window accumulates below
    pruning_before_resummarize: float = 0.0
    window_selected_pages: int = 0   # compact window since the last resummarize
    window_table_pages: int = 0

    @property
    def occupancy(self) -> float:
        """Fraction of *dispatched* slots that carried a real query.

        Dense mode dispatches the full batch width, so pads are the free
        batch slots; sharded mode dispatches per-shard bucketed widths, so
        pads are the bucket roundings (a query dispatched to several shards
        fills one slot in each)."""
        total = self.slots_filled + self.pad_slots
        return self.slots_filled / total if total else 0.0

    def shard_occupancy(self) -> dict[int, float]:
        """Per-shard occupancy of the sharded dispatch path."""
        return {s: self.shard_queries[s] / self.shard_slots[s]
                for s in sorted(self.shard_slots) if self.shard_slots[s]}

    @property
    def gather_occupancy(self) -> float:
        """Fraction of dispatched gather-slab capacity holding a selected
        page (compact mode). Low occupancy means the adaptive bucket is
        oversized for the workload; 1.0 means batches run at the edge of
        their bucket."""
        return (self.gather_union_pages / self.gather_slab_pages
                if self.gather_slab_pages else 0.0)

    @property
    def selected_page_ratio(self) -> float:
        """Batch-union pages over table pages across compact batches — the
        fraction of the table the batches selected (the dense path's
        denominator is always 1.0). Uses the unclamped union, so a
        truncating batch reports what it *selected*, not the slab-capped
        subset it managed to gather (that is ``gather_occupancy``'s job)."""
        return (self.selected_pages / self.table_pages_seen
                if self.table_pages_seen else 0.0)

    @property
    def pruning_after_resummarize(self) -> float:
        """Selected-page ratio of the compact batches since the last
        re-summarization was scheduled (the whole run, if none was) — the
        "after" half of the pruning-quality pair; lower is better pruning."""
        return (self.window_selected_pages / self.window_table_pages
                if self.window_table_pages else 0.0)


_DRAIN_POLICIES = ("sync", "between_batches", "on_depth", "manual")

_MODES = ("auto", "compact", "dense")


class QueryEngine:
    """Lock-step batched query executor with slot recycling.

    ``mode`` selects the execution path (see module docstring): ``compact``
    (the default via ``auto``) serves batches off the gathered
    union-of-selected-pages slab with adaptive power-of-two bucketing and a
    per-query dense fallback on truncation; ``dense`` is the full-table
    path. ``auto`` resolves to ``dense`` when ``sharded=True`` is requested
    explicitly (routed dispatch is a dense-mode feature) and to ``compact``
    otherwise.

    ``sharded`` selects the summary-routed per-shard dispatch of dense mode;
    under ``mode="dense"`` it defaults on whenever the index exposes the
    partition-layer routing surface (``plan_batch`` /
    ``search_batch_shard_arrays``). Compact mode on a sharded index runs the
    fused sharded gather instead.

    ``top_k`` (compact mode only) makes every ticket carry up to ``top_k``
    qualifying global row ids; ``compact_bucket`` seeds the adaptive slab
    bucket (rounded up to a power of two, adapted upward as batches reveal
    their union sizes).

    ``drain_policy`` selects the maintenance interleave (see module
    docstring); the default is ``between_batches`` when the index supports a
    writer and ``sync`` otherwise. ``drain_units`` bounds the shard
    queues/vacuums applied per batch under ``between_batches``;
    ``drain_depth`` is the ``on_depth`` trigger (staged tuples + dirty
    pages, checked on writes and deletes alike).

    ``drift_threshold`` / ``auto_resummarize`` / ``drift_min_observed``
    drive drift adaptation (writer-backed engines only): once at least
    ``drift_min_observed`` inserts have been staged since the last
    re-summarization and their edge-bucket overflow ratio reaches
    ``drift_threshold``, a re-summarization is scheduled automatically (one
    remap unit per shard, drained by the normal policy).
    ``drift_threshold=None`` or ``auto_resummarize=False`` disables the
    automatic trigger; ``resummarize()`` stays available either way.

    ``summary`` overrides the boundary policy every re-summarization this
    engine schedules uses (``core.partition.SUMMARY_POLICIES``:
    ``"equal_mass"`` quantiles or the ``"learned"`` piecewise-linear CDF
    fit, which falls back to equal-mass on degenerate samples); ``None``
    (default) defers to the index's own ``summary`` attribute, so an index
    created with ``summary="learned"`` keeps learned bounds across refits
    with no engine configuration. ``EngineStats.learned_refits`` /
    ``learned_fallbacks`` report which path the schedules actually took.
    """

    def __init__(self, index, batch: int = 64, sharded: bool | None = None,
                 drain_policy: str | None = None, drain_units: int = 1,
                 drain_depth: int = 256,
                 writer: MaintenanceWriter | None = None,
                 mode: str = "auto", top_k: int = 0,
                 compact_bucket: int | None = None,
                 drift_threshold: float | None = 0.25,
                 auto_resummarize: bool = True,
                 drift_min_observed: int = 256,
                 summary: str | None = None,
                 storage_dir=None, snapshot_on_drain: bool = True,
                 wal_sync: bool = True, snapshot_mode: str = "incremental",
                 background_save: bool = False, compact_every: int = 8,
                 compact_ratio: float = 0.5, snapshot_keep: int = 3,
                 persist_queue: int = 4):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.index = index
        self.batch = batch
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
        if mode == "auto":
            mode = "dense" if sharded is True else "compact"
        if mode == "compact":
            if sharded is True:
                raise ValueError(
                    "sharded=True selects dense mode's routed dispatch; "
                    "compact mode runs the fused sharded gather — pass "
                    "mode='dense' for routing or drop sharded=True")
            if not hasattr(index, "search_compact_batch"):
                raise ValueError(
                    "mode='compact' needs an index with the gather surface "
                    "(search_compact_batch/gather_cap); got "
                    f"{type(index).__name__}")
            sharded = False
        else:
            if sharded is None:
                sharded = hasattr(index, "plan_batch")
            if sharded and not hasattr(index, "plan_batch"):
                raise ValueError("sharded=True needs a ShardedHippoIndex-style "
                                 "index (plan_batch/search_batch_shard_arrays)")
        self.mode = mode
        self.sharded = sharded
        if top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {top_k}")
        if top_k and mode != "compact":
            raise ValueError("row-id payloads (top_k > 0) ride the gather "
                             "path; they need mode='compact'")
        self.top_k = top_k
        if compact_bucket is not None and compact_bucket < 1:
            raise ValueError(f"compact_bucket must be >= 1, got {compact_bucket}")
        self._compact_bucket = _pow2_at_least(compact_bucket
                                              or _COMPACT_BUCKET_MIN)
        supports_writer = hasattr(index, "plan_batch")
        if drain_policy is None:
            drain_policy = "between_batches" if supports_writer else "sync"
        if drain_policy not in _DRAIN_POLICIES:
            raise ValueError(f"drain_policy must be one of {_DRAIN_POLICIES}, "
                             f"got {drain_policy!r}")
        if drain_policy != "sync" and not supports_writer:
            raise ValueError(
                "async drain policies need a ShardedHippoIndex-style index "
                "(per-shard queues route by ShardSpec); use "
                "drain_policy='sync' for an unsharded index")
        self.drain_policy = drain_policy
        self.drain_units = drain_units
        self.drain_depth = drain_depth
        if writer is not None and writer.index is not index:
            raise ValueError("writer is bound to a different index than the "
                             "engine's — its staged rows and drains would "
                             "target the wrong index")
        if writer is None and drain_policy != "sync":
            writer = MaintenanceWriter(index)
        self.writer = writer
        if drift_threshold is not None and not 0.0 < drift_threshold <= 1.0:
            raise ValueError(f"drift_threshold must be in (0, 1] or None, "
                             f"got {drift_threshold}")
        self.drift_threshold = drift_threshold
        self.auto_resummarize = auto_resummarize
        self.drift_min_observed = drift_min_observed
        if summary is not None and summary not in SUMMARY_POLICIES:
            raise ValueError(f"summary must be one of {SUMMARY_POLICIES} or "
                             f"None (the index's policy), got {summary!r}")
        self.summary = summary
        self.slots: list[QueryTicket | None] = [None] * batch
        self.queue: deque[QueryTicket] = deque()
        self.stats = EngineStats()
        self._next_qid = 0
        self._auto_drain_suspended = False
        # -- durable storage (checkpointing.snapshot + checkpointing.wal) ----
        # With ``storage_dir`` set, every acknowledged write()/delete()/
        # resummarize journals before it stages (append before admission),
        # and each successful drain commits a snapshot then truncates the
        # journal — so QueryEngine.recover() restores the acknowledged state
        # after a crash at any instant. The directory must be fresh; an
        # existing snapshot/journal means a previous engine's durable state,
        # which recover() (not a new engine) must adopt.
        from pathlib import Path as _Path
        self.storage_dir = _Path(storage_dir) if storage_dir is not None \
            else None
        self.snapshot_on_drain = snapshot_on_drain
        self.journal = None
        if snapshot_mode not in ("full", "incremental"):
            raise ValueError(f"snapshot_mode must be 'full' or "
                             f"'incremental', got {snapshot_mode!r}")
        if compact_every < 1:
            raise ValueError(f"compact_every must be >= 1, got "
                             f"{compact_every}")
        if compact_ratio <= 0:
            raise ValueError(f"compact_ratio must be > 0, got "
                             f"{compact_ratio}")
        self.snapshot_mode = snapshot_mode
        self.background_save = background_save
        self.compact_every = compact_every
        self.compact_ratio = compact_ratio
        self.snapshot_keep = snapshot_keep
        self.persist_queue = persist_queue
        self._persister = None
        self._base_epoch = None        # epoch of the current full base
        self._delta_seq = 0            # committed deltas against it
        self._full_bytes = 0           # base snapshot payload size
        self._delta_bytes = 0          # cumulative chain payload size
        # the persister's commit callback (_commit_job, worker thread)
        # advances the durable watermark while the foreground reads it for
        # persist_lag; both sides go through this lock
        self._durable_lock = threading.Lock()
        self._durable_watermark = 0    # guarded-by: _durable_lock
        #                                (highest seqno covered by a commit)
        if self.storage_dir is not None:
            if self.writer is None:
                raise ValueError(
                    "storage_dir needs a writer-backed engine (an async "
                    "drain_policy on a ShardedHippoIndex); a writer-less "
                    "index persists directly via index.save()")
            from repro.checkpointing.snapshot import latest_epoch
            from repro.checkpointing.wal import Journal
            journal = Journal(self.storage_dir, index.spec.num_shards,
                              sync=wal_sync)
            if latest_epoch(self.storage_dir) is not None \
                    or journal.last_seqno > 0:
                raise ValueError(
                    f"storage_dir {self.storage_dir} already holds a "
                    f"snapshot or journal — use QueryEngine.recover() to "
                    f"adopt existing durable state")
            self.journal = journal
            if self.writer.journal is None:
                self.writer.journal = journal
            # initial durable base: recovery needs a committed snapshot to
            # replay the journal against, even before the first drain
            self.save()
            self._start_persister()

    # -- admission (mirrors BatchServer.admit) -------------------------------

    def submit(self, pred: Predicate) -> QueryTicket:
        """Enqueue a predicate; returns its ticket (filled in by run_batch).

        The queue is a deque and admission pops from its head while slot ids
        come off a free list, so a deep backlog admits in O(1) per query —
        a 100k-query burst no longer pays the O(n^2) of ``list.pop(0)``."""
        t = QueryTicket(qid=self._next_qid, pred=pred)
        self._next_qid += 1
        self.stats.submitted += 1
        self.queue.append(t)
        return t

    def _admit(self) -> None:
        if not self.queue:
            return
        # the free-slot list is rebuilt from the slots each round (O(batch),
        # paid once per batch, and immune to external slot resets — the
        # documented way to discard admitted work); each admission is then
        # one O(1) popleft, so a deep backlog admits in O(1) per query
        for i in (i for i, t in enumerate(self.slots) if t is None):
            if not self.queue:
                break
            self.slots[i] = self.queue.popleft()

    # -- writes (async maintenance surface) ----------------------------------

    def write(self, value: float) -> None:
        """Insert one tuple. Sync policy runs Algorithm 3 immediately; async
        policies stage the row into its shard's queue (a host list append)
        and let the interleave policy drain it off the query path. Counts
        include the staged row either way."""
        self.stats.writes += 1
        if self.writer is None:
            self.index.insert(float(value))
            return
        self.writer.write(float(value))
        self._maybe_schedule_resummarize()
        if (self.drain_policy == "on_depth"
                and self._maintenance_backlog() >= self.drain_depth):
            self._drain(None)
        self._sync_writer_stats()

    def delete(self, lo: float, hi: float) -> int:
        """Delete tuples with key in [lo, hi]. The validity-mask update is
        immediate on every policy (queries stay exact, §5.2 lazy deletes);
        sync policy then vacuums on the spot, async policies queue the dirty
        shards for drained ``vacuum_shard`` calls. Returns tuples deleted."""
        if self.writer is None:
            n = self.index.table.delete_where(lo, hi)
            if n:   # a no-op delete dirtied nothing: skip the vacuum dispatch
                self.index.vacuum()
            self.stats.deletes += n
            return n
        n = self.writer.delete(lo, hi)
        self.stats.deletes += n
        # deletes add vacuum work, not queue depth — the on_depth trigger
        # must fire here too or a delete-heavy stream never drains
        if (self.drain_policy == "on_depth"
                and self._maintenance_backlog() >= self.drain_depth):
            self._drain(None)
        self._sync_writer_stats()
        return n

    def flush(self) -> int:
        """Drain every pending resummarize, shard queue, and vacuum now
        (explicit policy). Returns staged rows applied to the index."""
        if self.writer is None:
            return 0
        rows = self._drain(None)
        return rows

    def resummarize(self, bounds=None) -> int:
        """Schedule a re-summarization of every shard (bounds rebuilt from
        the drift reservoir unless given) and drain it now, along with any
        other pending maintenance. Returns remap units applied."""
        if self.writer is None:
            raise RuntimeError(
                "resummarize needs a writer-backed engine (an async "
                "drain_policy on a ShardedHippoIndex)")
        before = self.writer.stats.resummarizes
        # may refuse (no sample): then stats stay intact
        self.writer.schedule_resummarize(bounds, policy=self.summary)
        self._mark_resummarize_window()
        self._drain(None)
        return self.writer.stats.resummarizes - before

    def _maintenance_backlog(self) -> int:
        """What the ``on_depth`` trigger measures: staged tuples plus table
        pages dirtied by deletes and still awaiting their vacuum. Both terms
        are O(1) reads (``PagedTable.num_dirty`` is kept incrementally) —
        this runs on every write under the on_depth policy."""
        return self.writer.queue_depth + self.index.table.num_dirty

    def _maybe_schedule_resummarize(self) -> None:
        """Auto drift trigger: schedule a remap of every shard once enough
        inserts have been observed and their edge-bucket overflow ratio
        crosses the threshold. Scheduling is idempotent while a remap is
        pending; the drain policy applies the units off the query path."""
        w = self.writer
        if (not self.auto_resummarize or self.drift_threshold is None
                or w is None or w.pending_resummarize_shards()):
            return
        d = w.drift
        if (d.observed >= self.drift_min_observed
                and d.edge_overflow_ratio >= self.drift_threshold):
            # observed > 0: the reservoir holds at least one value
            w.schedule_resummarize(policy=self.summary)
            self._mark_resummarize_window()

    def _mark_resummarize_window(self) -> None:
        """Close the pruning-quality window: the ratio accumulated so far
        becomes the "before" figure, and the window restarts to measure the
        batches served after the re-summarization."""
        st = self.stats
        st.pruning_before_resummarize = st.pruning_after_resummarize
        st.window_selected_pages = 0
        st.window_table_pages = 0

    def _drain(self, max_units: int | None) -> int:
        before = self.writer.stats.drains
        try:
            rows = self.writer.drain(max_units)
        finally:
            # even a refused drain applied some units: propagate the partial
            # progress instead of letting EngineStats claim nothing happened
            self._sync_writer_stats()
        self._auto_drain_suspended = False      # a successful drain re-arms
        if (self.storage_dir is not None and self.snapshot_on_drain
                and self.writer.stats.drains > before):
            # drain-swap commit point: persist what the drain changed (the
            # watermark is recorded before the commit and the journal only
            # truncated through it after, so a crash anywhere between
            # replays nothing twice and loses nothing acknowledged)
            self._commit_snapshot()
            self._sync_writer_stats()
        return rows

    # -- durable commits (incremental deltas, background persistence) --------

    def _commit_snapshot(self) -> None:
        """The per-drain durable commit: a delta of the shards this drain
        round changed, or a full snapshot when one is due — first commit,
        ``snapshot_mode='full'``, or the compaction policy firing (K deltas
        accumulated, or the chain outweighing ``compact_ratio`` of the
        base). Runs synchronously unless ``background_save`` handed commits
        to the persister thread."""
        wm = self.journal.last_seqno
        dirty = self.writer.dirty_checkpoint_shards()
        full_due = (self.snapshot_mode == "full"
                    or self._base_epoch is None
                    or self._delta_seq >= self.compact_every
                    or (self._full_bytes > 0 and self._delta_bytes
                        >= self.compact_ratio * self._full_bytes))
        if self._persister is not None:
            self._submit_background(full_due, dirty, wm)
            return
        if full_due:
            self.save()
            return
        path = self.index.save_delta(
            self.storage_dir, shards=dirty, wal_seqno=wm,
            base_epoch=self._base_epoch, delta_seq=self._delta_seq + 1)
        self._note_delta(path, self._delta_seq + 1)
        self.writer.clear_checkpoint_dirty()
        self._truncate_journal(wm)
        self.stats.persists += 1

    def _submit_background(self, full: bool, dirty, wm: int) -> None:
        """Collect sections foreground (the index is mutable again the
        moment this returns), hand the file I/O to the persister. The
        epoch/sequence is reserved here so jobs commit in submission order
        with no allocation race; the dirty set clears at submit — safe
        because a later job failure poisons the persister, and the only
        way out of poison is a synchronous full save that captures
        everything regardless."""
        from repro.checkpointing.snapshot import (collect_delta_sections,
                                                  collect_full_sections)
        from repro.runtime.persister import PersisterPoisoned
        try:
            if full:
                epoch = (self._base_epoch or 0) + 1
                sections = collect_full_sections(self.index, wm)
                self._persister.submit(
                    {"kind": "full", "sections": sections, "epoch": epoch,
                     "compact": self._delta_seq > 0, "watermark": wm})
                self._base_epoch = epoch
                self._delta_seq = 0
                self._full_bytes = sum(a.nbytes for a in sections.values())
                self._delta_bytes = 0
            else:
                seq = self._delta_seq + 1
                sections = collect_delta_sections(self.index, wm, dirty,
                                                  self._base_epoch, seq)
                self._persister.submit(
                    {"kind": "delta", "sections": sections,
                     "base_epoch": self._base_epoch, "seq": seq,
                     "watermark": wm})
                self._delta_seq = seq
                self._delta_bytes += sum(a.nbytes
                                         for a in sections.values())
            self.writer.clear_checkpoint_dirty()
            self.stats.persists += 1
        except PersisterPoisoned:
            # a background commit failed: supersede the broken chain with
            # a synchronous full snapshot (clears the poison) rather than
            # let acknowledged state ride on the WAL alone indefinitely
            self.save()

    def _commit_job(self, job: dict) -> None:  # thread: worker
        """The persister worker's half: durable file I/O, then — and only
        then — WAL truncation through the job's watermark. Truncating here
        (the commit callback) rather than at submit is what keeps a slow
        background save from widening the crash window: records appended
        while the job was in flight survive to the next commit.

        Runs on the ``BackgroundPersister`` thread. It reads only
        attributes fixed before ``_start_persister()`` spawned the worker
        (``storage_dir``/``journal``/``snapshot_keep``) plus the job dict,
        and publishes exactly one thing back: the durable watermark, under
        ``_durable_lock``."""
        from repro.checkpointing.snapshot import (write_delta_snapshot,
                                                  write_full_snapshot)
        if job["kind"] == "full":
            # hippolint: disable=locks -- storage_dir is rebound only by
            # _adopt_storage, which runs before _start_persister spawns
            # this worker; it is immutable for the persister's lifetime
            write_full_snapshot(self.storage_dir, job["sections"],
                                keep=self.snapshot_keep,
                                epoch=job["epoch"], compact=job["compact"])
        else:
            write_delta_snapshot(self.storage_dir, job["sections"],
                                 job["base_epoch"], job["seq"])
        from repro.runtime.faultinject import crashpoint
        crashpoint("truncate.pre")
        # hippolint: disable=locks -- journal is rebound only by
        # _adopt_storage before _start_persister spawns this worker; the
        # Journal object itself is internally locked (wal.py)
        self.journal.truncate_through(job["watermark"])
        with self._durable_lock:
            self._durable_watermark = job["watermark"]

    def _truncate_journal(self, wm: int) -> None:
        """Post-commit journal GC: a quiet journal (nothing appended past
        the watermark) resets outright; otherwise only records at or below
        the watermark are dropped."""
        from repro.runtime.faultinject import crashpoint
        crashpoint("truncate.pre")
        if self.journal.last_seqno == wm:
            self.journal.reset()
        else:
            self.journal.truncate_through(wm)
        with self._durable_lock:
            self._durable_watermark = wm

    def _note_full(self, path, epoch: int) -> None:
        self._base_epoch = epoch
        self._delta_seq = 0
        self._full_bytes = (path / "index.bin").stat().st_size
        self._delta_bytes = 0

    def _note_delta(self, path, seq: int) -> None:
        self._delta_seq = seq
        self._delta_bytes += (path / "index.bin").stat().st_size

    def _start_persister(self) -> None:
        if self.background_save and self.storage_dir is not None \
                and self._persister is None:
            from repro.runtime.persister import BackgroundPersister
            self._persister = BackgroundPersister(
                self._commit_job, max_queue=self.persist_queue)

    def save(self):
        """Synchronous *full* durable commit: snapshot the whole index
        (staged queues included), fold any delta chain into the new base,
        truncate the journal. Returns the committed snapshot directory.
        Requires ``storage_dir``. This is also the poison-recovery escape:
        after a failed background commit it supersedes the broken chain and
        re-enables background persistence."""
        if self.storage_dir is None:
            raise RuntimeError("save() needs storage_dir (durable mode); "
                               "writer-less indexes persist via index.save()")
        if self._persister is not None:
            # settle in-flight commits first; if one failed, this full
            # snapshot is about to supersede the whole chain anyway
            self._persister.flush(raise_on_poison=False)
        wm = self.journal.last_seqno
        epoch = (self._base_epoch or 0) + 1
        path = self.index.save(self.storage_dir, wal_seqno=wm,
                               keep=self.snapshot_keep, epoch=epoch,
                               compact=self._delta_seq > 0)
        self._note_full(path, epoch)
        self.writer.clear_checkpoint_dirty()
        if self._persister is not None:
            self._persister.clear_poison()
        self._truncate_journal(wm)
        self.stats.persists += 1
        return path

    def flush_durable(self) -> None:
        """Barrier: return once every submitted background commit is
        durably on disk (no-op without ``background_save``). Raises
        ``PersisterPoisoned`` if a background commit failed — call
        ``save()`` to supersede the broken chain."""
        if self._persister is not None:
            self._persister.flush()

    def close(self) -> None:
        """Stop the background persister (flush + join) and close the
        journal's file handles. Safe to call more than once; the engine
        remains queryable, but durable commits stop."""
        if self._persister is not None:
            try:
                self._persister.flush(raise_on_poison=False)
            finally:
                self._persister.close()
            self._persister = None
        if self.journal is not None:
            self.journal.close()

    @classmethod
    def recover(cls, storage_dir, *, wal_sync: bool = True,
                snapshot_on_recover: bool = True, **kwargs) -> "QueryEngine":
        """Rebuild an engine from a durable directory after a crash: load
        the latest committed snapshot plus its delta chain (uncommitted
        partials are ignored, a gapped chain is refused), replay the
        journal suffix through a fresh writer, and re-attach the journal so
        subsequent writes stay durable. ``snapshot_on_recover`` immediately
        collapses base + deltas + replayed journal into a fresh committed
        full base. Extra ``kwargs`` configure the engine as usual
        (``storage_dir`` comes from the first argument; ``background_save``
        et al. apply to the recovered engine too)."""
        if "storage_dir" in kwargs or "writer" in kwargs:
            raise ValueError("recover() derives storage_dir and writer from "
                             "the durable directory itself")
        from pathlib import Path as _Path
        from repro.checkpointing.snapshot import recover_index
        idx, writer, journal = recover_index(storage_dir, wal_sync=wal_sync)
        if writer is None:
            writer = MaintenanceWriter(idx)
            writer.journal = journal
        eng = cls(idx, writer=writer, **kwargs)
        eng._adopt_storage(_Path(storage_dir), journal)
        eng._sync_writer_stats()
        if snapshot_on_recover:
            eng.save()
        return eng

    def _adopt_storage(self, root, journal) -> None:
        """Attach existing durable state (the recover() path): pick up the
        on-disk base epoch, delta chain position, and byte counters so the
        compaction policy resumes where the crashed process left off."""
        from repro.checkpointing.snapshot import latest_delta_seq, latest_epoch
        self.storage_dir = root
        self.journal = journal
        if self.writer.journal is None:
            self.writer.journal = journal
        self._base_epoch = latest_epoch(root)
        self._delta_seq = (latest_delta_seq(root, self._base_epoch)
                           if self._base_epoch is not None else 0)
        if self._base_epoch is not None:
            self._full_bytes = (root / f"snap_{self._base_epoch}"
                                / "index.bin").stat().st_size
            self._delta_bytes = sum(
                (root / f"delta_{self._base_epoch}_{k}"
                 / "index.bin").stat().st_size
                for k in range(1, self._delta_seq + 1))
        # until the next commit records a watermark, persist_lag honestly
        # reports the whole surviving journal as not-yet-snapshotted
        with self._durable_lock:
            self._durable_watermark = 0
        self._start_persister()

    def _sync_writer_stats(self) -> None:
        w = self.writer
        st = self.stats
        if self.journal is not None:
            with self._durable_lock:
                wm = self._durable_watermark
            st.persist_lag = max(0, self.journal.last_seqno - wm)
        if self._persister is not None:
            st.persist_pending = self._persister.pending
        st.drains = w.stats.drains
        st.drained_rows = w.stats.drained_rows
        st.drain_us = w.stats.total_drain_us
        st.queue_depth = w.queue_depth
        st.staged_rows = w.staged_rows
        st.peak_queue_depth = max(st.peak_queue_depth, w.queue_depth)
        st.resummarizes = w.stats.resummarizes
        st.edge_overflow_ratio = w.drift.edge_overflow_ratio
        st.learned_refits = w.stats.learned_refits
        st.learned_fallbacks = w.stats.learned_fallbacks

    # -- execution ------------------------------------------------------------

    def run_batch(self) -> list[QueryTicket]:
        """Admit queued queries into free slots and execute one device program
        (or, in sharded mode, one summary-routed dispatch per matched shard).

        Returns the tickets retired by this batch (empty if nothing pending).
        """
        # Drain *before* executing: the drain sits between the previous
        # batch and this one either way, and a drain refusal (slot capacity)
        # then raises before any query work instead of discarding a fully
        # computed batch on the way out.
        self._maybe_drain_between_batches()
        self._admit()
        active = [i for i, t in enumerate(self.slots) if t is not None]
        if not active:
            return []
        row_ids = None
        if self.mode == "compact":
            counts, inspected, matched, row_ids = self._execute_compact(active)
        elif self.sharded:
            counts, inspected, matched = self._execute_sharded(active)
        else:
            counts, inspected, matched = self._execute_dense(active)
        finished = []
        for k, i in enumerate(active):
            t = self.slots[i]
            t.count = int(counts[k])
            t.pages_inspected = int(inspected[k])
            t.entries_matched = int(matched[k])
            if row_ids is not None:
                ids = row_ids[k]
                t.row_ids = ids[ids >= 0].copy()   # strip the -1 pads
            t.done = True
            finished.append(t)
            self.slots[i] = None          # recycle the slot
        self.stats.batches += 1
        if not self.sharded:
            # compact and dense modes dispatch the full batch width; routed
            # dispatch accounting happens per shard inside _execute_sharded
            self.stats.slots_filled += len(active)
            self.stats.pad_slots += self.batch - len(active)
        self.stats.served += len(finished)
        return finished

    def _maybe_drain_between_batches(self) -> None:
        """Between-batches drain. A drain refusal (e.g. shard slot capacity)
        raises once, loudly, then suspends auto-draining so queries keep
        serving exactly through the staging overlay; an explicit ``flush()``
        (after fixing capacity) or ``writer.discard()`` re-arms it."""
        if (self.writer is None or self.drain_policy != "between_batches"
                or self._auto_drain_suspended
                or not self.writer.pending_units):
            return
        try:
            self._drain(self.drain_units)
        except RuntimeError:
            self._auto_drain_suspended = True
            raise

    def _execute_dense(self, active: list[int]) -> tuple:
        """One full-width device program; pads fill the free slots."""
        preds = [t.pred if t is not None else _EMPTY for t in self.slots]
        res = self.index.search_batch(preds)
        counts = np.asarray(res.counts)[active]
        inspected = np.asarray(res.pages_inspected)[active]
        matched = np.asarray(res.entries_matched)[active]
        return counts, inspected, matched

    def _execute_compact(self, active: list[int]) -> tuple:
        """The compact mode ladder: gather-path batch at the current slab
        bucket, widen the bucket for future batches when the union overflows
        it, and re-run this batch's truncated queries at the never-truncating
        cap (dense cost, still exact and row-id-capable).

        ``pages_inspected``/``entries_matched`` come from the first run even
        for truncated rows (they are computed before the gather and exact
        regardless); only counts and row ids are patched from the fallback.
        """
        preds = [t.pred if t is not None else _EMPTY for t in self.slots]
        cap = self.index.gather_cap
        bucket = min(self._compact_bucket, cap)   # never gather past the slab
        res = self.index.search_compact_batch(preds, max_selected=bucket,
                                              top_k=self.top_k)
        counts = np.asarray(res.counts).copy()
        inspected = np.asarray(res.pages_inspected)
        matched = np.asarray(res.entries_matched)
        trunc = np.asarray(res.truncated)
        row_ids = np.asarray(res.row_ids).copy() if self.top_k else None
        st = self.stats
        st.compact_batches += 1
        shards = getattr(self.index, "num_shards", 1)
        self._account_compact_dispatch(res, bucket * shards)
        needed = int(res.bucket_needed)
        if needed > bucket:
            # adapt: the next batch starts at a slab the last union fits
            self._compact_bucket = min(_pow2_at_least(needed), cap)
        bad = [i for i in active if trunc[i]]
        if bad:
            st.compact_fallbacks += len(bad)
            width = _pow2_at_least(max(len(bad), _FALLBACK_Q_MIN))
            fb_preds = [self.slots[i].pred for i in bad]
            fb_preds += [_EMPTY] * (width - len(bad))
            fb = self.index.search_compact_batch(fb_preds, max_selected=cap,
                                                 top_k=self.top_k)
            # the fallback is a real extra dispatch: its slot width and its
            # slab capacity must land in occupancy/gather accounting, or the
            # stats overreport exactly when the engine is doing extra work
            st.slots_filled += len(bad)
            st.pad_slots += width - len(bad)
            self._account_compact_dispatch(fb, cap * shards)
            if bool(np.asarray(fb.truncated)[: len(bad)].any()):
                raise RuntimeError(
                    "compact fallback truncated at the full gather cap — "
                    "the slab no longer covers the table (was the index "
                    "mutated mid-batch?)")
            fb_counts = np.asarray(fb.counts)
            fb_ids = np.asarray(fb.row_ids) if row_ids is not None else None
            for k, i in enumerate(bad):
                counts[i] = fb_counts[k]
                if row_ids is not None:
                    row_ids[i] = fb_ids[k]
        st.compact_hits += len(active) - len(bad)
        return (counts[active], inspected[active], matched[active],
                row_ids[active] if row_ids is not None else None)

    def _account_compact_dispatch(self, res, slab_capacity: int) -> None:
        """Fold one gather dispatch (primary batch or truncation fallback)
        into the gather telemetry and the pruning-quality window."""
        st = self.stats
        st.gather_union_pages += int(res.pages_gathered)
        st.gather_slab_pages += slab_capacity
        st.selected_pages += int(res.pages_selected)
        st.table_pages_seen += self.index.table.num_pages
        st.window_selected_pages += int(res.pages_selected)
        st.window_table_pages += self.index.table.num_pages

    def _execute_sharded(self, active: list[int]) -> tuple:
        """Per-shard dispatch with summary pruning and count-reduce.

        Each shard runs a program over only the active queries whose bucket
        bitmaps share a joint bucket with its summary — padded up to a bucket
        width so all shards share compiled traces — and per-query results sum
        across shards (shards partition the page space, so the reduction is
        exact; a pruned (query, shard) pair is provably count-zero). The
        predicates are converted to bucket bitmaps once per shard bounds
        epoch (``plan_batch`` returns (S, Q, W)); per-shard dispatches slice
        and pad shard s's converted rows, with zero bitmaps + (lo=1, hi=0)
        intervals as the pads.
        """
        preds = [self.slots[i].pred for i in active]
        qbms, los, his, match = self.index.plan_batch(preds)
        a = len(active)
        counts = np.zeros((a,), np.int64)
        inspected = np.zeros((a,), np.int64)
        matched = np.zeros((a,), np.int64)
        for s in range(self.index.num_shards):
            hit = np.flatnonzero(match[:, s])
            if hit.size == 0:
                self.stats.shards_pruned += 1
                continue
            width = _pow2_at_least(max(int(hit.size), _SHARD_BUCKET_MIN))
            qb = np.zeros((width, qbms.shape[2]), qbms.dtype)
            qb[: hit.size] = qbms[s, hit]       # shard s's epoch conversion
            lo = np.full((width,), _EMPTY.lo, np.float32)
            hi = np.full((width,), _EMPTY.hi, np.float32)
            lo[: hit.size] = los[hit]
            hi[: hit.size] = his[hit]
            res = self.index.search_batch_shard_arrays(s, qb, lo, hi)
            counts[hit] += np.asarray(res.counts)[: hit.size]
            inspected[hit] += np.asarray(res.pages_inspected)[: hit.size]
            matched[hit] += np.asarray(res.entries_matched)[: hit.size]
            self.stats.shard_dispatches += 1
            self.stats.slots_filled += int(hit.size)
            self.stats.pad_slots += width - int(hit.size)
            self.stats.shard_queries[s] = (
                self.stats.shard_queries.get(s, 0) + int(hit.size))
            self.stats.shard_slots[s] = (
                self.stats.shard_slots.get(s, 0) + width)
        # Staging overlay: rows waiting in a writer's queues belong to no
        # index entry yet, so summary routing can't see them — their counts
        # add on top, independent of which shards were dispatched or pruned.
        # Read the overlay from the index's *attached* writer (the single
        # source of truth), not this engine's handle: a sync-policy engine,
        # or one whose writer was superseded, must still see staged rows.
        staging = getattr(self.index, "staging", None)
        if staging is not None and staging.staged_rows:
            counts += staging.staged_counts(los, his).sum(axis=1)
        return counts, inspected, matched

    def drain(self) -> list[QueryTicket]:
        """Run batches until the queue and all slots are empty."""
        finished = []
        while self.queue or any(t is not None for t in self.slots):
            finished.extend(self.run_batch())
        return finished

    def run_all(self, preds: list[Predicate]) -> np.ndarray:
        """Submit + drain convenience; counts in submission order."""
        tickets = [self.submit(p) for p in preds]
        self.drain()
        return np.asarray([t.count for t in tickets], np.int64)
