"""Async maintenance writer — insert/vacuum off the query path (§5, Alg. 3).

The paper's headline maintenance claim (up to three orders of magnitude less
insert overhead than a B+-Tree, §5/Fig. 6c) assumes maintenance does not sit
on the query path. In this repro it did: every ``insert`` ran Algorithm 3
synchronously — one jit dispatch plus a full slab-view invalidation — before
the next query batch could run. ``MaintenanceWriter`` moves that work between
engine batches, exploiting the partition layer's locality (PR 2): a write
touches exactly one shard's arrays, so shard s can be rebuilt while every
other shard keeps serving.

Lifecycle (per shard):

  stage    ``write(v)`` routes v by ``ShardSpec`` page arithmetic into the
           owning shard's pending queue — a small staging buffer kept in
           table-append order, with a sorted view for overlay counting.
           Nothing touches the device index; staging is a host list append.
  overlay  queries stay exact while rows wait: ``search_batch``, the compact
           gather path (``search_compact_batch``), and the engine's routed
           dispatch all add the staged rows matching each predicate on top
           of the index counts — the never-stale contract. Staged rows
           occupy no page until their drain, so they appear in counts only,
           never in the compact path's row ids (nor in ``page_mask``).
           ``delete(lo, hi)`` marks table tuples invalid immediately (queries
           read the validity mask, §5.2 lazy deletes) and kills staged rows
           in range before they ever reach the table.
  drain    between engine batches the writer takes one shard's whole queue,
           appends its tuples to the table, and applies Algorithm 3 as a
           single fused ``insert_batch`` against a *copy* of that shard's
           slice of ``ShardedHippoState``; dirty shards get their §5.2
           ``vacuum_shard`` the same way. Queues drain in ascending shard
           order so staged page ids land exactly where stage-time routing
           predicted.
  swap     one assignment publishes the rebuilt slice (``set_shard`` + a
           refreshed summary bitmap) and the table patches just that shard's
           slab into the cached device view (``refresh_shard_slabs``) — no
           full (S, PPS, C) re-upload. While the swap is in flight the index
           refuses queries and maintenance (``swap_in_flight``) instead of
           serving a shard whose state and table disagree.

Failure atomicity: a drain that refuses (slot capacity) rolls the table back
to its pre-drain snapshot and requeues the shard's staged rows — the overlay
keeps counts exact, and the error surfaces from ``drain``/``flush``, not from
a query.

Drift re-summarization (beyond paper): every staged insert feeds a
``histogram.DriftTracker`` (per-bucket hit counters + reservoir sample), so
the writer knows when the complete histogram's bucket space has drifted out
from under the workload — the paper never rebuilds it on local updates
(§4.1), which under sustained drift clamps every new tuple into an edge
bucket and erodes pruning. ``schedule_resummarize`` queues a third drain-unit
kind: one per shard, each remapping that shard's bitmaps onto a fresh
boundary set (``histogram.rebuild`` from the reservoir;
``core.index.resummarize_shard``) under the same swap discipline as insert
drains. Resummarize units drain *before* insert queues so rows staged under
the drifted bounds land under the new ones and group well from their first
page; each remapped shard bumps its ``bounds_epochs`` entry, and queries stay
exact throughout because predicate conversion is per shard epoch
(``core.partition``).

``runtime.engine.QueryEngine`` owns the interleave policy (drain-between-
batches, drain-on-queue-depth, explicit ``flush``) and the drift policy knobs
(``drift_threshold``, auto vs. manual resummarize); the writer itself is
policy-free mechanism.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.core import histogram as hg
from repro.core import index as hix
from repro.core import learned as ln
from repro.core.partition import (SUMMARY_POLICIES, ShardedHippoState,
                                  set_shard, shard_state, summary_of)
from repro.runtime.faultinject import crashpoint

_STAGE_BUCKET_MIN = 8   # smallest device overlay width (trace bucketing)


class _ShardQueue:
    """Pending inserts for one shard, kept in table-append order.

    ``live`` marks rows not yet killed by a staged delete; the sorted view of
    live values backs the overlay's interval counting (two binary searches
    per query per shard).
    """
    __slots__ = ("values", "live", "n_live", "_sorted")

    def __init__(self):
        self.values: list[float] = []
        self.live: list[bool] = []
        self.n_live = 0
        self._sorted: np.ndarray | None = None

    def append(self, v: float) -> None:
        self.values.append(v)
        self.live.append(True)
        self.n_live += 1
        self._sorted = None

    def kill_range(self, lo: float, hi: float) -> int:
        """Mark live staged values in [lo, hi] dead (a delete overtaking a
        staged insert); they never reach the index's bitmaps."""
        n = 0
        for i, (v, alive) in enumerate(zip(self.values, self.live)):
            if alive and lo <= v <= hi:
                self.live[i] = False
                n += 1
        if n:
            self.n_live -= n
            self._sorted = None
        return n

    @property
    def sorted_live(self) -> np.ndarray:
        if self._sorted is None:
            self._sorted = np.sort(np.asarray(
                [v for v, alive in zip(self.values, self.live) if alive],
                np.float32))
        return self._sorted


@dataclass
class WriterStats:
    staged: int = 0           # tuples ever staged
    killed: int = 0           # staged tuples overtaken by a delete
    drains: int = 0           # drain units applied (inserts + vacuums + resummarizes)
    drained_rows: int = 0     # live tuples applied to the index by drains
    vacuums: int = 0          # shard vacuums drained
    resummarizes: int = 0     # shard remaps drained (drift re-summarization)
    learned_refits: int = 0   # resummarize schedules served by a learned fit
    learned_fallbacks: int = 0  # learned schedules that fell back to equal-mass
    last_drain_us: float = 0.0
    total_drain_us: float = 0.0


class MaintenanceWriter:
    """Per-shard staged maintenance over a ``ShardedHippoIndex``.

    Constructing the writer attaches it to the index (``index.staging``), so
    every search path folds the staging overlay into counts from then on.
    """

    def __init__(self, index, journal=None):
        for attr in ("spec", "state", "plan_batch"):
            if not hasattr(index, attr):
                raise ValueError(
                    "MaintenanceWriter needs a ShardedHippoIndex-style index "
                    "(ShardSpec routing + stacked per-shard state); got "
                    f"{type(index).__name__}")
        prior = getattr(index, "staging", None)
        if prior is not None and prior.queue_depth:
            # Replacing the attached writer would detach its overlay and
            # silently drop its staged rows from every count.
            raise RuntimeError(
                f"index already has a writer with {prior.queue_depth} staged "
                f"rows pending: flush() it before attaching a new one")
        self.index = index
        index.staging = self
        # Write-ahead journal (checkpointing.wal.Journal, or None): when
        # attached, every acknowledged operation appends one fsynced record
        # *before* any in-memory state changes — append before admission —
        # so crash recovery (checkpointing.snapshot.recover_index) can
        # replay exactly the acknowledged stream past the last snapshot.
        self.journal = journal
        self._queues: dict[int, _ShardQueue] = {}
        self._staged_total = 0       # pending tuples, dead rows included
        self._version = 0            # bumps on any staging change
        self._dev_cache: tuple | None = None
        self.stats = WriterStats()
        # Drift telemetry: armed with the bounds serving the table tail
        # (where appends route); rearmed when a re-summarization completes.
        s_tail = min(index.spec.owner(max(index.table.num_pages - 1, 0)),
                     index.spec.num_shards - 1)
        self.drift = hg.DriftTracker(index.shard_histogram(s_tail))
        self._pending_resummarize: list[int] = []
        self._pending_bounds: np.ndarray | None = None
        self._pending_model = None   # learned model behind the pending bounds
        self._resum_epoch = 0
        # Shards whose published state/table slab changed since the last
        # durable commit — exactly what an incremental delta must capture
        # (checkpointing.snapshot.save_delta). Fed by every mutation that
        # survives: drain swaps, vacuums, resummarize remaps, and deletes
        # (which flip validity bits across arbitrary shards' slabs).
        self._dirty_since_checkpoint: set[int] = set()

    # -- staging (the off-query-path write surface) --------------------------

    def _check_attached(self) -> None:
        """Refuse staging through a writer the index no longer consults —
        its rows would never be overlaid into counts."""
        if self.index.staging is not self:
            raise RuntimeError(
                "writer is detached: the index has a different (newer) "
                "staging writer attached; stage through that one")

    def _tail_pos(self) -> int:
        """Absolute tuple position of the table's append tail."""
        t = self.index.table
        if t.num_pages == 0:
            return 0
        return t.num_pages * t.page_card - (t.page_card - t.fill)

    def write(self, value: float) -> int:
        """Stage one insert; returns the owning shard.

        Routing is pure ``ShardSpec`` arithmetic on the page the tuple *will*
        occupy once every earlier staged row has drained — appends are
        sequential, so the k-th staged tuple's page is fully determined by
        the table tail. Refuses (before staging) writes the shard layout
        cannot ever hold, mirroring the synchronous path's refusal.
        """
        self.index._check_swap_guard()
        self._check_attached()
        spec = self.index.spec
        pos = self._tail_pos() + self._staged_total
        page = pos // self.index.table.page_card
        s = spec.owner(page)
        if s >= spec.num_shards:
            raise RuntimeError(
                f"shard layout full: staged tuple would land on page {page}, "
                f"past shard {spec.num_shards - 1}'s slab "
                f"(pages_per_shard={spec.pages_per_shard}); rebuild with more "
                f"shards or larger slabs")
        if self.journal is not None:
            # durable before acknowledged: if this append fails, the write
            # raises with nothing staged and nothing to lose
            self.journal.append_insert(s, float(value))
        self._queues.setdefault(s, _ShardQueue()).append(float(value))
        self._staged_total += 1
        self._version += 1
        self._dev_cache = None
        self.stats.staged += 1
        self.drift.observe(value)
        return s

    def delete(self, lo: float, hi: float) -> int:
        """Apply a delete: table tuples in range go invalid now (queries read
        the validity mask, so results stay exact with zero index work), staged
        rows in range die before ever reaching the table, and the dirtied
        shards queue for an async ``vacuum_shard`` drain. Returns tuples
        deleted (table + staged)."""
        self.index._check_swap_guard()
        self._check_attached()
        if self.journal is not None:
            self.journal.append_delete(float(lo), float(hi))
        table = self.index.table
        spec = self.index.spec
        was_fresh = table._dev_shard is not None and not table._dev_shard_stale
        n = table.delete_where(lo, hi)
        if n:
            self._dirty_since_checkpoint.update(
                int(s) for s in self.index.dirty_shards())
        if n and was_fresh:
            # every mutated page carries a dirty note until its vacuum, so
            # the dirty owners are exactly the slabs to patch
            table.refresh_shard_slabs(self.index.dirty_shards(),
                                      spec.num_shards, spec.pages_per_shard)
        killed = 0
        for q in self._queues.values():
            killed += q.kill_range(lo, hi)
        if killed:
            self._version += 1
            self._dev_cache = None
            self.stats.killed += killed
        return n + killed

    # -- introspection -------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        """Staged tuples pending a drain (dead rows included: they still
        occupy a staged table position)."""
        return self._staged_total

    @property
    def staged_rows(self) -> int:
        """Live staged rows currently overlaid into query counts."""
        return sum(q.n_live for q in self._queues.values())

    def pending_shards(self) -> list[int]:
        """Shards with queued inserts, in the mandatory drain order."""
        return sorted(s for s, q in self._queues.items() if q.values)

    def pending_vacuum_shards(self) -> list[int]:
        return [int(s) for s in self.index.dirty_shards()]

    def pending_resummarize_shards(self) -> list[int]:
        """Shards still awaiting their remap onto the pending bounds."""
        return list(self._pending_resummarize)

    @property
    def pending_units(self) -> int:
        """Drain units outstanding (resummarizes + insert queues + vacuums)."""
        return (len(self._pending_resummarize) + len(self.pending_shards())
                + len(self.pending_vacuum_shards()))

    def dirty_checkpoint_shards(self) -> list[int]:
        """Shards changed since the last durable commit (delta capture set)."""
        return sorted(self._dirty_since_checkpoint)

    def clear_checkpoint_dirty(self) -> None:
        """Mark the current state durably captured (commit just happened)."""
        self._dirty_since_checkpoint.clear()

    # -- drift re-summarization (the third drain-unit kind) ------------------

    def schedule_resummarize(self, bounds=None, policy=None) -> hg.Histogram:
        """Queue a remap of every shard onto new histogram bounds.

        With ``bounds=None`` the new boundary set comes from the summary
        policy — ``policy`` if given, else the index's ``summary`` attribute
        (``core.partition.SUMMARY_POLICIES``):

        - ``"equal_mass"``: ``histogram.rebuild`` — the armed bounds' own
          boundary summary blended *equal-mass* with the drift reservoir.
          Equal mass (rather than weighting by tuple counts) is a deliberate
          policy: the reservoir region is where the workload is writing —
          and, under drift, where it is querying — so it gets half the
          boundary budget however few rows it holds yet, while the old
          data's resolution loss is bounded at 2x.
        - ``"learned"``: ``learned.learned_rebuild`` — an error-bounded
          piecewise-linear fit of the same {old summary, reservoir} blend,
          with the reservoir carrying the dominant mass share and per-key
          mass clamped at one bucket's worth. A degenerate sample falls back
          to the equal-mass path (``stats.learned_fallbacks``); the fitted
          model is recorded per shard (``index.summary_models``) as each
          shard's remap drains.

        An explicit ``bounds`` array schedules a manual remap (callers
        wanting count-weighted blending can call ``histogram.rebuild`` with
        ``old_count``/``new_count`` themselves). Rescheduling before the
        previous remap finished replaces the pending bounds and re-queues
        every shard. The bounds are validated at *drain* time — the
        refusal-and-rollback point of every drain-unit kind — not here.

        Returns the histogram the shards will serve once all units drain.
        """
        self.index._check_swap_guard()
        self._check_attached()
        if policy is None:
            policy = getattr(self.index, "summary", "equal_mass")
        if policy not in SUMMARY_POLICIES:
            raise ValueError(f"policy must be one of {SUMMARY_POLICIES}, "
                             f"got {policy!r}")
        pending_model = None
        refit = fallback = False
        if bounds is None:
            sample = self.drift.sample()
            if sample.size == 0:
                raise RuntimeError(
                    "no drift sample: stage inserts through write() before "
                    "scheduling a reservoir-based resummarize, or pass "
                    "explicit bounds")
            if policy == "learned":
                hist, model = ln.learned_rebuild(self.drift.armed_histogram,
                                                 sample)
                pending_model = model
                fallback = model is None
                refit = not fallback
            else:
                hist = hg.rebuild(self.drift.armed_histogram, sample)
            bounds = hg.host_bounds(hist)
        bounds = np.asarray(bounds, np.float32)
        if self.journal is not None:
            # the *materialized* bounds are journaled (not the reservoir
            # they came from), so replay schedules the identical remap.
            # Everything above computed into locals only: append-before-
            # admission means no writer state may change until this record
            # is durable — a crash before here loses an operation that was
            # never acknowledged, a crash after replays it exactly.
            self.journal.append_resummarize(bounds, policy)
        self._pending_model = pending_model
        if fallback:
            self.stats.learned_fallbacks += 1
        elif refit:
            self.stats.learned_refits += 1
        self._pending_bounds = bounds
        self._pending_resummarize = list(range(self.index.spec.num_shards))
        self._resum_epoch = int(self.index.bounds_epochs.max()) + 1
        return hg.Histogram(jnp.asarray(bounds))

    def queue_depths(self) -> dict[int, int]:
        """Per-shard staged tuple counts (engine stats surface)."""
        return {s: len(q.values) for s, q in self._queues.items() if q.values}

    # -- overlay (queries never go stale) ------------------------------------

    def staged_counts(self, los, his) -> np.ndarray:
        """(Q, S) exact counts of live staged rows per (query, shard).

        Two binary searches per (query, shard) on the per-shard sorted
        staging buffers; empty predicates (lo > hi) count zero. Host-side
        twin of ``core.index.staged_overlay_counts``.
        """
        los = np.asarray(los, np.float32)
        his = np.asarray(his, np.float32)
        out = np.zeros((los.shape[0], self.index.spec.num_shards), np.int64)
        for s, q in self._queues.items():
            a = q.sorted_live
            if a.size == 0:
                continue
            out[:, s] = (np.searchsorted(a, his, side="right")
                         - np.searchsorted(a, los, side="left"))
        return np.maximum(out, 0)

    def device_buffers(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(vals (S, B) f32, live (S, B) bool) staged rows for the fused
        device overlay (``core.index.search_many_sharded_staged``). B is the
        max per-shard live depth rounded to a power of two (min 8) so the
        overlay re-traces only when the queue outgrows its bucket."""
        if self._dev_cache is not None and self._dev_cache[0] == self._version:
            return self._dev_cache[1], self._dev_cache[2]
        s_n = self.index.spec.num_shards
        depth = max((q.n_live for q in self._queues.values()), default=0)
        b = _STAGE_BUCKET_MIN
        while b < depth:
            b *= 2
        vals = np.zeros((s_n, b), np.float32)
        live = np.zeros((s_n, b), bool)
        for s, q in self._queues.items():
            a = q.sorted_live
            vals[s, : a.size] = a
            live[s, : a.size] = True
        out = (jnp.asarray(vals), jnp.asarray(live))
        self._dev_cache = (self._version, *out)
        return out

    # -- drain / swap --------------------------------------------------------

    def drain(self, max_units: int | None = None) -> int:
        """Apply up to ``max_units`` drain units (default: everything).

        A unit is one shard's resummarize remap, one shard's whole insert
        queue, or one shard's vacuum. Resummarize units go first so staged
        rows land under the new bounds (their pages group well from the
        start); then insert queues in ascending shard order — the order
        their staged page ids were predicted in — then dirty shards vacuum.
        Returns live rows applied to the index.

        Stats account per applied unit: a unit that refuses partway through
        the drain still leaves the units (and wall time) already applied in
        ``stats.drains``/``last_drain_us``/``total_drain_us`` — a 2-of-3
        drain records 2 drains, not 0.
        """
        t0 = time.perf_counter()
        units = rows = 0
        try:
            for s in self.pending_resummarize_shards():
                if max_units is not None and units >= max_units:
                    break
                self._drain_resummarize(s)
                units += 1
            for s in self.pending_shards():
                if max_units is not None and units >= max_units:
                    break
                rows += self._drain_shard(s)
                units += 1
            for s in self.pending_vacuum_shards():
                if max_units is not None and units >= max_units:
                    break
                self._drain_vacuum(s)
                units += 1
        finally:
            if units:
                us = (time.perf_counter() - t0) * 1e6
                self.stats.drains += units
                self.stats.last_drain_us = us
                self.stats.total_drain_us += us
        return rows

    def flush(self) -> int:
        """Drain every pending queue and vacuum; returns rows applied."""
        return self.drain(max_units=None)

    def discard(self) -> int:
        """Drop every staged row without applying it; returns rows dropped.

        The recovery path for a drain that keeps refusing (shard slot
        capacity): the staged rows never reach the table or the index, and
        counts simply stop including them. All-or-nothing by design — later
        queues' page routing was predicted assuming earlier queues land, so
        a single shard's queue cannot be dropped in isolation.
        """
        dropped = self._staged_total
        self._queues.clear()
        self._staged_total = 0
        self._version += 1
        self._dev_cache = None
        return dropped

    def _drain_shard(self, s: int) -> int:
        """Drain shard s's queue: append to the table, rebuild a copy of the
        shard's state slice via Algorithm 3, swap it in atomically."""
        idx = self.index
        table = idx.table
        spec = idx.spec
        q = self._queues.pop(s)
        values = np.asarray(q.values, np.float32)
        live = np.asarray(q.live, bool)
        snap_pages, snap_fill = table.num_pages, table.fill
        was_fresh = table._dev_shard is not None and not table._dev_shard_stale
        idx.swap_in_flight = s
        try:
            st = shard_state(idx.state.shards, s)   # working copy (functional)
            pages = np.empty(values.shape[0], np.int64)
            offs = np.empty(values.shape[0], np.int64)
            for i, v in enumerate(values):
                pages[i], _ = table.insert(float(v))
                offs[i] = table.fill - 1
            if pages.size and not (pages // spec.pages_per_shard == s).all():
                raise RuntimeError(
                    f"writer invariant violated: shard {s} drain appended "
                    f"pages outside its slab (was the table mutated behind "
                    f"the staged queues?)")
            # dead staged rows occupy their predicted slots but never go
            # live — they keep later queues' page routing exact
            for p, o in zip(pages[~live], offs[~live]):
                table.valid[int(p), int(o)] = False
            lp = (pages - spec.page_lo(s)).astype(np.int32)
            # Algorithm 3 against the copy: one fused scatter for tuples on
            # already-summarized pages, padded to a power-of-two width so
            # drains of different queue depths share one compiled trace ...
            old = live & (lp <= int(st.summarized_until))
            if old.any():
                n = values.shape[0]
                b = _STAGE_BUCKET_MIN
                while b < n:
                    b *= 2
                pv = np.zeros((b,), np.float32)
                pl = np.zeros((b,), np.int32)
                pm = np.zeros((b,), bool)
                pv[:n] = values
                pl[:n] = np.clip(lp, 0, spec.pages_per_shard - 1)
                pm[:n] = old
                st = hix.insert_batch_existing(idx.cfg, st, jnp.asarray(pv),
                                               jnp.asarray(pl),
                                               jnp.asarray(pm))
            # ... and the eager path for page-opening tuples (few: <= one per
            # page_card staged rows), capacity-checked against the copy
            for v, p in zip(values[live & ~old], lp[live & ~old]):
                opens = int(p) > int(st.summarized_until)
                if opens or idx.cfg.relocate_on_update:
                    if int(st.num_slots) + 1 > idx.cfg.max_slots:
                        raise RuntimeError(
                            f"shard {s} at slot capacity "
                            f"({int(st.num_slots)}/{idx.cfg.max_slots}); "
                            f"rebuild with a larger max_slots")
                st = hix.insert_tuple(idx.cfg, st, jnp.float32(v),
                                      jnp.int32(int(p)))
            # atomic swap: one assignment publishes the rebuilt slice +
            # refreshed summary; every other shard's arrays are untouched
            crashpoint("drain.pre_swap")
            idx.state = ShardedHippoState(
                shards=set_shard(idx.state.shards, s, st),
                summaries=idx.state.summaries.at[s].set(summary_of(st)))
        except Exception:
            table.truncate_to(snap_pages, snap_fill)
            self._queues[s] = q      # rows stay staged; overlay stays exact
            raise
        finally:
            idx.swap_in_flight = None
        self._staged_total -= len(q.values)
        self._version += 1
        self._dev_cache = None
        self._dirty_since_checkpoint.add(s)
        if was_fresh:
            table.refresh_shard_slabs([s], spec.num_shards,
                                      spec.pages_per_shard)
        applied = int(live.sum())
        idx.counters.inserts += applied
        self.stats.drained_rows += applied
        return applied

    def _drain_vacuum(self, s: int) -> int:
        """Drain one shard's §5.2 vacuum under the swap guard."""
        idx = self.index
        idx.swap_in_flight = s
        try:
            n = idx._vacuum_shard_locked(s)
        finally:
            idx.swap_in_flight = None
        if n:
            self._dirty_since_checkpoint.add(s)
        self.stats.vacuums += 1
        return n

    def _drain_resummarize(self, s: int) -> None:
        """Drain one shard's drift remap: rebuild its bitmaps onto the
        pending bounds against a copy of the shard's state slice, swap it in
        atomically, bump the shard's bounds epoch.

        Same discipline as an insert drain: the swap guard refuses queries
        mid-swap, and a refusal (invalid pending bounds) releases the guard
        with the old state — and the old bounds — still serving; the unit
        stays pending so a corrected ``schedule_resummarize`` can retry.
        No table mutation happens here, so there is no snapshot to restore
        and no slab to patch (the remap changes bitmaps, not pages).
        """
        idx = self.index
        b = self._pending_bounds
        idx.swap_in_flight = s
        try:
            if b is None or b.ndim != 1 or b.shape[0] != idx.cfg.resolution + 1:
                raise RuntimeError(
                    f"resummarize refused: pending bounds must be a "
                    f"({idx.cfg.resolution + 1},) boundary array, got "
                    f"{None if b is None else b.shape}")
            if not bool((np.diff(b) > 0).all()):
                raise RuntimeError(
                    "resummarize refused: pending bounds are not strictly "
                    "increasing (tied or decreasing boundaries would make "
                    "bucketize and the remap disagree)")
            keys, valid = idx._slabs()
            st = shard_state(idx.state.shards, s)   # working copy (functional)
            st = hix.resummarize_shard(idx.cfg, st, keys[s], valid[s],
                                       jnp.asarray(b))
            idx.state = ShardedHippoState(
                shards=set_shard(idx.state.shards, s, st),
                summaries=idx.state.summaries.at[s].set(summary_of(st)))
        finally:
            idx.swap_in_flight = None
        idx.bounds_epochs[s] = self._resum_epoch
        self._dirty_since_checkpoint.add(s)
        models = getattr(idx, "summary_models", None)
        if models is not None:
            # shard s now serves the pending bounds: its model (None under
            # equal-mass or a fallback) swaps in at the same moment
            models[s] = self._pending_model
        self._pending_resummarize.remove(s)
        self.stats.resummarizes += 1
        if not self._pending_resummarize:
            # every shard serves the new bounds: measure drift against them
            self.drift.rearm(hg.Histogram(jnp.asarray(b)))
            self._pending_bounds = None
            self._pending_model = None
