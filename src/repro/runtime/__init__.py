from repro.runtime.fault import StepWatchdog, resilient_loop  # noqa: F401
from repro.runtime.elastic import reshard_for_mesh  # noqa: F401
from repro.runtime.engine import EngineStats, QueryEngine, QueryTicket  # noqa: F401
from repro.runtime.writer import MaintenanceWriter, WriterStats  # noqa: F401
