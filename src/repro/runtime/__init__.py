from repro.runtime.fault import (LoopStats, ServeStats,  # noqa: F401
                                 StepWatchdog, resilient_loop,
                                 resilient_serve)
from repro.runtime.faultinject import (CrashPoints, InjectedCrash,  # noqa: F401
                                       crash_points, crashpoint)
from repro.runtime.elastic import reshard_for_mesh  # noqa: F401
from repro.runtime.engine import EngineStats, QueryEngine, QueryTicket  # noqa: F401
from repro.runtime.persister import (BackgroundPersister,  # noqa: F401
                                     PersisterPoisoned, PersistStats)
from repro.runtime.writer import MaintenanceWriter, WriterStats  # noqa: F401
