"""Elastic scaling: re-shard a host-resident state pytree onto a new mesh.

Checkpoints are stored unsharded (checkpointing/), so growing or shrinking
the cluster is: build the new mesh -> recompute PartitionSpecs (launch/
shardings.py is mesh-shape-agnostic) -> device_put every leaf. Divisibility
is validated here so a 13-way axis never silently replicates.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def validate_divisibility(shape: tuple, spec: P, mesh: Mesh) -> bool:
    for dim, axes in zip(shape, spec):
        if axes is None:
            continue
        axes = (axes,) if isinstance(axes, str) else axes
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % total:
            return False
    return True


def reshard_for_mesh(tree, specs, mesh: Mesh):
    """device_put every leaf with its spec on ``mesh``; specs is a matching
    pytree of PartitionSpec (or a single spec for all leaves)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if isinstance(specs, P):
        spec_leaves = [specs] * len(leaves)
    else:
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
    out = []
    for leaf, spec in zip(leaves, spec_leaves):
        arr = np.asarray(leaf)
        if not validate_divisibility(arr.shape, spec, mesh):
            spec = P()  # fall back to replication rather than failing restore
        out.append(jax.device_put(arr, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
