"""Crash-point injection registry — the reusable fault-injection hook.

PR 8's crash tests each monkeypatched one private method (``Journal.
append_insert``, ``writer.set_shard``, ``Journal.reset``) to simulate a
kill -9 at one instant. That worked, but every new durability mechanism
(incremental deltas, the background persister, compaction) would grow its
own ad-hoc patch target. This module turns the idea into infrastructure:
durability-bearing code declares its crash-critical instants by calling
``crashpoint("<site>")``, and tests/benches *arm* a site to make that call
raise ``InjectedCrash`` — the process-death stand-in — a bounded number of
times.

The contract mirrors the monkeypatch tests' crash-simulation note: an
injected raise models the process dying at that instant, so a correct
caller must be able to recover *from disk alone* afterwards. Arming is
thread-safe (the background persister hits sites from its worker thread),
and an unarmed ``crashpoint`` call is one dict lookup under a lock — cheap
enough to leave in production paths permanently.

Registered sites (``SITES``) — each names the instant just *before* a
durability-ordering-critical action:

  wal.pre_append        before a journal record is written (an acknowledged
                        op must never be staged without its record)
  drain.pre_swap        after a drain's table appends, before the rebuilt
                        shard state is published
  delta.pre_commit      delta snapshot payload written, COMMITTED sentinel
                        not yet renamed in
  snapshot.pre_commit   same instant for a full snapshot
  compact.pre_commit    compaction fold payload written, sentinel pending
  truncate.pre          snapshot committed, journal not yet truncated
                        (the classic double-apply window)
  persist.in_flight     a background persister job picked up, nothing
                        written yet (the queued-but-not-durable window)

``tests/test_fault_recovery.py`` kills at every one of these and asserts
recovery lands bit-identically on the acknowledged state; adding a site
here without covering it there fails that suite's completeness check.
"""
from __future__ import annotations

import threading

def _register(*sites: str) -> tuple[str, ...]:
    """Build the registry, refusing duplicates at import time: a
    copy-pasted site name would silently shadow its twin — ``arm`` would
    arm both call sites at once — blinding the fault tier and the
    hippolint bijectivity audit alike."""
    seen: set[str] = set()
    for site in sites:
        if site in seen:
            raise ValueError(f"duplicate crash site {site!r} in SITES")
        seen.add(site)
    return sites


SITES = _register(
    "wal.pre_append",
    "drain.pre_swap",
    "delta.pre_commit",
    "snapshot.pre_commit",
    "compact.pre_commit",
    "truncate.pre",
    "persist.in_flight",
)


class InjectedCrash(RuntimeError):
    """An armed crash point fired — stands in for the process dying here."""

    def __init__(self, site: str):
        super().__init__(f"injected crash at {site!r}")
        self.site = site


class CrashPoints:
    """Armable registry of crash sites.

    ``arm(site, times=n)`` makes the next ``n`` ``hit(site)`` calls raise
    ``InjectedCrash``; further hits pass through (the recovered process is
    not re-killed, so a test observes exactly the crash it asked for).
    ``fired(site)`` counts the raises actually delivered — a test can
    assert its site was really on the executed path, not silently skipped.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._armed: dict[str, int] = {}
        self._fired: dict[str, int] = {}

    @staticmethod
    def _check(site: str) -> None:
        if site not in SITES:
            raise ValueError(f"unknown crash site {site!r}; registered "
                             f"sites: {', '.join(SITES)}")

    def arm(self, site: str, times: int = 1) -> None:
        self._check(site)
        if times < 1:
            raise ValueError(f"times must be >= 1, got {times}")
        with self._lock:
            self._armed[site] = times

    def disarm(self, site: str | None = None) -> None:
        """Disarm one site (or every site), keeping the fired counts."""
        with self._lock:
            if site is None:
                self._armed.clear()
            else:
                self._check(site)
                self._armed.pop(site, None)

    def reset(self) -> None:
        """Disarm everything and zero the fired counts (test isolation)."""
        with self._lock:
            self._armed.clear()
            self._fired.clear()

    def fired(self, site: str) -> int:
        self._check(site)
        with self._lock:
            return self._fired.get(site, 0)

    def hit(self, site: str) -> None:
        """The instrumented-code side: raise if ``site`` is armed."""
        self._check(site)
        with self._lock:
            remaining = self._armed.get(site, 0)
            if remaining <= 0:
                return
            if remaining == 1:
                self._armed.pop(site)
            else:
                self._armed[site] = remaining - 1
            self._fired[site] = self._fired.get(site, 0) + 1
        raise InjectedCrash(site)


# The process-wide default registry: production code calls the module-level
# ``crashpoint``; tests arm through ``crash_points`` (or build their own
# ``CrashPoints`` and swap it in for full isolation).
crash_points = CrashPoints()


def crashpoint(site: str) -> None:
    """Declare a crash-critical instant; no-op unless a test armed it."""
    crash_points.hit(site)
