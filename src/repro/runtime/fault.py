"""Fault tolerance: step watchdog, checkpoint-restart loop, and the
self-healing engine supervisor.

Three layers, smallest to largest:

  * ``StepWatchdog`` — hang/straggler detection from host-observed step
    times: a robust (median-based) estimate over a bounded window flags
    steps exceeding ``threshold x`` the median. At pod scale a straggler
    shows up exactly as step-time inflation, and the mitigation is
    restart-from-checkpoint (JAX's multi-controller runtime cannot drop a
    single host without re-initializing the mesh); at serving scale a
    "step" is one workload slice — a batch of queries plus its drain — and
    a flagged step means the drain or a durable commit hung.
  * ``resilient_loop`` — the training-shaped wrapper: run
    ``step_fn(step, state) -> state`` with periodic ``save_fn`` and
    restore-on-exception. Determinism comes from the stateless
    step->batch mapping, so a replayed step consumes identical data.
  * ``resilient_serve`` — the serving-shaped supervisor this repo's
    durability layer actually needs: wrap a workload over a durable
    ``QueryEngine`` so that a crash (any exception — including an
    injected ``faultinject.InjectedCrash`` standing in for process
    death) or a watchdog-flagged hang tears the engine down and rebuilds
    it from disk via ``QueryEngine.recover(storage_dir)`` — snapshot +
    delta chain + WAL replay — with a retry budget and exponential
    backoff. No operator action: the loop owns the restart.

``resilient_serve``'s workload is a callable ``workload(engine) -> bool``
returning True when finished. It must be *resumption-aware*: after a
crash the engine is rebuilt from durable state, so the workload should
track its own cursor and only advance it when an operation returns
(i.e. was acknowledged) — exactly the discipline a real ingest client
replaying un-acked requests follows. ``tests/test_fault_recovery.py``
drives this against every registered crash site and asserts the
recovered counts match the acknowledged state bit-identically.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from statistics import median
from typing import Callable


@dataclass
class StepWatchdog:
    """Detects hung/straggling steps from host-observed step times."""

    threshold: float = 3.0          # x median
    window: int = 32
    min_samples: int = 5
    times: deque = field(default_factory=deque)
    flagged: list = field(default_factory=list)

    def __post_init__(self):
        # bounded window as a deque: admission is O(1), where a list's
        # pop(0) made every observation O(window) — the same admission
        # bug class PR 4 fixed in the engine's query queue
        self.times = deque(self.times, maxlen=self.window)

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if this step is a straggler."""
        self.times.append(dt)
        if len(self.times) < self.min_samples:
            return False
        med = median(self.times)
        slow = dt > self.threshold * med
        if slow:
            self.flagged.append((step, dt, med))
        return slow


@dataclass
class LoopStats:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    stragglers: int = 0


def resilient_loop(*, num_steps: int, step_fn: Callable[[int, dict], dict],
                   state: dict, save_fn: Callable[[int, dict], None],
                   restore_fn: Callable[[], tuple[int, dict]],
                   checkpoint_every: int = 10, max_failures: int = 5,
                   watchdog: StepWatchdog | None = None,
                   start_step: int = 0) -> tuple[dict, LoopStats]:
    """Run ``step_fn(step, state) -> state`` with checkpoint/restart.

    On any exception: restore the last committed checkpoint and continue from
    its step. ``step_fn`` failures inject exactly like device faults in tests.
    """
    stats = LoopStats()
    wd = watchdog or StepWatchdog()
    step = start_step
    while step < num_steps:
        try:
            t0 = time.perf_counter()
            state = step_fn(step, state)
            dt = time.perf_counter() - t0
            if wd.observe(step, dt):
                stats.stragglers += 1
            stats.steps_run += 1
            step += 1
            if step % checkpoint_every == 0:
                save_fn(step, state)
        except Exception:
            stats.failures += 1
            if stats.failures > max_failures:
                raise
            step, state = restore_fn()
            stats.restores += 1
    save_fn(step, state)
    return state, stats


# ---------------------------------------------------------------------------
# Engine supervisor
# ---------------------------------------------------------------------------

@dataclass
class ServeStats:
    steps: int = 0        # workload steps completed (crashed steps excluded)
    attempts: int = 0     # engine builds (initial + every recovery)
    crashes: int = 0      # steps torn down by an exception
    hangs: int = 0        # steps torn down by the watchdog
    restores: int = 0     # successful rebuilds from durable state
    backoff_s: float = 0.0  # total restart backoff slept


class _HungStep(RuntimeError):
    """Internal: a watchdog flag under ``hang_restart`` tears the step down
    through the same restart path a crash takes."""


def resilient_serve(storage_dir, workload: Callable, *,
                    engine=None, recover_kwargs: dict | None = None,
                    max_restarts: int = 5, backoff_base_s: float = 0.01,
                    backoff_cap_s: float = 1.0,
                    watchdog: StepWatchdog | None = None,
                    hang_restart: bool = True,
                    sleep: Callable[[float], None] = time.sleep):
    """Serve ``workload(engine) -> bool`` until it returns True, rebuilding
    the engine from ``storage_dir`` after every crash or flagged hang.

    The supervisor loop: (re)build the engine via
    ``QueryEngine.recover(storage_dir, **recover_kwargs)`` when it has
    none, run one workload step under the watchdog's timer, and on any
    exception — from the step *or* from recovery itself — tear the engine
    down, sleep an exponentially growing backoff (``backoff_base_s`` to
    ``backoff_cap_s``), and go again. ``max_restarts`` bounds total
    restarts; exhausting the budget re-raises the last failure. An
    ``engine`` may be passed in to adopt a live one for the first step
    (its ``storage_dir`` is still where recovery reads after it dies).

    Returns ``(engine, ServeStats)`` with the engine that completed the
    final step still live.
    """
    recover_kwargs = dict(recover_kwargs or {})
    wd = watchdog or StepWatchdog()
    stats = ServeStats()
    restarts = 0
    if engine is not None:
        stats.attempts += 1
    while True:
        try:
            if engine is None:
                # recovery runs inside the try: a crash *during* recovery
                # (e.g. an armed crash site on the recover path) counts
                # against the same budget instead of escaping the loop
                from repro.runtime.engine import QueryEngine
                stats.attempts += 1
                engine = QueryEngine.recover(storage_dir, **recover_kwargs)
                stats.restores += 1
            t0 = time.perf_counter()
            done = workload(engine)
            dt = time.perf_counter() - t0
            flagged = wd.observe(stats.steps, dt)
            stats.steps += 1
            if done:
                return engine, stats
            if flagged and hang_restart:
                stats.hangs += 1
                raise _HungStep(
                    f"step {stats.steps - 1} took {dt:.3f}s against a "
                    f"median-based budget — restarting from durable state")
        except Exception as e:
            if not isinstance(e, _HungStep):
                stats.crashes += 1
            restarts += 1
            if restarts > max_restarts:
                raise
            if engine is not None:
                try:
                    engine.close()
                except Exception:
                    pass     # a dying engine may fail to close cleanly
                engine = None
            delay = min(backoff_base_s * (2 ** (restarts - 1)),
                        backoff_cap_s)
            sleep(delay)
            stats.backoff_s += delay
